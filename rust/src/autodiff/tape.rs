//! Wengert-list tape: eager op evaluation, graph-mode reverse
//! differentiation, and a forward-mode JVP overlay.
//!
//! The two properties the MixFlow-MG composition rests on:
//!
//! 1. **Closure under differentiation** — [`Tape::grad`] *appends* the
//!    adjoint computation to the same tape as ordinary ops, so calling
//!    `grad` on a function of gradient nodes yields reverse-over-reverse
//!    (the naive hypergradient baseline) with no special cases.
//! 2. **Dual overlay** — [`Tape::jvp`] sweeps tangents forward through
//!    every recorded node, including appended gradient nodes.  Seeding
//!    the θ-leaves with a direction `v` makes the tangent of a `∇_θ L`
//!    node the Hessian-vector product `∂²L/∂θ² · v`, and the tangent of
//!    a `∇_η L` node the mixed product `(∂²L/∂θ∂η)ᵀ · v` — exactly the
//!    forward-over-reverse quantities of the paper's Eq. (8).
//!
//! Every node's value buffer is counted in [`TapeStats::bytes`]; the JVP
//! overlay reports the tangent bytes it materialises (zero tangents are
//! never stored, mirroring the paper's Ω-sparsity exploitation).

use super::tensor::Tensor;

/// Index of a node on the tape.
pub type NodeId = usize;

/// Primitive operations.  The set is closed under both `grad` (VJPs are
/// expressed via these same ops) and `jvp` (linearisations are computed
/// from stored primal values).
#[derive(Debug, Clone)]
pub enum Op {
    /// Differentiable input.
    Leaf,
    /// Non-differentiable input (data, labels, seeds).
    Const,
    Add(NodeId, NodeId),
    Sub(NodeId, NodeId),
    Mul(NodeId, NodeId),
    /// `x * c` for a compile-time constant `c`.
    Scale(NodeId, f64),
    /// `x + c` elementwise.
    Offset(NodeId, f64),
    Matmul { a: NodeId, b: NodeId, ta: bool, tb: bool },
    /// Elementwise `a / b`.  Both operands differentiable (Adam's
    /// `m̂/(√v̂+ε)` and layernorm's `(x−μ)/σ` need the denominator path).
    Div(NodeId, NodeId),
    Relu(NodeId),
    /// Heaviside step of the input (0/1 mask); derivative defined as 0,
    /// matching JAX's convention for `relu'` at a kink.
    Step(NodeId),
    Tanh(NodeId),
    Exp(NodeId),
    /// Elementwise `√x`; the input must stay positive wherever a gradient
    /// flows (Adam guards with an ε_root offset before the sqrt,
    /// layernorm with `σ² + ε`).
    Sqrt(NodeId),
    /// Sum of all elements → scalar.
    Sum(NodeId),
    /// Scalar → filled tensor of the given shape.
    Broadcast(NodeId, Vec<usize>),
    /// `[m,n] → [m]`, summing each row.
    RowSum(NodeId),
    /// `[m] → [m,n]`, repeating each entry across a row.
    RowBroadcast(NodeId, usize),
    /// `[m,n] → [n]`, summing each column.
    ColSum(NodeId),
    /// `[n] → [m,n]`, repeating the vector as every row.
    ColBroadcast(NodeId, usize),
    SoftmaxRows(NodeId),
    LogSumExpRows(NodeId),
    /// `[m,n] → [m]`: element `(i, idx[i])` per row.
    GatherCols(NodeId, Vec<usize>),
    /// `[m] → [m,n]`: value `i` placed at `(i, idx[i])`, zero elsewhere.
    ScatterCols(NodeId, Vec<usize>, usize),
    Reshape(NodeId, Vec<usize>),
}

struct Node {
    op: Op,
    value: Tensor,
}

/// Size/occupancy counters for one tape.
#[derive(Debug, Clone, Copy, Default)]
pub struct TapeStats {
    pub nodes: usize,
    /// Total bytes of all node value buffers currently on the tape.
    pub bytes: usize,
}

/// The Wengert list.
pub struct Tape {
    nodes: Vec<Node>,
    bytes: usize,
}

impl Default for Tape {
    fn default() -> Self {
        Tape::new()
    }
}

// ---- value-level kernels shared by eager eval and the JVP overlay ------

fn t_sum(v: &Tensor) -> Tensor {
    Tensor::scalar(v.data.iter().sum())
}

fn t_row_sum(v: &Tensor) -> Tensor {
    let (m, n) = v.dims2();
    let data = (0..m).map(|i| v.data[i * n..(i + 1) * n].iter().sum()).collect();
    Tensor::new(vec![m], data)
}

fn t_row_broadcast(v: &Tensor, n: usize) -> Tensor {
    assert_eq!(v.shape.len(), 1, "row_broadcast wants a vector");
    let m = v.shape[0];
    let mut data = Vec::with_capacity(m * n);
    for i in 0..m {
        data.extend(std::iter::repeat(v.data[i]).take(n));
    }
    Tensor::new(vec![m, n], data)
}

fn t_col_sum(v: &Tensor) -> Tensor {
    let (m, n) = v.dims2();
    let mut data = vec![0.0; n];
    for i in 0..m {
        for j in 0..n {
            data[j] += v.data[i * n + j];
        }
    }
    Tensor::new(vec![n], data)
}

fn t_col_broadcast(v: &Tensor, m: usize) -> Tensor {
    assert_eq!(v.shape.len(), 1, "col_broadcast wants a vector");
    let n = v.shape[0];
    let mut data = Vec::with_capacity(m * n);
    for _ in 0..m {
        data.extend_from_slice(&v.data);
    }
    Tensor::new(vec![m, n], data)
}

fn t_softmax_rows(z: &Tensor) -> Tensor {
    let (m, n) = z.dims2();
    let mut out = vec![0.0; m * n];
    for i in 0..m {
        let row = &z.data[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut denom = 0.0;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            out[i * n + j] = e;
            denom += e;
        }
        for j in 0..n {
            out[i * n + j] /= denom;
        }
    }
    Tensor::new(vec![m, n], out)
}

fn t_logsumexp_rows(z: &Tensor) -> Tensor {
    let (m, n) = z.dims2();
    let data = (0..m)
        .map(|i| {
            let row = &z.data[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            mx + row.iter().map(|x| (x - mx).exp()).sum::<f64>().ln()
        })
        .collect();
    Tensor::new(vec![m], data)
}

fn t_gather_cols(z: &Tensor, idx: &[usize]) -> Tensor {
    let (m, n) = z.dims2();
    assert_eq!(idx.len(), m, "gather index length");
    let data = idx
        .iter()
        .enumerate()
        .map(|(i, &j)| {
            assert!(j < n, "gather index {j} out of {n}");
            z.data[i * n + j]
        })
        .collect();
    Tensor::new(vec![m], data)
}

fn t_scatter_cols(v: &Tensor, idx: &[usize], n: usize) -> Tensor {
    assert_eq!(v.shape.len(), 1, "scatter wants a vector");
    let m = v.shape[0];
    assert_eq!(idx.len(), m, "scatter index length");
    let mut data = vec![0.0; m * n];
    for (i, &j) in idx.iter().enumerate() {
        data[i * n + j] = v.data[i];
    }
    Tensor::new(vec![m, n], data)
}

impl Tape {
    pub fn new() -> Tape {
        Tape { nodes: Vec::new(), bytes: 0 }
    }

    /// Value of a node.
    pub fn value(&self, id: NodeId) -> &Tensor {
        &self.nodes[id].value
    }

    /// Shape of a node (cloned).
    pub fn shape(&self, id: NodeId) -> Vec<usize> {
        self.nodes[id].value.shape.clone()
    }

    pub fn stats(&self) -> TapeStats {
        TapeStats { nodes: self.nodes.len(), bytes: self.bytes }
    }

    fn push(&mut self, op: Op, value: Tensor) -> NodeId {
        self.bytes += value.bytes();
        self.nodes.push(Node { op, value });
        self.nodes.len() - 1
    }

    // ---- builders ------------------------------------------------------

    /// Differentiable input.
    pub fn leaf(&mut self, value: Tensor) -> NodeId {
        self.push(Op::Leaf, value)
    }

    /// Non-differentiable input.
    pub fn constant(&mut self, value: Tensor) -> NodeId {
        self.push(Op::Const, value)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let value = self.value(a).zip(self.value(b), |x, y| x + y);
        self.push(Op::Add(a, b), value)
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let value = self.value(a).zip(self.value(b), |x, y| x - y);
        self.push(Op::Sub(a, b), value)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let value = self.value(a).zip(self.value(b), |x, y| x * y);
        self.push(Op::Mul(a, b), value)
    }

    pub fn div(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let value = self.value(a).zip(self.value(b), |x, y| x / y);
        self.push(Op::Div(a, b), value)
    }

    pub fn scale(&mut self, a: NodeId, c: f64) -> NodeId {
        let value = self.value(a).map(|x| x * c);
        self.push(Op::Scale(a, c), value)
    }

    pub fn offset(&mut self, a: NodeId, c: f64) -> NodeId {
        let value = self.value(a).map(|x| x + c);
        self.push(Op::Offset(a, c), value)
    }

    pub fn matmul(&mut self, a: NodeId, b: NodeId, ta: bool, tb: bool) -> NodeId {
        let value = self.value(a).matmul(self.value(b), ta, tb);
        self.push(Op::Matmul { a, b, ta, tb }, value)
    }

    pub fn relu(&mut self, a: NodeId) -> NodeId {
        let value = self.value(a).map(|x| x.max(0.0));
        self.push(Op::Relu(a), value)
    }

    pub fn step(&mut self, a: NodeId) -> NodeId {
        let value = self.value(a).map(|x| if x > 0.0 { 1.0 } else { 0.0 });
        self.push(Op::Step(a), value)
    }

    pub fn tanh(&mut self, a: NodeId) -> NodeId {
        let value = self.value(a).map(f64::tanh);
        self.push(Op::Tanh(a), value)
    }

    pub fn exp(&mut self, a: NodeId) -> NodeId {
        let value = self.value(a).map(f64::exp);
        self.push(Op::Exp(a), value)
    }

    pub fn sqrt(&mut self, a: NodeId) -> NodeId {
        let value = self.value(a).map(f64::sqrt);
        self.push(Op::Sqrt(a), value)
    }

    pub fn sum(&mut self, a: NodeId) -> NodeId {
        let value = t_sum(self.value(a));
        self.push(Op::Sum(a), value)
    }

    /// Scalar → any shape.
    pub fn broadcast(&mut self, a: NodeId, shape: &[usize]) -> NodeId {
        let v = self.value(a);
        assert!(
            v.shape.is_empty(),
            "broadcast wants a rank-0 scalar, got {:?}",
            v.shape
        );
        let value = Tensor::full(shape, v.item());
        self.push(Op::Broadcast(a, shape.to_vec()), value)
    }

    pub fn row_sum(&mut self, a: NodeId) -> NodeId {
        let value = t_row_sum(self.value(a));
        self.push(Op::RowSum(a), value)
    }

    pub fn row_broadcast(&mut self, a: NodeId, n: usize) -> NodeId {
        let value = t_row_broadcast(self.value(a), n);
        self.push(Op::RowBroadcast(a, n), value)
    }

    pub fn col_sum(&mut self, a: NodeId) -> NodeId {
        let value = t_col_sum(self.value(a));
        self.push(Op::ColSum(a), value)
    }

    pub fn col_broadcast(&mut self, a: NodeId, m: usize) -> NodeId {
        let value = t_col_broadcast(self.value(a), m);
        self.push(Op::ColBroadcast(a, m), value)
    }

    pub fn softmax_rows(&mut self, a: NodeId) -> NodeId {
        let value = t_softmax_rows(self.value(a));
        self.push(Op::SoftmaxRows(a), value)
    }

    pub fn logsumexp_rows(&mut self, a: NodeId) -> NodeId {
        let value = t_logsumexp_rows(self.value(a));
        self.push(Op::LogSumExpRows(a), value)
    }

    pub fn gather_cols(&mut self, a: NodeId, idx: Vec<usize>) -> NodeId {
        let value = t_gather_cols(self.value(a), &idx);
        self.push(Op::GatherCols(a, idx), value)
    }

    pub fn scatter_cols(&mut self, a: NodeId, idx: Vec<usize>, n: usize) -> NodeId {
        let value = t_scatter_cols(self.value(a), &idx, n);
        self.push(Op::ScatterCols(a, idx, n), value)
    }

    pub fn reshape(&mut self, a: NodeId, shape: Vec<usize>) -> NodeId {
        let v = self.value(a);
        assert_eq!(
            v.elements(),
            shape.iter().product::<usize>(),
            "reshape {:?} → {shape:?}",
            v.shape
        );
        let value = Tensor::new(shape.clone(), v.data.clone());
        self.push(Op::Reshape(a, shape), value)
    }

    /// Mean of all elements (composite: `sum` then `scale`).
    pub fn mean(&mut self, a: NodeId) -> NodeId {
        let n = self.value(a).elements();
        let s = self.sum(a);
        self.scale(s, 1.0 / n as f64)
    }

    /// Row-wise layer normalisation `(x − μ) / √(σ² + ε)` of an `[m,n]`
    /// input (composite over row reductions, `sqrt` and `div`).
    pub fn layernorm_rows(&mut self, a: NodeId, eps: f64) -> NodeId {
        let n = self.value(a).dims2().1;
        let mu_sum = self.row_sum(a);
        let mu = self.scale(mu_sum, 1.0 / n as f64);
        let mu_b = self.row_broadcast(mu, n);
        let centered = self.sub(a, mu_b);
        let sq = self.mul(centered, centered);
        let var_sum = self.row_sum(sq);
        let var = self.scale(var_sum, 1.0 / n as f64);
        let var_eps = self.offset(var, eps);
        let std = self.sqrt(var_eps);
        let std_b = self.row_broadcast(std, n);
        self.div(centered, std_b)
    }

    // ---- reverse mode ---------------------------------------------------

    fn acc(&mut self, adj: &mut [Option<NodeId>], id: NodeId, contrib: NodeId) {
        adj[id] = Some(match adj[id] {
            Some(prev) => self.add(prev, contrib),
            None => contrib,
        });
    }

    /// Gradient of scalar node `y` with respect to `wrt`, appended to the
    /// tape as new nodes (graph-mode reverse).  Nodes unreachable from `y`
    /// get zero gradients.  Because the adjoint computation is itself made
    /// of tape ops, a later `grad` (or [`Tape::jvp`]) can differentiate
    /// straight through it.
    pub fn grad(&mut self, y: NodeId, wrt: &[NodeId]) -> Vec<NodeId> {
        assert_eq!(self.value(y).elements(), 1, "grad of a non-scalar");
        let mut adj: Vec<Option<NodeId>> = vec![None; y + 1];
        let seed_shape = self.shape(y);
        let seed = self.constant(Tensor::full(&seed_shape, 1.0));
        adj[y] = Some(seed);
        for i in (0..=y).rev() {
            let Some(g) = adj[i] else { continue };
            let op = self.nodes[i].op.clone();
            match op {
                Op::Leaf | Op::Const | Op::Step(_) => {}
                Op::Add(a, b) => {
                    self.acc(&mut adj, a, g);
                    self.acc(&mut adj, b, g);
                }
                Op::Sub(a, b) => {
                    self.acc(&mut adj, a, g);
                    let neg = self.scale(g, -1.0);
                    self.acc(&mut adj, b, neg);
                }
                Op::Mul(a, b) => {
                    let ca = self.mul(g, b);
                    let cb = self.mul(g, a);
                    self.acc(&mut adj, a, ca);
                    self.acc(&mut adj, b, cb);
                }
                Op::Div(a, b) => {
                    // y = a/b: da = g/b, db = −g·y/b (reusing this node
                    // as y, the same trick as tanh/exp).
                    let da = self.div(g, b);
                    self.acc(&mut adj, a, da);
                    let gy = self.mul(g, i);
                    let gyb = self.div(gy, b);
                    let db = self.scale(gyb, -1.0);
                    self.acc(&mut adj, b, db);
                }
                Op::Scale(a, c) => {
                    let s = self.scale(g, c);
                    self.acc(&mut adj, a, s);
                }
                Op::Offset(a, _) => self.acc(&mut adj, a, g),
                Op::Matmul { a, b, ta, tb } => {
                    let da = if !ta {
                        self.matmul(g, b, false, !tb)
                    } else {
                        self.matmul(b, g, tb, true)
                    };
                    let db = if !tb {
                        self.matmul(a, g, !ta, false)
                    } else {
                        self.matmul(g, a, true, ta)
                    };
                    self.acc(&mut adj, a, da);
                    self.acc(&mut adj, b, db);
                }
                Op::Relu(a) => {
                    let mask = self.step(a);
                    let c = self.mul(g, mask);
                    self.acc(&mut adj, a, c);
                }
                Op::Tanh(a) => {
                    // d tanh = (1 − y²): g − g·y², reusing this node as y.
                    let y2 = self.mul(i, i);
                    let gy2 = self.mul(g, y2);
                    let c = self.sub(g, gy2);
                    self.acc(&mut adj, a, c);
                }
                Op::Exp(a) => {
                    let c = self.mul(g, i);
                    self.acc(&mut adj, a, c);
                }
                Op::Sqrt(a) => {
                    // y = √a: da = g/(2y), reusing this node as y.
                    let gy = self.div(g, i);
                    let c = self.scale(gy, 0.5);
                    self.acc(&mut adj, a, c);
                }
                Op::Sum(a) => {
                    let sh = self.shape(a);
                    let c = self.broadcast(g, &sh);
                    self.acc(&mut adj, a, c);
                }
                Op::Broadcast(a, _) => {
                    let c = self.sum(g);
                    self.acc(&mut adj, a, c);
                }
                Op::RowSum(a) => {
                    let n = self.shape(a)[1];
                    let c = self.row_broadcast(g, n);
                    self.acc(&mut adj, a, c);
                }
                Op::RowBroadcast(a, _) => {
                    let c = self.row_sum(g);
                    self.acc(&mut adj, a, c);
                }
                Op::ColSum(a) => {
                    let m = self.shape(a)[0];
                    let c = self.col_broadcast(g, m);
                    self.acc(&mut adj, a, c);
                }
                Op::ColBroadcast(a, _) => {
                    let c = self.col_sum(g);
                    self.acc(&mut adj, a, c);
                }
                Op::SoftmaxRows(a) => {
                    // dz = s ⊙ (g − rowbcast(rowsum(g ⊙ s))), s = this node.
                    let n = self.shape(a)[1];
                    let gs = self.mul(g, i);
                    let rs = self.row_sum(gs);
                    let rb = self.row_broadcast(rs, n);
                    let diff = self.sub(g, rb);
                    let c = self.mul(i, diff);
                    self.acc(&mut adj, a, c);
                }
                Op::LogSumExpRows(a) => {
                    let n = self.shape(a)[1];
                    let s = self.softmax_rows(a);
                    let rb = self.row_broadcast(g, n);
                    let c = self.mul(rb, s);
                    self.acc(&mut adj, a, c);
                }
                Op::GatherCols(a, idx) => {
                    let n = self.shape(a)[1];
                    let c = self.scatter_cols(g, idx, n);
                    self.acc(&mut adj, a, c);
                }
                Op::ScatterCols(a, idx, _) => {
                    let c = self.gather_cols(g, idx);
                    self.acc(&mut adj, a, c);
                }
                Op::Reshape(a, _) => {
                    let sh = self.shape(a);
                    let c = self.reshape(g, sh);
                    self.acc(&mut adj, a, c);
                }
            }
        }
        let mut out = Vec::with_capacity(wrt.len());
        for &w in wrt {
            match adj.get(w).copied().flatten() {
                Some(id) => out.push(id),
                None => {
                    let sh = self.shape(w);
                    let z = self.constant(Tensor::zeros(&sh));
                    out.push(z);
                }
            }
        }
        out
    }

    // ---- forward mode ---------------------------------------------------

    /// Forward tangent sweep over the tape (dual-number overlay).
    ///
    /// `seeds` assigns tangents to leaf/const nodes; every other tangent is
    /// derived by the op linearisations.  Returns the tangents of
    /// `targets` (zeros where no tangent flows) and the total bytes of
    /// tangent buffers materialised — the memory cost of the overlay.
    /// Nodes after the last target can never influence it, so the sweep
    /// stops there: subgraphs recorded later (e.g. the optimiser update
    /// and its adjoint in the MixFlow backward step) cost nothing.
    pub fn jvp(
        &self,
        seeds: &[(NodeId, Tensor)],
        targets: &[NodeId],
    ) -> (Vec<Tensor>, usize) {
        for (id, t) in seeds {
            assert_eq!(
                t.shape,
                self.nodes[*id].value.shape,
                "seed shape mismatch at node {id}"
            );
        }
        let stop = match targets.iter().max() {
            Some(&last) => last + 1,
            None => 0,
        };
        let mut tan: Vec<Option<Tensor>> = vec![None; self.nodes.len()];
        let mut bytes = 0usize;
        for i in 0..stop {
            let out: Option<Tensor> = match &self.nodes[i].op {
                Op::Leaf | Op::Const => seeds
                    .iter()
                    .find(|(id, _)| *id == i)
                    .map(|(_, t)| t.clone()),
                Op::Step(_) => None,
                Op::Add(a, b) => match (&tan[*a], &tan[*b]) {
                    (Some(x), Some(y)) => Some(x.zip(y, |p, q| p + q)),
                    (Some(x), None) => Some(x.clone()),
                    (None, Some(y)) => Some(y.clone()),
                    (None, None) => None,
                },
                Op::Sub(a, b) => match (&tan[*a], &tan[*b]) {
                    (Some(x), Some(y)) => Some(x.zip(y, |p, q| p - q)),
                    (Some(x), None) => Some(x.clone()),
                    (None, Some(y)) => Some(y.map(|q| -q)),
                    (None, None) => None,
                },
                Op::Mul(a, b) => {
                    let va = &self.nodes[*a].value;
                    let vb = &self.nodes[*b].value;
                    match (&tan[*a], &tan[*b]) {
                        (Some(x), Some(y)) => {
                            let left = x.zip(vb, |p, q| p * q);
                            let right = va.zip(y, |p, q| p * q);
                            Some(left.zip(&right, |p, q| p + q))
                        }
                        (Some(x), None) => Some(x.zip(vb, |p, q| p * q)),
                        (None, Some(y)) => Some(va.zip(y, |p, q| p * q)),
                        (None, None) => None,
                    }
                }
                Op::Div(a, b) => {
                    // ẏ = (ȧ − y·ḃ)/b, using this node's value as y.
                    let vy = &self.nodes[i].value;
                    let vb = &self.nodes[*b].value;
                    match (&tan[*a], &tan[*b]) {
                        (Some(x), Some(bt)) => {
                            let ybt = vy.zip(bt, |y, q| y * q);
                            let num = x.zip(&ybt, |p, s| p - s);
                            Some(num.zip(vb, |p, q| p / q))
                        }
                        (Some(x), None) => Some(x.zip(vb, |p, q| p / q)),
                        (None, Some(bt)) => {
                            let ybt = vy.zip(bt, |y, q| y * q);
                            Some(ybt.zip(vb, |p, q| -p / q))
                        }
                        (None, None) => None,
                    }
                }
                Op::Scale(a, c) => tan[*a].as_ref().map(|t| t.map(|x| x * c)),
                Op::Offset(a, _) => tan[*a].clone(),
                Op::Matmul { a, b, ta, tb } => {
                    let va = &self.nodes[*a].value;
                    let vb = &self.nodes[*b].value;
                    let left =
                        tan[*a].as_ref().map(|t| t.matmul(vb, *ta, *tb));
                    let right =
                        tan[*b].as_ref().map(|t| va.matmul(t, *ta, *tb));
                    match (left, right) {
                        (Some(x), Some(y)) => Some(x.zip(&y, |p, q| p + q)),
                        (x, None) => x,
                        (None, y) => y,
                    }
                }
                Op::Relu(a) => tan[*a].as_ref().map(|t| {
                    t.zip(&self.nodes[*a].value, |p, x| {
                        if x > 0.0 {
                            p
                        } else {
                            0.0
                        }
                    })
                }),
                Op::Tanh(a) => tan[*a].as_ref().map(|t| {
                    t.zip(&self.nodes[i].value, |p, y| p * (1.0 - y * y))
                }),
                Op::Exp(a) => tan[*a]
                    .as_ref()
                    .map(|t| t.zip(&self.nodes[i].value, |p, y| p * y)),
                Op::Sqrt(a) => tan[*a].as_ref().map(|t| {
                    t.zip(&self.nodes[i].value, |p, y| p / (2.0 * y))
                }),
                Op::Sum(a) => tan[*a].as_ref().map(t_sum),
                Op::Broadcast(a, shape) => tan[*a]
                    .as_ref()
                    .map(|t| Tensor::full(shape, t.item())),
                Op::RowSum(a) => tan[*a].as_ref().map(t_row_sum),
                Op::RowBroadcast(a, n) => {
                    tan[*a].as_ref().map(|t| t_row_broadcast(t, *n))
                }
                Op::ColSum(a) => tan[*a].as_ref().map(t_col_sum),
                Op::ColBroadcast(a, m) => {
                    tan[*a].as_ref().map(|t| t_col_broadcast(t, *m))
                }
                Op::SoftmaxRows(a) => tan[*a].as_ref().map(|t| {
                    // ṡ = s ⊙ (ż − rowbcast(rowsum(s ⊙ ż)))
                    let s = &self.nodes[i].value;
                    let st = s.zip(t, |p, q| p * q);
                    let rb = t_row_broadcast(&t_row_sum(&st), s.shape[1]);
                    let inner = t.zip(&rb, |p, q| p - q);
                    s.zip(&inner, |p, q| p * q)
                }),
                Op::LogSumExpRows(a) => tan[*a].as_ref().map(|t| {
                    let s = t_softmax_rows(&self.nodes[*a].value);
                    t_row_sum(&s.zip(t, |p, q| p * q))
                }),
                Op::GatherCols(a, idx) => {
                    tan[*a].as_ref().map(|t| t_gather_cols(t, idx))
                }
                Op::ScatterCols(a, idx, n) => {
                    tan[*a].as_ref().map(|t| t_scatter_cols(t, idx, *n))
                }
                Op::Reshape(a, shape) => tan[*a]
                    .as_ref()
                    .map(|t| Tensor::new(shape.clone(), t.data.clone())),
            };
            if let Some(t) = out {
                bytes += t.bytes();
                tan[i] = Some(t);
            }
        }
        let out = targets
            .iter()
            .map(|&t| match &tan[t] {
                Some(x) => x.clone(),
                None => Tensor::zeros(&self.nodes[t].value.shape),
            })
            .collect();
        (out, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_of_square_sum() {
        // f(x) = Σ x² → ∇f = 2x
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![3], vec![1.0, -2.0, 3.0]));
        let sq = tape.mul(x, x);
        let y = tape.sum(sq);
        let g = tape.grad(y, &[x]);
        assert_eq!(tape.value(g[0]).data, vec![2.0, -4.0, 6.0]);
    }

    #[test]
    fn grad_unreachable_is_zero() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::scalar(1.0));
        let z = tape.leaf(Tensor::new(vec![2], vec![5.0, 5.0]));
        let y = tape.mul(x, x);
        let g = tape.grad(y, &[z]);
        assert_eq!(tape.value(g[0]).data, vec![0.0, 0.0]);
    }

    #[test]
    fn grad_matmul_sum_is_row_col_counts() {
        // f = Σ (A·B) → dA = 1·Bᵀ, dB = Aᵀ·1
        let mut tape = Tape::new();
        let a = tape.leaf(Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]));
        let b = tape.leaf(Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]));
        let c = tape.matmul(a, b, false, false);
        let y = tape.sum(c);
        let g = tape.grad(y, &[a, b]);
        // dA[i,k] = Σ_j B[k,j]
        assert_eq!(tape.value(g[0]).data, vec![11., 15., 11., 15.]);
        // dB[k,j] = Σ_i A[i,k]
        assert_eq!(tape.value(g[1]).data, vec![4., 4., 6., 6.]);
    }

    #[test]
    fn jvp_matches_linearity() {
        // y = 3x + 2 → tangent 3v
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![2], vec![1.0, 2.0]));
        let s = tape.scale(x, 3.0);
        let y = tape.offset(s, 2.0);
        let (tans, bytes) =
            tape.jvp(&[(x, Tensor::new(vec![2], vec![1.0, -1.0]))], &[y]);
        assert_eq!(tans[0].data, vec![3.0, -3.0]);
        assert!(bytes > 0);
    }

    #[test]
    fn jvp_zero_tangents_not_materialised() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![4], vec![1.0; 4]));
        let c = tape.constant(Tensor::new(vec![4], vec![2.0; 4]));
        let _y = tape.mul(x, c);
        // No seeds → nothing materialised.
        let (tans, bytes) = tape.jvp(&[], &[_y]);
        assert_eq!(bytes, 0);
        assert_eq!(tans[0].data, vec![0.0; 4]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut tape = Tape::new();
        let z = tape.constant(Tensor::new(vec![2, 3], vec![1., 2., 3., -1., 0., 1.]));
        let s = tape.softmax_rows(z);
        let rows = t_row_sum(tape.value(s));
        for r in rows.data {
            assert!((r - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn div_sqrt_values_and_grads() {
        // f(x) = Σ 1/√x → ∇f = −½ x^{−3/2}
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![2], vec![4.0, 1.0]));
        let r = tape.sqrt(x);
        let one = tape.constant(Tensor::full(&[2], 1.0));
        let inv = tape.div(one, r);
        assert_eq!(tape.value(inv).data, vec![0.5, 1.0]);
        let y = tape.sum(inv);
        let g = tape.grad(y, &[x]);
        let want = [-0.5 * 4.0f64.powf(-1.5), -0.5];
        for (got, w) in tape.value(g[0]).data.iter().zip(want.iter()) {
            assert!((got - w).abs() < 1e-12, "{got} vs {w}");
        }
    }

    #[test]
    fn layernorm_rows_zero_mean_unit_var() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![2, 4], vec![
            1.0, 2.0, 3.0, 4.0, -1.0, 0.0, 5.0, 2.0,
        ]));
        let y = tape.layernorm_rows(x, 1e-8);
        let v = tape.value(y);
        let (m, n) = v.dims2();
        for i in 0..m {
            let row = &v.data[i * n..(i + 1) * n];
            let mu: f64 = row.iter().sum::<f64>() / n as f64;
            let var: f64 =
                row.iter().map(|a| (a - mu) * (a - mu)).sum::<f64>() / n as f64;
            assert!(mu.abs() < 1e-9, "row mean {mu}");
            assert!((var - 1.0).abs() < 1e-6, "row var {var}");
        }
    }

    #[test]
    fn bytes_accumulate() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::zeros(&[8]));
        let _ = tape.scale(x, 2.0);
        assert_eq!(tape.stats().bytes, 2 * 8 * 8);
        assert_eq!(tape.stats().nodes, 2);
    }
}

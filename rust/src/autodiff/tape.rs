//! Wengert-list tape: eager op evaluation, graph-mode reverse
//! differentiation, and a forward-mode JVP overlay.
//!
//! The two properties the MixFlow-MG composition rests on:
//!
//! 1. **Closure under differentiation** — [`Tape::grad`] *appends* the
//!    adjoint computation to the same tape as ordinary ops, so calling
//!    `grad` on a function of gradient nodes yields reverse-over-reverse
//!    (the naive hypergradient baseline) with no special cases.
//! 2. **Dual overlay** — [`Tape::jvp`] sweeps tangents forward through
//!    every recorded node, including appended gradient nodes.  Seeding
//!    the θ-leaves with a direction `v` makes the tangent of a `∇_θ L`
//!    node the Hessian-vector product `∂²L/∂θ² · v`, and the tangent of
//!    a `∇_η L` node the mixed product `(∂²L/∂θ∂η)ᵀ · v` — exactly the
//!    forward-over-reverse quantities of the paper's Eq. (8).
//!
//! Storage comes from a [`BufferArena`] owned by the tape: node values
//! are written into recycled buffers via the `*_into` kernels, and
//! [`Tape::reset`] parks every uniquely-owned buffer for the next
//! step-tape to reuse — the allocator leaves the hot path.  `Reshape`
//! nodes alias their input buffer (zero copy, zero bytes counted), the
//! reverse sweep borrows ops instead of cloning them (gather/scatter
//! indices are `Arc`-shared), and the JVP overlay recycles its tangent
//! buffers when the sweep finishes.
//!
//! Every owning node's value buffer is counted in [`TapeStats::bytes`];
//! the JVP overlay reports the tangent bytes it *materialises* — aliased
//! pass-through tangents and zero tangents cost nothing, mirroring the
//! paper's Ω-sparsity exploitation.
//!
//! Steady-state cycles go through [`Tape::plan_step`]: the first cycle
//! under a [`PlanKey`] records dynamically and **compiles** a
//! [`StepPlan`] (static op schedule, last-use liveness, positional
//! buffer-take assignment); later cycles **replay** — the builders
//! re-execute (payloads are per-step) but every buffer take is served by
//! direct slot indexing instead of an arena free-list probe, and any
//! topology change falls back to the dynamic path and recompiles.  See
//! [`super::plan`] for the lifecycle and invariants.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::arena::{ArenaStats, BufferArena};
use super::plan::{PlanKey, PlanStats, StepPlan};
use super::tensor::Tensor;
use crate::kernels::DetPool;
use crate::obs::{Counter, Gauge, Phase, Telemetry};

/// Index of a node on the tape.
pub type NodeId = usize;

// ---- robustness signals ---------------------------------------------------
//
// The serving layer (`crate::serve`) needs failures on the tape's hot
// paths to be *classifiable* after a `catch_unwind`.  Rather than parse
// panic message strings, the guard and the cancellation check unwind
// with these typed payloads via `std::panic::panic_any`; the supervisor
// downcasts them back into its error taxonomy.  They live here — not in
// `serve` — so autodiff never depends on the serving layer.

/// Panic payload raised by the non-finite guard ([`Tape::set_guard_enabled`])
/// when a freshly pushed node value contains a NaN or infinity.
#[derive(Debug, Clone)]
pub struct NonFiniteSignal {
    /// Index the offending node would have occupied on the tape.
    pub node: usize,
    /// Name of the innermost open telemetry phase (`"forward"` when no
    /// span is open), attributing the blow-up to a sweep.
    pub phase: &'static str,
}

/// Panic payload raised by [`Tape::check_cancel`] when the attached
/// [`CancelToken`] has fired (explicit cancel or deadline expiry).
#[derive(Debug, Clone, Copy)]
pub struct CancelSignal;

/// Cooperative cancellation handle shared between a supervisor thread
/// and the tape it is watching.  The tape polls it at phase boundaries
/// — cancellation is *cooperative*, never preemptive, so a fired token
/// stops the job at the next boundary rather than mid-kernel.
#[derive(Debug, Default)]
pub struct CancelToken {
    flag: AtomicBool,
    /// Instant after which the token counts as fired even without an
    /// explicit [`CancelToken::cancel`] call.
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that only fires on explicit [`CancelToken::cancel`].
    pub fn new() -> CancelToken {
        CancelToken { flag: AtomicBool::new(false), deadline: None }
    }

    /// A token that also fires once `deadline` has passed.
    pub fn with_deadline(deadline: Instant) -> CancelToken {
        CancelToken { flag: AtomicBool::new(false), deadline: Some(deadline) }
    }

    /// Fire the token explicitly.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Whether the token has fired (explicitly or by deadline).
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
            || self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// Primitive operations.  The set is closed under both `grad` (VJPs are
/// expressed via these same ops) and `jvp` (linearisations are computed
/// from stored primal values).  Gather/scatter indices are `Arc`-shared
/// so the reverse sweep can mint adjoint nodes without copying them.
#[derive(Debug, Clone)]
pub enum Op {
    /// Differentiable input.
    Leaf,
    /// Non-differentiable input (data, labels, seeds).
    Const,
    Add(NodeId, NodeId),
    Sub(NodeId, NodeId),
    Mul(NodeId, NodeId),
    /// `x * c` for a compile-time constant `c`.
    Scale(NodeId, f64),
    /// `x + c` elementwise.
    Offset(NodeId, f64),
    Matmul { a: NodeId, b: NodeId, ta: bool, tb: bool },
    /// Batched matmul over rank-3 `[g, m, k] × [g, k, n] → [g, m, n]`
    /// operands sharing a leading group dimension (`g` = batch × heads in
    /// the attention stack).  Per group the kernel is bit-for-bit the
    /// rank-2 [`Op::Matmul`], so `g = 1` reproduces the unbatched path.
    BatchMatmul { a: NodeId, b: NodeId, ta: bool, tb: bool },
    /// Column-wise concatenation of same-row-count matrices
    /// `[m, n₁] ⧺ … ⧺ [m, n_p] → [m, Σnᵢ]` — head-stacking.
    ConcatCols(Vec<NodeId>),
    /// Columns `[offset, offset + width)` of an `[m, n]` input —
    /// head-splitting; the adjoint zero-pads back via [`Op::ConcatCols`].
    SplitCols(NodeId, usize, usize),
    /// Elementwise `a / b`.  Both operands differentiable (Adam's
    /// `m̂/(√v̂+ε)` and layernorm's `(x−μ)/σ` need the denominator path).
    Div(NodeId, NodeId),
    Relu(NodeId),
    /// Heaviside step of the input (0/1 mask); derivative defined as 0,
    /// matching JAX's convention for `relu'` at a kink.
    Step(NodeId),
    Tanh(NodeId),
    Exp(NodeId),
    /// Elementwise `√x`; the input must stay positive wherever a gradient
    /// flows (Adam guards with an ε_root offset before the sqrt,
    /// layernorm with `σ² + ε`).
    Sqrt(NodeId),
    /// Sum of all elements → scalar.
    Sum(NodeId),
    /// Scalar → filled tensor of the given shape.
    Broadcast(NodeId, Vec<usize>),
    /// `[m,n] → [m]`, summing each row.
    RowSum(NodeId),
    /// `[m] → [m,n]`, repeating each entry across a row.
    RowBroadcast(NodeId, usize),
    /// `[m,n] → [n]`, summing each column.
    ColSum(NodeId),
    /// `[n] → [m,n]`, repeating the vector as every row.
    ColBroadcast(NodeId, usize),
    SoftmaxRows(NodeId),
    LogSumExpRows(NodeId),
    /// `[m,n] → [m]`: element `(i, idx[i])` per row.
    GatherCols(NodeId, Arc<[usize]>),
    /// `[m] → [m,n]`: value `i` placed at `(i, idx[i])`, zero elsewhere.
    ScatterCols(NodeId, Arc<[usize]>, usize),
    /// Zero-copy view: the node's value aliases its input's buffer.
    Reshape(NodeId, Vec<usize>),
}

struct Node {
    op: Op,
    value: Tensor,
}

/// Size/occupancy counters for one tape.
#[derive(Debug, Clone, Copy, Default)]
pub struct TapeStats {
    pub nodes: usize,
    /// Total bytes of all *owning* node value buffers currently on the
    /// tape (aliased views such as `Reshape` contribute 0).
    pub bytes: usize,
    /// Bytes of nodes marked as K/V projections via [`Tape::mark_kv`] —
    /// the attention problems tag their key/value projection outputs so
    /// the hypergradient paths can report how much of the naive-vs-
    /// MixFlow gap comes from KV tensors specifically.
    pub kv_bytes: usize,
}

/// One compiled plan plus the buffers parked for its next replay.
struct PlanEntry {
    plan: StepPlan,
    /// Uniquely-owned buffers awaiting the next replay, one optional
    /// slot per scheduled take, in take order.
    slots: Vec<Option<Arc<Vec<f64>>>>,
}

/// The Wengert list.
pub struct Tape {
    nodes: Vec<Node>,
    bytes: usize,
    kv_bytes: usize,
    /// Nodes tagged via [`Tape::mark_kv`] this cycle — the JVP overlay
    /// reads them to split tangent bytes into a KV-specific ledger.
    kv_marks: Vec<NodeId>,
    /// Tangent bytes the last [`Tape::jvp`] sweep materialised for
    /// marked K/V nodes.
    jvp_kv_bytes: usize,
    arena: BufferArena,
    /// Compiled step plans, one optional entry per [`PlanKey`].
    plans: Vec<Option<PlanEntry>>,
    /// Key of the cycle whose nodes currently sit on the tape — the
    /// drain at the next [`Tape::plan_step`] parks their buffers into
    /// that plan's slots.
    last_cycle_key: Option<PlanKey>,
    plan_enabled: bool,
    plan_stats: PlanStats,
    /// The current cycle runs against an armed arena.
    replaying: bool,
    /// Non-finite guard (off by default): when set, [`Tape::push`]
    /// scans each new node value and unwinds with [`NonFiniteSignal`]
    /// on the first NaN/inf.  Off, the scan is a single untaken branch
    /// — the fast path stays bit-identical and unmeasurably close in
    /// cost (pinned by `rust/tests/serve.rs`).
    guard_enabled: bool,
    /// Cooperative cancellation token polled at phase boundaries.
    cancel: Option<Arc<CancelToken>>,
    /// Telemetry recorder (disabled by default).  Living here means the
    /// strategies — which already hold `&mut Tape` — and the tape's own
    /// hot paths all reach the same recorder without signature changes.
    obs: Telemetry,
    /// The kernel thread pool every builder/VJP/JVP kernel call runs
    /// against.  Defaults to the process-wide serial singleton; the
    /// engine installs its own pool at build time
    /// (`EngineBuilder::threads`).  Pooled kernels parallelise only
    /// disjoint-output axes, so tape values are bit-identical at every
    /// thread count.
    pool: Arc<DetPool>,
}

impl Default for Tape {
    fn default() -> Self {
        Tape::new()
    }
}

// ---- value-level kernels shared by eager eval and the JVP overlay ------
//
// Every kernel is an `*_into` form writing into a recycled buffer: both
// the tape builders and the JVP overlay route them through the arena,
// so neither sweep touches the allocator in steady state.

fn t_sum_into(v: &Tensor, out: &mut Vec<f64>) {
    out.clear();
    out.push(v.data.iter().sum());
}

fn t_row_sum_into(v: &Tensor, out: &mut Vec<f64>) {
    let (m, n) = v.dims2();
    out.clear();
    out.extend(
        (0..m).map(|i| v.data[i * n..(i + 1) * n].iter().sum::<f64>()),
    );
}

#[cfg(test)]
fn t_row_sum(v: &Tensor) -> Tensor {
    let m = v.dims2().0;
    let mut out = Vec::with_capacity(m);
    t_row_sum_into(v, &mut out);
    Tensor::new(vec![m], out)
}

fn t_row_broadcast_into(v: &Tensor, n: usize, out: &mut Vec<f64>) {
    assert_eq!(v.shape.len(), 1, "row_broadcast wants a vector");
    out.clear();
    for &x in v.data.iter() {
        out.extend(std::iter::repeat(x).take(n));
    }
}

fn t_col_sum_into(v: &Tensor, out: &mut Vec<f64>) {
    let (m, n) = v.dims2();
    out.clear();
    out.resize(n, 0.0);
    for i in 0..m {
        for j in 0..n {
            out[j] += v.data[i * n + j];
        }
    }
}

fn t_col_broadcast_into(v: &Tensor, m: usize, out: &mut Vec<f64>) {
    assert_eq!(v.shape.len(), 1, "col_broadcast wants a vector");
    out.clear();
    for _ in 0..m {
        out.extend_from_slice(&v.data);
    }
}

fn t_softmax_rows_into(pool: &DetPool, z: &Tensor, out: &mut Vec<f64>) {
    let (m, n) = z.dims2();
    out.clear();
    out.resize(m * n, 0.0);
    crate::kernels::rows::softmax_rows_into(pool, &z.data, m, n, out);
}

fn t_logsumexp_rows_into(pool: &DetPool, z: &Tensor, out: &mut Vec<f64>) {
    let (m, n) = z.dims2();
    out.clear();
    out.resize(m, 0.0);
    crate::kernels::rows::logsumexp_rows_into(pool, &z.data, m, n, out);
}

fn t_gather_cols_into(z: &Tensor, idx: &[usize], out: &mut Vec<f64>) {
    let (m, n) = z.dims2();
    assert_eq!(idx.len(), m, "gather index length");
    out.clear();
    out.extend(idx.iter().enumerate().map(|(i, &j)| {
        assert!(j < n, "gather index {j} out of {n}");
        z.data[i * n + j]
    }));
}

/// Column-concatenate matrices sharing a row count.  `parts` supplies
/// `(tensor, is_some)` pairs via `Option`: a `None` part contributes
/// `widths[i]` zero columns (the JVP overlay uses this for inputs with
/// no tangent).
fn t_concat_cols_into(
    parts: &[Option<&Tensor>],
    widths: &[usize],
    m: usize,
    out: &mut Vec<f64>,
) {
    assert_eq!(parts.len(), widths.len(), "concat parts vs widths");
    out.clear();
    for i in 0..m {
        for (p, &w) in parts.iter().zip(widths.iter()) {
            match p {
                Some(t) => {
                    debug_assert_eq!(t.dims2(), (m, w));
                    out.extend_from_slice(&t.data[i * w..(i + 1) * w]);
                }
                None => out.extend(std::iter::repeat(0.0).take(w)),
            }
        }
    }
}

fn t_split_cols_into(
    v: &Tensor,
    offset: usize,
    width: usize,
    out: &mut Vec<f64>,
) {
    let (m, n) = v.dims2();
    assert!(
        offset + width <= n,
        "split cols [{offset}, {}) out of {n}",
        offset + width
    );
    out.clear();
    for i in 0..m {
        out.extend_from_slice(&v.data[i * n + offset..i * n + offset + width]);
    }
}

fn t_scatter_cols_into(
    v: &Tensor,
    idx: &[usize],
    n: usize,
    out: &mut Vec<f64>,
) {
    assert_eq!(v.shape.len(), 1, "scatter wants a vector");
    let m = v.shape[0];
    assert_eq!(idx.len(), m, "scatter index length");
    out.clear();
    out.resize(m * n, 0.0);
    for (i, &j) in idx.iter().enumerate() {
        out[i * n + j] = v.data[i];
    }
}

/// Pull a buffer for `shape` from the arena and fill it.  `fill` must
/// leave exactly `shape.iter().product()` elements in the buffer (the
/// recycled contents are stale, so every `*_into` kernel clears first).
fn arena_tensor(
    arena: &mut BufferArena,
    shape: Vec<usize>,
    fill: impl FnOnce(&mut Vec<f64>),
) -> Tensor {
    let len = shape.iter().product::<usize>();
    let mut buf = arena.take(len);
    {
        let out = Arc::get_mut(&mut buf).expect("arena buffer uniquely owned");
        fill(out);
        // Hard assert: a kernel that forgot to clear/resize a recycled
        // buffer must panic, never ship stale trailing elements.
        assert_eq!(out.len(), len, "kernel wrote a wrong-sized buffer");
    }
    Tensor::from_shared(shape, buf)
}

/// Does this op's builder draw a buffer from the arena?  Leaves and
/// constants share their caller's buffer, `Reshape` aliases its input;
/// every other builder calls [`arena_tensor`] exactly once before its
/// push — the positional invariant the plan slot assignment rests on.
fn takes_buffer(op: &Op) -> bool {
    !matches!(op, Op::Leaf | Op::Const | Op::Reshape(..))
}

impl Tape {
    pub fn new() -> Tape {
        Tape {
            nodes: Vec::new(),
            bytes: 0,
            kv_bytes: 0,
            kv_marks: Vec::new(),
            jvp_kv_bytes: 0,
            arena: BufferArena::new(),
            plans: (0..PlanKey::COUNT).map(|_| None).collect(),
            last_cycle_key: None,
            plan_enabled: true,
            plan_stats: PlanStats::default(),
            replaying: false,
            guard_enabled: false,
            cancel: None,
            obs: Telemetry::new(),
            pool: Arc::new(DetPool::new(1)),
        }
    }

    /// Install the kernel thread pool (the engine builds one per
    /// [`super::engine::EngineBuilder::threads`] and shares the handle
    /// for stats).  Purely a scheduling change: values stay
    /// bit-identical at every thread count.
    pub fn set_pool(&mut self, pool: Arc<DetPool>) {
        self.pool = pool;
    }

    /// The kernel thread pool the tape dispatches through.
    pub fn pool(&self) -> &Arc<DetPool> {
        &self.pool
    }

    // ---- robustness: guard, cancellation, invariants -------------------

    /// Enable or disable the non-finite guard (off by default).  See
    /// the field doc on `guard_enabled` for the cost discipline.
    pub fn set_guard_enabled(&mut self, enabled: bool) {
        self.guard_enabled = enabled;
    }

    pub fn guard_enabled(&self) -> bool {
        self.guard_enabled
    }

    /// Attach (or with `None` detach) a cancellation token.  The tape
    /// polls it in [`Tape::check_cancel`] and at each plan-cycle entry.
    pub fn set_cancel(&mut self, cancel: Option<Arc<CancelToken>>) {
        self.cancel = cancel;
    }

    /// Unwind with [`CancelSignal`] if the attached token has fired.
    /// Strategies call this at phase boundaries (checkpoint-segment and
    /// backward-segment edges); with no token attached it is one branch.
    pub fn check_cancel(&self) {
        if let Some(token) = &self.cancel {
            if token.is_cancelled() {
                std::panic::panic_any(CancelSignal);
            }
        }
    }

    /// Whether the tape's structural invariants hold — no replay in
    /// flight, arena not armed, no telemetry phase span left open.  An
    /// unwind that escapes mid-cycle (guard trip, injected panic,
    /// deadline) violates at least one of these; the serving supervisor
    /// uses that as its quarantine trigger, and a `true` here means the
    /// engine is safe to keep warm.
    pub fn invariants_ok(&self) -> bool {
        !self.replaying
            && !self.arena.is_armed()
            && self.obs.open_phases() == 0
    }

    /// The tape's telemetry recorder (disabled by default).
    pub fn obs(&self) -> &Telemetry {
        &self.obs
    }

    /// Mutable access to the telemetry recorder — how the engine and the
    /// strategies open/close steps and phase spans.
    pub fn obs_mut(&mut self) -> &mut Telemetry {
        &mut self.obs
    }

    /// Value of a node.
    pub fn value(&self, id: NodeId) -> &Tensor {
        &self.nodes[id].value
    }

    /// Op of a node (borrowed — the sweeps never clone ops).
    pub fn op(&self, id: NodeId) -> &Op {
        &self.nodes[id].op
    }

    /// Shape of a node (cloned).
    pub fn shape(&self, id: NodeId) -> Vec<usize> {
        self.nodes[id].value.shape.clone()
    }

    pub fn stats(&self) -> TapeStats {
        TapeStats {
            nodes: self.nodes.len(),
            bytes: self.bytes,
            kv_bytes: self.kv_bytes,
        }
    }

    /// Tag a node as a K/V projection: its buffer bytes are counted in
    /// [`TapeStats::kv_bytes`] until the next [`Tape::reset`].  The
    /// attention problems mark their key/value projection outputs so
    /// [`super::mixflow::MemoryReport`] can split the memory saving into
    /// KV-specific counters.
    pub fn mark_kv(&mut self, id: NodeId) {
        let bytes = self.nodes[id].value.bytes();
        self.kv_bytes += bytes;
        self.kv_marks.push(id);
        if self.obs.enabled() {
            self.obs.count(Counter::KvBytes, bytes as u64);
            self.obs.gauge_max(Gauge::KvPeakBytes, self.kv_bytes as u64);
        }
    }

    /// Tangent bytes the most recent [`Tape::jvp`] sweep materialised
    /// for nodes tagged via [`Tape::mark_kv`] — the JVP-overlay half of
    /// the KV ledger (the primal half is [`TapeStats::kv_bytes`]).
    pub fn jvp_kv_bytes(&self) -> usize {
        self.jvp_kv_bytes
    }

    /// Traffic counters of the tape's buffer arena.
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    /// Clear the tape, recycling every node buffer that nothing else
    /// still references into the arena.  Values cloned out of the tape
    /// (checkpoints, gradients, aliases) keep their buffers alive.  All
    /// `NodeId`s from before the reset are invalidated.
    pub fn reset(&mut self) {
        let Tape { nodes, arena, bytes, kv_bytes, kv_marks, last_cycle_key, .. } =
            self;
        for node in nodes.drain(..) {
            arena.recycle(node.value);
        }
        *bytes = 0;
        *kv_bytes = 0;
        kv_marks.clear();
        // The drained buffers went to the free list, so positional
        // parking for the previous key no longer applies.
        *last_cycle_key = None;
    }

    // ---- compiled step plans -------------------------------------------

    /// Enable or disable compiled step plans (on by default).  Disabled,
    /// [`Tape::plan_step`] degenerates to [`Tape::reset`] + record —
    /// the pre-plan dynamic behaviour, bit-for-bit.
    pub fn set_plan_enabled(&mut self, enabled: bool) {
        self.plan_enabled = enabled;
    }

    pub fn plan_enabled(&self) -> bool {
        self.plan_enabled
    }

    /// Lifetime compile/replay/fallback counters (telemetry-free mirror
    /// of the `plan.*` obs counters).
    pub fn plan_stats(&self) -> PlanStats {
        self.plan_stats
    }

    /// The compiled plan for `key`, if one exists.
    pub fn plan(&self, key: PlanKey) -> Option<&StepPlan> {
        self.plans[key.idx()].as_ref().map(|e| &e.plan)
    }

    /// Run one record-or-replay cycle under `key`.  Subsumes the
    /// per-cycle [`Tape::reset`]: the previous cycle's nodes are drained
    /// on entry (parking their buffers into the previous key's plan
    /// slots when one exists), the closure records the cycle, and on
    /// exit the plan for `key` is compiled (first cycle), validated
    /// (replay) or dropped-and-recompiled (fallback).  Cycles must not
    /// nest — a `plan_step` closure must not itself call `plan_step`.
    pub fn plan_step<R>(
        &mut self,
        key: PlanKey,
        f: impl FnOnce(&mut Tape) -> R,
    ) -> R {
        self.plan_begin(key);
        let out = f(self);
        self.plan_end(key);
        out
    }

    fn plan_begin(&mut self, key: PlanKey) {
        self.check_cancel();
        if !self.plan_enabled {
            self.reset();
            return;
        }
        self.drain_cycle();
        if let Some(entry) = self.plans[key.idx()].as_mut() {
            let mut slots = std::mem::take(&mut entry.slots);
            let lens = entry.plan.take_lens_arc();
            // First replay after a compile has no parked buffers yet;
            // missing slots simply serve from the free list.
            slots.resize(lens.len(), None);
            self.arena.arm(slots, lens);
            self.replaying = true;
            self.obs.phase_begin(Phase::PlanReplay);
        }
    }

    /// Drain the previous cycle's nodes.  With a plan for the previous
    /// key, uniquely-owned buffers of take-backed nodes park
    /// positionally into that plan's slots; everything else recycles
    /// onto the free list exactly like [`Tape::reset`].  The walk runs
    /// in reverse node order so `Reshape` aliases release their clones
    /// before the owning node is inspected for uniqueness.
    fn drain_cycle(&mut self) {
        let Tape {
            nodes,
            arena,
            bytes,
            kv_bytes,
            kv_marks,
            plans,
            last_cycle_key,
            ..
        } = self;
        *bytes = 0;
        *kv_bytes = 0;
        kv_marks.clear();
        let prev = *last_cycle_key;
        let Some(entry) = prev.and_then(|k| plans[k.idx()].as_mut()) else {
            for node in nodes.drain(..) {
                arena.recycle(node.value);
            }
            return;
        };
        let n_takes = entry.plan.take_count();
        let mut slots = std::mem::take(&mut entry.slots);
        slots.clear();
        slots.resize(n_takes, None);
        let mut pos = nodes.iter().filter(|n| takes_buffer(&n.op)).count();
        for node in nodes.drain(..).rev() {
            if takes_buffer(&node.op) {
                pos -= 1;
                let arc = node.value.into_data().into_arc();
                if Arc::strong_count(&arc) != 1 {
                    continue; // escaped to a caller: stays alive there
                }
                if pos < n_takes {
                    arena.note_parked(arc.len());
                    slots[pos] = Some(arc);
                } else {
                    arena.park(arc);
                }
            } else {
                arena.recycle(node.value);
            }
        }
        entry.slots = slots;
    }

    fn plan_end(&mut self, key: PlanKey) {
        if !self.plan_enabled {
            return;
        }
        self.last_cycle_key = Some(key);
        if self.replaying {
            self.replaying = false;
            self.obs.phase_end(Phase::PlanReplay);
            let (mut slots, takes, diverged) = self.arena.disarm();
            let valid = {
                let entry =
                    self.plans[key.idx()].as_ref().expect("armed without a plan");
                !diverged
                    && takes >= entry.plan.take_count()
                    && entry.plan.matches(
                        self.nodes
                            .iter()
                            .map(|n| (&n.op, n.value.shape.as_slice())),
                    )
            };
            if valid {
                self.plan_stats.replays += 1;
                if self.obs.enabled() {
                    self.obs.count(Counter::PlanReplays, 1);
                }
                slots.clear();
                self.plans[key.idx()].as_mut().unwrap().slots = slots;
            } else {
                // Topology changed under the plan.  The cycle itself
                // completed on the dynamic path (values are correct);
                // drop the stale plan, return its parked buffers to the
                // free list, and recompile from the cycle just recorded.
                self.plan_stats.fallbacks += 1;
                if self.obs.enabled() {
                    self.obs.count(Counter::PlanFallbacks, 1);
                }
                for arc in slots.into_iter().flatten() {
                    self.arena.park(arc);
                }
                self.plans[key.idx()] = None;
                self.compile_plan(key);
            }
        } else if self.plans[key.idx()].is_none() {
            self.compile_plan(key);
        }
    }

    fn compile_plan(&mut self, key: PlanKey) {
        let plan = StepPlan::compile(
            self.nodes.iter().map(|n| (&n.op, n.value.shape.as_slice())),
        );
        self.plan_stats.compiles += 1;
        if self.obs.enabled() {
            self.obs.count(Counter::PlanCompiles, 1);
        }
        self.plans[key.idx()] = Some(PlanEntry { plan, slots: Vec::new() });
    }

    fn push(&mut self, op: Op, value: Tensor) -> NodeId {
        if self.guard_enabled && value.data.iter().any(|v| !v.is_finite()) {
            std::panic::panic_any(NonFiniteSignal {
                node: self.nodes.len(),
                phase: self
                    .obs
                    .current_phase()
                    .map(Phase::name)
                    .unwrap_or("forward"),
            });
        }
        let bytes = value.bytes();
        self.bytes += bytes;
        if self.obs.enabled() {
            self.obs.count(Counter::TapeNodes, 1);
            self.obs.count(Counter::TapeBytes, bytes as u64);
            self.obs.gauge_max(Gauge::TapePeakBytes, self.bytes as u64);
        }
        self.nodes.push(Node { op, value });
        self.nodes.len() - 1
    }

    /// Push a node whose value aliases another buffer — it contributes
    /// 0 bytes to [`TapeStats::bytes`] (the storage is already counted
    /// at its owner).
    fn push_alias(&mut self, op: Op, value: Tensor) -> NodeId {
        if self.obs.enabled() {
            self.obs.count(Counter::TapeNodes, 1);
        }
        self.nodes.push(Node { op, value });
        self.nodes.len() - 1
    }

    // ---- builders ------------------------------------------------------
    //
    // Every value-producing builder goes through `unary_map` /
    // `binary_zip` / an explicit `arena_tensor` call, so node buffers
    // always come from the arena — a builder that bypassed it would
    // silently regress the allocator win.

    /// Differentiable input.  The tensor's buffer is shared, not copied:
    /// a caller handing in a clone of a checkpoint pays O(1).
    pub fn leaf(&mut self, value: Tensor) -> NodeId {
        self.push(Op::Leaf, value)
    }

    /// Non-differentiable input (same zero-copy sharing as [`Tape::leaf`]).
    pub fn constant(&mut self, value: Tensor) -> NodeId {
        self.push(Op::Const, value)
    }

    /// Elementwise unary node: `f` over `a`'s value, written into an
    /// arena buffer.
    fn unary_map(
        &mut self,
        a: NodeId,
        op: Op,
        f: impl Fn(f64) -> f64 + Sync,
    ) -> NodeId {
        self.obs.count(Counter::KernelMapCalls, 1);
        let value = {
            let Tape { nodes, arena, pool, .. } = self;
            let va = &nodes[a].value;
            arena_tensor(arena, va.shape.clone(), |o| {
                va.map_into_pooled(pool, &f, o)
            })
        };
        self.push(op, value)
    }

    /// Elementwise binary node: `f` over the (identically shaped) values
    /// of `a` and `b`, written into an arena buffer.
    fn binary_zip(
        &mut self,
        a: NodeId,
        b: NodeId,
        op: Op,
        f: impl Fn(f64, f64) -> f64 + Sync,
    ) -> NodeId {
        self.obs.count(Counter::KernelZipCalls, 1);
        let value = {
            let Tape { nodes, arena, pool, .. } = self;
            let (va, vb) = (&nodes[a].value, &nodes[b].value);
            arena_tensor(arena, va.shape.clone(), |o| {
                va.zip_into_pooled(pool, vb, &f, o)
            })
        };
        self.push(op, value)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_zip(a, b, Op::Add(a, b), |x, y| x + y)
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_zip(a, b, Op::Sub(a, b), |x, y| x - y)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_zip(a, b, Op::Mul(a, b), |x, y| x * y)
    }

    pub fn div(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary_zip(a, b, Op::Div(a, b), |x, y| x / y)
    }

    pub fn scale(&mut self, a: NodeId, c: f64) -> NodeId {
        self.unary_map(a, Op::Scale(a, c), |x| x * c)
    }

    pub fn offset(&mut self, a: NodeId, c: f64) -> NodeId {
        self.unary_map(a, Op::Offset(a, c), |x| x + c)
    }

    pub fn matmul(&mut self, a: NodeId, b: NodeId, ta: bool, tb: bool) -> NodeId {
        self.obs.count(Counter::KernelGemmCalls, 1);
        let value = {
            let Tape { nodes, arena, .. } = self;
            let (va, vb) = (&nodes[a].value, &nodes[b].value);
            let (m, n) = va.matmul_dims(vb, ta, tb);
            arena_tensor(arena, vec![m, n], |o| {
                va.matmul_into(vb, ta, tb, o);
            })
        };
        self.push(Op::Matmul { a, b, ta, tb }, value)
    }

    /// Batched rank-3 matmul `[g, m, k] × [g, k, n] → [g, m, n]` (with
    /// per-operand transposes of the trailing two dims).  `g = 1` is
    /// bit-for-bit the rank-2 [`Tape::matmul`].
    pub fn batch_matmul(
        &mut self,
        a: NodeId,
        b: NodeId,
        ta: bool,
        tb: bool,
    ) -> NodeId {
        self.obs.count(Counter::KernelGemmCalls, 1);
        let value = {
            let Tape { nodes, arena, pool, .. } = self;
            let (va, vb) = (&nodes[a].value, &nodes[b].value);
            let (g, m, n) = va.bmm_dims(vb, ta, tb);
            arena_tensor(arena, vec![g, m, n], |o| {
                va.bmm_into_pooled(pool, vb, ta, tb, o);
            })
        };
        self.push(Op::BatchMatmul { a, b, ta, tb }, value)
    }

    /// Column-wise concatenation of same-row-count matrices — the
    /// head-stacking op (`[m, d_h]` per head → `[m, d_model]`).
    pub fn concat_cols(&mut self, parts: &[NodeId]) -> NodeId {
        assert!(!parts.is_empty(), "concat_cols of nothing");
        let value = {
            let Tape { nodes, arena, .. } = self;
            let m = nodes[parts[0]].value.dims2().0;
            let tensors: Vec<&Tensor> =
                parts.iter().map(|&p| &nodes[p].value).collect();
            let widths: Vec<usize> =
                tensors.iter().map(|t| t.dims2().1).collect();
            let n: usize = widths.iter().sum();
            let opts: Vec<Option<&Tensor>> =
                tensors.iter().map(|t| Some(*t)).collect();
            arena_tensor(arena, vec![m, n], |o| {
                t_concat_cols_into(&opts, &widths, m, o)
            })
        };
        self.push(Op::ConcatCols(parts.to_vec()), value)
    }

    /// Columns `[offset, offset + width)` of an `[m, n]` input — the
    /// head-splitting op.
    pub fn split_cols(
        &mut self,
        a: NodeId,
        offset: usize,
        width: usize,
    ) -> NodeId {
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            let m = va.dims2().0;
            arena_tensor(arena, vec![m, width], |o| {
                t_split_cols_into(va, offset, width, o)
            })
        };
        self.push(Op::SplitCols(a, offset, width), value)
    }

    pub fn relu(&mut self, a: NodeId) -> NodeId {
        self.unary_map(a, Op::Relu(a), |x| x.max(0.0))
    }

    pub fn step(&mut self, a: NodeId) -> NodeId {
        self.unary_map(a, Op::Step(a), |x| if x > 0.0 { 1.0 } else { 0.0 })
    }

    pub fn tanh(&mut self, a: NodeId) -> NodeId {
        self.unary_map(a, Op::Tanh(a), f64::tanh)
    }

    pub fn exp(&mut self, a: NodeId) -> NodeId {
        self.unary_map(a, Op::Exp(a), f64::exp)
    }

    pub fn sqrt(&mut self, a: NodeId) -> NodeId {
        self.unary_map(a, Op::Sqrt(a), f64::sqrt)
    }

    pub fn sum(&mut self, a: NodeId) -> NodeId {
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            arena_tensor(arena, vec![], |o| t_sum_into(va, o))
        };
        self.push(Op::Sum(a), value)
    }

    /// Scalar → any shape.
    pub fn broadcast(&mut self, a: NodeId, shape: &[usize]) -> NodeId {
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            assert!(
                va.shape.is_empty(),
                "broadcast wants a rank-0 scalar, got {:?}",
                va.shape
            );
            let x = va.item();
            let len = shape.iter().product::<usize>();
            arena_tensor(arena, shape.to_vec(), |o| {
                o.clear();
                o.resize(len, x);
            })
        };
        self.push(Op::Broadcast(a, shape.to_vec()), value)
    }

    pub fn row_sum(&mut self, a: NodeId) -> NodeId {
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            let m = va.dims2().0;
            arena_tensor(arena, vec![m], |o| t_row_sum_into(va, o))
        };
        self.push(Op::RowSum(a), value)
    }

    pub fn row_broadcast(&mut self, a: NodeId, n: usize) -> NodeId {
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            assert_eq!(va.shape.len(), 1, "row_broadcast wants a vector");
            let m = va.shape[0];
            arena_tensor(arena, vec![m, n], |o| {
                t_row_broadcast_into(va, n, o)
            })
        };
        self.push(Op::RowBroadcast(a, n), value)
    }

    pub fn col_sum(&mut self, a: NodeId) -> NodeId {
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            let n = va.dims2().1;
            arena_tensor(arena, vec![n], |o| t_col_sum_into(va, o))
        };
        self.push(Op::ColSum(a), value)
    }

    pub fn col_broadcast(&mut self, a: NodeId, m: usize) -> NodeId {
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            assert_eq!(va.shape.len(), 1, "col_broadcast wants a vector");
            let n = va.shape[0];
            arena_tensor(arena, vec![m, n], |o| {
                t_col_broadcast_into(va, m, o)
            })
        };
        self.push(Op::ColBroadcast(a, m), value)
    }

    pub fn softmax_rows(&mut self, a: NodeId) -> NodeId {
        self.obs.count(Counter::KernelRowsCalls, 1);
        let value = {
            let Tape { nodes, arena, pool, .. } = self;
            let va = &nodes[a].value;
            let (m, n) = va.dims2();
            arena_tensor(arena, vec![m, n], |o| {
                t_softmax_rows_into(pool, va, o)
            })
        };
        self.push(Op::SoftmaxRows(a), value)
    }

    pub fn logsumexp_rows(&mut self, a: NodeId) -> NodeId {
        self.obs.count(Counter::KernelRowsCalls, 1);
        let value = {
            let Tape { nodes, arena, pool, .. } = self;
            let va = &nodes[a].value;
            let m = va.dims2().0;
            arena_tensor(arena, vec![m], |o| {
                t_logsumexp_rows_into(pool, va, o)
            })
        };
        self.push(Op::LogSumExpRows(a), value)
    }

    pub fn gather_cols(
        &mut self,
        a: NodeId,
        idx: impl Into<Arc<[usize]>>,
    ) -> NodeId {
        let idx: Arc<[usize]> = idx.into();
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            let m = va.dims2().0;
            arena_tensor(arena, vec![m], |o| {
                t_gather_cols_into(va, &idx, o)
            })
        };
        self.push(Op::GatherCols(a, idx), value)
    }

    pub fn scatter_cols(
        &mut self,
        a: NodeId,
        idx: impl Into<Arc<[usize]>>,
        n: usize,
    ) -> NodeId {
        let idx: Arc<[usize]> = idx.into();
        let value = {
            let Tape { nodes, arena, .. } = self;
            let va = &nodes[a].value;
            assert_eq!(va.shape.len(), 1, "scatter wants a vector");
            let m = va.shape[0];
            arena_tensor(arena, vec![m, n], |o| {
                t_scatter_cols_into(va, &idx, n, o)
            })
        };
        self.push(Op::ScatterCols(a, idx, n), value)
    }

    /// Zero-copy reshape: the node's value aliases the input buffer and
    /// contributes 0 bytes to [`TapeStats::bytes`].
    pub fn reshape(&mut self, a: NodeId, shape: Vec<usize>) -> NodeId {
        let v = &self.nodes[a].value;
        assert_eq!(
            v.elements(),
            shape.iter().product::<usize>(),
            "reshape {:?} → {shape:?}",
            v.shape
        );
        let value = v.alias(shape.clone());
        self.push_alias(Op::Reshape(a, shape), value)
    }

    /// Mean of all elements (composite: `sum` then `scale`).
    pub fn mean(&mut self, a: NodeId) -> NodeId {
        let n = self.value(a).elements();
        let s = self.sum(a);
        self.scale(s, 1.0 / n as f64)
    }

    /// Row-wise layer normalisation `(x − μ) / √(σ² + ε)` of an `[m,n]`
    /// input (composite over row reductions, `sqrt` and `div`).
    pub fn layernorm_rows(&mut self, a: NodeId, eps: f64) -> NodeId {
        let n = self.value(a).dims2().1;
        let mu_sum = self.row_sum(a);
        let mu = self.scale(mu_sum, 1.0 / n as f64);
        let mu_b = self.row_broadcast(mu, n);
        let centered = self.sub(a, mu_b);
        let sq = self.mul(centered, centered);
        let var_sum = self.row_sum(sq);
        let var = self.scale(var_sum, 1.0 / n as f64);
        let var_eps = self.offset(var, eps);
        let std = self.sqrt(var_eps);
        let std_b = self.row_broadcast(std, n);
        self.div(centered, std_b)
    }

    // ---- reverse mode ---------------------------------------------------

    fn acc(&mut self, adj: &mut [Option<NodeId>], id: NodeId, contrib: NodeId) {
        adj[id] = Some(match adj[id] {
            Some(prev) => self.add(prev, contrib),
            None => contrib,
        });
    }

    /// Gradient of scalar node `y` with respect to `wrt`, appended to the
    /// tape as new nodes (graph-mode reverse).  Nodes unreachable from `y`
    /// get zero gradients.  Because the adjoint computation is itself made
    /// of tape ops, a later `grad` (or [`Tape::jvp`]) can differentiate
    /// straight through it.
    ///
    /// The sweep borrows each node's op via a take-and-restore swap: no
    /// `Op::clone()`, and gather/scatter adjoints share the original
    /// index `Arc` instead of copying the index vector.
    pub fn grad(&mut self, y: NodeId, wrt: &[NodeId]) -> Vec<NodeId> {
        assert_eq!(self.value(y).elements(), 1, "grad of a non-scalar");
        let mut adj: Vec<Option<NodeId>> = vec![None; y + 1];
        let seed_shape = self.shape(y);
        let seed = self.constant(Tensor::full(&seed_shape, 1.0));
        adj[y] = Some(seed);
        for i in (0..=y).rev() {
            let Some(g) = adj[i] else { continue };
            // Borrow the op: swap it out for the duration of the match
            // (the arms only append new nodes) and put it back after.
            let op = std::mem::replace(&mut self.nodes[i].op, Op::Leaf);
            match &op {
                Op::Leaf | Op::Const | Op::Step(_) => {}
                Op::Add(a, b) => {
                    self.acc(&mut adj, *a, g);
                    self.acc(&mut adj, *b, g);
                }
                Op::Sub(a, b) => {
                    self.acc(&mut adj, *a, g);
                    let neg = self.scale(g, -1.0);
                    self.acc(&mut adj, *b, neg);
                }
                Op::Mul(a, b) => {
                    let ca = self.mul(g, *b);
                    let cb = self.mul(g, *a);
                    self.acc(&mut adj, *a, ca);
                    self.acc(&mut adj, *b, cb);
                }
                Op::Div(a, b) => {
                    // y = a/b: da = g/b, db = −g·y/b (reusing this node
                    // as y, the same trick as tanh/exp).
                    let da = self.div(g, *b);
                    self.acc(&mut adj, *a, da);
                    let gy = self.mul(g, i);
                    let gyb = self.div(gy, *b);
                    let db = self.scale(gyb, -1.0);
                    self.acc(&mut adj, *b, db);
                }
                Op::Scale(a, c) => {
                    let s = self.scale(g, *c);
                    self.acc(&mut adj, *a, s);
                }
                Op::Offset(a, _) => self.acc(&mut adj, *a, g),
                Op::Matmul { a, b, ta, tb } => {
                    let (a, b, ta, tb) = (*a, *b, *ta, *tb);
                    let da = if !ta {
                        self.matmul(g, b, false, !tb)
                    } else {
                        self.matmul(b, g, tb, true)
                    };
                    let db = if !tb {
                        self.matmul(a, g, !ta, false)
                    } else {
                        self.matmul(g, a, true, ta)
                    };
                    self.acc(&mut adj, a, da);
                    self.acc(&mut adj, b, db);
                }
                Op::BatchMatmul { a, b, ta, tb } => {
                    // Same adjoints as Matmul, per group.
                    let (a, b, ta, tb) = (*a, *b, *ta, *tb);
                    let da = if !ta {
                        self.batch_matmul(g, b, false, !tb)
                    } else {
                        self.batch_matmul(b, g, tb, true)
                    };
                    let db = if !tb {
                        self.batch_matmul(a, g, !ta, false)
                    } else {
                        self.batch_matmul(g, a, true, ta)
                    };
                    self.acc(&mut adj, a, da);
                    self.acc(&mut adj, b, db);
                }
                Op::ConcatCols(parts) => {
                    // Each input's adjoint is its column slice of g.
                    let mut offset = 0usize;
                    for &p in parts.iter() {
                        let w = self.shape(p)[1];
                        let c = self.split_cols(g, offset, w);
                        self.acc(&mut adj, p, c);
                        offset += w;
                    }
                }
                Op::SplitCols(a, offset, width) => {
                    // Zero-pad g back to the input width: concat
                    // [0 | g | 0] with constant zero blocks.
                    let (a, offset, width) = (*a, *offset, *width);
                    let sh = self.shape(a);
                    let (m, n) = (sh[0], sh[1]);
                    let mut parts: Vec<NodeId> = Vec::with_capacity(3);
                    if offset > 0 {
                        parts.push(
                            self.constant(Tensor::zeros(&[m, offset])),
                        );
                    }
                    parts.push(g);
                    if offset + width < n {
                        parts.push(self.constant(Tensor::zeros(&[
                            m,
                            n - offset - width,
                        ])));
                    }
                    let c = if parts.len() == 1 {
                        g
                    } else {
                        self.concat_cols(&parts)
                    };
                    self.acc(&mut adj, a, c);
                }
                Op::Relu(a) => {
                    let mask = self.step(*a);
                    let c = self.mul(g, mask);
                    self.acc(&mut adj, *a, c);
                }
                Op::Tanh(a) => {
                    // d tanh = (1 − y²): g − g·y², reusing this node as y.
                    let y2 = self.mul(i, i);
                    let gy2 = self.mul(g, y2);
                    let c = self.sub(g, gy2);
                    self.acc(&mut adj, *a, c);
                }
                Op::Exp(a) => {
                    let c = self.mul(g, i);
                    self.acc(&mut adj, *a, c);
                }
                Op::Sqrt(a) => {
                    // y = √a: da = g/(2y), reusing this node as y.
                    let gy = self.div(g, i);
                    let c = self.scale(gy, 0.5);
                    self.acc(&mut adj, *a, c);
                }
                Op::Sum(a) => {
                    let sh = self.shape(*a);
                    let c = self.broadcast(g, &sh);
                    self.acc(&mut adj, *a, c);
                }
                Op::Broadcast(a, _) => {
                    let c = self.sum(g);
                    self.acc(&mut adj, *a, c);
                }
                Op::RowSum(a) => {
                    let n = self.shape(*a)[1];
                    let c = self.row_broadcast(g, n);
                    self.acc(&mut adj, *a, c);
                }
                Op::RowBroadcast(a, _) => {
                    let c = self.row_sum(g);
                    self.acc(&mut adj, *a, c);
                }
                Op::ColSum(a) => {
                    let m = self.shape(*a)[0];
                    let c = self.col_broadcast(g, m);
                    self.acc(&mut adj, *a, c);
                }
                Op::ColBroadcast(a, _) => {
                    let c = self.col_sum(g);
                    self.acc(&mut adj, *a, c);
                }
                Op::SoftmaxRows(a) => {
                    // dz = s ⊙ (g − rowbcast(rowsum(g ⊙ s))), s = this node.
                    let n = self.shape(*a)[1];
                    let gs = self.mul(g, i);
                    let rs = self.row_sum(gs);
                    let rb = self.row_broadcast(rs, n);
                    let diff = self.sub(g, rb);
                    let c = self.mul(i, diff);
                    self.acc(&mut adj, *a, c);
                }
                Op::LogSumExpRows(a) => {
                    let n = self.shape(*a)[1];
                    let s = self.softmax_rows(*a);
                    let rb = self.row_broadcast(g, n);
                    let c = self.mul(rb, s);
                    self.acc(&mut adj, *a, c);
                }
                Op::GatherCols(a, idx) => {
                    let n = self.shape(*a)[1];
                    let c = self.scatter_cols(g, idx.clone(), n);
                    self.acc(&mut adj, *a, c);
                }
                Op::ScatterCols(a, idx, _) => {
                    let c = self.gather_cols(g, idx.clone());
                    self.acc(&mut adj, *a, c);
                }
                Op::Reshape(a, _) => {
                    let sh = self.shape(*a);
                    let c = self.reshape(g, sh);
                    self.acc(&mut adj, *a, c);
                }
            }
            self.nodes[i].op = op;
        }
        let mut out = Vec::with_capacity(wrt.len());
        for &w in wrt {
            match adj.get(w).copied().flatten() {
                Some(id) => out.push(id),
                None => {
                    let sh = self.shape(w);
                    let z = self.constant(Tensor::zeros(&sh));
                    out.push(z);
                }
            }
        }
        out
    }

    // ---- forward mode ---------------------------------------------------

    /// Forward tangent sweep over the tape (dual-number overlay).
    ///
    /// `seeds` assigns tangents to leaf/const nodes; every other tangent is
    /// derived by the op linearisations.  Returns the tangents of
    /// `targets` (zeros where no tangent flows) and the total bytes of
    /// tangent buffers *materialised* — aliased pass-through tangents
    /// (identity-like ops, seed handles) and zero tangents cost nothing.
    /// Nodes after the last target can never influence it, so the sweep
    /// stops there: subgraphs recorded later (e.g. the optimiser update
    /// and its adjoint in the MixFlow backward step) cost nothing.
    ///
    /// Every materialised tangent is written into a buffer drawn from
    /// the tape's arena (two-operand rules fuse their intermediate
    /// products into the one output pass, so no hidden temporaries
    /// allocate either), and when the sweep finishes all non-returned
    /// tangent buffers are recycled back — a second sweep over the same
    /// shapes, or the next step-tape, runs without touching the
    /// allocator.
    pub fn jvp(
        &mut self,
        seeds: &[(NodeId, Tensor)],
        targets: &[NodeId],
    ) -> (Vec<Tensor>, usize) {
        let Tape { nodes, arena, kv_marks, pool, obs, .. } = self;
        for (id, t) in seeds {
            assert_eq!(
                t.shape, nodes[*id].value.shape,
                "seed shape mismatch at node {id}"
            );
        }
        let stop = match targets.iter().max() {
            Some(&last) => last + 1,
            None => 0,
        };
        let mut tan: Vec<Option<Tensor>> = vec![None; nodes.len()];
        let mut bytes = 0usize;
        for i in 0..stop {
            let out: Option<Tensor> = match &nodes[i].op {
                Op::Leaf | Op::Const => seeds
                    .iter()
                    .find(|(id, _)| *id == i)
                    .map(|(_, t)| t.clone()),
                Op::Step(_) => None,
                Op::Add(a, b) => match (&tan[*a], &tan[*b]) {
                    (Some(x), Some(y)) => {
                        Some(arena_tensor(arena, x.shape.clone(), |o| {
                            x.zip_into_pooled(pool, y, |p, q| p + q, o)
                        }))
                    }
                    (Some(x), None) => Some(x.clone()),
                    (None, Some(y)) => Some(y.clone()),
                    (None, None) => None,
                },
                Op::Sub(a, b) => match (&tan[*a], &tan[*b]) {
                    (Some(x), Some(y)) => {
                        Some(arena_tensor(arena, x.shape.clone(), |o| {
                            x.zip_into_pooled(pool, y, |p, q| p - q, o)
                        }))
                    }
                    (Some(x), None) => Some(x.clone()),
                    (None, Some(y)) => {
                        Some(arena_tensor(arena, y.shape.clone(), |o| {
                            y.map_into_pooled(pool, |q| -q, o)
                        }))
                    }
                    (None, None) => None,
                },
                Op::Mul(a, b) => {
                    let va = &nodes[*a].value;
                    let vb = &nodes[*b].value;
                    match (&tan[*a], &tan[*b]) {
                        (Some(x), Some(y)) => {
                            // ẋ·b + a·ẏ fused into one output pass.
                            let len = va.data.len();
                            Some(arena_tensor(arena, va.shape.clone(), |o| {
                                o.clear();
                                o.resize(len, 0.0);
                                crate::kernels::elementwise::fill_indexed(
                                    pool,
                                    len,
                                    |j| {
                                        x.data[j] * vb.data[j]
                                            + va.data[j] * y.data[j]
                                    },
                                    o,
                                );
                            }))
                        }
                        (Some(x), None) => {
                            Some(arena_tensor(arena, va.shape.clone(), |o| {
                                x.zip_into_pooled(pool, vb, |p, q| p * q, o)
                            }))
                        }
                        (None, Some(y)) => {
                            Some(arena_tensor(arena, va.shape.clone(), |o| {
                                va.zip_into_pooled(pool, y, |p, q| p * q, o)
                            }))
                        }
                        (None, None) => None,
                    }
                }
                Op::Div(a, b) => {
                    // ẏ = (ȧ − y·ḃ)/b, using this node's value as y.
                    let vy = &nodes[i].value;
                    let vb = &nodes[*b].value;
                    match (&tan[*a], &tan[*b]) {
                        (Some(x), Some(bt)) => {
                            let len = vy.data.len();
                            Some(arena_tensor(arena, vy.shape.clone(), |o| {
                                o.clear();
                                o.resize(len, 0.0);
                                crate::kernels::elementwise::fill_indexed(
                                    pool,
                                    len,
                                    |j| {
                                        (x.data[j] - vy.data[j] * bt.data[j])
                                            / vb.data[j]
                                    },
                                    o,
                                );
                            }))
                        }
                        (Some(x), None) => {
                            Some(arena_tensor(arena, vy.shape.clone(), |o| {
                                x.zip_into_pooled(pool, vb, |p, q| p / q, o)
                            }))
                        }
                        (None, Some(bt)) => {
                            let len = vy.data.len();
                            Some(arena_tensor(arena, vy.shape.clone(), |o| {
                                o.clear();
                                o.resize(len, 0.0);
                                crate::kernels::elementwise::fill_indexed(
                                    pool,
                                    len,
                                    |j| {
                                        -(vy.data[j] * bt.data[j])
                                            / vb.data[j]
                                    },
                                    o,
                                );
                            }))
                        }
                        (None, None) => None,
                    }
                }
                Op::Scale(a, c) => {
                    let c = *c;
                    tan[*a].as_ref().map(|t| {
                        arena_tensor(arena, t.shape.clone(), |o| {
                            t.map_into_pooled(pool, |x| x * c, o)
                        })
                    })
                }
                Op::Offset(a, _) => tan[*a].clone(),
                Op::Matmul { a, b, ta, tb } => {
                    let va = &nodes[*a].value;
                    let vb = &nodes[*b].value;
                    let (ta, tb) = (*ta, *tb);
                    match (&tan[*a], &tan[*b]) {
                        (Some(x), Some(y)) => {
                            // ẋ·B into one arena buffer, A·ẏ into a
                            // second, summed in place (the left buffer is
                            // uniquely owned), second buffer recycled.
                            obs.count(Counter::KernelGemmCalls, 2);
                            let (m, n) = x.matmul_dims(vb, ta, tb);
                            let mut left =
                                arena_tensor(arena, vec![m, n], |o| {
                                    x.matmul_into(vb, ta, tb, o);
                                });
                            let right =
                                arena_tensor(arena, vec![m, n], |o| {
                                    va.matmul_into(y, ta, tb, o);
                                });
                            for (d, s) in
                                left.data.iter_mut().zip(right.data.iter())
                            {
                                *d += s;
                            }
                            arena.recycle(right);
                            Some(left)
                        }
                        (Some(x), None) => {
                            obs.count(Counter::KernelGemmCalls, 1);
                            let (m, n) = x.matmul_dims(vb, ta, tb);
                            Some(arena_tensor(arena, vec![m, n], |o| {
                                x.matmul_into(vb, ta, tb, o);
                            }))
                        }
                        (None, Some(y)) => {
                            obs.count(Counter::KernelGemmCalls, 1);
                            let (m, n) = va.matmul_dims(y, ta, tb);
                            Some(arena_tensor(arena, vec![m, n], |o| {
                                va.matmul_into(y, ta, tb, o);
                            }))
                        }
                        (None, None) => None,
                    }
                }
                Op::BatchMatmul { a, b, ta, tb } => {
                    // Same dual rule as Matmul, per group: ẋ·B + A·ẏ,
                    // left buffer summed in place, right recycled.
                    let va = &nodes[*a].value;
                    let vb = &nodes[*b].value;
                    let (ta, tb) = (*ta, *tb);
                    match (&tan[*a], &tan[*b]) {
                        (Some(x), Some(y)) => {
                            obs.count(Counter::KernelGemmCalls, 2);
                            let (g, m, n) = x.bmm_dims(vb, ta, tb);
                            let mut left =
                                arena_tensor(arena, vec![g, m, n], |o| {
                                    x.bmm_into_pooled(pool, vb, ta, tb, o);
                                });
                            let right =
                                arena_tensor(arena, vec![g, m, n], |o| {
                                    va.bmm_into_pooled(pool, y, ta, tb, o);
                                });
                            for (d, s) in
                                left.data.iter_mut().zip(right.data.iter())
                            {
                                *d += s;
                            }
                            arena.recycle(right);
                            Some(left)
                        }
                        (Some(x), None) => {
                            obs.count(Counter::KernelGemmCalls, 1);
                            let (g, m, n) = x.bmm_dims(vb, ta, tb);
                            Some(arena_tensor(arena, vec![g, m, n], |o| {
                                x.bmm_into_pooled(pool, vb, ta, tb, o);
                            }))
                        }
                        (None, Some(y)) => {
                            obs.count(Counter::KernelGemmCalls, 1);
                            let (g, m, n) = va.bmm_dims(y, ta, tb);
                            Some(arena_tensor(arena, vec![g, m, n], |o| {
                                va.bmm_into_pooled(pool, y, ta, tb, o);
                            }))
                        }
                        (None, None) => None,
                    }
                }
                Op::ConcatCols(parts) => {
                    if parts.iter().all(|p| tan[*p].is_none()) {
                        None
                    } else {
                        // Concat the part tangents; parts with no
                        // tangent contribute zero columns.
                        let m = nodes[i].value.dims2().0;
                        let widths: Vec<usize> = parts
                            .iter()
                            .map(|&p| nodes[p].value.dims2().1)
                            .collect();
                        let n: usize = widths.iter().sum();
                        let opts: Vec<Option<&Tensor>> =
                            parts.iter().map(|&p| tan[p].as_ref()).collect();
                        Some(arena_tensor(arena, vec![m, n], |o| {
                            t_concat_cols_into(&opts, &widths, m, o)
                        }))
                    }
                }
                Op::SplitCols(a, offset, width) => {
                    tan[*a].as_ref().map(|t| {
                        let m = t.dims2().0;
                        arena_tensor(arena, vec![m, *width], |o| {
                            t_split_cols_into(t, *offset, *width, o)
                        })
                    })
                }
                Op::Relu(a) => {
                    let va = &nodes[*a].value;
                    tan[*a].as_ref().map(|t| {
                        arena_tensor(arena, t.shape.clone(), |o| {
                            t.zip_into_pooled(
                                pool,
                                va,
                                |p, x| if x > 0.0 { p } else { 0.0 },
                                o,
                            )
                        })
                    })
                }
                Op::Tanh(a) => {
                    let vy = &nodes[i].value;
                    tan[*a].as_ref().map(|t| {
                        arena_tensor(arena, t.shape.clone(), |o| {
                            t.zip_into_pooled(
                                pool,
                                vy,
                                |p, y| p * (1.0 - y * y),
                                o,
                            )
                        })
                    })
                }
                Op::Exp(a) => {
                    let vy = &nodes[i].value;
                    tan[*a].as_ref().map(|t| {
                        arena_tensor(arena, t.shape.clone(), |o| {
                            t.zip_into_pooled(pool, vy, |p, y| p * y, o)
                        })
                    })
                }
                Op::Sqrt(a) => {
                    let vy = &nodes[i].value;
                    tan[*a].as_ref().map(|t| {
                        arena_tensor(arena, t.shape.clone(), |o| {
                            t.zip_into_pooled(
                                pool,
                                vy,
                                |p, y| p / (2.0 * y),
                                o,
                            )
                        })
                    })
                }
                Op::Sum(a) => tan[*a].as_ref().map(|t| {
                    arena_tensor(arena, vec![], |o| t_sum_into(t, o))
                }),
                Op::Broadcast(a, shape) => tan[*a].as_ref().map(|t| {
                    let x = t.item();
                    let len = shape.iter().product::<usize>();
                    arena_tensor(arena, shape.clone(), |o| {
                        o.clear();
                        o.resize(len, x);
                    })
                }),
                Op::RowSum(a) => tan[*a].as_ref().map(|t| {
                    arena_tensor(arena, vec![t.dims2().0], |o| {
                        t_row_sum_into(t, o)
                    })
                }),
                Op::RowBroadcast(a, n) => tan[*a].as_ref().map(|t| {
                    arena_tensor(arena, vec![t.shape[0], *n], |o| {
                        t_row_broadcast_into(t, *n, o)
                    })
                }),
                Op::ColSum(a) => tan[*a].as_ref().map(|t| {
                    arena_tensor(arena, vec![t.dims2().1], |o| {
                        t_col_sum_into(t, o)
                    })
                }),
                Op::ColBroadcast(a, m) => tan[*a].as_ref().map(|t| {
                    arena_tensor(arena, vec![*m, t.shape[0]], |o| {
                        t_col_broadcast_into(t, *m, o)
                    })
                }),
                Op::SoftmaxRows(a) => {
                    let s = &nodes[i].value;
                    tan[*a].as_ref().map(|t| {
                        // ṡ_ij = s_ij (ż_ij − Σ_k s_ik ż_ik), per row in
                        // one pass with no softmax/row-sum temporaries;
                        // rows are independent, so the row kernel driver
                        // may fan them across the pool.
                        obs.count(Counter::KernelRowsCalls, 1);
                        arena_tensor(arena, s.shape.clone(), |o| {
                            let (m, n) = s.dims2();
                            o.clear();
                            o.resize(m * n, 0.0);
                            crate::kernels::rows::for_each_row(
                                pool,
                                m,
                                n,
                                n,
                                o,
                                |r, orow| {
                                    let srow =
                                        &s.data[r * n..(r + 1) * n];
                                    let trow =
                                        &t.data[r * n..(r + 1) * n];
                                    let dot: f64 = srow
                                        .iter()
                                        .zip(trow.iter())
                                        .map(|(p, q)| p * q)
                                        .sum();
                                    for (ov, (p, q)) in orow
                                        .iter_mut()
                                        .zip(srow.iter().zip(trow.iter()))
                                    {
                                        *ov = p * (q - dot);
                                    }
                                },
                            );
                        })
                    })
                }
                Op::LogSumExpRows(a) => {
                    let vz = &nodes[*a].value;
                    tan[*a].as_ref().map(|t| {
                        // rowsum(softmax(z) ⊙ ż) without materialising the
                        // softmax; each term is (e_j/denom)·ż_j summed
                        // left-to-right — the identical float-op order the
                        // softmax+rowsum composition used, so the fusion is
                        // bit-for-bit.  One output scalar per row, so rows
                        // chunk across the pool.
                        obs.count(Counter::KernelRowsCalls, 1);
                        arena_tensor(arena, vec![vz.dims2().0], |o| {
                            let (m, n) = vz.dims2();
                            o.clear();
                            o.resize(m, 0.0);
                            crate::kernels::rows::for_each_row(
                                pool,
                                m,
                                1,
                                n,
                                o,
                                |r, orow| {
                                    let zrow =
                                        &vz.data[r * n..(r + 1) * n];
                                    let trow =
                                        &t.data[r * n..(r + 1) * n];
                                    let mx = zrow
                                        .iter()
                                        .cloned()
                                        .fold(f64::NEG_INFINITY, f64::max);
                                    let denom: f64 = zrow
                                        .iter()
                                        .map(|&z| (z - mx).exp())
                                        .sum();
                                    let mut acc = 0.0;
                                    for j in 0..n {
                                        let e = (zrow[j] - mx).exp();
                                        acc += (e / denom) * trow[j];
                                    }
                                    orow[0] = acc;
                                },
                            );
                        })
                    })
                }
                Op::GatherCols(a, idx) => tan[*a].as_ref().map(|t| {
                    arena_tensor(arena, vec![t.dims2().0], |o| {
                        t_gather_cols_into(t, idx, o)
                    })
                }),
                Op::ScatterCols(a, idx, n) => tan[*a].as_ref().map(|t| {
                    arena_tensor(arena, vec![t.shape[0], *n], |o| {
                        t_scatter_cols_into(t, idx, *n, o)
                    })
                }),
                Op::Reshape(a, shape) => {
                    // Zero-copy, like the primal: alias the tangent.
                    tan[*a].as_ref().map(|t| t.alias(shape.clone()))
                }
            };
            if let Some(t) = out {
                // Aliased pass-throughs (Offset, one-sided Add/Sub,
                // Reshape, seed handles) share a counted buffer: only
                // freshly materialised tangents cost bytes.
                if t.data.is_unique() {
                    bytes += t.bytes();
                }
                tan[i] = Some(t);
            }
        }
        let out = targets
            .iter()
            .map(|&t| match &tan[t] {
                Some(x) => x.clone(),
                None => Tensor::zeros(&nodes[t].value.shape),
            })
            .collect();
        // KV ledger for the tangent overlay: tangents flowing through
        // nodes tagged by `mark_kv` on the primal sweep are the K/V
        // duals mixflow materialises per step.  Counted per sweep, not
        // accumulated — the backward step reads it after each `jvp`.
        let mut kv_tangent = 0usize;
        for &id in kv_marks.iter() {
            if let Some(t) = tan.get(id).and_then(Option::as_ref) {
                kv_tangent += t.bytes();
            }
        }
        // The returned targets were cloned above, so their buffers are
        // shared and survive; everything else goes back to the arena.
        for t in tan.into_iter().flatten() {
            arena.recycle(t);
        }
        self.jvp_kv_bytes = kv_tangent;
        if self.obs.enabled() {
            self.obs.count(Counter::KvTangentBytes, kv_tangent as u64);
        }
        (out, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_of_square_sum() {
        // f(x) = Σ x² → ∇f = 2x
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![3], vec![1.0, -2.0, 3.0]));
        let sq = tape.mul(x, x);
        let y = tape.sum(sq);
        let g = tape.grad(y, &[x]);
        assert_eq!(tape.value(g[0]).data, vec![2.0, -4.0, 6.0]);
    }

    #[test]
    fn grad_unreachable_is_zero() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::scalar(1.0));
        let z = tape.leaf(Tensor::new(vec![2], vec![5.0, 5.0]));
        let y = tape.mul(x, x);
        let g = tape.grad(y, &[z]);
        assert_eq!(tape.value(g[0]).data, vec![0.0, 0.0]);
    }

    #[test]
    fn grad_matmul_sum_is_row_col_counts() {
        // f = Σ (A·B) → dA = 1·Bᵀ, dB = Aᵀ·1
        let mut tape = Tape::new();
        let a = tape.leaf(Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]));
        let b = tape.leaf(Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]));
        let c = tape.matmul(a, b, false, false);
        let y = tape.sum(c);
        let g = tape.grad(y, &[a, b]);
        // dA[i,k] = Σ_j B[k,j]
        assert_eq!(tape.value(g[0]).data, vec![11., 15., 11., 15.]);
        // dB[k,j] = Σ_i A[i,k]
        assert_eq!(tape.value(g[1]).data, vec![4., 4., 6., 6.]);
    }

    #[test]
    fn jvp_matches_linearity() {
        // y = 3x + 2 → tangent 3v
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![2], vec![1.0, 2.0]));
        let s = tape.scale(x, 3.0);
        let y = tape.offset(s, 2.0);
        let (tans, bytes) =
            tape.jvp(&[(x, Tensor::new(vec![2], vec![1.0, -1.0]))], &[y]);
        assert_eq!(tans[0].data, vec![3.0, -3.0]);
        assert!(bytes > 0);
    }

    #[test]
    fn jvp_zero_tangents_not_materialised() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![4], vec![1.0; 4]));
        let c = tape.constant(Tensor::new(vec![4], vec![2.0; 4]));
        let _y = tape.mul(x, c);
        // No seeds → nothing materialised.
        let (tans, bytes) = tape.jvp(&[], &[_y]);
        assert_eq!(bytes, 0);
        assert_eq!(tans[0].data, vec![0.0; 4]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut tape = Tape::new();
        let z = tape.constant(Tensor::new(vec![2, 3], vec![1., 2., 3., -1., 0., 1.]));
        let s = tape.softmax_rows(z);
        let rows = t_row_sum(tape.value(s));
        for r in rows.data.iter() {
            assert!((r - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn div_sqrt_values_and_grads() {
        // f(x) = Σ 1/√x → ∇f = −½ x^{−3/2}
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![2], vec![4.0, 1.0]));
        let r = tape.sqrt(x);
        let one = tape.constant(Tensor::full(&[2], 1.0));
        let inv = tape.div(one, r);
        assert_eq!(tape.value(inv).data, vec![0.5, 1.0]);
        let y = tape.sum(inv);
        let g = tape.grad(y, &[x]);
        let want = [-0.5 * 4.0f64.powf(-1.5), -0.5];
        for (got, w) in tape.value(g[0]).data.iter().zip(want.iter()) {
            assert!((got - w).abs() < 1e-12, "{got} vs {w}");
        }
    }

    #[test]
    fn layernorm_rows_zero_mean_unit_var() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![2, 4], vec![
            1.0, 2.0, 3.0, 4.0, -1.0, 0.0, 5.0, 2.0,
        ]));
        let y = tape.layernorm_rows(x, 1e-8);
        let v = tape.value(y);
        let (m, n) = v.dims2();
        for i in 0..m {
            let row = &v.data[i * n..(i + 1) * n];
            let mu: f64 = row.iter().sum::<f64>() / n as f64;
            let var: f64 =
                row.iter().map(|a| (a - mu) * (a - mu)).sum::<f64>() / n as f64;
            assert!(mu.abs() < 1e-9, "row mean {mu}");
            assert!((var - 1.0).abs() < 1e-6, "row var {var}");
        }
    }

    #[test]
    fn bytes_accumulate() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::zeros(&[8]));
        let _ = tape.scale(x, 2.0);
        assert_eq!(tape.stats().bytes, 2 * 8 * 8);
        assert_eq!(tape.stats().nodes, 2);
    }

    #[test]
    fn leaf_is_zero_copy() {
        let t = Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        let mut tape = Tape::new();
        let l = tape.leaf(t.clone());
        assert!(
            tape.value(l).aliases(&t),
            "leaf must share the caller's buffer, not copy it"
        );
    }

    #[test]
    fn reshape_is_zero_copy_and_counts_zero_bytes() {
        // Regression: reshape used to clone the whole data buffer and
        // add it to TapeStats::bytes a second time.
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::zeros(&[6]));
        let before = tape.stats();
        let r = tape.reshape(x, vec![2, 3]);
        let after = tape.stats();
        assert_eq!(
            after.bytes, before.bytes,
            "aliased reshape must contribute 0 bytes"
        );
        assert_eq!(after.nodes, before.nodes + 1);
        assert!(tape.value(r).aliases(tape.value(x)));
        assert_eq!(tape.value(r).shape, vec![2, 3]);
        // The view still differentiates correctly through the alias.
        let sq = tape.mul(r, r);
        let y = tape.sum(sq);
        let g = tape.grad(y, &[x]);
        assert_eq!(tape.value(g[0]).data, vec![0.0; 6]);
    }

    #[test]
    fn reset_recycles_buffers_for_reuse() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::zeros(&[16]));
        let _ = tape.scale(x, 2.0);
        assert_eq!(tape.arena_stats().reuses, 0);
        tape.reset();
        assert_eq!(tape.stats().nodes, 0);
        assert_eq!(tape.stats().bytes, 0);
        // Same shapes again: the scale output's buffer must be reused.
        let x2 = tape.leaf(Tensor::zeros(&[16]));
        let _ = tape.scale(x2, 3.0);
        assert!(
            tape.arena_stats().reuses > 0,
            "second pass must draw from the free list"
        );
    }

    #[test]
    fn reset_spares_buffers_cloned_out() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![3], vec![1.0, 2.0, 3.0]));
        let s = tape.scale(x, 2.0);
        let kept = tape.value(s).clone();
        tape.reset();
        // Force the arena to hand out same-length buffers again: if the
        // reset had wrongly parked the shared buffer, these writes would
        // corrupt `kept`.
        let x2 = tape.leaf(Tensor::zeros(&[3]));
        let _ = tape.scale(x2, 7.0);
        let _ = tape.offset(x2, 9.0);
        assert_eq!(kept.data, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn jvp_tangents_draw_from_and_return_to_the_arena() {
        // Build a graph whose JVP materialises several tangents (matmul,
        // tanh, mul, sum), sweep it twice: the first sweep's recycled
        // tangent buffers must serve the second sweep from the free list.
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(
            vec![2, 3],
            vec![0.1, -0.2, 0.3, 0.4, -0.5, 0.6],
        ));
        let w = tape.constant(Tensor::new(
            vec![3, 2],
            vec![1.0, 0.5, -0.5, 1.0, 0.25, -0.25],
        ));
        let xw = tape.matmul(x, w, false, false);
        let th = tape.tanh(xw);
        let sq = tape.mul(th, th);
        let y = tape.sum(sq);
        let seed = Tensor::full(&[2, 3], 1.0);
        let (t1, b1) = tape.jvp(&[(x, seed.clone())], &[y]);
        let s1 = tape.arena_stats();
        let (t2, b2) = tape.jvp(&[(x, seed)], &[y]);
        let s2 = tape.arena_stats();
        assert!(
            s2.reuses > s1.reuses,
            "second jvp must reuse the first sweep's recycled tangents \
             ({} vs {})",
            s2.reuses,
            s1.reuses
        );
        assert_eq!(t1[0].data, t2[0].data, "reuse must not change tangents");
        assert_eq!(b1, b2, "materialised tangent bytes must be stable");
        assert!(b1 > 0);
    }

    #[test]
    fn concat_then_split_round_trips() {
        let mut tape = Tape::new();
        let a = tape.leaf(Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]));
        let b = tape.leaf(Tensor::new(vec![2, 3], vec![5., 6., 7., 8., 9., 10.]));
        let cat = tape.concat_cols(&[a, b]);
        assert_eq!(tape.shape(cat), vec![2, 5]);
        assert_eq!(
            tape.value(cat).data,
            vec![1., 2., 5., 6., 7., 3., 4., 8., 9., 10.]
        );
        let left = tape.split_cols(cat, 0, 2);
        let right = tape.split_cols(cat, 2, 3);
        assert_eq!(tape.value(left).data, tape.value(a).data);
        assert_eq!(tape.value(right).data, tape.value(b).data);
    }

    #[test]
    fn concat_split_grads_route_columns() {
        // y = Σ (2·a ⧺ 3·b) → da = 2, db = 3 everywhere.
        let mut tape = Tape::new();
        let a = tape.leaf(Tensor::full(&[2, 2], 1.0));
        let b = tape.leaf(Tensor::full(&[2, 3], 1.0));
        let sa = tape.scale(a, 2.0);
        let sb = tape.scale(b, 3.0);
        let cat = tape.concat_cols(&[sa, sb]);
        let y = tape.sum(cat);
        let g = tape.grad(y, &[a, b]);
        assert_eq!(tape.value(g[0]).data, vec![2.0; 4]);
        assert_eq!(tape.value(g[1]).data, vec![3.0; 6]);
        // Split adjoint zero-pads: z = Σ split(cat, 2, 3) → da = 0, db = 3.
        let mut tape = Tape::new();
        let a = tape.leaf(Tensor::full(&[2, 2], 1.0));
        let b = tape.leaf(Tensor::full(&[2, 3], 1.0));
        let cat = tape.concat_cols(&[a, b]);
        let right = tape.split_cols(cat, 2, 3);
        let sr = tape.scale(right, 3.0);
        let z = tape.sum(sr);
        let g = tape.grad(z, &[a, b]);
        assert_eq!(tape.value(g[0]).data, vec![0.0; 4]);
        assert_eq!(tape.value(g[1]).data, vec![3.0; 6]);
    }

    #[test]
    fn batch_matmul_grad_matches_per_group_matmul_grad() {
        // Batched f = Σ bmm(A, B) gradients must equal the per-group
        // rank-2 gradients stacked.
        let a_data = vec![1., 2., 3., 4., 5., 6., 7., 8.];
        let b_data = vec![1., 0., 0., 1., 2., 1., 1., 2.];
        let mut tape = Tape::new();
        let a = tape.leaf(Tensor::new(vec![2, 2, 2], a_data.clone()));
        let b = tape.leaf(Tensor::new(vec![2, 2, 2], b_data.clone()));
        let c = tape.batch_matmul(a, b, false, false);
        let y = tape.sum(c);
        let g = tape.grad(y, &[a, b]);
        for group in 0..2 {
            let mut t2 = Tape::new();
            let a2 = t2.leaf(Tensor::new(
                vec![2, 2],
                a_data[group * 4..(group + 1) * 4].to_vec(),
            ));
            let b2 = t2.leaf(Tensor::new(
                vec![2, 2],
                b_data[group * 4..(group + 1) * 4].to_vec(),
            ));
            let c2 = t2.matmul(a2, b2, false, false);
            let y2 = t2.sum(c2);
            let g2 = t2.grad(y2, &[a2, b2]);
            assert_eq!(
                &tape.value(g[0]).data[group * 4..(group + 1) * 4],
                &t2.value(g2[0]).data[..],
                "dA group {group}"
            );
            assert_eq!(
                &tape.value(g[1]).data[group * 4..(group + 1) * 4],
                &t2.value(g2[1]).data[..],
                "dB group {group}"
            );
        }
    }

    #[test]
    fn mark_kv_counts_until_reset() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::zeros(&[4, 4]));
        let k = tape.scale(x, 2.0);
        let v = tape.scale(x, 3.0);
        assert_eq!(tape.stats().kv_bytes, 0);
        tape.mark_kv(k);
        tape.mark_kv(v);
        assert_eq!(tape.stats().kv_bytes, 2 * 16 * 8);
        assert!(tape.stats().kv_bytes < tape.stats().bytes);
        tape.reset();
        assert_eq!(tape.stats().kv_bytes, 0, "reset must clear the KV ledger");
    }

    #[test]
    fn grad_shares_gather_indices_instead_of_copying() {
        let mut tape = Tape::new();
        let z = tape.leaf(Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        let picked = tape.gather_cols(z, vec![2usize, 0]);
        let y = tape.sum(picked);
        let _g = tape.grad(y, &[z]);
        let Op::GatherCols(_, gather_idx) = tape.op(picked) else {
            panic!("expected GatherCols op");
        };
        let shared = (0..tape.stats().nodes).any(|i| {
            matches!(
                tape.op(i),
                Op::ScatterCols(_, idx, _) if Arc::ptr_eq(idx, gather_idx)
            )
        });
        assert!(shared, "scatter adjoint must share the gather index Arc");
    }

    /// One record-or-replay cycle of a tiny fixed-topology step.
    fn plan_cycle(tape: &mut Tape, c: f64) -> f64 {
        tape.plan_step(PlanKey::Inner, |tape| {
            let x = tape.leaf(Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]));
            let s = tape.scale(x, c);
            let m = tape.mul(s, x);
            let y = tape.sum(m);
            tape.value(y).item()
        })
    }

    #[test]
    fn plan_replay_is_warm_after_first_replay() {
        let mut tape = Tape::new();
        let v0 = plan_cycle(&mut tape, 2.0); // records + compiles
        let v1 = plan_cycle(&mut tape, 2.0); // first replay: fills slots
        let a1 = tape.arena_stats();
        let v2 = plan_cycle(&mut tape, 2.0); // warm replay
        let a2 = tape.arena_stats();
        assert_eq!(v0, v1);
        assert_eq!(v1, v2);
        assert_eq!(
            a2.allocs, a1.allocs,
            "a warm replay must not touch the allocator"
        );
        let stats = tape.plan_stats();
        assert_eq!(stats.compiles, 1);
        assert_eq!(stats.replays, 2);
        assert_eq!(stats.fallbacks, 0);
    }

    #[test]
    fn topology_change_falls_back_and_recompiles() {
        let mut tape = Tape::new();
        let _ = plan_cycle(&mut tape, 2.0);
        // Same key, different topology: Offset instead of Scale+Mul.
        let v = tape.plan_step(PlanKey::Inner, |tape| {
            let x = tape.leaf(Tensor::new(vec![4], vec![1.0, 1.0, 1.0, 1.0]));
            let o = tape.offset(x, 1.0);
            let y = tape.sum(o);
            tape.value(y).item()
        });
        assert_eq!(v, 8.0, "fallback cycle still computes correct values");
        let stats = tape.plan_stats();
        assert_eq!(stats.compiles, 2, "fallback recompiles from the new cycle");
        assert_eq!(stats.fallbacks, 1);
        assert_eq!(stats.replays, 0);
    }

    #[test]
    fn payload_changes_replay_without_fallback() {
        let mut tape = Tape::new();
        let v0 = plan_cycle(&mut tape, 2.0);
        let v1 = plan_cycle(&mut tape, 3.0); // same topology, new immediate
        assert_eq!(v0, 2.0 * 30.0);
        assert_eq!(v1, 3.0 * 30.0);
        let stats = tape.plan_stats();
        assert_eq!(stats.compiles, 1, "payload change must not recompile");
        assert_eq!(stats.replays, 1);
        assert_eq!(stats.fallbacks, 0);
    }

    #[test]
    fn jvp_counts_tangents_of_marked_kv_nodes() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]));
        let k = tape.scale(x, 2.0);
        tape.mark_kv(k);
        let m = tape.mul(k, x);
        let y = tape.sum(m);
        let (_, _) =
            tape.jvp(&[(x, Tensor::new(vec![4], vec![1.0; 4]))], &[y]);
        assert_eq!(
            tape.jvp_kv_bytes(),
            4 * 8,
            "the marked node's materialised tangent is KV traffic"
        );
        let (_, _) = tape.jvp(&[], &[y]);
        assert_eq!(tape.jvp_kv_bytes(), 0, "no seeds, no tangent, no KV bytes");
    }
}

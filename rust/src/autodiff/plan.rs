//! Compiled step plans: static schedules extracted from a recorded
//! step-tape.
//!
//! MixFlow-MG's hot loop re-records the *same* tape topology T times per
//! outer step (and again for every remat segment rebuild): only the leaf
//! values and per-step payloads (data constants, label indices, Adam
//! bias-correction immediates) change.  A [`StepPlan`] captures the
//! stable part once — the topologically ordered op sequence with resolved
//! shapes, per-node last-use liveness, and the positional buffer-take
//! schedule — so subsequent cycles replay against a static buffer-slot
//! assignment instead of probing the [`super::arena::BufferArena`]
//! free-list `HashMap` per node.
//!
//! The lifecycle (driven by [`super::tape::Tape::plan_step`]):
//!
//! 1. **Record** — the first cycle under a [`PlanKey`] runs exactly as a
//!    dynamic tape; at cycle end the plan is **compiled** from the
//!    recorded nodes.
//! 2. **Replay** — later cycles re-record through the same builder code
//!    (payloads are per-step, so ops must re-execute), but every buffer
//!    take is served from the plan's slot for that position: direct
//!    indexing, no free-list probe, and bit-for-bit the same values
//!    because the plan never changes *what* is computed, only *where*
//!    the output buffer comes from.
//! 3. **Fallback** — a take whose length disagrees with the schedule, or
//!    a recorded cycle whose ops/shapes no longer match the plan,
//!    invalidates it: the cycle completes on the dynamic free-list path
//!    (values stay correct by construction) and the plan is recompiled
//!    from the cycle just recorded.
//!
//! Plan signatures are deliberately payload-insensitive: `Scale`/`Offset`
//! immediates, `Const` values and gather/scatter index *contents* vary
//! across steps without changing the schedule, so they are excluded from
//! the match.  Structure — operand wiring, transpose flags, shapes,
//! index lengths — is pinned exactly.
//!
//! The compiled liveness doubles as the calibration vehicle for
//! [`crate::hlo::memory`]: [`StepPlan::to_hlo_text`] exports the recorded
//! graph in HLO text form under the *same* buffer model the simulator
//! uses (aliases forward liveness, params/constants static, ROOT survives
//! to the end), so `analyze_text(..).peak_dynamic` must equal
//! [`StepPlan::peak_bytes`] exactly — a conformance test pins this.

use std::fmt::Write as _;
use std::sync::Arc;

use super::tape::{NodeId, Op};
use super::tensor::ELEM_BYTES;

/// Which steady-state cycle a plan describes.  One persistent tape holds
/// at most one plan per key; the keys partition the cycles the three
/// hypergradient strategies run:
///
/// * [`PlanKey::Inner`] — one inner optimisation step
///   (`inner_step_values_into`): the MixFlow forward sweep, remat segment
///   rebuilds and FD unrolls all share it.
/// * [`PlanKey::Backward`] — the MixFlow per-step backward cycle
///   (VJP + JVP overlay).
/// * [`PlanKey::Outer`] — an outer-loss evaluation cycle (MixFlow's
///   λ-seed, FD's probe losses).
/// * [`PlanKey::Naive`] — the naive strategy's monolithic
///   unroll-plus-reverse tape.
/// * [`PlanKey::Evograd`] — the EvoGrad tail cycle (in-graph last step,
///   population perturbations, softmax weighting, first-order VJP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKey {
    Inner,
    Backward,
    Outer,
    Naive,
    Evograd,
}

impl PlanKey {
    /// Number of plan keys (sizing the tape's plan table).
    pub const COUNT: usize = 5;

    pub(crate) fn idx(self) -> usize {
        match self {
            PlanKey::Inner => 0,
            PlanKey::Backward => 1,
            PlanKey::Outer => 2,
            PlanKey::Naive => 3,
            PlanKey::Evograd => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanKey::Inner => "inner",
            PlanKey::Backward => "backward",
            PlanKey::Outer => "outer",
            PlanKey::Naive => "naive",
            PlanKey::Evograd => "evograd",
        }
    }
}

/// Lifetime counters for a tape's plan machinery (telemetry-free mirror
/// of the `plan.compiles` / `plan.replays` / `plan.fallbacks` obs
/// counters, so tests and reports can read them without enabling
/// tracing).
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanStats {
    /// Plans compiled from a recorded cycle (first cycles + recompiles
    /// after a fallback).
    pub compiles: u64,
    /// Cycles replayed against a valid plan.
    pub replays: u64,
    /// Replays whose recorded cycle diverged from the plan (the cycle
    /// still completed correctly on the dynamic path).
    pub fallbacks: u64,
}

/// Payload-insensitive structural signature of one tape op.  Everything
/// that determines the buffer schedule is kept (operand wiring, transpose
/// flags, split offsets, index lengths); everything that legitimately
/// varies across steady-state steps (float immediates, constant values,
/// index contents) is dropped.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum OpSig {
    Leaf,
    Const,
    Add(NodeId, NodeId),
    Sub(NodeId, NodeId),
    Mul(NodeId, NodeId),
    Div(NodeId, NodeId),
    Scale(NodeId),
    Offset(NodeId),
    Matmul { a: NodeId, b: NodeId, ta: bool, tb: bool },
    BatchMatmul { a: NodeId, b: NodeId, ta: bool, tb: bool },
    ConcatCols(Vec<NodeId>),
    SplitCols(NodeId, usize, usize),
    Relu(NodeId),
    Step(NodeId),
    Tanh(NodeId),
    Exp(NodeId),
    Sqrt(NodeId),
    Sum(NodeId),
    Broadcast(NodeId),
    RowSum(NodeId),
    RowBroadcast(NodeId, usize),
    ColSum(NodeId),
    ColBroadcast(NodeId, usize),
    SoftmaxRows(NodeId),
    LogSumExpRows(NodeId),
    GatherCols(NodeId, usize),
    ScatterCols(NodeId, usize, usize),
    Reshape(NodeId),
}

impl OpSig {
    pub(crate) fn of(op: &Op) -> OpSig {
        match op {
            Op::Leaf => OpSig::Leaf,
            Op::Const => OpSig::Const,
            Op::Add(a, b) => OpSig::Add(*a, *b),
            Op::Sub(a, b) => OpSig::Sub(*a, *b),
            Op::Mul(a, b) => OpSig::Mul(*a, *b),
            Op::Div(a, b) => OpSig::Div(*a, *b),
            Op::Scale(a, _) => OpSig::Scale(*a),
            Op::Offset(a, _) => OpSig::Offset(*a),
            Op::Matmul { a, b, ta, tb } => {
                OpSig::Matmul { a: *a, b: *b, ta: *ta, tb: *tb }
            }
            Op::BatchMatmul { a, b, ta, tb } => {
                OpSig::BatchMatmul { a: *a, b: *b, ta: *ta, tb: *tb }
            }
            Op::ConcatCols(parts) => OpSig::ConcatCols(parts.clone()),
            Op::SplitCols(a, o, w) => OpSig::SplitCols(*a, *o, *w),
            Op::Relu(a) => OpSig::Relu(*a),
            Op::Step(a) => OpSig::Step(*a),
            Op::Tanh(a) => OpSig::Tanh(*a),
            Op::Exp(a) => OpSig::Exp(*a),
            Op::Sqrt(a) => OpSig::Sqrt(*a),
            Op::Sum(a) => OpSig::Sum(*a),
            Op::Broadcast(a, _) => OpSig::Broadcast(*a),
            Op::RowSum(a) => OpSig::RowSum(*a),
            Op::RowBroadcast(a, n) => OpSig::RowBroadcast(*a, *n),
            Op::ColSum(a) => OpSig::ColSum(*a),
            Op::ColBroadcast(a, m) => OpSig::ColBroadcast(*a, *m),
            Op::SoftmaxRows(a) => OpSig::SoftmaxRows(*a),
            Op::LogSumExpRows(a) => OpSig::LogSumExpRows(*a),
            Op::GatherCols(a, idx) => OpSig::GatherCols(*a, idx.len()),
            Op::ScatterCols(a, idx, n) => {
                OpSig::ScatterCols(*a, idx.len(), *n)
            }
            Op::Reshape(a, _) => OpSig::Reshape(*a),
        }
    }

    /// Operand node ids, appended to `out` (reused scratch).
    fn operands_into(&self, out: &mut Vec<NodeId>) {
        out.clear();
        match self {
            OpSig::Leaf | OpSig::Const => {}
            OpSig::Add(a, b)
            | OpSig::Sub(a, b)
            | OpSig::Mul(a, b)
            | OpSig::Div(a, b)
            | OpSig::Matmul { a, b, .. }
            | OpSig::BatchMatmul { a, b, .. } => out.extend([*a, *b]),
            OpSig::ConcatCols(parts) => out.extend_from_slice(parts),
            OpSig::Scale(a)
            | OpSig::Offset(a)
            | OpSig::SplitCols(a, _, _)
            | OpSig::Relu(a)
            | OpSig::Step(a)
            | OpSig::Tanh(a)
            | OpSig::Exp(a)
            | OpSig::Sqrt(a)
            | OpSig::Sum(a)
            | OpSig::Broadcast(a)
            | OpSig::RowSum(a)
            | OpSig::RowBroadcast(a, _)
            | OpSig::ColSum(a)
            | OpSig::ColBroadcast(a, _)
            | OpSig::SoftmaxRows(a)
            | OpSig::LogSumExpRows(a)
            | OpSig::GatherCols(a, _)
            | OpSig::ScatterCols(a, _, _)
            | OpSig::Reshape(a) => out.push(*a),
        }
    }

    /// Does the builder for this op draw exactly one arena buffer?
    /// Leaves and constants share their caller's buffer; `Reshape`
    /// aliases its input.  Everything else routes through `arena_tensor`
    /// exactly once, in push order — the invariant the positional slot
    /// assignment rests on.
    pub(crate) fn takes_buffer(&self) -> bool {
        !matches!(self, OpSig::Leaf | OpSig::Const | OpSig::Reshape(_))
    }
}

/// A compiled step plan: the static schedule of one steady-state cycle.
pub struct StepPlan {
    /// Per-node structural signatures (payload-insensitive).
    sigs: Vec<OpSig>,
    /// Per-node resolved output shapes.
    shapes: Vec<Vec<usize>>,
    /// Element counts of the arena takes, in take (= push) order over
    /// buffer-owning nodes.  Shared with the arena while armed.
    take_lens: Arc<[usize]>,
    /// Per-node index of the last op consuming it (the node's own index
    /// when nothing does; `nodes()` for the surviving ROOT).  Aliases
    /// forward their uses to the owning node, mirroring
    /// [`crate::hlo::memory`].
    last_use: Vec<usize>,
    /// Peak live bytes over the schedule under last-use liveness —
    /// the exact quantity `hlo::memory::MemoryReport::peak_dynamic`
    /// estimates for the same graph.
    peak_bytes: usize,
}

impl StepPlan {
    /// Compile a plan from a recorded cycle's `(op, shape)` sequence.
    pub(crate) fn compile<'a, I>(nodes: I) -> StepPlan
    where
        I: Iterator<Item = (&'a Op, &'a [usize])>,
    {
        let mut sigs = Vec::new();
        let mut shapes: Vec<Vec<usize>> = Vec::new();
        let mut take_lens = Vec::new();
        for (op, shape) in nodes {
            let sig = OpSig::of(op);
            if sig.takes_buffer() {
                take_lens.push(shape.iter().product::<usize>());
            }
            sigs.push(sig);
            shapes.push(shape.to_vec());
        }
        let n = sigs.len();

        // Alias-resolved buffer owner per node: `None` for statically
        // backed nodes (leaves, constants and views of them).
        let mut owner: Vec<Option<usize>> = Vec::with_capacity(n);
        for (i, sig) in sigs.iter().enumerate() {
            owner.push(match sig {
                OpSig::Leaf | OpSig::Const => None,
                OpSig::Reshape(a) => owner[*a],
                _ => Some(i),
            });
        }

        // Last use per node (by owning buffer), ROOT = final node
        // surviving to the end — the same model `hlo::memory` walks.
        let mut last_use: Vec<usize> = (0..n).collect();
        let mut operands = Vec::new();
        for (i, sig) in sigs.iter().enumerate() {
            sig.operands_into(&mut operands);
            for &a in &operands {
                if let Some(o) = owner[a] {
                    last_use[o] = i;
                }
            }
        }
        if let Some(&Some(root)) = owner.last() {
            last_use[root] = n;
        }

        // Program-order walk: allocate at definition, free after last
        // use, track the peak.
        let mut frees: Vec<Vec<usize>> = vec![Vec::new(); n + 1];
        let mut live = 0usize;
        let mut peak = 0usize;
        for i in 0..n {
            if owner[i] == Some(i) {
                let bytes =
                    shapes[i].iter().product::<usize>() * ELEM_BYTES;
                live += bytes;
                frees[last_use[i]].push(bytes);
            }
            peak = peak.max(live);
            for &b in &frees[i] {
                live -= b;
            }
        }

        StepPlan {
            sigs,
            shapes,
            take_lens: take_lens.into(),
            last_use,
            peak_bytes: peak,
        }
    }

    /// Does a just-recorded cycle match this plan structurally?
    pub(crate) fn matches<'a, I>(&self, nodes: I) -> bool
    where
        I: Iterator<Item = (&'a Op, &'a [usize])>,
    {
        let mut count = 0usize;
        for (i, (op, shape)) in nodes.enumerate() {
            if i >= self.sigs.len()
                || self.sigs[i] != OpSig::of(op)
                || self.shapes[i] != shape
            {
                return false;
            }
            count += 1;
        }
        count == self.sigs.len()
    }

    /// Number of nodes in the compiled cycle.
    pub fn nodes(&self) -> usize {
        self.sigs.len()
    }

    /// Number of arena takes the cycle performs (buffer-owning nodes).
    pub fn take_count(&self) -> usize {
        self.take_lens.len()
    }

    /// The take schedule, shared with the arena while armed.
    pub(crate) fn take_lens_arc(&self) -> Arc<[usize]> {
        Arc::clone(&self.take_lens)
    }

    /// Peak live bytes of the schedule under last-use liveness.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Index of the last op consuming node `i` (its own index if unused;
    /// `nodes()` for the ROOT's buffer, which survives the cycle).
    pub fn last_use(&self, i: NodeId) -> usize {
        self.last_use[i]
    }

    /// Export the compiled graph as HLO text for
    /// [`crate::hlo::memory::analyze_text`].  The mapping preserves the
    /// buffer model exactly: leaves → entry `parameter`s (static),
    /// constants → `constant`s (static), `Reshape` → the simulator's
    /// aliasing `reshape`, every buffer-owning op → a non-alias opcode
    /// with its resolved `f64` shape, final node → ROOT.  With both
    /// sides walking identical last-use liveness over identical byte
    /// counts (`ELEM_BYTES` = `f64` = 8), the simulator's `peak_dynamic`
    /// equals [`StepPlan::peak_bytes`] with zero tolerance.
    pub fn to_hlo_text(&self) -> String {
        let mut s = String::from("HloModule plan\n\nENTRY plan {\n");
        let mut params = 0usize;
        for (i, sig) in self.sigs.iter().enumerate() {
            let root = if i + 1 == self.sigs.len() { "ROOT " } else { "" };
            let shape = shape_text(&self.shapes[i]);
            let body = match sig {
                OpSig::Leaf => {
                    let t = format!("parameter({params})");
                    params += 1;
                    t
                }
                OpSig::Const => "constant(0)".to_string(),
                OpSig::Reshape(a) => format!("reshape(n{a})"),
                OpSig::Add(a, b) => format!("add(n{a}, n{b})"),
                OpSig::Sub(a, b) => format!("subtract(n{a}, n{b})"),
                OpSig::Mul(a, b) => format!("multiply(n{a}, n{b})"),
                OpSig::Div(a, b) => format!("divide(n{a}, n{b})"),
                OpSig::Scale(a) => format!("scale(n{a})"),
                OpSig::Offset(a) => format!("offset(n{a})"),
                OpSig::Matmul { a, b, .. } => format!("dot(n{a}, n{b})"),
                OpSig::BatchMatmul { a, b, .. } => {
                    format!("batch-dot(n{a}, n{b})")
                }
                OpSig::ConcatCols(parts) => {
                    let mut ops = String::new();
                    for (k, p) in parts.iter().enumerate() {
                        if k > 0 {
                            ops.push_str(", ");
                        }
                        let _ = write!(ops, "n{p}");
                    }
                    format!("concatenate({ops})")
                }
                OpSig::SplitCols(a, _, _) => format!("slice(n{a})"),
                OpSig::Relu(a) => format!("relu(n{a})"),
                OpSig::Step(a) => format!("step(n{a})"),
                OpSig::Tanh(a) => format!("tanh(n{a})"),
                OpSig::Exp(a) => format!("exponential(n{a})"),
                OpSig::Sqrt(a) => format!("sqrt(n{a})"),
                OpSig::Sum(a) => format!("reduce-sum(n{a})"),
                OpSig::Broadcast(a) => format!("broadcast(n{a})"),
                OpSig::RowSum(a) => format!("row-sum(n{a})"),
                OpSig::RowBroadcast(a, _) => format!("row-broadcast(n{a})"),
                OpSig::ColSum(a) => format!("col-sum(n{a})"),
                OpSig::ColBroadcast(a, _) => format!("col-broadcast(n{a})"),
                OpSig::SoftmaxRows(a) => format!("softmax-rows(n{a})"),
                OpSig::LogSumExpRows(a) => {
                    format!("logsumexp-rows(n{a})")
                }
                OpSig::GatherCols(a, _) => format!("gather(n{a})"),
                OpSig::ScatterCols(a, _, _) => format!("scatter(n{a})"),
            };
            let _ = writeln!(s, "  {root}n{i} = {shape} {body}");
        }
        s.push_str("}\n");
        s
    }
}

/// `f64[2,3]{1,0}`-style shape text (descending layout, empty for
/// scalars) — the grammar `hlo::parser` reads.
fn shape_text(shape: &[usize]) -> String {
    if shape.is_empty() {
        return "f64[]".to_string();
    }
    let mut s = String::from("f64[");
    for (i, d) in shape.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{d}");
    }
    s.push(']');
    s.push('{');
    for (i, d) in (0..shape.len()).rev().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{d}");
    }
    s.push('}');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc as StdArc;

    fn sig_nodes(ops: &[(Op, Vec<usize>)]) -> StepPlan {
        StepPlan::compile(
            ops.iter().map(|(op, sh)| (op, sh.as_slice())),
        )
    }

    #[test]
    fn chain_liveness_peak_counts_two_intermediates() {
        // leaf -> a -> b -> c (ROOT): at `b` both a and b are live; the
        // ROOT c survives, so the walk peaks at b+c as well — 2 buffers.
        let plan = sig_nodes(&[
            (Op::Leaf, vec![4]),
            (Op::Scale(0, 2.0), vec![4]),
            (Op::Scale(1, 2.0), vec![4]),
            (Op::Scale(2, 2.0), vec![4]),
        ]);
        assert_eq!(plan.take_count(), 3);
        assert_eq!(plan.peak_bytes(), 2 * 4 * ELEM_BYTES);
        // a's last use is b (index 2); the ROOT survives to the end.
        assert_eq!(plan.last_use(1), 2);
        assert_eq!(plan.last_use(3), 4);
    }

    #[test]
    fn reshape_aliases_forward_liveness_to_owner() {
        // owner -> reshape view -> consumer: the owner must stay live
        // through the consumer, and the view itself owns nothing.
        let plan = sig_nodes(&[
            (Op::Leaf, vec![6]),
            (Op::Scale(0, 1.0), vec![6]),
            (Op::Reshape(1, vec![2, 3]), vec![2, 3]),
            (Op::Scale(2, 1.0), vec![2, 3]),
        ]);
        assert_eq!(plan.take_count(), 2, "reshape must not take a buffer");
        assert_eq!(plan.last_use(1), 3, "alias use extends the owner");
    }

    #[test]
    fn matches_ignores_payloads_but_pins_structure() {
        let base = vec![
            (Op::Leaf, vec![2]),
            (Op::Scale(0, 2.0), vec![2]),
            (Op::Sum(1), vec![]),
        ];
        let plan = sig_nodes(&base);
        // Same structure, different immediate: still a match.
        let other = vec![
            (Op::Leaf, vec![2]),
            (Op::Scale(0, 7.5), vec![2]),
            (Op::Sum(1), vec![]),
        ];
        assert!(plan.matches(
            other.iter().map(|(op, sh)| (op, sh.as_slice()))
        ));
        // Different wiring: no match.
        let rewired = vec![
            (Op::Leaf, vec![2]),
            (Op::Offset(0, 2.0), vec![2]),
            (Op::Sum(1), vec![]),
        ];
        assert!(!plan.matches(
            rewired.iter().map(|(op, sh)| (op, sh.as_slice()))
        ));
        // Shorter cycle: no match.
        assert!(!plan.matches(
            base[..2].iter().map(|(op, sh)| (op, sh.as_slice()))
        ));
    }

    #[test]
    fn index_length_is_structural_contents_are_not() {
        let a: StdArc<[usize]> = StdArc::from(vec![0usize, 1]);
        let b: StdArc<[usize]> = StdArc::from(vec![1usize, 0]);
        let plan = sig_nodes(&[
            (Op::Leaf, vec![2, 3]),
            (Op::GatherCols(0, a), vec![2]),
        ]);
        let same_len = vec![
            (Op::Leaf, vec![2, 3]),
            (Op::GatherCols(0, b), vec![2]),
        ];
        assert!(plan.matches(
            same_len.iter().map(|(op, sh)| (op, sh.as_slice()))
        ));
        let longer: StdArc<[usize]> = StdArc::from(vec![0usize, 1, 1]);
        let diff = vec![
            (Op::Leaf, vec![3, 3]),
            (Op::GatherCols(0, longer), vec![3]),
        ];
        assert!(!plan.matches(
            diff.iter().map(|(op, sh)| (op, sh.as_slice()))
        ));
    }

    #[test]
    fn hlo_export_round_trips_through_the_parser() {
        let plan = sig_nodes(&[
            (Op::Leaf, vec![2, 3]),
            (Op::Const, vec![2, 3]),
            (Op::Mul(0, 1), vec![2, 3]),
            (Op::Reshape(2, vec![6]), vec![6]),
            (Op::Sum(3), vec![]),
        ]);
        let text = plan.to_hlo_text();
        let report = crate::hlo::memory::analyze_text(&text)
            .expect("exported plan text must parse");
        assert_eq!(report.peak_dynamic as usize, plan.peak_bytes());
        assert_eq!(report.instructions, plan.nodes());
    }
}

//! `HypergradEngine` — the unified, persistent solver API for every
//! hypergradient path.
//!
//! Before the engine, the public surface was three free functions
//! (`naive_hypergrad`, `mixflow_hypergrad`, `mixflow_hypergrad_with`)
//! plus the `fd_hypergrad` oracle, each rebuilding its [`Tape`] and
//! buffer arena per call — so the arena's recycling never amortised
//! *across* outer steps, and every driver (the `native` CLI command,
//! `NativeMetaTrainer`, the figure benches, the examples) re-wired the
//! same configuration by hand.
//!
//! The engine owns ONE persistent tape + arena for its whole lifetime.
//! Each [`HypergradEngine::run`] resets the tape (returning the previous
//! step's buffers to the arena) and computes the next hypergradient out
//! of recycled storage: from the second outer step on, the hot path is
//! allocator-free and [`MemoryReport::arena_reuses`] counts the savings.
//! The strategy behind `run` is a [`HypergradStrategy`] trait object —
//! naive reverse-over-reverse, MixFlow-MG forward-over-reverse (with the
//! [`CheckpointPolicy`] remat knob, including the run-time
//! [`CheckpointPolicy::Auto`] `K ≈ √T` resolution), or central finite
//! differences as a first-class cross-check mode — so drivers select a
//! path by value ([`HypergradMode`]) and exotic callers can plug their
//! own strategy.
//!
//! The old free functions survive as thin shims that build a throwaway
//! engine, so existing call sites keep compiling; see the "Engine API"
//! section of `rust/src/autodiff/README.md` for the builder surface and
//! migration notes.

use std::time::Instant;

use super::mixflow::{
    evograd_hypergrad_in, inner_step_values_into, mixflow_hypergrad_in,
    naive_hypergrad_in, truncated_hypergrad_in, BilevelProblem,
    CheckpointPolicy, Hypergrad, MemoryReport,
};
use super::optim::InnerOptimiser;
use super::plan::PlanKey;
use super::tape::{NodeId, Tape};
use super::tensor::Tensor;
use crate::kernels::{DetPool, PoolStats};
use crate::obs::{Counter, Gauge, MetricsRegistry, Phase, StepTrace};
use crate::util::args::CliEnum;
use crate::util::prng::Prng;

/// Which hypergradient path an engine (or the `native` CLI) drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HypergradMode {
    /// Reverse-over-reverse over one monolithic tape.
    Naive,
    /// Forward-over-reverse with per-step tape reuse (MixFlow-MG).
    Mixflow,
    /// Central finite differences over every η element — the slow
    /// numerical oracle, exposed as a first-class mode for cross-checks.
    Fd,
    /// Truncated back-propagation (Shaban et al.): the mixflow adjoint
    /// sweep over only the last `horizon` inner steps.  `horizon = T`
    /// is exactly mixflow, bit-for-bit.
    Truncated { horizon: usize },
    /// EvoGrad (Bohdal et al.): a population-based stochastic estimate
    /// with no second-order terms — O(1) memory in the unroll.
    Evograd,
}

impl HypergradMode {
    pub fn name(&self) -> String {
        match self {
            HypergradMode::Naive => "naive".to_string(),
            HypergradMode::Mixflow => "mixflow".to_string(),
            HypergradMode::Fd => "fd".to_string(),
            HypergradMode::Truncated { horizon } => {
                format!("truncated:{horizon}")
            }
            HypergradMode::Evograd => "evograd".to_string(),
        }
    }

    /// Case- and whitespace-insensitive (`--mode Mixflow` must work).
    /// The windowed mode takes its horizon inline: `truncated:<K>` with
    /// `K ≥ 1` (the printed names round-trip, like the other CLI enums).
    pub fn parse(s: &str) -> Option<HypergradMode> {
        let t = s.trim().to_lowercase();
        match t.as_str() {
            "naive" => Some(HypergradMode::Naive),
            "mixflow" => Some(HypergradMode::Mixflow),
            "fd" => Some(HypergradMode::Fd),
            "evograd" => Some(HypergradMode::Evograd),
            _ => t
                .strip_prefix("truncated:")
                .and_then(|k| k.trim().parse::<usize>().ok())
                .filter(|&k| k >= 1)
                .map(|horizon| HypergradMode::Truncated { horizon }),
        }
    }
}

impl CliEnum for HypergradMode {
    fn name(&self) -> String {
        // Method-call syntax resolves to the inherent `name` above.
        HypergradMode::name(self)
    }

    fn parse(s: &str) -> Option<HypergradMode> {
        HypergradMode::parse(s)
    }

    /// Parseable exemplars; the open-ended `truncated:<K>` form is
    /// described by the [`CliEnum::valid_values`] override below.
    fn variants() -> &'static [&'static str] {
        &["naive", "mixflow", "fd", "truncated:4", "evograd"]
    }

    fn valid_values() -> String {
        "naive, mixflow, fd, truncated:<K> (mixflow adjoint over the \
         last K inner steps, K >= 1), or evograd (population estimate, \
         no second-order terms)"
            .to_string()
    }
}

/// One hypergradient path behind the engine: given the engine's
/// persistent tape, compute `dF/dη` for a bilevel problem at `(θ₀, η)`.
///
/// Implementations must treat the tape as scratch — reset it on entry
/// (recycling whatever the previous run left) and leave nothing behind
/// that a later run would trip over.  The built-in strategies are
/// [`NaiveStrategy`], [`MixflowStrategy`] and [`FdStrategy`]; custom
/// ones plug in via [`HypergradEngine::with_strategy`].
pub trait HypergradStrategy: Send {
    /// Short path name, used in artifact labels and reports.
    fn name(&self) -> &'static str;

    /// Re-key any per-run randomness to `seed` and rewind the stream
    /// (no-op for the deterministic strategies).  The serving
    /// supervisor calls this before every attempt so an evograd job's
    /// perturbations depend only on its spec — never on how many jobs
    /// the pooled engine served before it.
    fn reseed(&mut self, _seed: u64) {}

    /// Compute one hypergradient on the engine's persistent tape.
    fn run(
        &mut self,
        tape: &mut Tape,
        problem: &dyn BilevelProblem,
        theta0: &[Tensor],
        eta: &[Tensor],
    ) -> Hypergrad;
}

/// Reverse-over-reverse on one monolithic tape (the baseline).
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveStrategy;

impl HypergradStrategy for NaiveStrategy {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn run(
        &mut self,
        tape: &mut Tape,
        problem: &dyn BilevelProblem,
        theta0: &[Tensor],
        eta: &[Tensor],
    ) -> Hypergrad {
        naive_hypergrad_in(tape, problem, theta0, eta)
    }
}

/// MixFlow-MG forward-over-reverse with per-step tape reuse under a
/// [`CheckpointPolicy`] ([`CheckpointPolicy::Auto`] resolves `K ≈ √T`
/// from the problem's unroll at run time).
#[derive(Debug, Clone, Copy, Default)]
pub struct MixflowStrategy {
    pub policy: CheckpointPolicy,
}

impl HypergradStrategy for MixflowStrategy {
    fn name(&self) -> &'static str {
        "mixflow"
    }

    fn run(
        &mut self,
        tape: &mut Tape,
        problem: &dyn BilevelProblem,
        theta0: &[Tensor],
        eta: &[Tensor],
    ) -> Hypergrad {
        mixflow_hypergrad_in(tape, problem, theta0, eta, self.policy)
    }
}

/// Truncated back-propagation (Shaban et al.): the mixflow
/// forward-over-reverse machinery — checkpoints, remat, compiled step
/// plans and all — confined to the last `horizon` inner steps.  The
/// forward unroll still runs every step (the window state is exact);
/// only the adjoint sweep is cut short, so checkpoint memory scales
/// with `horizon` instead of `T` at the cost of a truncation bias.
/// `horizon = T` is *exactly* [`MixflowStrategy`], bit-for-bit (same
/// code path, same op sequence).
#[derive(Debug, Clone, Copy)]
pub struct TruncatedStrategy {
    /// Window length K ≥ 1 (clamped to the problem's unroll at run
    /// time).
    pub horizon: usize,
    /// Checkpoint policy *within* the window
    /// ([`CheckpointPolicy::Auto`] resolves `K' ≈ √horizon`).
    pub policy: CheckpointPolicy,
}

impl TruncatedStrategy {
    pub fn new(horizon: usize, policy: CheckpointPolicy) -> TruncatedStrategy {
        assert!(horizon >= 1, "truncation horizon must be at least 1");
        TruncatedStrategy { horizon, policy }
    }
}

impl HypergradStrategy for TruncatedStrategy {
    fn name(&self) -> &'static str {
        "truncated"
    }

    fn run(
        &mut self,
        tape: &mut Tape,
        problem: &dyn BilevelProblem,
        theta0: &[Tensor],
        eta: &[Tensor],
    ) -> Hypergrad {
        truncated_hypergrad_in(
            tape,
            problem,
            theta0,
            eta,
            self.policy,
            self.horizon,
        )
    }
}

/// Default EvoGrad population size ([`EvoGradStrategy`] / the builder's
/// `evo_population` knob).
pub const DEFAULT_EVO_POPULATION: usize = 8;

/// Default EvoGrad perturbation scale σ.
pub const DEFAULT_EVO_SIGMA: f64 = 1e-2;

/// EvoGrad (Bohdal et al.): softmax-weighted population hypergradient
/// with no second-order terms — see
/// [`super::mixflow::evograd_hypergrad_in`] for the estimator.  Each
/// [`HypergradStrategy::run`] draws its antithetic perturbations from
/// the deterministic per-(seed, outer-step) stream
/// `Prng::new(seed).fold_in(step)`, so a rebuilt engine (serve
/// quarantine) or a replayed job reproduces the same estimates
/// bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct EvoGradStrategy {
    /// Population size (rounded up to 2; antithetic pairs).
    pub population: usize,
    /// Perturbation scale σ > 0.
    pub sigma: f64,
    /// Base seed of the perturbation stream.
    pub seed: u64,
    /// Outer-step counter folded into the stream per run.
    calls: u64,
}

impl EvoGradStrategy {
    pub fn new(population: usize, sigma: f64, seed: u64) -> EvoGradStrategy {
        assert!(sigma > 0.0, "evograd sigma must be positive, got {sigma}");
        EvoGradStrategy { population: population.max(2), sigma, seed, calls: 0 }
    }
}

impl Default for EvoGradStrategy {
    fn default() -> EvoGradStrategy {
        EvoGradStrategy::new(DEFAULT_EVO_POPULATION, DEFAULT_EVO_SIGMA, 0)
    }
}

impl HypergradStrategy for EvoGradStrategy {
    fn name(&self) -> &'static str {
        "evograd"
    }

    fn reseed(&mut self, seed: u64) {
        self.seed = seed;
        self.calls = 0;
    }

    fn run(
        &mut self,
        tape: &mut Tape,
        problem: &dyn BilevelProblem,
        theta0: &[Tensor],
        eta: &[Tensor],
    ) -> Hypergrad {
        let mut rng = Prng::new(self.seed).fold_in(self.calls);
        self.calls += 1;
        evograd_hypergrad_in(
            tape,
            problem,
            theta0,
            eta,
            self.population,
            self.sigma,
            &mut rng,
        )
    }
}

/// Central finite differences over every η element: `2·|η|` forward
/// unrolls per hypergradient, all on the engine's reused tape.  The
/// returned [`MemoryReport`] carries the peak step-tape footprint and
/// the arena traffic; `checkpoint_bytes` is 0 (nothing is checkpointed)
/// and the whole wall-clock lands in `forward_seconds` (there is no
/// adjoint sweep).
#[derive(Debug, Clone, Copy)]
pub struct FdStrategy {
    pub epsilon: f64,
}

impl FdStrategy {
    pub fn new(epsilon: f64) -> FdStrategy {
        assert!(
            epsilon > 0.0,
            "fd epsilon must be positive, got {epsilon}"
        );
        FdStrategy { epsilon }
    }
}

impl Default for FdStrategy {
    fn default() -> FdStrategy {
        FdStrategy::new(DEFAULT_FD_EPSILON)
    }
}

/// Default central-difference step for [`FdStrategy`] / `--fd-eps`.
pub const DEFAULT_FD_EPSILON: f64 = 1e-5;

/// `F(η)` by forward unroll on a reused tape, folding each step tape's
/// size into `peak = (bytes, nodes)`.
fn fd_outer_at(
    tape: &mut Tape,
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
    peak: &mut (usize, usize),
) -> f64 {
    let opt = problem.optimiser();
    let mut theta: Vec<Tensor> = theta0.to_vec();
    let mut state = opt.init_state(theta0);
    for t in 0..problem.unroll() {
        let (next_theta, next_state, stats) =
            inner_step_values_into(problem, tape, &theta, &state, eta, t);
        peak.0 = peak.0.max(stats.bytes);
        peak.1 = peak.1.max(stats.nodes);
        theta = next_theta;
        state = next_state;
    }
    // The outer-loss evaluation shares the `Outer` plan with mixflow's
    // λ seeding: same graph shape, same slot schedule.
    tape.plan_step(PlanKey::Outer, |tape| {
        let ids: Vec<NodeId> =
            theta.iter().map(|v| tape.leaf(v.clone())).collect();
        let outer = problem.outer_loss(tape, &ids);
        peak.0 = peak.0.max(tape.stats().bytes);
        peak.1 = peak.1.max(tape.stats().nodes);
        tape.value(outer).item()
    })
}

impl HypergradStrategy for FdStrategy {
    fn name(&self) -> &'static str {
        "fd"
    }

    fn run(
        &mut self,
        tape: &mut Tape,
        problem: &dyn BilevelProblem,
        theta0: &[Tensor],
        eta: &[Tensor],
    ) -> Hypergrad {
        let h = self.epsilon;
        tape.reset();
        let arena_before = tape.arena_stats();
        let t0 = Instant::now();
        let mut peak = (0usize, 0usize);
        tape.obs_mut().phase_begin(Phase::Forward);
        let outer_loss = fd_outer_at(tape, problem, theta0, eta, &mut peak);
        tape.obs_mut().phase_end(Phase::Forward);
        let mut d_eta = Vec::with_capacity(eta.len());
        for (li, leaf) in eta.iter().enumerate() {
            let mut g = Tensor::zeros(&leaf.shape);
            for j in 0..leaf.elements() {
                let mut plus: Vec<Tensor> = eta.to_vec();
                plus[li].data[j] += h;
                let mut minus: Vec<Tensor> = eta.to_vec();
                minus[li].data[j] -= h;
                tape.obs_mut().phase_begin(Phase::Forward);
                let f_plus =
                    fd_outer_at(tape, problem, theta0, &plus, &mut peak);
                let f_minus =
                    fd_outer_at(tape, problem, theta0, &minus, &mut peak);
                tape.obs_mut().phase_end(Phase::Forward);
                g.data[j] = (f_plus - f_minus) / (2.0 * h);
            }
            d_eta.push(g);
        }
        let arena = tape.arena_stats();
        Hypergrad {
            d_eta,
            outer_loss,
            memory: MemoryReport {
                tape_bytes: peak.0,
                checkpoint_bytes: 0,
                nodes: peak.1,
                peak_bytes: peak.0,
                arena_allocs: arena.allocs - arena_before.allocs,
                arena_reuses: arena.reuses - arena_before.reuses,
                forward_seconds: t0.elapsed().as_secs_f64(),
                backward_seconds: 0.0,
                // fd never walks a backward sweep, so the KV-reuse
                // ledger (an adjoint-path notion) stays empty.
                kv_peak_bytes: 0,
                kv_ckpt_alias_bytes: 0,
                kv_remat_bytes: 0,
                kv_tangent_bytes: 0,
            },
        }
    }
}

/// Fluent configuration for a [`HypergradEngine`].  All fields are
/// plain values, so a builder can be stored and re-`build()` cheaply
/// (the trainers do this when a mode/policy knob changes pre-training).
#[derive(Debug, Clone, Copy)]
pub struct EngineBuilder {
    mode: HypergradMode,
    policy: CheckpointPolicy,
    inner_opt: Option<InnerOptimiser>,
    fd_epsilon: f64,
    evo_population: usize,
    evo_sigma: f64,
    evo_seed: u64,
    telemetry: bool,
    plan: bool,
    guard: bool,
    threads: usize,
}

impl Default for EngineBuilder {
    fn default() -> EngineBuilder {
        EngineBuilder {
            mode: HypergradMode::Mixflow,
            policy: CheckpointPolicy::Full,
            inner_opt: None,
            fd_epsilon: DEFAULT_FD_EPSILON,
            evo_population: DEFAULT_EVO_POPULATION,
            evo_sigma: DEFAULT_EVO_SIGMA,
            evo_seed: 0,
            telemetry: false,
            plan: true,
            guard: false,
            threads: crate::kernels::pool::default_threads(),
        }
    }
}

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Select the hypergradient path (default: mixflow).
    pub fn mode(mut self, mode: HypergradMode) -> EngineBuilder {
        self.mode = mode;
        self
    }

    /// Checkpoint policy for the mixflow path (default: full; ignored by
    /// naive/fd, which have no checkpoints to thin).
    pub fn checkpoint(mut self, policy: CheckpointPolicy) -> EngineBuilder {
        self.policy = policy;
        self
    }

    /// Inner-loop optimiser the engine installs on problems it is asked
    /// to [`HypergradEngine::configure_problem`] (default: leave the
    /// problem's own choice alone).
    pub fn inner_opt(mut self, opt: InnerOptimiser) -> EngineBuilder {
        self.inner_opt = Some(opt);
        self
    }

    /// Central-difference step for the fd path (default 1e-5).
    pub fn fd_epsilon(mut self, epsilon: f64) -> EngineBuilder {
        assert!(epsilon > 0.0, "fd epsilon must be positive");
        self.fd_epsilon = epsilon;
        self
    }

    /// EvoGrad population size (default 8; rounded up to 2 — the
    /// estimator needs at least one antithetic pair).  Ignored by the
    /// other modes.
    pub fn evo_population(mut self, population: usize) -> EngineBuilder {
        self.evo_population = population.max(2);
        self
    }

    /// EvoGrad perturbation scale σ (default 1e-2).  Ignored by the
    /// other modes.
    pub fn evo_sigma(mut self, sigma: f64) -> EngineBuilder {
        assert!(sigma > 0.0, "evograd sigma must be positive");
        self.evo_sigma = sigma;
        self
    }

    /// Base seed of the EvoGrad perturbation stream (default 0); each
    /// outer step folds its index in, so replays are deterministic
    /// per (seed, step).  Ignored by the other modes.
    pub fn evo_seed(mut self, seed: u64) -> EngineBuilder {
        self.evo_seed = seed;
        self
    }

    /// Enable the `obs` telemetry recorder on the engine's tape
    /// (default off — and off means the recorder is a strict no-op:
    /// no timestamps, no counters, bit-identical hypergradients).
    pub fn telemetry(mut self, on: bool) -> EngineBuilder {
        self.telemetry = on;
        self
    }

    /// Enable compiled step plans on the engine's tape (default on).
    /// Off, every cycle records dynamically — the pre-plan behaviour,
    /// bit-for-bit; the A/B knob behind the `mixflow_noplan` bench
    /// variant and the plan conformance tests.
    pub fn plan(mut self, on: bool) -> EngineBuilder {
        self.plan = on;
        self
    }

    /// Whether [`EngineBuilder::plan`] left compiled plans enabled.
    pub fn plan_enabled(&self) -> bool {
        self.plan
    }

    /// Enable the tape's non-finite guard (default off).  On, every
    /// node push scans its value and unwinds with a typed
    /// [`super::tape::NonFiniteSignal`] on the first NaN/inf — the
    /// serving layer turns this into `HypergradError::NonFinite`.  Off,
    /// the guard is a single untaken branch and hypergradients stay
    /// bit-identical to a guard-free build.
    pub fn guard(mut self, on: bool) -> EngineBuilder {
        self.guard = on;
        self
    }

    /// Whether [`EngineBuilder::guard`] enabled the non-finite guard.
    pub fn guard_enabled(&self) -> bool {
        self.guard
    }

    /// Kernel worker threads for the engine's [`DetPool`] (default:
    /// `MIXFLOW_THREADS` or 1).  Clamped to the pool's supported range
    /// at build time.  Hypergradients are bit-for-bit identical at
    /// every thread count — the pool only splits disjoint-output axes.
    pub fn threads(mut self, threads: usize) -> EngineBuilder {
        self.threads = threads.max(1);
        self
    }

    /// The configured kernel thread count.
    pub fn threads_configured(&self) -> usize {
        self.threads
    }

    pub fn build(self) -> HypergradEngine {
        let strategy: Box<dyn HypergradStrategy> = match self.mode {
            HypergradMode::Naive => Box::new(NaiveStrategy),
            HypergradMode::Mixflow => {
                Box::new(MixflowStrategy { policy: self.policy })
            }
            HypergradMode::Fd => Box::new(FdStrategy::new(self.fd_epsilon)),
            HypergradMode::Truncated { horizon } => {
                Box::new(TruncatedStrategy::new(horizon, self.policy))
            }
            HypergradMode::Evograd => Box::new(EvoGradStrategy::new(
                self.evo_population,
                self.evo_sigma,
                self.evo_seed,
            )),
        };
        let mut tape = Tape::new();
        tape.obs_mut().set_enabled(self.telemetry);
        tape.set_plan_enabled(self.plan);
        tape.set_guard_enabled(self.guard);
        tape.set_pool(std::sync::Arc::new(DetPool::new(self.threads)));
        HypergradEngine {
            tape,
            strategy,
            config: self,
            outer_steps: 0,
        }
    }
}

/// A persistent hypergradient solver: one strategy + one tape/arena,
/// reused across outer steps so buffer recycling amortises over the
/// whole outer loop.
///
/// ```text
/// let mut engine = HypergradEngine::builder()
///     .mode(HypergradMode::Mixflow)
///     .checkpoint(CheckpointPolicy::Auto)
///     .build();
/// for _ in 0..outer_steps {
///     problem.resample();
///     let h = engine.run(&problem, &problem.theta0(), &eta);
///     // h.memory.arena_reuses > 0 from the second step on
/// }
/// ```
pub struct HypergradEngine {
    tape: Tape,
    strategy: Box<dyn HypergradStrategy>,
    config: EngineBuilder,
    outer_steps: usize,
}

impl HypergradEngine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// An engine around a caller-supplied strategy.  `mode()`/`policy()`
    /// report the builder defaults (mixflow/full) — the strategy's
    /// [`HypergradStrategy::name`] is the authoritative label.
    pub fn with_strategy(
        strategy: Box<dyn HypergradStrategy>,
    ) -> HypergradEngine {
        HypergradEngine {
            tape: Tape::new(),
            strategy,
            config: EngineBuilder::default(),
            outer_steps: 0,
        }
    }

    pub fn mode(&self) -> HypergradMode {
        self.config.mode
    }

    pub fn policy(&self) -> CheckpointPolicy {
        self.config.policy
    }

    pub fn fd_epsilon(&self) -> f64 {
        self.config.fd_epsilon
    }

    /// The builder-configured inner optimiser, if any (what
    /// [`HypergradEngine::configure_problem`] installs).
    pub fn inner_opt(&self) -> Option<InnerOptimiser> {
        self.config.inner_opt
    }

    /// The strategy's path name (`naive`/`mixflow`/`fd`, or whatever a
    /// custom strategy reports).
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// How many hypergradients this engine has computed.
    pub fn outer_steps(&self) -> usize {
        self.outer_steps
    }

    /// Traffic counters of the persistent arena (cumulative over the
    /// engine's lifetime; per-run deltas live in
    /// [`MemoryReport::arena_allocs`]/[`MemoryReport::arena_reuses`]).
    pub fn arena_stats(&self) -> super::arena::ArenaStats {
        self.tape.arena_stats()
    }

    /// Worker-thread count of the engine's kernel pool (after clamping).
    pub fn threads(&self) -> usize {
        self.tape.pool().threads()
    }

    /// Lifetime parallel-region counters of the engine's kernel pool
    /// (readable without enabling telemetry; serial fast-path dispatches
    /// are not counted).
    pub fn pool_stats(&self) -> PoolStats {
        self.tape.pool().stats()
    }

    /// Whether the `obs` telemetry recorder is on for this engine.
    pub fn telemetry_enabled(&self) -> bool {
        self.tape.obs().enabled()
    }

    /// Whether compiled step plans are on for this engine's tape.
    pub fn plan_enabled(&self) -> bool {
        self.tape.plan_enabled()
    }

    /// Lifetime compile/replay/fallback counters of the tape's plan
    /// machinery (readable without enabling telemetry).
    pub fn plan_stats(&self) -> super::plan::PlanStats {
        self.tape.plan_stats()
    }

    /// The compiled plan for `key`, if one has been compiled — the
    /// conformance tests export its liveness as HLO text from here.
    pub fn plan(&self, key: PlanKey) -> Option<&super::plan::StepPlan> {
        self.tape.plan(key)
    }

    /// Turn the telemetry recorder on/off mid-life (the builder knob
    /// [`EngineBuilder::telemetry`] is the usual way).
    pub fn set_telemetry(&mut self, on: bool) {
        self.tape.obs_mut().set_enabled(on);
    }

    /// The builder this engine was configured from — `config().build()`
    /// yields a fresh engine with identical knobs (how the serving
    /// supervisor rebuilds a quarantined engine).
    pub fn config(&self) -> EngineBuilder {
        self.config
    }

    /// Re-key the strategy's per-run randomness (evograd's
    /// perturbation stream) and rewind it to step 0; a no-op for the
    /// deterministic strategies.  Serving calls this per attempt so
    /// warm-engine pooling never leaks stream position across jobs.
    pub fn reseed(&mut self, seed: u64) {
        self.strategy.reseed(seed);
    }

    /// Whether the tape's non-finite guard is on for this engine.
    pub fn guard_enabled(&self) -> bool {
        self.tape.guard_enabled()
    }

    /// Toggle the non-finite guard mid-life (the builder knob
    /// [`EngineBuilder::guard`] is the usual way).
    pub fn set_guard(&mut self, on: bool) {
        self.tape.set_guard_enabled(on);
    }

    /// Attach (or with `None` detach) a cooperative cancellation token;
    /// the tape polls it at phase boundaries and unwinds with a typed
    /// [`super::tape::CancelSignal`] once it fires.
    pub fn set_cancel(
        &mut self,
        cancel: Option<std::sync::Arc<super::tape::CancelToken>>,
    ) {
        self.tape.set_cancel(cancel);
    }

    /// Whether the persistent tape's structural invariants hold (no
    /// replay in flight, arena disarmed, no open phase span).  `false`
    /// after a caught unwind means the engine must be rebuilt before it
    /// serves again — the supervisor's quarantine trigger.
    pub fn invariants_ok(&self) -> bool {
        self.tape.invariants_ok()
    }

    /// The engine's metrics registry (counters/gauges/histograms,
    /// cumulative over the engine's lifetime).
    pub fn metrics(&self) -> &MetricsRegistry {
        self.tape.obs().registry()
    }

    /// Completed per-step traces not yet drained.
    pub fn step_traces(&self) -> &[StepTrace] {
        self.tape.obs().steps()
    }

    /// The most recent completed step trace, if any.
    pub fn last_trace(&self) -> Option<&StepTrace> {
        self.tape.obs().steps().last()
    }

    /// Drain completed step traces (registry totals stay).
    pub fn take_step_traces(&mut self) -> Vec<StepTrace> {
        self.tape.obs_mut().take_steps()
    }

    /// Install the builder-configured inner optimiser (if any) on a
    /// problem.  Call once before the outer loop; a no-op when the
    /// builder left the optimiser unset.
    pub fn configure_problem(&self, problem: &mut dyn BilevelProblem) {
        if let Some(opt) = self.config.inner_opt {
            problem.set_optimiser(opt);
        }
    }

    /// Compute one hypergradient.  The persistent tape is reset
    /// (recycling the previous run's buffers through the arena) and
    /// reused — from the second call on, step tapes draw from the free
    /// list instead of the allocator.
    pub fn run(
        &mut self,
        problem: &dyn BilevelProblem,
        theta0: &[Tensor],
        eta: &[Tensor],
    ) -> Hypergrad {
        let step = self.outer_steps;
        let HypergradEngine { tape, strategy, .. } = self;
        if !tape.obs().enabled() {
            let h = strategy.run(tape, problem, theta0, eta);
            self.outer_steps += 1;
            return h;
        }
        // Telemetry on: bracket the strategy in a step trace.  Arena
        // traffic is mirrored into the registry as deltas of the arena's
        // own counters (the strategies never report recycle traffic, so
        // the registry is the only place the full ledger exists), and
        // the strategy's MemoryReport rides along in the trace for
        // conformance checking against the registry deltas.
        let arena0 = tape.arena_stats();
        let pool0 = tape.pool().stats();
        tape.obs_mut().step_begin(step, strategy.name());
        let h = strategy.run(tape, problem, theta0, eta);
        let arena = tape.arena_stats();
        let pool = tape.pool().stats();
        let obs = tape.obs_mut();
        let d = |now: usize, was: usize| (now - was) as u64;
        obs.count(Counter::ArenaAllocs, d(arena.allocs, arena0.allocs));
        obs.count(Counter::ArenaReuses, d(arena.reuses, arena0.reuses));
        obs.count(
            Counter::ArenaRecycled,
            d(arena.recycled, arena0.recycled),
        );
        obs.count(
            Counter::ArenaAllocBytes,
            d(arena.alloc_bytes, arena0.alloc_bytes),
        );
        obs.count(
            Counter::ArenaReuseBytes,
            d(arena.reuse_bytes, arena0.reuse_bytes),
        );
        obs.count(
            Counter::ArenaRecycleBytes,
            d(arena.recycle_bytes, arena0.recycle_bytes),
        );
        obs.count(Counter::PoolJobs, pool.jobs - pool0.jobs);
        obs.count(Counter::PoolChunks, pool.chunks - pool0.chunks);
        obs.gauge_max(
            Gauge::CheckpointPeakBytes,
            h.memory.checkpoint_bytes as u64,
        );
        let report = [
            ("arena_allocs", h.memory.arena_allocs as u64),
            ("arena_reuses", h.memory.arena_reuses as u64),
            ("tape_bytes", h.memory.tape_bytes as u64),
            ("checkpoint_bytes", h.memory.checkpoint_bytes as u64),
            ("peak_bytes", h.memory.peak_bytes as u64),
            ("nodes", h.memory.nodes as u64),
            ("kv_peak_bytes", h.memory.kv_peak_bytes as u64),
        ];
        obs.step_end(&report);
        self.outer_steps += 1;
        h
    }

    /// Drop the recorded graph while keeping the arena warm (parked
    /// buffers stay available to the next [`HypergradEngine::run`]).
    /// Strategies reset the tape on entry anyway, so this is only needed
    /// to release tape-held values early.
    pub fn reset(&mut self) {
        self.tape.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::mixflow::{mixflow_hypergrad_with, rel_err};
    use crate::autodiff::problems::HyperLrProblem;

    fn small_problem() -> HyperLrProblem {
        HyperLrProblem::with_config(11, 3, 4, 3, 4, 3, 0.08)
    }

    #[test]
    fn builder_defaults_are_mixflow_full() {
        let engine = HypergradEngine::builder().build();
        assert_eq!(engine.mode(), HypergradMode::Mixflow);
        assert_eq!(engine.policy(), CheckpointPolicy::Full);
        assert_eq!(engine.strategy_name(), "mixflow");
        assert_eq!(engine.outer_steps(), 0);
    }

    #[test]
    fn engine_matches_free_function_and_counts_steps() {
        let p = small_problem();
        let theta0 = p.theta0();
        let eta = p.eta0();
        let mut engine = HypergradEngine::builder().build();
        let a = engine.run(&p, &theta0, &eta);
        let b = mixflow_hypergrad_with(
            &p,
            &theta0,
            &eta,
            CheckpointPolicy::Full,
        );
        for (x, y) in a.d_eta.iter().zip(b.d_eta.iter()) {
            assert_eq!(x.max_abs_diff(y), 0.0, "engine vs shim bit-for-bit");
        }
        assert_eq!(engine.outer_steps(), 1);
    }

    #[test]
    fn persistent_naive_engine_reuses_buffers_on_the_second_step() {
        let p = small_problem();
        let theta0 = p.theta0();
        let eta = p.eta0();
        let mut engine =
            HypergradEngine::builder().mode(HypergradMode::Naive).build();
        let first = engine.run(&p, &theta0, &eta);
        assert_eq!(
            first.memory.arena_reuses, 0,
            "a monolithic tape's first recording has nothing to reuse"
        );
        let second = engine.run(&p, &theta0, &eta);
        assert!(
            second.memory.arena_reuses > 0,
            "second outer step must draw the first step's buffers back \
             out of the persistent arena"
        );
        for (x, y) in first.d_eta.iter().zip(second.d_eta.iter()) {
            assert_eq!(x.max_abs_diff(y), 0.0, "reuse must not change values");
        }
    }

    #[test]
    fn fd_strategy_matches_mixflow() {
        let p = small_problem();
        let theta0 = p.theta0();
        let eta = p.eta0();
        let mut fd_engine =
            HypergradEngine::builder().mode(HypergradMode::Fd).build();
        let fd = fd_engine.run(&p, &theta0, &eta);
        let mixed = mixflow_hypergrad_with(
            &p,
            &theta0,
            &eta,
            CheckpointPolicy::Full,
        );
        assert!(
            rel_err(&fd.d_eta, &mixed.d_eta) < 1e-4,
            "fd engine must agree with mixflow"
        );
        assert!((fd.outer_loss - mixed.outer_loss).abs() < 1e-9);
        assert_eq!(fd.memory.checkpoint_bytes, 0);
        assert!(fd.memory.tape_bytes > 0 && fd.memory.nodes > 0);
    }

    #[test]
    fn configure_problem_installs_the_builder_inner_opt() {
        let mut p = small_problem();
        assert_eq!(p.optimiser(), InnerOptimiser::Sgd);
        let engine = HypergradEngine::builder()
            .inner_opt(InnerOptimiser::adam())
            .build();
        engine.configure_problem(&mut p);
        assert_eq!(p.optimiser(), InnerOptimiser::adam());
    }

    #[test]
    fn custom_strategy_plugs_in() {
        struct Zero;
        impl HypergradStrategy for Zero {
            fn name(&self) -> &'static str {
                "zero"
            }
            fn run(
                &mut self,
                _tape: &mut Tape,
                _problem: &dyn BilevelProblem,
                _theta0: &[Tensor],
                eta: &[Tensor],
            ) -> Hypergrad {
                Hypergrad {
                    d_eta: eta.iter().map(|e| Tensor::zeros(&e.shape)).collect(),
                    outer_loss: 0.0,
                    memory: MemoryReport::default(),
                }
            }
        }
        let p = small_problem();
        let mut engine = HypergradEngine::with_strategy(Box::new(Zero));
        assert_eq!(engine.strategy_name(), "zero");
        let h = engine.run(&p, &p.theta0(), &p.eta0());
        assert!(h.d_eta.iter().all(|g| g.max_abs() == 0.0));
    }
}

//! The paper's §5.2 bilevel tasks, scaled to the native engine:
//!
//! * [`HyperLrProblem`] — meta-learned per-leaf learning rates
//!   (Bengio 2000): η is a log-scale LR multiplier per θ leaf, entering
//!   the unroll only through the inner optimiser `P(η) = α₀·exp(η)`.
//! * [`LossWeightingProblem`] — a meta-learned example-weighting net
//!   (Hu et al. 2023): half of each training batch comes from a noise
//!   cluster with random labels, and η parametrises a linear+sigmoid
//!   weight over inputs; the mixed ∂²L/∂η∂θ term is dense here.
//! * [`AttentionProblem`] — hyper-LR over a single-head self-attention
//!   block with row layer-normalisation: the transformer-shaped workload
//!   the paper actually benchmarks, usually driven with an Adam inner
//!   optimiser (`InnerOptimiser::adam()`).
//!
//! The first two use a 2-layer tanh MLP classifier, the attention task a
//! per-token classifier, all on a Gaussian-mixture corpus drawn from
//! [`crate::util::prng::Prng`], deterministic per seed.  Every problem
//! carries a configurable [`InnerOptimiser`] (default SGD).

use super::mixflow::BilevelProblem;
use super::optim::InnerOptimiser;
use super::tape::{NodeId, Tape};
use super::tensor::Tensor;
use crate::util::prng::Prng;

/// Gaussian-mixture classification data (plus an optional noise cluster).
struct MixtureData {
    rng: Prng,
    d: usize,
    classes: usize,
    means: Vec<f64>,      // classes × d
    noise_mean: Vec<f64>, // d
}

impl MixtureData {
    fn new(seed: u64, d: usize, classes: usize) -> MixtureData {
        let mut rng = Prng::new(seed);
        let means = rng.normal_vec_f64(classes * d, 2.0);
        let noise_mean = rng.normal_vec_f64(d, 2.0);
        MixtureData { rng, d, classes, means, noise_mean }
    }

    /// `m` examples; the first `m·corrupt_frac` are drawn from the noise
    /// cluster with uniformly random labels.
    fn batch(&mut self, m: usize, corrupt_frac: f64) -> (Tensor, Vec<usize>) {
        let mut labels: Vec<usize> = (0..m)
            .map(|_| self.rng.next_below(self.classes as u32) as usize)
            .collect();
        let mut x = vec![0.0; m * self.d];
        for i in 0..m {
            for j in 0..self.d {
                x[i * self.d + j] = self.means[labels[i] * self.d + j]
                    + 0.4 * self.rng.next_normal_f64();
            }
        }
        let corrupt = ((m as f64) * corrupt_frac) as usize;
        for i in 0..corrupt {
            for j in 0..self.d {
                x[i * self.d + j] =
                    self.noise_mean[j] + 0.4 * self.rng.next_normal_f64();
            }
            labels[i] = self.rng.next_below(self.classes as u32) as usize;
        }
        (Tensor::new(vec![m, self.d], x), labels)
    }
}

/// Per-example cross-entropy `[m]` of a 2-layer tanh MLP.
///
/// `theta = [W1 (d×h), b1 (h), W2 (h×c), b2 (c)]`; `x_id` must be a node
/// holding the `[m,d]` input batch.
pub fn mlp_ce_vec(
    tape: &mut Tape,
    x_id: NodeId,
    theta: &[NodeId],
    labels: &[usize],
) -> NodeId {
    let m = tape.shape(x_id)[0];
    let (w1, b1, w2, b2) = (theta[0], theta[1], theta[2], theta[3]);
    let xw = tape.matmul(x_id, w1, false, false);
    let b1b = tape.col_broadcast(b1, m);
    let pre = tape.add(xw, b1b);
    let h = tape.tanh(pre);
    let hw = tape.matmul(h, w2, false, false);
    let b2b = tape.col_broadcast(b2, m);
    let z = tape.add(hw, b2b);
    let lse = tape.logsumexp_rows(z);
    let picked = tape.gather_cols(z, labels);
    tape.sub(lse, picked)
}

fn mean_ce(
    tape: &mut Tape,
    batch: &(Tensor, Vec<usize>),
    theta: &[NodeId],
) -> NodeId {
    let x_id = tape.constant(batch.0.clone());
    let ce = mlp_ce_vec(tape, x_id, theta, &batch.1);
    let s = tape.sum(ce);
    tape.scale(s, 1.0 / batch.1.len() as f64)
}

fn init_theta(d: usize, hidden: usize, classes: usize, rng: &mut Prng) -> Vec<Tensor> {
    vec![
        Tensor::randn(&[d, hidden], 0.5, rng),
        Tensor::zeros(&[hidden]),
        Tensor::randn(&[hidden, classes], 0.5, rng),
        Tensor::zeros(&[classes]),
    ]
}

/// Meta-learned per-leaf learning rates (paper §5.2 task 1).
pub struct HyperLrProblem {
    data: MixtureData,
    theta_init: Vec<Tensor>,
    unroll: usize,
    alpha0: f64,
    batch: usize,
    opt: InnerOptimiser,
    train: Vec<(Tensor, Vec<usize>)>,
    val: (Tensor, Vec<usize>),
}

impl HyperLrProblem {
    pub fn new(seed: u64) -> HyperLrProblem {
        HyperLrProblem::with_config(seed, 6, 12, 4, 12, 8, 0.08)
    }

    pub fn with_config(
        seed: u64,
        d: usize,
        hidden: usize,
        classes: usize,
        batch: usize,
        unroll: usize,
        alpha0: f64,
    ) -> HyperLrProblem {
        let data = MixtureData::new(seed, d, classes);
        let mut init_rng = Prng::new(seed).fold_in(0xA11CE);
        let theta_init = init_theta(d, hidden, classes, &mut init_rng);
        let mut p = HyperLrProblem {
            data,
            theta_init,
            unroll,
            alpha0,
            batch,
            opt: InnerOptimiser::Sgd,
            train: Vec::new(),
            val: (Tensor::zeros(&[1, d]), vec![0]),
        };
        p.resample();
        p
    }

    /// Same task with a different unroll length (memory benches).
    pub fn with_unroll(seed: u64, unroll: usize) -> HyperLrProblem {
        HyperLrProblem::with_config(seed, 6, 12, 4, 12, unroll, 0.08)
    }

    /// Builder-style inner-optimiser override.
    pub fn with_optimiser(mut self, opt: InnerOptimiser) -> HyperLrProblem {
        self.opt = opt;
        self
    }
}

impl BilevelProblem for HyperLrProblem {
    fn theta0(&self) -> Vec<Tensor> {
        self.theta_init.clone()
    }

    fn eta0(&self) -> Vec<Tensor> {
        self.theta_init.iter().map(|_| Tensor::scalar(0.0)).collect()
    }

    fn unroll(&self) -> usize {
        self.unroll
    }

    fn inner_loss(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        _eta: &[NodeId],
        step: usize,
    ) -> NodeId {
        mean_ce(tape, &self.train[step % self.train.len()], theta)
    }

    fn outer_loss(&self, tape: &mut Tape, theta: &[NodeId]) -> NodeId {
        mean_ce(tape, &self.val, theta)
    }

    fn lr_nodes(&self, tape: &mut Tape, eta: &[NodeId]) -> Vec<NodeId> {
        self.theta_init
            .iter()
            .enumerate()
            .map(|(i, leaf)| {
                let e = tape.exp(eta[i]);
                let s = tape.scale(e, self.alpha0);
                tape.broadcast(s, &leaf.shape)
            })
            .collect()
    }

    fn optimiser(&self) -> InnerOptimiser {
        self.opt
    }

    fn set_optimiser(&mut self, opt: InnerOptimiser) {
        self.opt = opt;
    }

    fn resample(&mut self) {
        self.train = (0..self.unroll)
            .map(|_| self.data.batch(self.batch, 0.0))
            .collect();
        self.val = self.data.batch(self.batch * 2, 0.0);
    }
}

/// Meta-learned example weighting under label noise (paper §5.2 task 3).
pub struct LossWeightingProblem {
    data: MixtureData,
    theta_init: Vec<Tensor>,
    d: usize,
    unroll: usize,
    alpha0: f64,
    batch: usize,
    corrupt_frac: f64,
    opt: InnerOptimiser,
    train: Vec<(Tensor, Vec<usize>)>,
    val: (Tensor, Vec<usize>),
}

impl LossWeightingProblem {
    pub fn new(seed: u64) -> LossWeightingProblem {
        LossWeightingProblem::with_config(seed, 6, 12, 4, 16, 8, 0.15, 0.5)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn with_config(
        seed: u64,
        d: usize,
        hidden: usize,
        classes: usize,
        batch: usize,
        unroll: usize,
        alpha0: f64,
        corrupt_frac: f64,
    ) -> LossWeightingProblem {
        let data = MixtureData::new(seed, d, classes);
        let mut init_rng = Prng::new(seed).fold_in(0xB0B);
        let theta_init = init_theta(d, hidden, classes, &mut init_rng);
        let mut p = LossWeightingProblem {
            data,
            theta_init,
            d,
            unroll,
            alpha0,
            batch,
            corrupt_frac,
            opt: InnerOptimiser::Sgd,
            train: Vec::new(),
            val: (Tensor::zeros(&[1, d]), vec![0]),
        };
        p.resample();
        p
    }

    pub fn with_unroll(seed: u64, unroll: usize) -> LossWeightingProblem {
        LossWeightingProblem::with_config(seed, 6, 12, 4, 16, unroll, 0.15, 0.5)
    }

    /// Builder-style inner-optimiser override.
    pub fn with_optimiser(
        mut self,
        opt: InnerOptimiser,
    ) -> LossWeightingProblem {
        self.opt = opt;
        self
    }
}

impl BilevelProblem for LossWeightingProblem {
    fn theta0(&self) -> Vec<Tensor> {
        self.theta_init.clone()
    }

    fn eta0(&self) -> Vec<Tensor> {
        vec![Tensor::zeros(&[self.d, 1]), Tensor::scalar(0.0)]
    }

    fn unroll(&self) -> usize {
        self.unroll
    }

    fn inner_loss(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        eta: &[NodeId],
        step: usize,
    ) -> NodeId {
        let batch = &self.train[step % self.train.len()];
        let m = batch.1.len();
        let x_id = tape.constant(batch.0.clone());
        let ce = mlp_ce_vec(tape, x_id, theta, &batch.1);
        // w = σ(x·v + c) via σ(z) = ½(1 + tanh(z/2)) — in (0, 1).
        let z2 = tape.matmul(x_id, eta[0], false, false);
        let z = tape.reshape(z2, vec![m]);
        let cb = tape.broadcast(eta[1], &[m]);
        let zc = tape.add(z, cb);
        let half = tape.scale(zc, 0.5);
        let th = tape.tanh(half);
        let sh = tape.scale(th, 0.5);
        let w = tape.offset(sh, 0.5);
        let wce = tape.mul(w, ce);
        let s = tape.sum(wce);
        tape.scale(s, 1.0 / m as f64)
    }

    fn outer_loss(&self, tape: &mut Tape, theta: &[NodeId]) -> NodeId {
        mean_ce(tape, &self.val, theta)
    }

    fn lr_nodes(&self, tape: &mut Tape, _eta: &[NodeId]) -> Vec<NodeId> {
        self.theta_init
            .iter()
            .map(|leaf| {
                let a = tape.constant(Tensor::scalar(self.alpha0));
                tape.broadcast(a, &leaf.shape)
            })
            .collect()
    }

    fn optimiser(&self) -> InnerOptimiser {
        self.opt
    }

    fn set_optimiser(&mut self, opt: InnerOptimiser) {
        self.opt = opt;
    }

    fn resample(&mut self) {
        self.train = (0..self.unroll)
            .map(|_| self.data.batch(self.batch, self.corrupt_frac))
            .collect();
        self.val = self.data.batch(self.batch * 2, 0.0);
    }
}

/// Per-token cross-entropy `[s]` of a single-head self-attention block
/// with row layer-normalisation.
///
/// `theta = [Wq (d×d), Wk (d×d), Wv (d×d), Wo (d×c)]`; `x_id` must be a
/// node holding the `[s,d]` token batch.  Scores are scaled by `1/√d`,
/// the attended values are layer-normalised per token, and `Wo` projects
/// to class logits.
pub fn attention_ce_vec(
    tape: &mut Tape,
    x_id: NodeId,
    theta: &[NodeId],
    labels: &[usize],
) -> NodeId {
    let d = tape.shape(x_id)[1];
    let (wq, wk, wv, wo) = (theta[0], theta[1], theta[2], theta[3]);
    let q = tape.matmul(x_id, wq, false, false);
    let k = tape.matmul(x_id, wk, false, false);
    let v = tape.matmul(x_id, wv, false, false);
    tape.mark_kv(k);
    tape.mark_kv(v);
    let scores = tape.matmul(q, k, false, true);
    let scaled = tape.scale(scores, 1.0 / (d as f64).sqrt());
    let attn = tape.softmax_rows(scaled);
    let ctx = tape.matmul(attn, v, false, false);
    let normed = tape.layernorm_rows(ctx, 1e-5);
    let z = tape.matmul(normed, wo, false, false);
    let lse = tape.logsumexp_rows(z);
    let picked = tape.gather_cols(z, labels);
    tape.sub(lse, picked)
}

/// Hyper-LR over a single-head self-attention block (the transformer
/// configuration the paper benchmarks; pair with
/// [`InnerOptimiser::adam`] for the headline workload).  Tokens are
/// drawn from the Gaussian-mixture corpus; every token is classified
/// into its mixture component, and η is a log-scale LR multiplier per θ
/// leaf exactly as in [`HyperLrProblem`].
pub struct AttentionProblem {
    data: MixtureData,
    theta_init: Vec<Tensor>,
    seq: usize,
    unroll: usize,
    alpha0: f64,
    opt: InnerOptimiser,
    train: Vec<(Tensor, Vec<usize>)>,
    val: (Tensor, Vec<usize>),
}

impl AttentionProblem {
    /// α₀ defaults deliberately small: the meta-learned multipliers must
    /// *grow* the LRs to cut the post-unroll validation loss, which gives
    /// the E2E runs an unambiguous improvement signal.
    pub fn new(seed: u64) -> AttentionProblem {
        AttentionProblem::with_config(seed, 6, 8, 4, 8, 0.01)
    }

    pub fn with_config(
        seed: u64,
        d: usize,
        seq: usize,
        classes: usize,
        unroll: usize,
        alpha0: f64,
    ) -> AttentionProblem {
        let data = MixtureData::new(seed, d, classes);
        let mut init_rng = Prng::new(seed).fold_in(0xA77E);
        let theta_init = vec![
            Tensor::randn(&[d, d], 0.5, &mut init_rng),
            Tensor::randn(&[d, d], 0.5, &mut init_rng),
            Tensor::randn(&[d, d], 0.5, &mut init_rng),
            Tensor::randn(&[d, classes], 0.5, &mut init_rng),
        ];
        let mut p = AttentionProblem {
            data,
            theta_init,
            seq,
            unroll,
            alpha0,
            opt: InnerOptimiser::Sgd,
            train: Vec::new(),
            val: (Tensor::zeros(&[1, d]), vec![0]),
        };
        p.resample();
        p
    }

    /// Same task with a different unroll length (memory benches).
    pub fn with_unroll(seed: u64, unroll: usize) -> AttentionProblem {
        AttentionProblem::with_config(seed, 6, 8, 4, unroll, 0.01)
    }

    /// Builder-style inner-optimiser override.
    pub fn with_optimiser(mut self, opt: InnerOptimiser) -> AttentionProblem {
        self.opt = opt;
        self
    }

    fn mean_attention_ce(
        &self,
        tape: &mut Tape,
        batch: &(Tensor, Vec<usize>),
        theta: &[NodeId],
    ) -> NodeId {
        let x_id = tape.constant(batch.0.clone());
        let ce = attention_ce_vec(tape, x_id, theta, &batch.1);
        let s = tape.sum(ce);
        tape.scale(s, 1.0 / batch.1.len() as f64)
    }
}

impl BilevelProblem for AttentionProblem {
    fn theta0(&self) -> Vec<Tensor> {
        self.theta_init.clone()
    }

    fn eta0(&self) -> Vec<Tensor> {
        self.theta_init.iter().map(|_| Tensor::scalar(0.0)).collect()
    }

    fn unroll(&self) -> usize {
        self.unroll
    }

    fn inner_loss(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        _eta: &[NodeId],
        step: usize,
    ) -> NodeId {
        self.mean_attention_ce(
            tape,
            &self.train[step % self.train.len()],
            theta,
        )
    }

    fn outer_loss(&self, tape: &mut Tape, theta: &[NodeId]) -> NodeId {
        self.mean_attention_ce(tape, &self.val, theta)
    }

    fn lr_nodes(&self, tape: &mut Tape, eta: &[NodeId]) -> Vec<NodeId> {
        self.theta_init
            .iter()
            .enumerate()
            .map(|(i, leaf)| {
                let e = tape.exp(eta[i]);
                let s = tape.scale(e, self.alpha0);
                tape.broadcast(s, &leaf.shape)
            })
            .collect()
    }

    fn optimiser(&self) -> InnerOptimiser {
        self.opt
    }

    fn set_optimiser(&mut self, opt: InnerOptimiser) {
        self.opt = opt;
    }

    fn resample(&mut self) {
        self.train = (0..self.unroll)
            .map(|_| self.data.batch(self.seq, 0.0))
            .collect();
        self.val = self.data.batch(self.seq * 2, 0.0);
    }
}

/// Per-token cross-entropy `[b·s]` of a **multi-head, batched**
/// self-attention block with row layer-normalisation.
///
/// `theta = [Wq (d×d), Wk (d×d), Wv (d×d), Wo (d×c)]` exactly as the
/// single-head [`attention_ce_vec`]; the heads live in column blocks of
/// the shared projections.  `x_id` must hold a `[b·s, d]` token batch —
/// `b` sequences of `s = rows / b` tokens each, flattened row-major, so
/// attention is block-diagonal over the `b` sequences.  Per head `h`
/// (width `d_h = d / heads`):
///
/// 1. split columns `[h·d_h, (h+1)·d_h)` out of the shared Q/K/V
///    projections ([`Tape::split_cols`]),
/// 2. reshape `[b·s, d_h] → [b, s, d_h]` (zero-copy — row-major blocks
///    are already contiguous per sequence),
/// 3. batched scores `Q·Kᵀ / √d_h` over the `b` groups
///    ([`Tape::batch_matmul`]), row softmax, batched context `A·V`,
/// 4. reshape back and head-stack the contexts ([`Tape::concat_cols`]).
///
/// With `heads = 1, b = 1` every step degenerates to the single-head
/// path bit-for-bit (the splits/concats are exact copies and a
/// one-group batched matmul runs the identical kernel loop).  The K and
/// V projections are tagged via [`Tape::mark_kv`] so `MemoryReport`'s
/// KV-reuse counters see them.
pub fn multihead_attention_ce_vec(
    tape: &mut Tape,
    x_id: NodeId,
    theta: &[NodeId],
    labels: &[usize],
    heads: usize,
    batch: usize,
) -> NodeId {
    let rows = tape.shape(x_id)[0];
    let d = tape.shape(x_id)[1];
    assert!(heads >= 1, "heads must be >= 1");
    assert!(batch >= 1, "batch must be >= 1");
    assert_eq!(rows % batch, 0, "token rows {rows} not divisible by batch {batch}");
    assert_eq!(d % heads, 0, "d_model {d} not divisible by heads {heads}");
    let s = rows / batch;
    let dh = d / heads;
    let (wq, wk, wv, wo) = (theta[0], theta[1], theta[2], theta[3]);
    let q = tape.matmul(x_id, wq, false, false);
    let k = tape.matmul(x_id, wk, false, false);
    let v = tape.matmul(x_id, wv, false, false);
    tape.mark_kv(k);
    tape.mark_kv(v);
    let mut head_ctx = Vec::with_capacity(heads);
    for h in 0..heads {
        let off = h * dh;
        let qh = tape.split_cols(q, off, dh);
        let kh = tape.split_cols(k, off, dh);
        let vh = tape.split_cols(v, off, dh);
        let q3 = tape.reshape(qh, vec![batch, s, dh]);
        let k3 = tape.reshape(kh, vec![batch, s, dh]);
        let v3 = tape.reshape(vh, vec![batch, s, dh]);
        let scores = tape.batch_matmul(q3, k3, false, true);
        let scaled = tape.scale(scores, 1.0 / (dh as f64).sqrt());
        let flat = tape.reshape(scaled, vec![batch * s, s]);
        let attn = tape.softmax_rows(flat);
        let attn3 = tape.reshape(attn, vec![batch, s, s]);
        let ctx = tape.batch_matmul(attn3, v3, false, false);
        head_ctx.push(tape.reshape(ctx, vec![batch * s, dh]));
    }
    let ctx = tape.concat_cols(&head_ctx);
    let normed = tape.layernorm_rows(ctx, 1e-5);
    let z = tape.matmul(normed, wo, false, false);
    let lse = tape.logsumexp_rows(z);
    let picked = tape.gather_cols(z, labels);
    tape.sub(lse, picked)
}

/// Hyper-LR over a **multi-head, batched** self-attention block — the
/// shape-for-shape match of the paper's transformer benchmark setting.
/// `heads = 1, batch = 1` reproduces [`AttentionProblem`] bit-for-bit
/// (same data stream, same θ init, degenerate tape ops), which the
/// conformance proptest in `rust/tests/autodiff.rs` pins.
///
/// Training batches hold `batch` sequences of `seq` tokens; the
/// validation batch holds `batch` sequences of `2·seq` tokens (the
/// sequence count is fixed at `batch`, so the per-forward group count
/// never changes).  η is a log-scale LR multiplier per θ leaf exactly as
/// in [`HyperLrProblem`].
pub struct MultiHeadAttentionProblem {
    data: MixtureData,
    theta_init: Vec<Tensor>,
    heads: usize,
    batch: usize,
    seq: usize,
    unroll: usize,
    alpha0: f64,
    opt: InnerOptimiser,
    train: Vec<(Tensor, Vec<usize>)>,
    val: (Tensor, Vec<usize>),
}

impl MultiHeadAttentionProblem {
    /// Default multi-head shape: d_model 6, 2 heads × head dim 3,
    /// 2-sequence batches, α₀ deliberately small like
    /// [`AttentionProblem::new`].
    pub fn new(seed: u64) -> MultiHeadAttentionProblem {
        MultiHeadAttentionProblem::with_config(seed, 6, 2, 2, 8, 4, 8, 0.01)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn with_config(
        seed: u64,
        d_model: usize,
        heads: usize,
        batch: usize,
        seq: usize,
        classes: usize,
        unroll: usize,
        alpha0: f64,
    ) -> MultiHeadAttentionProblem {
        assert!(heads >= 1, "heads must be >= 1");
        assert!(batch >= 1, "batch must be >= 1");
        assert_eq!(
            d_model % heads,
            0,
            "d_model {d_model} not divisible by heads {heads}"
        );
        let data = MixtureData::new(seed, d_model, classes);
        // Same init stream as AttentionProblem (fold 0xA77E, three d×d
        // projections + the d×c output head) so heads=1/batch=1 is
        // bit-for-bit the single-head problem.
        let mut init_rng = Prng::new(seed).fold_in(0xA77E);
        let theta_init = vec![
            Tensor::randn(&[d_model, d_model], 0.5, &mut init_rng),
            Tensor::randn(&[d_model, d_model], 0.5, &mut init_rng),
            Tensor::randn(&[d_model, d_model], 0.5, &mut init_rng),
            Tensor::randn(&[d_model, classes], 0.5, &mut init_rng),
        ];
        let mut p = MultiHeadAttentionProblem {
            data,
            theta_init,
            heads,
            batch,
            seq,
            unroll,
            alpha0,
            opt: InnerOptimiser::Sgd,
            train: Vec::new(),
            val: (Tensor::zeros(&[1, d_model]), vec![0]),
        };
        p.resample();
        p
    }

    /// Same task with a different unroll length (memory benches).
    pub fn with_unroll(seed: u64, unroll: usize) -> MultiHeadAttentionProblem {
        MultiHeadAttentionProblem::with_config(seed, 6, 2, 2, 8, 4, unroll, 0.01)
    }

    /// Builder-style inner-optimiser override.
    pub fn with_optimiser(
        mut self,
        opt: InnerOptimiser,
    ) -> MultiHeadAttentionProblem {
        self.opt = opt;
        self
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    fn mean_ce(
        &self,
        tape: &mut Tape,
        batch: &(Tensor, Vec<usize>),
        theta: &[NodeId],
    ) -> NodeId {
        let x_id = tape.constant(batch.0.clone());
        let ce = multihead_attention_ce_vec(
            tape, x_id, theta, &batch.1, self.heads, self.batch,
        );
        let s = tape.sum(ce);
        tape.scale(s, 1.0 / batch.1.len() as f64)
    }
}

impl BilevelProblem for MultiHeadAttentionProblem {
    fn theta0(&self) -> Vec<Tensor> {
        self.theta_init.clone()
    }

    fn eta0(&self) -> Vec<Tensor> {
        self.theta_init.iter().map(|_| Tensor::scalar(0.0)).collect()
    }

    fn unroll(&self) -> usize {
        self.unroll
    }

    fn inner_loss(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        _eta: &[NodeId],
        step: usize,
    ) -> NodeId {
        self.mean_ce(tape, &self.train[step % self.train.len()], theta)
    }

    fn outer_loss(&self, tape: &mut Tape, theta: &[NodeId]) -> NodeId {
        self.mean_ce(tape, &self.val, theta)
    }

    fn lr_nodes(&self, tape: &mut Tape, eta: &[NodeId]) -> Vec<NodeId> {
        self.theta_init
            .iter()
            .enumerate()
            .map(|(i, leaf)| {
                let e = tape.exp(eta[i]);
                let s = tape.scale(e, self.alpha0);
                tape.broadcast(s, &leaf.shape)
            })
            .collect()
    }

    fn optimiser(&self) -> InnerOptimiser {
        self.opt
    }

    fn set_optimiser(&mut self, opt: InnerOptimiser) {
        self.opt = opt;
    }

    fn resample(&mut self) {
        // Same PRNG consumption as AttentionProblem when batch = 1:
        // batch·seq tokens per train step, batch·seq·2 for validation
        // (i.e. the same `batch` sequence count with doubled length).
        self.train = (0..self.unroll)
            .map(|_| self.data.batch(self.batch * self.seq, 0.0))
            .collect();
        self.val = self.data.batch(self.batch * self.seq * 2, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_deterministic_and_in_range() {
        let mut a = MixtureData::new(3, 4, 5);
        let mut b = MixtureData::new(3, 4, 5);
        let (xa, ya) = a.batch(6, 0.0);
        let (xb, yb) = b.batch(6, 0.0);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        assert_eq!(xa.shape, vec![6, 4]);
        assert!(ya.iter().all(|&y| y < 5));
    }

    #[test]
    fn inner_loss_is_finite_scalar() {
        let prob = HyperLrProblem::new(11);
        let mut tape = Tape::new();
        let theta: Vec<NodeId> = prob
            .theta0()
            .into_iter()
            .map(|t| tape.leaf(t))
            .collect();
        let eta: Vec<NodeId> =
            prob.eta0().into_iter().map(|t| tape.leaf(t)).collect();
        let l = prob.inner_loss(&mut tape, &theta, &eta, 0);
        assert!(tape.value(l).item().is_finite());
        assert!(tape.value(l).item() > 0.0, "CE must be positive");
    }

    #[test]
    fn weighting_loss_depends_on_eta() {
        // ∇_η of the weighted inner loss must be non-zero (dense mixed
        // term is the whole point of the task).
        let prob = LossWeightingProblem::new(17);
        let mut tape = Tape::new();
        let theta: Vec<NodeId> = prob
            .theta0()
            .into_iter()
            .map(|t| tape.leaf(t))
            .collect();
        let eta: Vec<NodeId> =
            prob.eta0().into_iter().map(|t| tape.leaf(t)).collect();
        let l = prob.inner_loss(&mut tape, &theta, &eta, 0);
        let g = tape.grad(l, &eta);
        let total: f64 = g.iter().map(|&id| tape.value(id).max_abs()).sum();
        assert!(total > 1e-8, "eta gradient unexpectedly zero");
    }

    #[test]
    fn attention_loss_is_finite_scalar_and_theta_sensitive() {
        let prob = AttentionProblem::new(23);
        let mut tape = Tape::new();
        let theta: Vec<NodeId> = prob
            .theta0()
            .into_iter()
            .map(|t| tape.leaf(t))
            .collect();
        let eta: Vec<NodeId> =
            prob.eta0().into_iter().map(|t| tape.leaf(t)).collect();
        let l = prob.inner_loss(&mut tape, &theta, &eta, 0);
        assert!(tape.value(l).item().is_finite());
        assert!(tape.value(l).item() > 0.0, "CE must be positive");
        let g = tape.grad(l, &theta);
        let total: f64 = g.iter().map(|&id| tape.value(id).max_abs()).sum();
        assert!(total > 1e-8, "attention θ gradient unexpectedly zero");
    }

    #[test]
    fn attention_default_optimiser_is_configurable() {
        let mut prob = AttentionProblem::new(3);
        assert_eq!(prob.optimiser(), InnerOptimiser::Sgd);
        prob.set_optimiser(InnerOptimiser::adam());
        assert_eq!(prob.optimiser(), InnerOptimiser::adam());
        let prob2 =
            AttentionProblem::new(3).with_optimiser(InnerOptimiser::momentum());
        assert_eq!(prob2.optimiser(), InnerOptimiser::momentum());
    }

    #[test]
    fn multihead_loss_is_finite_and_theta_sensitive() {
        let prob = MultiHeadAttentionProblem::with_config(
            29, 6, 3, 2, 4, 4, 3, 0.05,
        );
        assert_eq!(prob.heads(), 3);
        assert_eq!(prob.batch(), 2);
        let mut tape = Tape::new();
        let theta: Vec<NodeId> =
            prob.theta0().into_iter().map(|t| tape.leaf(t)).collect();
        let eta: Vec<NodeId> =
            prob.eta0().into_iter().map(|t| tape.leaf(t)).collect();
        let l = prob.inner_loss(&mut tape, &theta, &eta, 0);
        assert!(tape.value(l).item().is_finite());
        assert!(tape.value(l).item() > 0.0, "CE must be positive");
        let g = tape.grad(l, &theta);
        let total: f64 = g.iter().map(|&id| tape.value(id).max_abs()).sum();
        assert!(total > 1e-8, "multihead θ gradient unexpectedly zero");
        assert!(
            tape.stats().kv_bytes > 0,
            "K/V projections must be tagged on the tape"
        );
    }

    #[test]
    fn multihead_heads1_batch1_matches_single_head_loss_values() {
        // The degenerate configuration must reproduce the single-head
        // problem's loss value exactly (full hypergradient conformance
        // is property-tested in rust/tests/autodiff.rs).
        let old = AttentionProblem::with_config(31, 4, 5, 3, 2, 0.03);
        let new = MultiHeadAttentionProblem::with_config(
            31, 4, 1, 1, 5, 3, 2, 0.03,
        );
        for (a, b) in old.theta0().iter().zip(new.theta0().iter()) {
            assert_eq!(a.data, b.data, "theta init must match");
        }
        let mut t_old = Tape::new();
        let theta: Vec<NodeId> =
            old.theta0().into_iter().map(|t| t_old.leaf(t)).collect();
        let eta: Vec<NodeId> =
            old.eta0().into_iter().map(|t| t_old.leaf(t)).collect();
        let l_old = old.inner_loss(&mut t_old, &theta, &eta, 0);
        let mut t_new = Tape::new();
        let theta: Vec<NodeId> =
            new.theta0().into_iter().map(|t| t_new.leaf(t)).collect();
        let eta: Vec<NodeId> =
            new.eta0().into_iter().map(|t| t_new.leaf(t)).collect();
        let l_new = new.inner_loss(&mut t_new, &theta, &eta, 0);
        assert_eq!(
            t_old.value(l_old).item(),
            t_new.value(l_new).item(),
            "heads=1/batch=1 inner loss must be bit-for-bit single-head"
        );
    }

    #[test]
    #[should_panic(expected = "not divisible by heads")]
    fn multihead_rejects_indivisible_d_model() {
        MultiHeadAttentionProblem::with_config(1, 6, 4, 1, 4, 3, 2, 0.05);
    }

    #[test]
    fn lr_nodes_match_leaf_shapes() {
        let prob = HyperLrProblem::new(2);
        let mut tape = Tape::new();
        let eta: Vec<NodeId> =
            prob.eta0().into_iter().map(|t| tape.leaf(t)).collect();
        let lrs = prob.lr_nodes(&mut tape, &eta);
        for (lr, leaf) in lrs.iter().zip(prob.theta0().iter()) {
            assert_eq!(tape.shape(*lr), leaf.shape);
            // η = 0 → multiplier exp(0)·α₀ = α₀ everywhere.
            for v in &tape.value(*lr).data {
                assert!((v - 0.08).abs() < 1e-12);
            }
        }
    }
}

//! Length-keyed free-list arena for tensor backing buffers.
//!
//! The native engine's hot loops (per-step tapes in
//! [`super::mixflow::mixflow_hypergrad`], the adjoint sweep, the JVP
//! overlay) build and drop the *same* tensor shapes T times per
//! hypergradient.  Allocating a fresh `Vec<f64>` per node made the
//! allocator the bottleneck.  The arena parks uniquely-owned buffers when
//! a tape is [`reset`](super::tape::Tape::reset) and hands them back out
//! keyed by exact element count, so steady-state step tapes run with
//! (almost) zero allocator traffic.
//!
//! Safety invariant: every `Arc` parked on the free list has a strong
//! count of exactly 1 — [`BufferArena::recycle`] refuses shared buffers
//! (checkpoints, returned hypergradients, aliased views keep theirs
//! alive), and [`BufferArena::take`] hands each parked buffer out at most
//! once.  A violation would panic in the tape's `Arc::get_mut`, never
//! silently corrupt values.
//!
//! **Plan mode** (see [`super::plan`]): when a compiled step plan is
//! replaying, the tape *arms* the arena with a positional slot table —
//! one optional unique `Arc` per scheduled take, in take order — and
//! every `take` is served by moving the Arc out of the next slot: direct
//! indexing, no length-keyed `HashMap` probe.  The same invariant holds
//! (slots only ever hold strong-count-1 Arcs, each moved out at most
//! once), and any disagreement with the schedule — a length mismatch, an
//! empty slot (the buffer escaped to a caller last cycle), or a take
//! past the scheduled count (the JVP overlay's tangent region) — falls
//! back to the ordinary free-list path, so a diverged replay can degrade
//! performance but never values.

use std::collections::HashMap;
use std::sync::Arc;

use super::tensor::{Tensor, ELEM_BYTES};

/// Traffic counters for one arena (surfaced in
/// [`super::mixflow::MemoryReport`] and mirrored per outer step into the
/// `obs` metrics registry by the engine).
#[derive(Debug, Clone, Copy, Default)]
pub struct ArenaStats {
    /// Buffers allocated fresh from the system allocator.
    pub allocs: usize,
    /// Buffers served from the free list instead of the allocator.
    pub reuses: usize,
    /// Buffers returned to the free list so far.
    pub recycled: usize,
    /// Cumulative bytes of freshly allocated buffers.
    pub alloc_bytes: usize,
    /// Cumulative bytes served from the free list.
    pub reuse_bytes: usize,
    /// Cumulative bytes returned to the free list.
    pub recycle_bytes: usize,
    /// Bytes currently parked on the free list.
    pub free_bytes: usize,
    /// Buffers currently parked on the free list.
    pub free_buffers: usize,
}

/// Armed replay state: the positional slot table of a compiled plan.
struct ArmedPlan {
    /// One optional unique buffer per scheduled take, in take order.
    slots: Vec<Option<Arc<Vec<f64>>>>,
    /// Scheduled element count per take (shared with the `StepPlan`).
    lens: Arc<[usize]>,
    /// Next take position.
    cursor: usize,
    /// A take's length disagreed with the schedule.
    diverged: bool,
}

/// The free list itself: `element count → parked buffers`.
#[derive(Default)]
pub struct BufferArena {
    free: HashMap<usize, Vec<Arc<Vec<f64>>>>,
    plan: Option<ArmedPlan>,
    allocs: usize,
    reuses: usize,
    recycled: usize,
    alloc_bytes: usize,
    reuse_bytes: usize,
    recycle_bytes: usize,
}

impl BufferArena {
    pub fn new() -> BufferArena {
        BufferArena::default()
    }

    /// Hand out a uniquely-owned buffer of exactly `len` elements.  The
    /// contents are unspecified (stale values from a recycled buffer):
    /// every kernel writing into it must overwrite all elements.
    ///
    /// While armed (plan replay), the take is served from the plan's
    /// slot for this position when the scheduled length agrees; slot
    /// serves count as `reuses` like free-list hits (both bypass the
    /// allocator).  Disagreements fall through to the free-list path.
    pub fn take(&mut self, len: usize) -> Arc<Vec<f64>> {
        if let Some(plan) = self.plan.as_mut() {
            let pos = plan.cursor;
            plan.cursor += 1;
            if pos < plan.lens.len() {
                if plan.lens[pos] == len {
                    if let Some(buf) = plan.slots[pos].take() {
                        self.reuses += 1;
                        self.reuse_bytes += len * ELEM_BYTES;
                        return buf;
                    }
                } else {
                    plan.diverged = true;
                }
            }
        }
        match self.free.get_mut(&len).and_then(|v| v.pop()) {
            Some(buf) => {
                self.reuses += 1;
                self.reuse_bytes += len * ELEM_BYTES;
                buf
            }
            None => {
                self.allocs += 1;
                self.alloc_bytes += len * ELEM_BYTES;
                Arc::new(vec![0.0; len])
            }
        }
    }

    /// Enter plan mode for one replay cycle.  `slots[i]` (when `Some`)
    /// must hold a strong-count-1 Arc of exactly `lens[i]` elements.
    pub(crate) fn arm(
        &mut self,
        slots: Vec<Option<Arc<Vec<f64>>>>,
        lens: Arc<[usize]>,
    ) {
        debug_assert!(self.plan.is_none(), "arena already armed");
        debug_assert_eq!(slots.len(), lens.len(), "slot table vs schedule");
        self.plan = Some(ArmedPlan { slots, lens, cursor: 0, diverged: false });
    }

    /// Leave plan mode: `(leftover slots, takes observed, diverged)`.
    /// After a clean replay every slot is `None`; leftovers mean the
    /// cycle diverged or shrank and should be parked via
    /// [`BufferArena::park`].
    pub(crate) fn disarm(&mut self) -> (Vec<Option<Arc<Vec<f64>>>>, usize, bool) {
        let plan = self.plan.take().expect("arena not armed");
        (plan.slots, plan.cursor, plan.diverged)
    }

    /// Whether a replay plan is currently armed.  A `true` outside a
    /// plan cycle means an unwind escaped between `arm` and `disarm` —
    /// one of the invariants `Tape::invariants_ok` checks.
    pub(crate) fn is_armed(&self) -> bool {
        self.plan.is_some()
    }

    /// Park a uniquely-owned raw buffer on the free list (plan-mode
    /// bookkeeping: leftover slots, takes past the scheduled region).
    pub(crate) fn park(&mut self, arc: Arc<Vec<f64>>) {
        debug_assert_eq!(Arc::strong_count(&arc), 1, "parking a shared buffer");
        self.recycled += 1;
        self.recycle_bytes += arc.len() * ELEM_BYTES;
        self.free.entry(arc.len()).or_default().push(arc);
    }

    /// Count a buffer parked into a plan slot (it bypasses the free
    /// list but is recycled traffic all the same).
    pub(crate) fn note_parked(&mut self, len: usize) {
        self.recycled += 1;
        self.recycle_bytes += len * ELEM_BYTES;
    }

    /// Return a tensor's backing buffer to the free list if this tensor
    /// was the last reference to it.  Shared buffers — checkpoints,
    /// hypergradient outputs, aliased views — are simply dropped here and
    /// stay alive through their other handles.
    pub fn recycle(&mut self, t: Tensor) {
        let arc = t.into_data().into_arc();
        if Arc::strong_count(&arc) == 1 {
            self.recycled += 1;
            self.recycle_bytes += arc.len() * ELEM_BYTES;
            self.free.entry(arc.len()).or_default().push(arc);
        }
    }

    pub fn stats(&self) -> ArenaStats {
        let mut free_bytes = 0usize;
        let mut free_buffers = 0usize;
        for bucket in self.free.values() {
            free_buffers += bucket.len();
            free_bytes += bucket
                .iter()
                .map(|b| b.len() * ELEM_BYTES)
                .sum::<usize>();
        }
        ArenaStats {
            allocs: self.allocs,
            reuses: self.reuses,
            recycled: self.recycled,
            alloc_bytes: self.alloc_bytes,
            reuse_bytes: self.reuse_bytes,
            recycle_bytes: self.recycle_bytes,
            free_bytes,
            free_buffers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_take_reuses_the_buffer() {
        let mut arena = BufferArena::new();
        let t = Tensor::from_shared(vec![4], arena.take(4));
        assert_eq!(arena.stats().allocs, 1);
        arena.recycle(t);
        assert_eq!(arena.stats().free_buffers, 1);
        let _again = arena.take(4);
        let s = arena.stats();
        assert_eq!(s.reuses, 1);
        assert_eq!(s.free_buffers, 0);
    }

    #[test]
    fn shared_buffers_are_not_recycled() {
        let mut arena = BufferArena::new();
        let t = Tensor::from_shared(vec![3], arena.take(3));
        let keep = t.clone(); // second handle to the same allocation
        arena.recycle(t);
        assert_eq!(arena.stats().free_buffers, 0, "shared buffer parked");
        assert_eq!(keep.elements(), 3);
    }

    #[test]
    fn lengths_are_keyed_exactly() {
        let mut arena = BufferArena::new();
        let t = Tensor::from_shared(vec![8], arena.take(8));
        arena.recycle(t);
        let _other = arena.take(4); // different length: fresh alloc
        let s = arena.stats();
        assert_eq!(s.allocs, 2);
        assert_eq!(s.reuses, 0);
        assert_eq!(s.free_buffers, 1, "len-8 buffer still parked");
        assert_eq!(s.free_bytes, 64);
        // Byte-traffic counters: 8 + 4 elements allocated fresh, the
        // len-8 buffer parked once, nothing reused yet.
        assert_eq!(s.alloc_bytes, 96);
        assert_eq!(s.recycle_bytes, 64);
        assert_eq!(s.reuse_bytes, 0);
        let _back = arena.take(8);
        assert_eq!(arena.stats().reuse_bytes, 64);
    }

    #[test]
    fn armed_takes_serve_slots_positionally() {
        let mut arena = BufferArena::new();
        let a = arena.take(4);
        let b = arena.take(8);
        let lens: Arc<[usize]> = Arc::from(vec![4usize, 8]);
        arena.arm(vec![Some(a), Some(b)], lens);
        let base = arena.stats();
        let s0 = arena.take(4); // slot 0
        assert_eq!(s0.len(), 4);
        let _s1 = arena.take(8); // slot 1
        let _extra = arena.take(16); // past the schedule: free-list path
        let (slots, takes, diverged) = arena.disarm();
        assert!(slots.iter().all(Option::is_none));
        assert_eq!(takes, 3);
        assert!(!diverged);
        let s = arena.stats();
        assert_eq!(s.reuses - base.reuses, 2, "slot serves count as reuses");
        assert_eq!(
            s.allocs - base.allocs,
            1,
            "only the off-schedule take allocates"
        );
    }

    #[test]
    fn length_mismatch_marks_divergence_but_stays_correct() {
        let mut arena = BufferArena::new();
        let a = arena.take(4);
        let lens: Arc<[usize]> = Arc::from(vec![4usize]);
        arena.arm(vec![Some(a)], lens);
        let wrong = arena.take(6); // schedule said 4
        assert_eq!(wrong.len(), 6, "fallback hands out the right length");
        let (slots, _, diverged) = arena.disarm();
        assert!(diverged);
        assert!(slots[0].is_some(), "mismatched slot is left for parking");
    }
}

//! Length-keyed free-list arena for tensor backing buffers.
//!
//! The native engine's hot loops (per-step tapes in
//! [`super::mixflow::mixflow_hypergrad`], the adjoint sweep, the JVP
//! overlay) build and drop the *same* tensor shapes T times per
//! hypergradient.  Allocating a fresh `Vec<f64>` per node made the
//! allocator the bottleneck.  The arena parks uniquely-owned buffers when
//! a tape is [`reset`](super::tape::Tape::reset) and hands them back out
//! keyed by exact element count, so steady-state step tapes run with
//! (almost) zero allocator traffic.
//!
//! Safety invariant: every `Arc` parked on the free list has a strong
//! count of exactly 1 — [`BufferArena::recycle`] refuses shared buffers
//! (checkpoints, returned hypergradients, aliased views keep theirs
//! alive), and [`BufferArena::take`] hands each parked buffer out at most
//! once.  A violation would panic in the tape's `Arc::get_mut`, never
//! silently corrupt values.

use std::collections::HashMap;
use std::sync::Arc;

use super::tensor::{Tensor, ELEM_BYTES};

/// Traffic counters for one arena (surfaced in
/// [`super::mixflow::MemoryReport`] and mirrored per outer step into the
/// `obs` metrics registry by the engine).
#[derive(Debug, Clone, Copy, Default)]
pub struct ArenaStats {
    /// Buffers allocated fresh from the system allocator.
    pub allocs: usize,
    /// Buffers served from the free list instead of the allocator.
    pub reuses: usize,
    /// Buffers returned to the free list so far.
    pub recycled: usize,
    /// Cumulative bytes of freshly allocated buffers.
    pub alloc_bytes: usize,
    /// Cumulative bytes served from the free list.
    pub reuse_bytes: usize,
    /// Cumulative bytes returned to the free list.
    pub recycle_bytes: usize,
    /// Bytes currently parked on the free list.
    pub free_bytes: usize,
    /// Buffers currently parked on the free list.
    pub free_buffers: usize,
}

/// The free list itself: `element count → parked buffers`.
#[derive(Default)]
pub struct BufferArena {
    free: HashMap<usize, Vec<Arc<Vec<f64>>>>,
    allocs: usize,
    reuses: usize,
    recycled: usize,
    alloc_bytes: usize,
    reuse_bytes: usize,
    recycle_bytes: usize,
}

impl BufferArena {
    pub fn new() -> BufferArena {
        BufferArena::default()
    }

    /// Hand out a uniquely-owned buffer of exactly `len` elements.  The
    /// contents are unspecified (stale values from a recycled buffer):
    /// every kernel writing into it must overwrite all elements.
    pub fn take(&mut self, len: usize) -> Arc<Vec<f64>> {
        match self.free.get_mut(&len).and_then(|v| v.pop()) {
            Some(buf) => {
                self.reuses += 1;
                self.reuse_bytes += len * ELEM_BYTES;
                buf
            }
            None => {
                self.allocs += 1;
                self.alloc_bytes += len * ELEM_BYTES;
                Arc::new(vec![0.0; len])
            }
        }
    }

    /// Return a tensor's backing buffer to the free list if this tensor
    /// was the last reference to it.  Shared buffers — checkpoints,
    /// hypergradient outputs, aliased views — are simply dropped here and
    /// stay alive through their other handles.
    pub fn recycle(&mut self, t: Tensor) {
        let arc = t.into_data().into_arc();
        if Arc::strong_count(&arc) == 1 {
            self.recycled += 1;
            self.recycle_bytes += arc.len() * ELEM_BYTES;
            self.free.entry(arc.len()).or_default().push(arc);
        }
    }

    pub fn stats(&self) -> ArenaStats {
        let mut free_bytes = 0usize;
        let mut free_buffers = 0usize;
        for bucket in self.free.values() {
            free_buffers += bucket.len();
            free_bytes += bucket
                .iter()
                .map(|b| b.len() * ELEM_BYTES)
                .sum::<usize>();
        }
        ArenaStats {
            allocs: self.allocs,
            reuses: self.reuses,
            recycled: self.recycled,
            alloc_bytes: self.alloc_bytes,
            reuse_bytes: self.reuse_bytes,
            recycle_bytes: self.recycle_bytes,
            free_bytes,
            free_buffers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_take_reuses_the_buffer() {
        let mut arena = BufferArena::new();
        let t = Tensor::from_shared(vec![4], arena.take(4));
        assert_eq!(arena.stats().allocs, 1);
        arena.recycle(t);
        assert_eq!(arena.stats().free_buffers, 1);
        let _again = arena.take(4);
        let s = arena.stats();
        assert_eq!(s.reuses, 1);
        assert_eq!(s.free_buffers, 0);
    }

    #[test]
    fn shared_buffers_are_not_recycled() {
        let mut arena = BufferArena::new();
        let t = Tensor::from_shared(vec![3], arena.take(3));
        let keep = t.clone(); // second handle to the same allocation
        arena.recycle(t);
        assert_eq!(arena.stats().free_buffers, 0, "shared buffer parked");
        assert_eq!(keep.elements(), 3);
    }

    #[test]
    fn lengths_are_keyed_exactly() {
        let mut arena = BufferArena::new();
        let t = Tensor::from_shared(vec![8], arena.take(8));
        arena.recycle(t);
        let _other = arena.take(4); // different length: fresh alloc
        let s = arena.stats();
        assert_eq!(s.allocs, 2);
        assert_eq!(s.reuses, 0);
        assert_eq!(s.free_buffers, 1, "len-8 buffer still parked");
        assert_eq!(s.free_bytes, 64);
        // Byte-traffic counters: 8 + 4 elements allocated fresh, the
        // len-8 buffer parked once, nothing reused yet.
        assert_eq!(s.alloc_bytes, 96);
        assert_eq!(s.recycle_bytes, 64);
        assert_eq!(s.reuse_bytes, 0);
        let _back = arena.take(8);
        assert_eq!(arena.stats().reuse_bytes, 64);
    }
}

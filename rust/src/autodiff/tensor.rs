//! Dense f64 tensors over copy-on-write flat buffers — the value type of
//! the native autodiff engine.  Scalars are rank-0 (`shape == []`),
//! vectors rank-1, matrices rank-2 row-major.  Shapes are checked eagerly
//! with panics: a shape error is a bug in graph construction, never a
//! data condition.
//!
//! Storage is a [`Buf`]: an `Arc`-shared buffer with copy-on-write
//! mutation.  Cloning a `Tensor` is therefore O(1) — leaves, checkpoints
//! and `Reshape` views all alias one allocation until somebody writes —
//! and the tape's [`super::arena::BufferArena`] can recycle a buffer
//! exactly when the last handle drops.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

use crate::kernels::DetPool;
use crate::util::prng::Prng;

/// Bytes per element (everything is f64).
pub const ELEM_BYTES: usize = 8;

/// Copy-on-write backing store for [`Tensor`].  Reads deref straight to
/// the underlying `Vec<f64>`; writes go through [`Arc::make_mut`], so a
/// shared buffer is copied before the first mutation and writes through
/// one handle can never be observed through another.
#[derive(Clone)]
pub struct Buf(Arc<Vec<f64>>);

impl Buf {
    pub fn new(data: Vec<f64>) -> Buf {
        Buf(Arc::new(data))
    }

    /// Do two handles share the same allocation?
    pub fn ptr_eq(a: &Buf, b: &Buf) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }

    /// Is this the only live handle to the allocation?
    pub(crate) fn is_unique(&self) -> bool {
        Arc::strong_count(&self.0) == 1
    }

    pub(crate) fn from_arc(arc: Arc<Vec<f64>>) -> Buf {
        Buf(arc)
    }

    pub(crate) fn into_arc(self) -> Arc<Vec<f64>> {
        self.0
    }
}

impl Deref for Buf {
    type Target = Vec<f64>;
    fn deref(&self) -> &Vec<f64> {
        &self.0
    }
}

impl DerefMut for Buf {
    fn deref_mut(&mut self) -> &mut Vec<f64> {
        Arc::make_mut(&mut self.0)
    }
}

impl fmt::Debug for Buf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&*self.0, f)
    }
}

// Content equality only — no ptr_eq fast path, so IEEE semantics are
// preserved (a tensor with a NaN element never equals its own alias,
// exactly as the pre-CoW Vec<f64> comparison behaved).  Aliasing is
// queried explicitly via [`Buf::ptr_eq`] / [`Tensor::aliases`].
impl PartialEq for Buf {
    fn eq(&self, other: &Buf) -> bool {
        *self.0 == *other.0
    }
}

impl PartialEq<Vec<f64>> for Buf {
    fn eq(&self, other: &Vec<f64>) -> bool {
        *self.0 == *other
    }
}

impl From<Vec<f64>> for Buf {
    fn from(v: Vec<f64>) -> Buf {
        Buf::new(v)
    }
}

impl<'a> IntoIterator for &'a Buf {
    type Item = &'a f64;
    type IntoIter = std::slice::Iter<'a, f64>;
    fn into_iter(self) -> Self::IntoIter {
        self.0.iter()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Buf,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f64>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Tensor { shape, data: Buf::new(data) }
    }

    /// Wrap an arena buffer without copying (the arena guarantees the
    /// buffer is uniquely owned and exactly sized).
    pub(crate) fn from_shared(shape: Vec<usize>, data: Arc<Vec<f64>>) -> Tensor {
        debug_assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shared buffer length mismatch for shape {shape:?}"
        );
        Tensor { shape, data: Buf::from_arc(data) }
    }

    pub(crate) fn into_data(self) -> Buf {
        self.data
    }

    /// Zero-copy view of the same buffer under a different shape (the
    /// element count must match).
    pub fn alias(&self, shape: Vec<usize>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "alias {:?} → {shape:?}",
            self.shape
        );
        Tensor { shape, data: self.data.clone() }
    }

    /// Do two tensors share the same backing allocation?
    pub fn aliases(&self, other: &Tensor) -> bool {
        Buf::ptr_eq(&self.data, &other.data)
    }

    pub fn scalar(x: f64) -> Tensor {
        Tensor { shape: vec![], data: Buf::new(vec![x]) }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: Buf::new(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn full(shape: &[usize], x: f64) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: Buf::new(vec![x; shape.iter().product()]),
        }
    }

    /// N(0, std²) entries.
    pub fn randn(shape: &[usize], std: f64, rng: &mut Prng) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: Buf::new(rng.normal_vec_f64(shape.iter().product(), std)),
        }
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * ELEM_BYTES
    }

    /// The single value of a rank-0/one-element tensor.
    pub fn item(&self) -> f64 {
        assert_eq!(self.data.len(), 1, "item() on shape {:?}", self.shape);
        self.data[0]
    }

    /// Rank-2 dimensions.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected matrix, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    /// Rank-3 dimensions `(groups, rows, cols)` — the batched-matmul
    /// layout (`group` is batch × heads in the attention stack).
    pub fn dims3(&self) -> (usize, usize, usize) {
        assert_eq!(
            self.shape.len(),
            3,
            "expected rank-3 tensor, got {:?}",
            self.shape
        );
        (self.shape[0], self.shape[1], self.shape[2])
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Tensor {
        let mut out = Vec::with_capacity(self.data.len());
        self.map_into(f, &mut out);
        Tensor { shape: self.shape.clone(), data: Buf::new(out) }
    }

    /// Elementwise map writing into a recycled buffer (cleared first).
    /// Serial wrapper over the fused kernel; the tape uses
    /// [`Tensor::map_into_pooled`] with the engine's pool instead.
    pub fn map_into(
        &self,
        f: impl Fn(f64) -> f64 + Sync,
        out: &mut Vec<f64>,
    ) {
        self.map_into_pooled(DetPool::serial_ref(), f, out);
    }

    /// Elementwise map through `crate::kernels::elementwise`, row
    /// chunks fanned across `pool` (bit-identical to the serial path
    /// at every thread count).
    pub fn map_into_pooled(
        &self,
        pool: &DetPool,
        f: impl Fn(f64) -> f64 + Sync,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.resize(self.data.len(), 0.0);
        crate::kernels::elementwise::map_into(pool, &self.data, f, out);
    }

    /// Elementwise combine with an identically-shaped tensor.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        let mut out = Vec::with_capacity(self.data.len());
        self.zip_into(other, f, &mut out);
        Tensor { shape: self.shape.clone(), data: Buf::new(out) }
    }

    /// Elementwise combine writing into a recycled buffer (cleared
    /// first).  Serial wrapper over the fused kernel; the tape uses
    /// [`Tensor::zip_into_pooled`] with the engine's pool instead.
    pub fn zip_into(
        &self,
        other: &Tensor,
        f: impl Fn(f64, f64) -> f64 + Sync,
        out: &mut Vec<f64>,
    ) {
        self.zip_into_pooled(DetPool::serial_ref(), other, f, out);
    }

    /// Elementwise combine through `crate::kernels::elementwise`,
    /// chunks fanned across `pool` (bit-identical to the serial path
    /// at every thread count).
    pub fn zip_into_pooled(
        &self,
        pool: &DetPool,
        other: &Tensor,
        f: impl Fn(f64, f64) -> f64 + Sync,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(
            self.shape, other.shape,
            "zip shape mismatch {:?} vs {:?}",
            self.shape, other.shape
        );
        out.clear();
        out.resize(self.data.len(), 0.0);
        crate::kernels::elementwise::zip_into(
            pool,
            &self.data,
            &other.data,
            f,
            out,
        );
    }

    /// Output dims `(m, n)` of `op(self, ta) · op(other, tb)` with
    /// `op(X, true) = Xᵀ`, after checking the contraction dims agree.
    pub fn matmul_dims(&self, other: &Tensor, ta: bool, tb: bool) -> (usize, usize) {
        let (ar, ac) = self.dims2();
        let (br, bc) = other.dims2();
        let (m, k) = if ta { (ac, ar) } else { (ar, ac) };
        let (kb, n) = if tb { (bc, br) } else { (br, bc) };
        assert_eq!(k, kb, "matmul inner dims {k} vs {kb}");
        (m, n)
    }

    /// `C = op(A, ta) · op(B, tb)` through the cache-blocked
    /// `crate::kernels::gemm` kernel (bit-identical to the scalar
    /// reference loop).
    pub fn matmul(&self, other: &Tensor, ta: bool, tb: bool) -> Tensor {
        let mut out = Vec::new();
        let (m, n) = self.matmul_into(other, ta, tb, &mut out);
        Tensor { shape: vec![m, n], data: Buf::new(out) }
    }

    /// Matmul writing into a recycled buffer (zeroed to `m·n` first)
    /// through the cache-blocked `crate::kernels::gemm` kernel, which
    /// is bit-for-bit the scalar reference loop — and, unlike the old
    /// in-place loop, carries no `ail == 0.0` zero-skip: a zero times
    /// a NaN/Inf contribution propagates as NaN instead of silently
    /// becoming 0, and the branch-free inner loop auto-vectorises.
    /// Returns the output dims `(m, n)`.
    pub fn matmul_into(
        &self,
        other: &Tensor,
        ta: bool,
        tb: bool,
        out: &mut Vec<f64>,
    ) -> (usize, usize) {
        let (m, n) = self.matmul_dims(other, ta, tb);
        let (ar, ac) = self.dims2();
        let (br, bc) = other.dims2();
        out.clear();
        out.resize(m * n, 0.0);
        crate::kernels::gemm::gemm_into(
            &self.data, ar, ac, ta, &other.data, br, bc, tb, out,
        );
        (m, n)
    }

    /// Output dims `(g, m, n)` of the batched product
    /// `op(self[g], ta) · op(other[g], tb)` over rank-3 operands that
    /// share a leading group dimension, after checking the per-group
    /// contraction dims agree.
    pub fn bmm_dims(
        &self,
        other: &Tensor,
        ta: bool,
        tb: bool,
    ) -> (usize, usize, usize) {
        let (ga, ar, ac) = self.dims3();
        let (gb, br, bc) = other.dims3();
        assert_eq!(ga, gb, "batch_matmul group dims {ga} vs {gb}");
        let (m, k) = if ta { (ac, ar) } else { (ar, ac) };
        let (kb, n) = if tb { (bc, br) } else { (br, bc) };
        assert_eq!(k, kb, "batch_matmul inner dims {k} vs {kb}");
        (ga, m, n)
    }

    /// Batched matmul writing into a recycled buffer (zeroed to `g·m·n`
    /// first).  Serial wrapper over [`Tensor::bmm_into_pooled`]; per
    /// group the kernel is exactly [`Tensor::matmul_into`]'s, so a
    /// single-group batched product is bit-for-bit the rank-2 product.
    /// Returns `(g, m, n)`.
    pub fn bmm_into(
        &self,
        other: &Tensor,
        ta: bool,
        tb: bool,
        out: &mut Vec<f64>,
    ) -> (usize, usize, usize) {
        self.bmm_into_pooled(DetPool::serial_ref(), other, ta, tb, out)
    }

    /// Batched matmul through `crate::kernels::gemm::bmm_into`, the
    /// batch·head group axis fanned across `pool` — group outputs are
    /// disjoint, so results are bit-identical to the serial path at
    /// every thread count.
    pub fn bmm_into_pooled(
        &self,
        pool: &DetPool,
        other: &Tensor,
        ta: bool,
        tb: bool,
        out: &mut Vec<f64>,
    ) -> (usize, usize, usize) {
        let (g, m, n) = self.bmm_dims(other, ta, tb);
        let (_, ar, ac) = self.dims3();
        let (_, br, bc) = other.dims3();
        out.clear();
        out.resize(g * m * n, 0.0);
        crate::kernels::gemm::bmm_into(
            pool,
            g,
            &self.data,
            ar,
            ac,
            ta,
            &other.data,
            br,
            bc,
            tb,
            out,
        );
        (g, m, n)
    }

    /// Batched matmul into a new tensor (rank-3 in, rank-3 out).
    pub fn bmm(&self, other: &Tensor, ta: bool, tb: bool) -> Tensor {
        let mut out = Vec::new();
        let (g, m, n) = self.bmm_into(other, ta, tb, &mut out);
        Tensor { shape: vec![g, m, n], data: Buf::new(out) }
    }

    /// Max |entry| difference to another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|x| x.abs()).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_item() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.item(), 3.5);
        assert_eq!(Tensor::zeros(&[2, 3]).elements(), 6);
        assert_eq!(Tensor::full(&[4], 2.0).data, vec![2.0; 4]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn clone_is_zero_copy_until_write() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(b.aliases(&a), "clone must share the buffer");
        b.data[0] = 9.0; // copy-on-write kicks in here
        assert!(!b.aliases(&a), "write must detach the buffer");
        assert_eq!(a.data[0], 1.0, "original unchanged after CoW write");
        assert_eq!(b.data[0], 9.0);
    }

    #[test]
    fn alias_shares_buffer_across_shapes() {
        let a = Tensor::new(vec![2, 3], vec![0.0; 6]);
        let v = a.alias(vec![6]);
        assert!(v.aliases(&a));
        assert_eq!(v.shape, vec![6]);
        assert_eq!(v.bytes(), a.bytes());
    }

    #[test]
    #[should_panic(expected = "alias")]
    fn alias_with_wrong_count_panics() {
        Tensor::zeros(&[2, 3]).alias(vec![7]);
    }

    #[test]
    fn into_kernels_match_allocating_kernels() {
        let mut rng = Prng::new(3);
        let a = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let mut out = vec![99.0; 2]; // stale, wrong-sized: must be reset
        a.map_into(|x| x * 2.0, &mut out);
        assert_eq!(a.map(|x| x * 2.0).data, out);
        a.zip_into(&b, |x, y| x - y, &mut out);
        assert_eq!(a.zip(&b, |x, y| x - y).data, out);
        let c = Tensor::randn(&[4, 2], 1.0, &mut rng);
        let (m, n) = a.matmul_into(&c, false, false, &mut out);
        assert_eq!((m, n), (3, 2));
        assert_eq!(a.matmul(&c, false, false).data, out);
    }

    #[test]
    fn matmul_all_transpose_combos() {
        // A = [[1,2],[3,4],[5,6]] (3x2), B = [[1,0],[0,1]] picks columns.
        let a = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let id = Tensor::new(vec![2, 2], vec![1., 0., 0., 1.]);
        assert_eq!(a.matmul(&id, false, false).data, a.data);
        assert_eq!(a.matmul(&id, false, true).data, a.data);
        // Aᵀ·A = [[35,44],[44,56]]
        let ata = a.matmul(&a, true, false);
        assert_eq!(ata.shape, vec![2, 2]);
        assert_eq!(ata.data, vec![35., 44., 44., 56.]);
        // A·Aᵀ diag = [5, 25, 61]
        let aat = a.matmul(&a, false, true);
        assert_eq!(aat.shape, vec![3, 3]);
        assert_eq!(aat.data[0], 5.0);
        assert_eq!(aat.data[4], 25.0);
        assert_eq!(aat.data[8], 61.0);
        // (Aᵀ)ᵀ·(Aᵀ)ᵀ—ᵀ combo: Aᵀ·(Aᵀ)ᵀ == AᵀA via (true, true) on (A, Aᵀ)
        let at = Tensor::new(vec![2, 3], vec![1., 3., 5., 2., 4., 6.]);
        let both = a.matmul(&at, true, true);
        assert_eq!(both.data, ata.data);
    }

    #[test]
    fn bmm_single_group_is_bitwise_matmul() {
        let mut rng = Prng::new(17);
        for &(ta, tb) in &[(false, false), (true, false), (false, true), (true, true)] {
            let (ar, ac) = if ta { (4, 3) } else { (3, 4) };
            let (br, bc) = if tb { (2, 4) } else { (4, 2) };
            let a2 = Tensor::randn(&[ar, ac], 1.0, &mut rng);
            let b2 = Tensor::randn(&[br, bc], 1.0, &mut rng);
            let a3 = a2.alias(vec![1, ar, ac]);
            let b3 = b2.alias(vec![1, br, bc]);
            let flat = a2.matmul(&b2, ta, tb);
            let batched = a3.bmm(&b3, ta, tb);
            assert_eq!(batched.shape[0], 1);
            assert_eq!(
                batched.data, flat.data,
                "g=1 bmm must be bit-for-bit matmul (ta={ta}, tb={tb})"
            );
        }
    }

    #[test]
    fn bmm_groups_are_independent_blocks() {
        // Two groups computed batched must equal the two per-group
        // rank-2 products stacked.
        let mut rng = Prng::new(18);
        let a = Tensor::randn(&[2, 3, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[2, 4, 2], 1.0, &mut rng);
        let out = a.bmm(&b, false, false);
        assert_eq!(out.shape, vec![2, 3, 2]);
        for g in 0..2 {
            let a2 = Tensor::new(vec![3, 4], a.data[g * 12..(g + 1) * 12].to_vec());
            let b2 = Tensor::new(vec![4, 2], b.data[g * 8..(g + 1) * 8].to_vec());
            let want = a2.matmul(&b2, false, false);
            assert_eq!(&out.data[g * 6..(g + 1) * 6], &want.data[..]);
        }
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Prng::new(9);
        let mut r2 = Prng::new(9);
        let a = Tensor::randn(&[3, 3], 0.5, &mut r1);
        let b = Tensor::randn(&[3, 3], 0.5, &mut r2);
        assert_eq!(a, b);
    }
}

//! Dense f64 tensors over flat buffers — the value type of the native
//! autodiff engine.  Scalars are rank-0 (`shape == []`), vectors rank-1,
//! matrices rank-2 row-major.  Shapes are checked eagerly with panics:
//! a shape error is a bug in graph construction, never a data condition.

use crate::util::prng::Prng;

/// Bytes per element (everything is f64).
pub const ELEM_BYTES: usize = 8;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f64>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn scalar(x: f64) -> Tensor {
        Tensor { shape: vec![], data: vec![x] }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], x: f64) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![x; shape.iter().product()] }
    }

    /// N(0, std²) entries.
    pub fn randn(shape: &[usize], std: f64, rng: &mut Prng) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec_f64(shape.iter().product(), std),
        }
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * ELEM_BYTES
    }

    /// The single value of a rank-0/one-element tensor.
    pub fn item(&self) -> f64 {
        assert_eq!(self.data.len(), 1, "item() on shape {:?}", self.shape);
        self.data[0]
    }

    /// Rank-2 dimensions.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected matrix, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise combine with an identically-shaped tensor.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        assert_eq!(
            self.shape, other.shape,
            "zip shape mismatch {:?} vs {:?}",
            self.shape, other.shape
        );
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    /// `C = op(A, ta) · op(B, tb)` with `op(X, true) = Xᵀ`; plain loops —
    /// the native engine's models are small enough that clarity wins.
    pub fn matmul(&self, other: &Tensor, ta: bool, tb: bool) -> Tensor {
        let (ar, ac) = self.dims2();
        let (br, bc) = other.dims2();
        let (m, k) = if ta { (ac, ar) } else { (ar, ac) };
        let (kb, n) = if tb { (bc, br) } else { (br, bc) };
        assert_eq!(k, kb, "matmul inner dims {k} vs {kb}");
        let a = |i: usize, j: usize| {
            if ta {
                self.data[j * ac + i]
            } else {
                self.data[i * ac + j]
            }
        };
        let b = |i: usize, j: usize| {
            if tb {
                other.data[j * bc + i]
            } else {
                other.data[i * bc + j]
            }
        };
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for l in 0..k {
                let ail = a(i, l);
                if ail == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += ail * b(l, j);
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// Max |entry| difference to another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|x| x.abs()).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_item() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.item(), 3.5);
        assert_eq!(Tensor::zeros(&[2, 3]).elements(), 6);
        assert_eq!(Tensor::full(&[4], 2.0).data, vec![2.0; 4]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn matmul_all_transpose_combos() {
        // A = [[1,2],[3,4],[5,6]] (3x2), B = [[1,0],[0,1]] picks columns.
        let a = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let id = Tensor::new(vec![2, 2], vec![1., 0., 0., 1.]);
        assert_eq!(a.matmul(&id, false, false).data, a.data);
        assert_eq!(a.matmul(&id, false, true).data, a.data);
        // Aᵀ·A = [[35,44],[44,56]]
        let ata = a.matmul(&a, true, false);
        assert_eq!(ata.shape, vec![2, 2]);
        assert_eq!(ata.data, vec![35., 44., 44., 56.]);
        // A·Aᵀ diag = [5, 25, 61]
        let aat = a.matmul(&a, false, true);
        assert_eq!(aat.shape, vec![3, 3]);
        assert_eq!(aat.data[0], 5.0);
        assert_eq!(aat.data[4], 25.0);
        assert_eq!(aat.data[8], 61.0);
        // (Aᵀ)ᵀ·(Aᵀ)ᵀ—ᵀ combo: Aᵀ·(Aᵀ)ᵀ == AᵀA via (true, true) on (A, Aᵀ)
        let at = Tensor::new(vec![2, 3], vec![1., 3., 5., 2., 4., 6.]);
        let both = a.matmul(&at, true, true);
        assert_eq!(both.data, ata.data);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Prng::new(9);
        let mut r2 = Prng::new(9);
        let a = Tensor::randn(&[3, 3], 0.5, &mut r1);
        let b = Tensor::randn(&[3, 3], 0.5, &mut r2);
        assert_eq!(a, b);
    }
}

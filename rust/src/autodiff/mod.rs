//! Native Rust autodiff engine with MixFlow-MG mixed-mode hypergradients.
//!
//! This subsystem makes the Rust layer able to *compute* meta-gradients on
//! its own — no JAX, no AOT artifacts, no PJRT.  It is the ground-truth
//! oracle for the HLO buffer-liveness simulator ([`crate::hlo::memory`])
//! and the engine behind [`crate::meta::native`].
//!
//! * [`tensor`] — dense f64 tensors over flat buffers.
//! * [`tape`] — Wengert-list reverse mode whose adjoint pass is itself a
//!   graph (so grad-of-grad works), plus a forward-mode JVP overlay.
//! * [`optim`] — differentiable inner-loop optimisers (SGD, momentum,
//!   Adam) whose per-step update — moment state and bias correction
//!   included — is built in-graph on the step tape.
//! * [`mixflow`] — the [`mixflow::BilevelProblem`] trait and two
//!   hypergradient paths: [`mixflow::naive_hypergrad`]
//!   (reverse-over-reverse, monolithic tape) and
//!   [`mixflow::mixflow_hypergrad`] (forward-over-reverse, per-step tape
//!   reuse — the paper's contribution, with the adjoint carried jointly
//!   over θ and optimiser state), both instrumented with tape counters.
//! * [`problems`] — the paper's hyper-LR and loss-weighting tasks plus a
//!   self-attention + layernorm workload.
//!
//! See `rust/src/autodiff/README.md` for the derivation.

pub mod mixflow;
pub mod optim;
pub mod problems;
pub mod tape;
pub mod tensor;

pub use mixflow::{
    fd_hypergrad, inner_step_values, mixflow_hypergrad, naive_hypergrad,
    BilevelProblem, Hypergrad, MemoryReport,
};
pub use optim::InnerOptimiser;
pub use tape::{NodeId, Op, Tape, TapeStats};
pub use tensor::Tensor;

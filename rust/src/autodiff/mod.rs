//! Native Rust autodiff engine with MixFlow-MG mixed-mode hypergradients.
//!
//! This subsystem makes the Rust layer able to *compute* meta-gradients on
//! its own — no JAX, no AOT artifacts, no PJRT.  It is the ground-truth
//! oracle for the HLO buffer-liveness simulator ([`crate::hlo::memory`])
//! and the engine behind [`crate::meta::native`].
//!
//! * [`tensor`] — dense f64 tensors over flat buffers.
//! * [`tape`] — Wengert-list reverse mode whose adjoint pass is itself a
//!   graph (so grad-of-grad works), plus a forward-mode JVP overlay.
//! * [`mixflow`] — the [`mixflow::BilevelProblem`] trait and two
//!   hypergradient paths: [`mixflow::naive_hypergrad`]
//!   (reverse-over-reverse, monolithic tape) and
//!   [`mixflow::mixflow_hypergrad`] (forward-over-reverse, per-step tape
//!   reuse — the paper's contribution), both instrumented with tape-byte
//!   counters.
//! * [`problems`] — the paper's hyper-LR and loss-weighting tasks.
//!
//! See `rust/src/autodiff/README.md` for the derivation.

pub mod mixflow;
pub mod problems;
pub mod tape;
pub mod tensor;

pub use mixflow::{
    fd_hypergrad, mixflow_hypergrad, naive_hypergrad, BilevelProblem,
    Hypergrad, MemoryReport,
};
pub use tape::{NodeId, Op, Tape, TapeStats};
pub use tensor::Tensor;

//! Native Rust autodiff engine with MixFlow-MG mixed-mode hypergradients.
//!
//! This subsystem makes the Rust layer able to *compute* meta-gradients on
//! its own — no JAX, no AOT artifacts, no PJRT.  It is the ground-truth
//! oracle for the HLO buffer-liveness simulator ([`crate::hlo::memory`])
//! and the engine behind [`crate::meta::native`].
//!
//! * [`tensor`] — dense f64 tensors over copy-on-write flat buffers
//!   (cloning is an O(1) alias; mutation detaches).
//! * [`arena`] — length-keyed free-list arena the tape draws node
//!   buffers from, so reset-and-reused step tapes bypass the allocator.
//! * [`tape`] — Wengert-list reverse mode whose adjoint pass is itself a
//!   graph (so grad-of-grad works), plus a forward-mode JVP overlay;
//!   sweeps borrow ops, `Reshape` aliases its input buffer.  Batched
//!   rank-3 matmul and column concat/split ops carry the multi-head
//!   attention stack, and `Tape::mark_kv` tags K/V projections for the
//!   [`mixflow::MemoryReport`] KV-reuse counters (primal and JVP
//!   tangent).  `Tape::plan_step` brackets each steady-state cycle for
//!   the compiled-plan machinery.
//! * [`plan`] — compiled step plans: a [`plan::StepPlan`] captures a
//!   recorded cycle's op schedule, resolved shapes, last-use liveness
//!   and static take schedule; replays arm the arena with a positional
//!   slot table (direct indexing instead of free-list probing) and fall
//!   back to dynamic taping when the topology changes.  Exports its
//!   liveness as HLO text so [`crate::hlo::memory`] can be conformance-
//!   checked against the native peak.
//! * [`optim`] — differentiable inner-loop optimisers (SGD, momentum,
//!   Adam) whose per-step update — moment state and bias correction
//!   included — is built in-graph on the step tape.
//! * [`mixflow`] — the [`mixflow::BilevelProblem`] trait and the
//!   hypergradient path implementations: [`mixflow::naive_hypergrad_in`]
//!   (reverse-over-reverse, monolithic tape) and
//!   [`mixflow::mixflow_hypergrad_in`] (forward-over-reverse, per-step
//!   tape reuse — the paper's contribution, with the adjoint carried
//!   jointly over θ and optimiser state) under the
//!   [`mixflow::CheckpointPolicy`] block-remat knob (including the
//!   run-time `Auto` K ≈ √T resolution); all instrumented with
//!   tape/arena counters and wall-clock timings.  The historical free
//!   functions (`naive_hypergrad`, `mixflow_hypergrad[_with]`,
//!   `fd_hypergrad`) remain as thin shims over the engine.
//! * [`engine`] — [`engine::HypergradEngine`]: the unified, persistent
//!   solver API.  One tape + arena reused across outer steps, a
//!   [`engine::HypergradStrategy`] trait unifying naive / mixflow / fd
//!   behind one `run(problem, θ₀, η)` call, configured through the
//!   fluent [`engine::EngineBuilder`].
//! * [`problems`] — the paper's hyper-LR and loss-weighting tasks plus
//!   self-attention + layernorm workloads: the legacy single-head
//!   [`problems::AttentionProblem`] and the multi-head batched
//!   [`problems::MultiHeadAttentionProblem`] (`heads = 1, batch = 1`
//!   reproduces the single-head path bit-for-bit).
//!
//! See `rust/src/autodiff/README.md` for the derivation and the memory
//! model.

// The engine's perf story is "no redundant copies on the hot path";
// keep clippy watching for clones that a move would do (CI runs clippy
// with -D warnings, so a redundant clone fails the build).
#![warn(clippy::redundant_clone)]

pub mod arena;
pub mod engine;
pub mod mixflow;
pub mod optim;
pub mod plan;
pub mod problems;
pub mod tape;
pub mod tensor;

pub use arena::{ArenaStats, BufferArena};
pub use plan::{PlanKey, PlanStats, StepPlan};
pub use engine::{
    EngineBuilder, EvoGradStrategy, FdStrategy, HypergradEngine,
    HypergradMode, HypergradStrategy, MixflowStrategy, NaiveStrategy,
    TruncatedStrategy, DEFAULT_EVO_POPULATION, DEFAULT_EVO_SIGMA,
};
pub use mixflow::{
    evograd_hypergrad_in, fd_hypergrad, inner_step_values,
    inner_step_values_into, mixflow_hypergrad, mixflow_hypergrad_in,
    mixflow_hypergrad_with, naive_hypergrad, naive_hypergrad_in,
    truncated_hypergrad_in, BilevelProblem, CheckpointPolicy, Hypergrad,
    MemoryReport,
};
pub use optim::InnerOptimiser;
pub use tape::{
    CancelSignal, CancelToken, NodeId, NonFiniteSignal, Op, Tape, TapeStats,
};
pub use tensor::{Buf, Tensor, ELEM_BYTES};

//! Hypergradients for bilevel problems: naive reverse-over-reverse vs
//! MixFlow-MG forward-over-reverse (the paper's core contribution, Eq. 8).
//!
//! The inner loop is `T` steps of a differentiable optimiser
//! ([`crate::autodiff::optim::InnerOptimiser`]) with a per-leaf
//! learning-rate tensor produced by the problem:
//!
//! ```text
//! (θ_{t+1}, s_{t+1}) = Φ_t(θ_t, s_t, η)      s = optimiser moments
//! F(η)               = L_val(θ_T)
//! ```
//!
//! [`naive_hypergrad`] records all `T` steps — each containing its own
//! in-graph gradient *and* in-graph optimiser update — on ONE tape and
//! backpropagates through everything: the reverse-over-reverse baseline
//! whose live tape grows ∝ T (plus the appended second-order subgraphs).
//!
//! [`mixflow_hypergrad`] checkpoints only `(θ_t, s_t)` values on the way
//! forward, then walks the unroll backwards with the general adjoint
//! recursion over the joint state.  Splitting the transition as
//! `Φ_t = φ(θ, s, g, η)` with `g = ∇_θ L_t(θ, η)` treated as an input:
//!
//! ```text
//! (λθ', λs')          adjoints arriving from step t+1
//! (dθ, ds, w, dη₀)  = φᵀ-VJP of ⟨λ, Φ outputs⟩  (g frozen — tiny graph)
//! λθ  = dθ + (∂²L/∂θ²) w                        (HVP)
//! λs  = ds
//! dη += dη₀ + (∂²L/∂θ∂η)ᵀ w                     (mixed term)
//! ```
//!
//! Both second-order products come from ONE forward-over-reverse dual
//! sweep ([`Tape::jvp`] seeded with `tangent(θ) = w` over the step's live
//! gradient nodes).  `dη₀` already contains the `(∂P/∂η)ᵀ` learning-rate
//! path because `P(η)` is built in-graph.  Each step's tape is dropped
//! before the next is built, so peak memory is one step's tape + tangents
//! + the `(θ, s)` checkpoints.  For plain SGD this reduces exactly to the
//! hand-derived `λ_t = λ_{t+1} − (∂²L/∂θ²)(P⊙λ_{t+1})` recursion.

use super::optim::InnerOptimiser;
use super::tape::{NodeId, Tape, TapeStats};
use super::tensor::Tensor;

/// A bilevel (meta-learning) problem: builds inner/outer losses as tape
/// graphs over θ and η leaf nodes.  `step` indexes the inner batch.
pub trait BilevelProblem {
    /// Initial inner parameters θ₀ (leaf templates).
    fn theta0(&self) -> Vec<Tensor>;
    /// Initial meta-parameters η₀.
    fn eta0(&self) -> Vec<Tensor>;
    /// Inner unroll length T.
    fn unroll(&self) -> usize;
    /// Training loss at inner step `step` (scalar node).
    fn inner_loss(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        eta: &[NodeId],
        step: usize,
    ) -> NodeId;
    /// Validation loss at θ_T (scalar node).
    fn outer_loss(&self, tape: &mut Tape, theta: &[NodeId]) -> NodeId;
    /// Per-leaf learning-rate tensors P(η), broadcast to each θ leaf's
    /// shape.  Constant nodes for η-independent inner optimisers.
    fn lr_nodes(&self, tape: &mut Tape, eta: &[NodeId]) -> Vec<NodeId>;
    /// The inner-loop optimiser driving the θ updates.
    fn optimiser(&self) -> InnerOptimiser;
    /// Swap the inner-loop optimiser (drivers configure this from CLI).
    fn set_optimiser(&mut self, opt: InnerOptimiser);
    /// Draw fresh train/val batches (between outer steps).
    fn resample(&mut self);
}

/// Where the bytes went, for the naive-vs-MixFlow comparison.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemoryReport {
    /// Peak live tape bytes (naive: the single monolithic tape; mixflow:
    /// the largest per-step tape + its JVP tangent overlay).
    pub tape_bytes: usize,
    /// `(θ_t, state_t)` checkpoint bytes (mixflow only), slot-major
    /// state after the θ leaves at each step.
    pub checkpoint_bytes: usize,
    /// Node count of the biggest live tape, forward *and* backward
    /// sweeps included.
    pub nodes: usize,
}

impl MemoryReport {
    /// Total live-memory proxy: tape + checkpoints.
    pub fn total_bytes(&self) -> usize {
        self.tape_bytes + self.checkpoint_bytes
    }
}

/// A hypergradient result.
#[derive(Debug, Clone)]
pub struct Hypergrad {
    /// dF/dη, one tensor per η leaf.
    pub d_eta: Vec<Tensor>,
    /// F(η) = validation loss after the unroll.
    pub outer_loss: f64,
    pub memory: MemoryReport,
}

fn leaves(tape: &mut Tape, values: &[Tensor]) -> Vec<NodeId> {
    values.iter().map(|v| tape.leaf(v.clone())).collect()
}

/// Reverse-over-reverse baseline: one monolithic tape through the whole
/// unroll — gradients *and* optimiser-state updates in-graph — then
/// `grad` straight through every per-step second-order subgraph.
pub fn naive_hypergrad<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta0: &[Tensor],
    eta: &[Tensor],
) -> Hypergrad {
    let opt = problem.optimiser();
    let mut tape = Tape::new();
    let mut theta = leaves(&mut tape, theta0);
    let mut state = leaves(&mut tape, &opt.init_state(theta0));
    let eta_ids = leaves(&mut tape, eta);
    for t in 0..problem.unroll() {
        let loss = problem.inner_loss(&mut tape, &theta, &eta_ids, t);
        let grads = tape.grad(loss, &theta);
        let lrs = problem.lr_nodes(&mut tape, &eta_ids);
        let (next_theta, next_state) =
            opt.step(&mut tape, &theta, &state, &lrs, &grads, t);
        theta = next_theta;
        state = next_state;
    }
    let outer = problem.outer_loss(&mut tape, &theta);
    let d_eta_ids = tape.grad(outer, &eta_ids);
    let d_eta = d_eta_ids.iter().map(|&id| tape.value(id).clone()).collect();
    let stats = tape.stats();
    Hypergrad {
        d_eta,
        outer_loss: tape.value(outer).item(),
        memory: MemoryReport {
            tape_bytes: stats.bytes,
            checkpoint_bytes: 0,
            nodes: stats.nodes,
        },
    }
}

/// One inner optimiser step on a throwaway tape; returns the `θ_{t+1}`
/// and `state_{t+1}` values plus the step tape's [`TapeStats`] (both its
/// byte and node counters feed the [`MemoryReport`] peak).
pub fn inner_step_values<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta: &[Tensor],
    state: &[Tensor],
    eta: &[Tensor],
    step: usize,
) -> (Vec<Tensor>, Vec<Tensor>, TapeStats) {
    let opt = problem.optimiser();
    let mut tape = Tape::new();
    let theta_ids = leaves(&mut tape, theta);
    let state_ids = leaves(&mut tape, state);
    let eta_ids = leaves(&mut tape, eta);
    let loss = problem.inner_loss(&mut tape, &theta_ids, &eta_ids, step);
    let grads = tape.grad(loss, &theta_ids);
    let lrs = problem.lr_nodes(&mut tape, &eta_ids);
    let (next_theta, next_state) =
        opt.step(&mut tape, &theta_ids, &state_ids, &lrs, &grads, step);
    let theta_out =
        next_theta.iter().map(|&id| tape.value(id).clone()).collect();
    let state_out =
        next_state.iter().map(|&id| tape.value(id).clone()).collect();
    (theta_out, state_out, tape.stats())
}

/// MixFlow-MG: forward-over-reverse mixed-mode hypergradient with
/// per-step tape reuse (the paper's Algorithm 1 shape), the adjoint
/// carried jointly over `(θ, optimiser state)`.
pub fn mixflow_hypergrad<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta0: &[Tensor],
    eta: &[Tensor],
) -> Hypergrad {
    let unroll = problem.unroll();
    let opt = problem.optimiser();
    let nt = theta0.len();

    // Forward: checkpoint (θ_t, state_t) values only; every step tape is
    // dropped.  Both stats counters fold into the peak — the forward
    // sweep's node counts used to be silently ignored.
    let mut theta_ckpt: Vec<Vec<Tensor>> = vec![theta0.to_vec()];
    let mut state_ckpt: Vec<Vec<Tensor>> = vec![opt.init_state(theta0)];
    let mut peak_tape = 0usize;
    let mut peak_nodes = 0usize;
    for t in 0..unroll {
        let (next_theta, next_state, stats) =
            inner_step_values(problem, &theta_ckpt[t], &state_ckpt[t], eta, t);
        peak_tape = peak_tape.max(stats.bytes);
        peak_nodes = peak_nodes.max(stats.nodes);
        theta_ckpt.push(next_theta);
        state_ckpt.push(next_state);
    }
    let checkpoint_bytes: usize = theta_ckpt
        .iter()
        .chain(state_ckpt.iter())
        .map(|c| c.iter().map(Tensor::bytes).sum::<usize>())
        .sum();

    // λ_T = (∇_θ L_val(θ_T), 0 state adjoint) from a small outer tape.
    let (mut lambda, outer_loss) = {
        let mut tape = Tape::new();
        let theta_ids = leaves(&mut tape, &theta_ckpt[unroll]);
        let outer = problem.outer_loss(&mut tape, &theta_ids);
        let grads = tape.grad(outer, &theta_ids);
        peak_tape = peak_tape.max(tape.stats().bytes);
        peak_nodes = peak_nodes.max(tape.stats().nodes);
        let mut lambda: Vec<Tensor> =
            grads.iter().map(|&id| tape.value(id).clone()).collect();
        lambda.extend(
            state_ckpt[unroll].iter().map(|s| Tensor::zeros(&s.shape)),
        );
        (lambda, tape.value(outer).item())
    };

    let mut d_eta: Vec<Tensor> =
        eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();

    // Backward sweep: rebuild one step's tape at a time.
    for t in (0..unroll).rev() {
        let mut tape = Tape::new();
        let theta_ids = leaves(&mut tape, &theta_ckpt[t]);
        let state_ids = leaves(&mut tape, &state_ckpt[t]);
        let eta_ids = leaves(&mut tape, eta);
        let ns = state_ids.len();
        let loss = problem.inner_loss(&mut tape, &theta_ids, &eta_ids, t);
        // One reverse sweep for the *live* ∇_θL and ∇_ηL nodes — the
        // targets of the dual sweep below.
        let mut gwrt = theta_ids.clone();
        gwrt.extend(eta_ids.iter().copied());
        let live = tape.grad(loss, &gwrt);
        let (g_theta_live, g_eta_live) = live.split_at(nt);

        // Stop-gradient copies of ∇_θL: the optimiser update is built
        // over these constants, so the reverse sweep of c below is the
        // φ-level VJP — first-order, over the tiny update subgraph only.
        let g_const: Vec<NodeId> = g_theta_live
            .iter()
            .map(|&g| {
                let v = tape.value(g).clone();
                tape.constant(v)
            })
            .collect();
        let lr_ids = problem.lr_nodes(&mut tape, &eta_ids);
        let (theta_next, state_next) =
            opt.step(&mut tape, &theta_ids, &state_ids, &lr_ids, &g_const, t);

        // c = Σ ⟨λ, Φ outputs⟩; ∇c gives every direct adjoint at once.
        let outs: Vec<NodeId> = theta_next
            .iter()
            .chain(state_next.iter())
            .copied()
            .collect();
        assert_eq!(outs.len(), lambda.len(), "λ / Φ output arity");
        let mut c: Option<NodeId> = None;
        for (&o, lam) in outs.iter().zip(lambda.iter()) {
            let l = tape.constant(lam.clone());
            let p = tape.mul(l, o);
            let s = tape.sum(p);
            c = Some(match c {
                Some(prev) => tape.add(prev, s),
                None => s,
            });
        }
        let c = c.expect("optimiser step produced no outputs");
        let mut wrt: Vec<NodeId> = theta_ids.clone();
        wrt.extend(state_ids.iter().copied());
        wrt.extend(g_const.iter().copied());
        wrt.extend(eta_ids.iter().copied());
        let adj = tape.grad(c, &wrt);
        let d_theta_direct = &adj[..nt];
        let d_state = &adj[nt..nt + ns];
        let w_ids = &adj[nt + ns..nt + ns + nt];
        let d_eta_direct = &adj[nt + ns + nt..];

        // Forward-over-reverse: tangents of the live gradient nodes,
        // seeded with tangent(θ) = w.  Tangent of ∇_θL is the HVP;
        // tangent of ∇_ηL is the mixed ∂² product.
        let seeds: Vec<(NodeId, Tensor)> = theta_ids
            .iter()
            .copied()
            .zip(w_ids.iter().map(|&id| tape.value(id).clone()))
            .collect();
        let mut targets: Vec<NodeId> = g_theta_live.to_vec();
        targets.extend(g_eta_live.iter().copied());
        let (tangents, tangent_bytes) = tape.jvp(&seeds, &targets);
        let (hvp, mixed) = tangents.split_at(nt);

        let mut new_lambda = Vec::with_capacity(nt + ns);
        for i in 0..nt {
            new_lambda.push(
                tape.value(d_theta_direct[i]).zip(&hvp[i], |p, q| p + q),
            );
        }
        for &id in d_state {
            new_lambda.push(tape.value(id).clone());
        }
        lambda = new_lambda;
        for i in 0..d_eta.len() {
            let updated = d_eta[i]
                .zip(tape.value(d_eta_direct[i]), |p, q| p + q)
                .zip(&mixed[i], |p, q| p + q);
            d_eta[i] = updated;
        }

        peak_tape = peak_tape.max(tape.stats().bytes + tangent_bytes);
        peak_nodes = peak_nodes.max(tape.stats().nodes);
    }

    Hypergrad {
        d_eta,
        outer_loss,
        memory: MemoryReport {
            tape_bytes: peak_tape,
            checkpoint_bytes,
            nodes: peak_nodes,
        },
    }
}

/// Central finite differences over every η element — the slow oracle the
/// tests compare both hypergradient paths against.  Uses the same
/// in-graph update builder, so stateful optimisers are held to the same
/// oracle as SGD.
pub fn fd_hypergrad<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta0: &[Tensor],
    eta: &[Tensor],
    h: f64,
) -> Vec<Tensor> {
    let opt = problem.optimiser();
    let outer_at = |eta_v: &[Tensor]| -> f64 {
        let mut theta: Vec<Tensor> = theta0.to_vec();
        let mut state = opt.init_state(theta0);
        for t in 0..problem.unroll() {
            let (next_theta, next_state, _) =
                inner_step_values(problem, &theta, &state, eta_v, t);
            theta = next_theta;
            state = next_state;
        }
        let mut tape = Tape::new();
        let ids = leaves(&mut tape, &theta);
        let outer = problem.outer_loss(&mut tape, &ids);
        tape.value(outer).item()
    };
    let mut out = Vec::with_capacity(eta.len());
    for (li, leaf) in eta.iter().enumerate() {
        let mut g = Tensor::zeros(&leaf.shape);
        for j in 0..leaf.elements() {
            let mut plus: Vec<Tensor> = eta.to_vec();
            plus[li].data[j] += h;
            let mut minus: Vec<Tensor> = eta.to_vec();
            minus[li].data[j] -= h;
            g.data[j] = (outer_at(&plus) - outer_at(&minus)) / (2.0 * h);
        }
        out.push(g);
    }
    out
}

/// Max |Δ| between two η-gradient pytrees, normalised by the largest
/// reference entry (for tolerance checks).
pub fn rel_err(a: &[Tensor], b: &[Tensor]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num: f64 = 0.0;
    let mut den: f64 = 1.0;
    for (x, y) in a.iter().zip(b.iter()) {
        num = num.max(x.max_abs_diff(y));
        den = den.max(1.0 + y.max_abs());
    }
    num / den
}

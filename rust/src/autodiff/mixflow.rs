//! Hypergradients for bilevel problems: naive reverse-over-reverse vs
//! MixFlow-MG forward-over-reverse (the paper's core contribution, Eq. 8).
//!
//! The inner loop is `T` steps of a differentiable optimiser
//! ([`crate::autodiff::optim::InnerOptimiser`]) with a per-leaf
//! learning-rate tensor produced by the problem:
//!
//! ```text
//! (θ_{t+1}, s_{t+1}) = Φ_t(θ_t, s_t, η)      s = optimiser moments
//! F(η)               = L_val(θ_T)
//! ```
//!
//! [`naive_hypergrad`] records all `T` steps — each containing its own
//! in-graph gradient *and* in-graph optimiser update — on ONE tape and
//! backpropagates through everything: the reverse-over-reverse baseline
//! whose live tape grows ∝ T (plus the appended second-order subgraphs).
//!
//! [`mixflow_hypergrad`] checkpoints only `(θ_t, s_t)` values on the way
//! forward, then walks the unroll backwards with the general adjoint
//! recursion over the joint state.  Splitting the transition as
//! `Φ_t = φ(θ, s, g, η)` with `g = ∇_θ L_t(θ, η)` treated as an input:
//!
//! ```text
//! (λθ', λs')          adjoints arriving from step t+1
//! (dθ, ds, w, dη₀)  = φᵀ-VJP of ⟨λ, Φ outputs⟩  (g frozen — tiny graph)
//! λθ  = dθ + (∂²L/∂θ²) w                        (HVP)
//! λs  = ds
//! dη += dη₀ + (∂²L/∂θ∂η)ᵀ w                     (mixed term)
//! ```
//!
//! Both second-order products come from ONE forward-over-reverse dual
//! sweep ([`Tape::jvp`] seeded with `tangent(θ) = w` over the step's live
//! gradient nodes).  `dη₀` already contains the `(∂P/∂η)ᵀ` learning-rate
//! path because `P(η)` is built in-graph.  All step tapes — forward,
//! backward and remat recompute — share ONE [`Tape`] whose cycles run
//! under [`Tape::plan_step`]: the first cycle of each kind compiles a
//! [`super::plan::StepPlan`] and every later one replays against its
//! static buffer-slot schedule, so buffers recirculate by direct slot
//! indexing instead of hitting the allocator (or the free-list probe)
//! T times.  For plain SGD this reduces exactly to the hand-derived
//! `λ_t = λ_{t+1} − (∂²L/∂θ²)(P⊙λ_{t+1})` recursion.
//!
//! [`CheckpointPolicy`] adds the paper's block-rematerialisation knob on
//! top: `Remat { segment: K }` stores `(θ_t, s_t)` only every K steps and
//! recomputes the intra-segment states during the backward sweep — live
//! checkpoints drop from `T` to `~T/K + K` at the cost of one extra
//! forward pass.  `K = 1` reproduces full checkpointing bit-for-bit, and
//! [`CheckpointPolicy::Auto`] resolves `K ≈ √T` at run time.
//!
//! The `*_in` functions here record onto a caller-owned tape; they are
//! the strategy implementations behind
//! [`super::engine::HypergradEngine`], the persistent solver every
//! driver goes through.  The historical free functions
//! ([`naive_hypergrad`], [`mixflow_hypergrad`],
//! [`mixflow_hypergrad_with`], [`fd_hypergrad`]) remain as thin shims
//! that build a throwaway engine per call.

use std::time::Instant;

use super::engine::{FdStrategy, HypergradEngine, HypergradMode};
use super::plan::PlanKey;
use super::tape::{NodeId, Tape, TapeStats};
use super::tensor::Tensor;
use crate::obs::{Counter, Phase};
use crate::util::args::CliEnum;
use crate::util::prng::Prng;

use super::optim::InnerOptimiser;

/// A bilevel (meta-learning) problem: builds inner/outer losses as tape
/// graphs over θ and η leaf nodes.  `step` indexes the inner batch.
pub trait BilevelProblem {
    /// Initial inner parameters θ₀ (leaf templates).
    fn theta0(&self) -> Vec<Tensor>;
    /// Initial meta-parameters η₀.
    fn eta0(&self) -> Vec<Tensor>;
    /// Inner unroll length T.
    fn unroll(&self) -> usize;
    /// Training loss at inner step `step` (scalar node).
    fn inner_loss(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        eta: &[NodeId],
        step: usize,
    ) -> NodeId;
    /// Validation loss at θ_T (scalar node).
    fn outer_loss(&self, tape: &mut Tape, theta: &[NodeId]) -> NodeId;
    /// Per-leaf learning-rate tensors P(η), broadcast to each θ leaf's
    /// shape.  Constant nodes for η-independent inner optimisers.
    fn lr_nodes(&self, tape: &mut Tape, eta: &[NodeId]) -> Vec<NodeId>;
    /// The inner-loop optimiser driving the θ updates.
    fn optimiser(&self) -> InnerOptimiser;
    /// Swap the inner-loop optimiser (drivers configure this from CLI).
    fn set_optimiser(&mut self, opt: InnerOptimiser);
    /// Draw fresh train/val batches (between outer steps).
    fn resample(&mut self);
}

/// How the MixFlow backward sweep trades checkpoint memory for
/// recompute — the paper's segment-wise rematerialisation knob (the same
/// truncation/checkpointing trade-off studied by Shaban et al. and
/// Franceschi et al.).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CheckpointPolicy {
    /// Checkpoint `(θ_t, s_t)` at every step (segment length 1): minimum
    /// recompute, `T + 1` live checkpoints.
    #[default]
    Full,
    /// Store `(θ_t, s_t)` only every `segment` steps; the backward sweep
    /// re-runs the forward inside each segment to rebuild the missing
    /// states.  Live checkpoints drop to `~T/K + K` for `K = segment`,
    /// at the cost of roughly one extra forward pass.  `segment = 1` is
    /// exactly [`CheckpointPolicy::Full`], bit-for-bit.
    Remat { segment: usize },
    /// Resolve the segment length at run time as `K ≈ √T` from the
    /// problem's unroll — the balance point of the `~T/K + K` live
    /// checkpoint count.  `T ≤ 2` resolves to `K = 1`, i.e. full
    /// checkpointing.
    Auto,
}

impl CheckpointPolicy {
    /// Segment length K for a `unroll`-step inner loop (1 for
    /// [`CheckpointPolicy::Full`]; `round(√unroll)` for
    /// [`CheckpointPolicy::Auto`], which is 1 whenever `unroll ≤ 2`).
    pub fn segment_for(&self, unroll: usize) -> usize {
        match self {
            CheckpointPolicy::Full => 1,
            CheckpointPolicy::Remat { segment } => (*segment).max(1),
            CheckpointPolicy::Auto => {
                ((unroll as f64).sqrt().round() as usize).max(1)
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            CheckpointPolicy::Full => "full".to_string(),
            CheckpointPolicy::Remat { segment } => format!("remat{segment}"),
            CheckpointPolicy::Auto => "auto".to_string(),
        }
    }

    /// Case- and whitespace-insensitive: `full` or `1` parse to `Full`,
    /// `auto` to the run-time `K ≈ √T` policy, an integer `K ≥ 2` to
    /// `Remat { segment: K }`.  The names this type prints round-trip
    /// too: `remat4` parses like `4` (matching the other CLI enums,
    /// whose printed names all re-parse).
    pub fn parse(s: &str) -> Option<CheckpointPolicy> {
        let t = s.trim().to_lowercase();
        if t == "full" || t == "1" {
            return Some(CheckpointPolicy::Full);
        }
        if t == "auto" {
            return Some(CheckpointPolicy::Auto);
        }
        match t.strip_prefix("remat").unwrap_or(t.as_str()).parse::<usize>() {
            Ok(1) => Some(CheckpointPolicy::Full),
            Ok(k) if k >= 2 => Some(CheckpointPolicy::Remat { segment: k }),
            _ => None,
        }
    }
}

impl CliEnum for CheckpointPolicy {
    fn name(&self) -> String {
        self.name()
    }

    fn parse(s: &str) -> Option<CheckpointPolicy> {
        CheckpointPolicy::parse(s)
    }

    /// Parseable exemplars; the open-ended integer form is described by
    /// the [`CliEnum::valid_values`] override below.
    fn variants() -> &'static [&'static str] {
        &["full", "auto", "2", "remat4"]
    }

    fn valid_values() -> String {
        "full|1 (checkpoint every step), auto (K ≈ √T at run time), or an \
         integer K >= 2 (remat segment length)"
            .to_string()
    }
}

/// Where the bytes (and the wall-clock) went, for the naive-vs-MixFlow
/// comparison.  The byte counters map onto the paper's Table 1 split of
/// activation memory vs checkpoint memory — see the "Memory model"
/// section of `rust/src/autodiff/README.md`.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemoryReport {
    /// Peak live tape bytes (naive: the single monolithic tape; mixflow:
    /// the largest per-step tape + its JVP tangent overlay).
    pub tape_bytes: usize,
    /// Peak live `(θ_t, state_t)` bytes (mixflow only): stored
    /// checkpoints plus any transient states rematerialised inside the
    /// backward segment, at the worst moment.
    pub checkpoint_bytes: usize,
    /// Node count of the biggest live tape, forward *and* backward
    /// sweeps included.
    pub nodes: usize,
    /// Peak bytes live simultaneously: step tape + JVP tangents + live
    /// checkpoint/state values at the worst single moment, counting each
    /// physical buffer once (step-tape leaves alias the checkpoints they
    /// were seeded from, so the overlap is deduplicated).
    pub peak_bytes: usize,
    /// Buffers drawn fresh from the allocator by the tape's arena.
    pub arena_allocs: usize,
    /// Buffers served from the arena free list instead of the allocator.
    pub arena_reuses: usize,
    /// Wall-clock of the forward unroll (mixflow) / graph build (naive).
    pub forward_seconds: f64,
    /// Wall-clock of the adjoint sweep, remat recompute included.
    pub backward_seconds: f64,
    /// Peak K/V-projection bytes live on any single tape (nodes tagged
    /// via [`super::tape::Tape::mark_kv`] by the attention problems).
    /// Naive accumulates every step's K/V on the monolithic tape, so
    /// this grows ∝ T; mixflow holds at most one step's worth — the
    /// per-tensor view of where the attention memory saving comes from.
    /// 0 for problems with no tagged K/V nodes and for the fd path.
    pub kv_peak_bytes: usize,
    /// K/V bytes rebuilt on backward-sweep step tapes whose `(θ_t, s_t)`
    /// seed was **aliased straight from a stored checkpoint** (segment
    /// boundaries; every backward step under full checkpointing).  These
    /// rebuilds cost one step-tape's transient storage instead of T live
    /// projections — the KV-reuse half of the MixFlow saving.
    pub kv_ckpt_alias_bytes: usize,
    /// K/V bytes rebuilt from **rematerialised** intra-segment states
    /// (the segment recompute plus backward steps seeded by recomputed
    /// states).  0 under full checkpointing (`K = 1`); grows as the
    /// remat segment K trades recompute for checkpoint memory.
    pub kv_remat_bytes: usize,
    /// K/V bytes materialised as **JVP tangents**: the dual sweep's
    /// tangent tensors flowing through K/V-marked nodes, summed over the
    /// backward steps.  A separate ledger from [`kv_peak_bytes`]
    /// (`Self::kv_peak_bytes`), which tracks primal projections only —
    /// the tangent overlay is transient per step and never accumulates
    /// ∝ T.  0 for the naive and fd paths (no JVP sweep).
    pub kv_tangent_bytes: usize,
}

impl MemoryReport {
    /// Total live-memory proxy: tape + checkpoints.
    pub fn total_bytes(&self) -> usize {
        self.tape_bytes + self.checkpoint_bytes
    }
}

/// A hypergradient result.
#[derive(Debug, Clone)]
pub struct Hypergrad {
    /// dF/dη, one tensor per η leaf.
    pub d_eta: Vec<Tensor>,
    /// F(η) = validation loss after the unroll.
    pub outer_loss: f64,
    pub memory: MemoryReport,
}

/// Leaf nodes for a slice of values.  `Tensor::clone` is an O(1) buffer
/// alias (copy-on-write), so this shares the caller's storage with the
/// tape instead of copying every input per call.
fn leaves(tape: &mut Tape, values: &[Tensor]) -> Vec<NodeId> {
    values.iter().map(|v| tape.leaf(v.clone())).collect()
}

/// θ leaves plus slot-major optimiser-state leaves, as one call.
type StatePair = (Vec<Tensor>, Vec<Tensor>);

fn pair_bytes(theta: &[Tensor], state: &[Tensor]) -> usize {
    theta.iter().chain(state.iter()).map(Tensor::bytes).sum()
}

/// Reverse-over-reverse baseline: one monolithic tape through the whole
/// unroll — gradients *and* optimiser-state updates in-graph — then
/// `grad` straight through every per-step second-order subgraph.
///
/// Thin shim over a throwaway [`HypergradEngine`]; a caller looping over
/// outer steps should hold a persistent engine instead, so the monolithic
/// tape's buffers recirculate through its arena between steps.
pub fn naive_hypergrad(
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
) -> Hypergrad {
    HypergradEngine::builder()
        .mode(HypergradMode::Naive)
        .build()
        .run(problem, theta0, eta)
}

/// [`naive_hypergrad`] recorded on a caller-owned tape (which is
/// [`Tape::reset`] first) — the engine's naive strategy, where a
/// persistent tape lets consecutive outer steps reuse each other's
/// buffers.
pub fn naive_hypergrad_in(
    tape: &mut Tape,
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
) -> Hypergrad {
    let opt = problem.optimiser();
    let arena_before = tape.arena_stats();
    // The whole monolithic unroll+reverse is one plan cycle: a persistent
    // engine replays it against the compiled buffer schedule on every
    // outer step after the first.
    let (outer, d_eta, forward_seconds, backward_seconds) = tape
        .plan_step(PlanKey::Naive, |tape| {
            let t_fwd = Instant::now();
            tape.obs_mut().phase_begin(Phase::Forward);
            let mut theta = leaves(tape, theta0);
            let mut state = leaves(tape, &opt.init_state(theta0));
            let eta_ids = leaves(tape, eta);
            for t in 0..problem.unroll() {
                let loss = problem.inner_loss(tape, &theta, &eta_ids, t);
                let grads = tape.grad(loss, &theta);
                let lrs = problem.lr_nodes(tape, &eta_ids);
                let (next_theta, next_state) =
                    opt.step(tape, &theta, &state, &lrs, &grads, t);
                theta = next_theta;
                state = next_state;
            }
            let outer = problem.outer_loss(tape, &theta);
            tape.obs_mut().phase_end(Phase::Forward);
            let forward_seconds = t_fwd.elapsed().as_secs_f64();
            let t_bwd = Instant::now();
            tape.obs_mut().phase_begin(Phase::BackwardVjp);
            let d_eta_ids = tape.grad(outer, &eta_ids);
            let d_eta: Vec<Tensor> = d_eta_ids
                .iter()
                .map(|&id| tape.value(id).clone())
                .collect();
            tape.obs_mut().phase_end(Phase::BackwardVjp);
            let backward_seconds = t_bwd.elapsed().as_secs_f64();
            (outer, d_eta, forward_seconds, backward_seconds)
        });
    let stats = tape.stats();
    let arena = tape.arena_stats();
    Hypergrad {
        d_eta,
        outer_loss: tape.value(outer).item(),
        memory: MemoryReport {
            tape_bytes: stats.bytes,
            checkpoint_bytes: 0,
            nodes: stats.nodes,
            peak_bytes: stats.bytes,
            arena_allocs: arena.allocs - arena_before.allocs,
            arena_reuses: arena.reuses - arena_before.reuses,
            forward_seconds,
            backward_seconds,
            // The monolithic tape keeps every step's K/V projection
            // live at once; nothing is rebuilt, so both reuse counters
            // stay 0.
            kv_peak_bytes: stats.kv_bytes,
            kv_ckpt_alias_bytes: 0,
            kv_remat_bytes: 0,
            kv_tangent_bytes: 0,
        },
    }
}

/// One inner optimiser step recorded on `tape` (which is [`Tape::reset`]
/// first, recycling the previous step's buffers through the tape's
/// arena); returns the `θ_{t+1}` and `state_{t+1}` values plus the step
/// tape's [`TapeStats`] (both its byte and node counters feed the
/// [`MemoryReport`] peak).
pub fn inner_step_values_into(
    problem: &dyn BilevelProblem,
    tape: &mut Tape,
    theta: &[Tensor],
    state: &[Tensor],
    eta: &[Tensor],
    step: usize,
) -> (Vec<Tensor>, Vec<Tensor>, TapeStats) {
    let opt = problem.optimiser();
    // One inner step is the canonical steady-state cycle: the mixflow
    // forward sweep, remat segment rebuilds and FD unrolls all replay
    // the same `Inner` plan after the first step compiles it.
    tape.plan_step(PlanKey::Inner, |tape| {
        let theta_ids = leaves(tape, theta);
        let state_ids = leaves(tape, state);
        let eta_ids = leaves(tape, eta);
        let loss = problem.inner_loss(tape, &theta_ids, &eta_ids, step);
        let grads = tape.grad(loss, &theta_ids);
        let lrs = problem.lr_nodes(tape, &eta_ids);
        let (next_theta, next_state) =
            opt.step(tape, &theta_ids, &state_ids, &lrs, &grads, step);
        let theta_out =
            next_theta.iter().map(|&id| tape.value(id).clone()).collect();
        let state_out =
            next_state.iter().map(|&id| tape.value(id).clone()).collect();
        (theta_out, state_out, tape.stats())
    })
}

/// [`inner_step_values_into`] on a throwaway tape — kept for callers that
/// only need a single step (the arena benefit needs a reused tape).
pub fn inner_step_values(
    problem: &dyn BilevelProblem,
    theta: &[Tensor],
    state: &[Tensor],
    eta: &[Tensor],
    step: usize,
) -> (Vec<Tensor>, Vec<Tensor>, TapeStats) {
    let mut tape = Tape::new();
    inner_step_values_into(problem, &mut tape, theta, state, eta, step)
}

/// MixFlow-MG with full per-step checkpointing — equivalent to
/// [`mixflow_hypergrad_with`] under [`CheckpointPolicy::Full`].
pub fn mixflow_hypergrad(
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
) -> Hypergrad {
    mixflow_hypergrad_with(problem, theta0, eta, CheckpointPolicy::Full)
}

/// MixFlow-MG under the given checkpoint policy, on a throwaway engine.
///
/// Thin shim over [`HypergradEngine`]; a caller looping over outer steps
/// should hold a persistent engine instead so the step tapes of
/// consecutive hypergradients share one arena.
pub fn mixflow_hypergrad_with(
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
    policy: CheckpointPolicy,
) -> Hypergrad {
    HypergradEngine::builder()
        .checkpoint(policy)
        .build()
        .run(problem, theta0, eta)
}

/// MixFlow-MG: forward-over-reverse mixed-mode hypergradient with
/// per-step tape reuse (the paper's Algorithm 1 shape), the adjoint
/// carried jointly over `(θ, optimiser state)`, under the given
/// checkpoint policy, on a caller-owned tape — the engine's mixflow
/// strategy.
///
/// With `Remat { segment: K }` the forward sweep stores `(θ_t, s_t)`
/// only at `t ≡ 0 (mod K)`; the backward sweep then re-runs the forward
/// inside each segment (newest segment first) to rebuild the missing
/// states, consumes them in reverse, and drops the whole segment before
/// moving to the next.  `K = 1` takes exactly the full-checkpoint path —
/// same float-op sequence, bit-for-bit equal hypergradients.
/// [`CheckpointPolicy::Auto`] resolves `K ≈ √T` here, from the
/// problem's unroll.
pub fn mixflow_hypergrad_in(
    tape: &mut Tape,
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
    policy: CheckpointPolicy,
) -> Hypergrad {
    truncated_hypergrad_in(
        tape,
        problem,
        theta0,
        eta,
        policy,
        problem.unroll(),
    )
}

/// Truncated back-propagation through the last `horizon` inner steps
/// (Shaban et al.) on a caller-owned tape — the engine's truncated
/// strategy, and the shared core behind [`mixflow_hypergrad_in`].
///
/// The forward unroll always runs all `T` steps (the window state
/// `(θ_{T−K}, s_{T−K})` is exact), but checkpoints are stored only
/// inside the window `[T−K, T)` and the adjoint sweep stops at the
/// window edge: λ arriving at `t = T−K` is dropped instead of being
/// propagated further back, and `dη` accumulates the direct + mixed
/// terms of the window steps only.  That is the truncation bias; in
/// exchange, live checkpoints and remat segments scale with `K`
/// instead of `T`.  `horizon` is clamped to `[1, T]`, and
/// `horizon = T` takes *exactly* the full mixflow path — same op
/// sequence, bit-for-bit equal hypergradients.  The
/// [`CheckpointPolicy`] applies within the window
/// ([`CheckpointPolicy::Auto`] resolves `K' ≈ √horizon`).
pub fn truncated_hypergrad_in(
    tape: &mut Tape,
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
    policy: CheckpointPolicy,
    horizon: usize,
) -> Hypergrad {
    let unroll = problem.unroll();
    let opt = problem.optimiser();
    let nt = theta0.len();
    let horizon = horizon.clamp(1, unroll.max(1));
    let start = unroll.saturating_sub(horizon);
    let k = policy.segment_for(horizon).clamp(1, horizon.max(1));

    // ONE tape for every step — forward, λ seeding, remat recompute and
    // backward cycles all run through `Tape::plan_step`, which drains
    // the previous cycle into the arena (or the previous plan's slot
    // table) before recording, so buffers recirculate instead of being
    // reallocated T times; when the tape belongs to a persistent engine,
    // the recirculation also spans outer steps.
    let arena_before = tape.arena_stats();
    let mut peak_tape = 0usize;
    let mut peak_nodes = 0usize;
    let mut live_state = 0usize; // bytes of live (θ, s) checkpoint values
    let mut peak_state = 0usize;
    let mut peak_total = 0usize;
    // KV-reuse ledger: peak K/V bytes on any one step tape, plus the
    // backward-sweep rebuilds split by what seeded them (stored
    // checkpoint alias vs rematerialised intra-segment state).
    let mut kv_peak = 0usize;
    let mut kv_ckpt_alias = 0usize;
    let mut kv_remat = 0usize;
    let mut kv_tangent = 0usize;

    // ---- forward: checkpoint (θ_t, s_t) at segment boundaries ----------
    let t_fwd = Instant::now();
    if start > 0 {
        tape.obs_mut()
            .count(Counter::TruncatedSkippedSteps, start as u64);
    }
    let mut ckpt: Vec<Option<StatePair>> = Vec::new();
    let mut theta = theta0.to_vec();
    let mut state = opt.init_state(theta0);
    for t in 0..unroll {
        // Cooperative cancellation fires between steps, never mid-step.
        tape.check_cancel();
        // The step tape's (θ, s) leaves are O(1) aliases; when the pair
        // is also checkpointed it sits in `live_state` AND in the tape's
        // byte counter, so the physical-peak accounting subtracts the
        // overlap once.  Steps before the truncation window (`t < start`,
        // empty for the full-horizon case) advance the state but store
        // nothing — the backward sweep never visits them.
        let mut overlap = 0usize;
        if t >= start && (t - start) % k == 0 {
            tape.obs_mut().phase_begin(Phase::CheckpointStore);
            let pb = pair_bytes(&theta, &state);
            live_state += pb;
            peak_state = peak_state.max(live_state);
            // O(1) clones: the checkpoint aliases the live values.
            ckpt.push(Some((theta.clone(), state.clone())));
            overlap = pb;
            tape.obs_mut().count(Counter::CheckpointStores, 1);
            tape.obs_mut().count(Counter::CheckpointBytes, pb as u64);
            tape.obs_mut().phase_end(Phase::CheckpointStore);
        }
        tape.obs_mut().phase_begin(Phase::Forward);
        let (next_theta, next_state, stats) =
            inner_step_values_into(problem, tape, &theta, &state, eta, t);
        tape.obs_mut().phase_end(Phase::Forward);
        peak_tape = peak_tape.max(stats.bytes);
        peak_nodes = peak_nodes.max(stats.nodes);
        peak_total = peak_total.max(stats.bytes + (live_state - overlap));
        kv_peak = kv_peak.max(stats.kv_bytes);
        theta = next_theta;
        state = next_state;
    }
    // (θ_T, s_T) stays live through the λ seeding below.
    let final_bytes = pair_bytes(&theta, &state);
    live_state += final_bytes;
    peak_state = peak_state.max(live_state);
    let forward_seconds = t_fwd.elapsed().as_secs_f64();

    // ---- λ_T = (∇_θ L_val(θ_T), 0 state adjoint) -----------------------
    let t_bwd = Instant::now();
    tape.obs_mut().phase_begin(Phase::LambdaSeed);
    let (mut lambda, outer_loss) = tape.plan_step(PlanKey::Outer, |tape| {
        let theta_ids = leaves(tape, &theta);
        let outer = problem.outer_loss(tape, &theta_ids);
        let grads = tape.grad(outer, &theta_ids);
        // θ_T leaves alias the live final pair — counted once.
        let overlap: usize = theta.iter().map(Tensor::bytes).sum();
        peak_tape = peak_tape.max(tape.stats().bytes);
        peak_nodes = peak_nodes.max(tape.stats().nodes);
        peak_total =
            peak_total.max(tape.stats().bytes + (live_state - overlap));
        // The λ-seeding tape rebuilds the validation K/V from θ_T —
        // aliased from the live final state, so it books as a
        // checkpoint-alias rebuild.
        kv_peak = kv_peak.max(tape.stats().kv_bytes);
        kv_ckpt_alias += tape.stats().kv_bytes;
        let mut lambda: Vec<Tensor> =
            grads.iter().map(|&id| tape.value(id).clone()).collect();
        lambda.extend(state.iter().map(|s| Tensor::zeros(&s.shape)));
        (lambda, tape.value(outer).item())
    });
    tape.obs_mut().phase_end(Phase::LambdaSeed);
    drop(theta);
    drop(state);
    live_state -= final_bytes;

    let mut d_eta: Vec<Tensor> =
        eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();

    // ---- backward sweep, newest segment first --------------------------
    // Segments cover `[start, unroll)` only; the adjoint λ arriving at
    // `t = start` is dropped — the truncation cut (a no-op at full
    // horizon, where start = 0 and λ₀ is unused anyway).
    for j in (0..ckpt.len()).rev() {
        tape.check_cancel();
        let seg_start = start + j * k;
        let seg_end = (seg_start + k).min(unroll);
        let seed = ckpt[j].take().expect("segment checkpoint stored once");
        // Rematerialise the intra-segment states (θ_t, s_t) for
        // t ∈ [seg_start, seg_end); with K = 1 this is just the stored
        // checkpoint and no recompute happens.
        let mut seg: Vec<StatePair> = Vec::with_capacity(seg_end - seg_start);
        seg.push(seed);
        for t in seg_start..seg_end - 1 {
            tape.obs_mut().phase_begin(Phase::RematRebuild);
            let (th, st, stats, overlap) = {
                let (prev_th, prev_st) = seg.last().expect("segment seeded");
                let overlap = pair_bytes(prev_th, prev_st);
                let (th, st, stats) = inner_step_values_into(
                    problem, tape, prev_th, prev_st, eta, t,
                );
                (th, st, stats, overlap)
            };
            tape.obs_mut().count(Counter::RematRebuilds, 1);
            tape.obs_mut().phase_end(Phase::RematRebuild);
            // Physical peak while this recompute tape is live: the new
            // pair still aliases the tape's output nodes (inside
            // stats.bytes), so it joins the state ledger only after the
            // peak candidate is taken; the previous pair's leaf aliases
            // are deduplicated via `overlap`.
            peak_tape = peak_tape.max(stats.bytes);
            peak_nodes = peak_nodes.max(stats.nodes);
            peak_total = peak_total.max(stats.bytes + (live_state - overlap));
            // Segment recompute rebuilds K/V it threw away forward.
            kv_peak = kv_peak.max(stats.kv_bytes);
            kv_remat += stats.kv_bytes;
            live_state += pair_bytes(&th, &st);
            peak_state = peak_state.max(live_state);
            seg.push((th, st));
        }

        for t in (seg_start..seg_end).rev() {
            let (theta_t, state_t) = &seg[t - seg_start];
            // This step's (θ_t, s_t) leaves alias the segment state
            // already counted in `live_state`.
            let overlap = pair_bytes(theta_t, state_t);
            tape.obs_mut().phase_begin(Phase::BackwardVjp);
            // One backward step — VJP plus JVP overlay — is its own plan
            // cycle: every t replays the `Backward` plan compiled at the
            // first backward step.
            tape.plan_step(PlanKey::Backward, |tape| {
                let theta_ids = leaves(tape, theta_t);
                let state_ids = leaves(tape, state_t);
                let eta_ids = leaves(tape, eta);
                let ns = state_ids.len();
                let loss = problem.inner_loss(tape, &theta_ids, &eta_ids, t);
                // One reverse sweep for the *live* ∇_θL and ∇_ηL nodes —
                // the targets of the dual sweep below.
                let mut gwrt = theta_ids.clone();
                gwrt.extend(eta_ids.iter().copied());
                let live = tape.grad(loss, &gwrt);
                let (g_theta_live, g_eta_live) = live.split_at(nt);

                // Stop-gradient copies of ∇_θL: the optimiser update is
                // built over these constants, so the reverse sweep of c
                // below is the φ-level VJP — first-order, over the tiny
                // update subgraph only.  (The "copy" is an O(1) buffer
                // alias.)
                let g_const: Vec<NodeId> = g_theta_live
                    .iter()
                    .map(|&g| {
                        let v = tape.value(g).clone();
                        tape.constant(v)
                    })
                    .collect();
                let lr_ids = problem.lr_nodes(tape, &eta_ids);
                let (theta_next, state_next) = opt.step(
                    tape, &theta_ids, &state_ids, &lr_ids, &g_const, t,
                );

                // c = Σ ⟨λ, Φ outputs⟩; ∇c gives every direct adjoint at
                // once.
                let outs: Vec<NodeId> = theta_next
                    .iter()
                    .chain(state_next.iter())
                    .copied()
                    .collect();
                assert_eq!(outs.len(), lambda.len(), "λ / Φ output arity");
                let mut c: Option<NodeId> = None;
                for (&o, lam) in outs.iter().zip(lambda.iter()) {
                    let l = tape.constant(lam.clone());
                    let p = tape.mul(l, o);
                    let s = tape.sum(p);
                    c = Some(match c {
                        Some(prev) => tape.add(prev, s),
                        None => s,
                    });
                }
                let c = c.expect("optimiser step produced no outputs");
                let mut wrt: Vec<NodeId> = theta_ids.clone();
                wrt.extend(state_ids.iter().copied());
                wrt.extend(g_const.iter().copied());
                wrt.extend(eta_ids.iter().copied());
                let adj = tape.grad(c, &wrt);
                let d_theta_direct = &adj[..nt];
                let d_state = &adj[nt..nt + ns];
                let w_ids = &adj[nt + ns..nt + ns + nt];
                let d_eta_direct = &adj[nt + ns + nt..];

                // Forward-over-reverse: tangents of the live gradient
                // nodes, seeded with tangent(θ) = w.  Tangent of ∇_θL is
                // the HVP; tangent of ∇_ηL is the mixed ∂² product.
                let seeds: Vec<(NodeId, Tensor)> = theta_ids
                    .iter()
                    .copied()
                    .zip(w_ids.iter().map(|&id| tape.value(id).clone()))
                    .collect();
                let mut targets: Vec<NodeId> = g_theta_live.to_vec();
                targets.extend(g_eta_live.iter().copied());
                tape.obs_mut().phase_begin(Phase::Jvp);
                let (tangents, tangent_bytes) = tape.jvp(&seeds, &targets);
                tape.obs_mut().phase_end(Phase::Jvp);
                kv_tangent += tape.jvp_kv_bytes();
                let (hvp, mixed) = tangents.split_at(nt);

                let mut new_lambda = Vec::with_capacity(nt + ns);
                for i in 0..nt {
                    new_lambda.push(
                        tape.value(d_theta_direct[i])
                            .zip(&hvp[i], |p, q| p + q),
                    );
                }
                for &id in d_state {
                    new_lambda.push(tape.value(id).clone());
                }
                lambda = new_lambda;
                for i in 0..d_eta.len() {
                    let updated = d_eta[i]
                        .zip(tape.value(d_eta_direct[i]), |p, q| p + q)
                        .zip(&mixed[i], |p, q| p + q);
                    d_eta[i] = updated;
                }

                peak_tape =
                    peak_tape.max(tape.stats().bytes + tangent_bytes);
                peak_nodes = peak_nodes.max(tape.stats().nodes);
                peak_total = peak_total.max(
                    tape.stats().bytes
                        + tangent_bytes
                        + (live_state - overlap),
                );
                // This backward step rebuilt step t's K/V projections.
                // At a segment boundary the (θ_t, s_t) seed is an alias
                // of a stored checkpoint; inside a segment it was
                // rematerialised by the recompute pass above.
                kv_peak = kv_peak.max(tape.stats().kv_bytes);
                if t == seg_start {
                    kv_ckpt_alias += tape.stats().kv_bytes;
                } else {
                    kv_remat += tape.stats().kv_bytes;
                }
            });
            tape.obs_mut().phase_end(Phase::BackwardVjp);
        }

        // Whole segment consumed: its states (stored + rematerialised)
        // go dead together.
        for (th, st) in seg.drain(..) {
            live_state -= pair_bytes(&th, &st);
        }
    }
    let backward_seconds = t_bwd.elapsed().as_secs_f64();

    let arena = tape.arena_stats();
    Hypergrad {
        d_eta,
        outer_loss,
        memory: MemoryReport {
            tape_bytes: peak_tape,
            checkpoint_bytes: peak_state,
            nodes: peak_nodes,
            peak_bytes: peak_total,
            arena_allocs: arena.allocs - arena_before.allocs,
            arena_reuses: arena.reuses - arena_before.reuses,
            forward_seconds,
            backward_seconds,
            kv_peak_bytes: kv_peak,
            kv_ckpt_alias_bytes: kv_ckpt_alias,
            kv_remat_bytes: kv_remat,
            kv_tangent_bytes: kv_tangent,
        },
    }
}

/// EvoGrad (Bohdal et al.): a variance-reduced stochastic hypergradient
/// with **no second-order terms**, on a caller-owned tape — the engine's
/// evograd strategy.
///
/// The unroll runs values-only to `(θ_{T−1}, s_{T−1})`; the tail is one
/// in-graph cycle: the last optimiser step `θ_T(η)` is built over a
/// stop-gradient copy of `∇_θ L` (so the learning-rate path `P(η)` stays
/// differentiable first-order while the Hessian path is severed), a
/// population of `θ_i = θ_T + ε_i` is perturbed with antithetic
/// Gaussian noise `ε ~ N(0, σ²)`, and the estimate is
///
/// ```text
/// dη = ∂/∂η  Σ_i softmax(−ℓ(θ_i, η))_i · L_val(θ_i)
/// ```
///
/// — one first-order reverse sweep over a graph that never materialises
/// a Hessian- or mixed-vector product.  η enters through both the
/// optimiser path (`θ_T(η)`, e.g. hyper-LR) and the weighting path
/// (`ℓ(·, η)`, e.g. loss-weighting), so every problem family gets a
/// non-trivial gradient.  The perturbations are drawn **host-side**
/// from the caller's deterministic [`Prng`] stream — the tape sees them
/// as constants, so results are bit-identical at every thread count and
/// the tail replays the compiled [`PlanKey::Evograd`] plan (constant
/// payloads and the host-computed softmax shift are excluded from plan
/// signatures).
///
/// The estimator is biased (one-step lookahead, smoothed by σ) but its
/// memory is O(1) in `T`: no checkpoints, no adjoint sweep, no tangent
/// overlay — the cheapest point on the bias-vs-memory frontier.
pub fn evograd_hypergrad_in(
    tape: &mut Tape,
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
    population: usize,
    sigma: f64,
    rng: &mut Prng,
) -> Hypergrad {
    assert!(sigma > 0.0, "evograd sigma must be positive, got {sigma}");
    let population = population.max(2);
    let unroll = problem.unroll();
    let opt = problem.optimiser();
    let arena_before = tape.arena_stats();
    let mut peak_tape = 0usize;
    let mut peak_nodes = 0usize;
    let mut kv_peak = 0usize;

    // ---- forward: values-only unroll to (θ_{T−1}, s_{T−1}) -------------
    let t_fwd = Instant::now();
    let last = unroll.saturating_sub(1);
    let mut theta = theta0.to_vec();
    let mut state = opt.init_state(theta0);
    for t in 0..last {
        tape.check_cancel();
        tape.obs_mut().phase_begin(Phase::Forward);
        let (next_theta, next_state, stats) =
            inner_step_values_into(problem, tape, &theta, &state, eta, t);
        tape.obs_mut().phase_end(Phase::Forward);
        peak_tape = peak_tape.max(stats.bytes);
        peak_nodes = peak_nodes.max(stats.nodes);
        kv_peak = kv_peak.max(stats.kv_bytes);
        theta = next_theta;
        state = next_state;
    }
    let forward_seconds = t_fwd.elapsed().as_secs_f64();

    // Antithetic perturbation pairs (ε_{2j+1} = −ε_{2j}), drawn
    // host-side before the tail cycle records.
    let mut eps: Vec<Vec<Tensor>> = Vec::with_capacity(population);
    for i in 0..population {
        if i % 2 == 1 {
            let neg: Vec<Tensor> =
                eps[i - 1].iter().map(|e| e.map(|x| -x)).collect();
            eps.push(neg);
        } else {
            eps.push(
                theta
                    .iter()
                    .map(|t| Tensor::randn(&t.shape, sigma, rng))
                    .collect(),
            );
        }
    }
    tape.obs_mut()
        .count(Counter::EvogradPerturbations, population as u64);

    // ---- tail: one first-order cycle under the Evograd plan ------------
    let t_bwd = Instant::now();
    tape.check_cancel();
    tape.obs_mut().phase_begin(Phase::BackwardVjp);
    let (d_eta, outer_loss) = tape.plan_step(PlanKey::Evograd, |tape| {
        let theta_ids = leaves(tape, &theta);
        let state_ids = leaves(tape, &state);
        let eta_ids = leaves(tape, eta);
        // Last step in-graph, gradient frozen: first-order through the
        // η→P(η)→θ_T optimiser path only.
        let loss = problem.inner_loss(tape, &theta_ids, &eta_ids, last);
        let g_live = tape.grad(loss, &theta_ids);
        let g_const: Vec<NodeId> = g_live
            .iter()
            .map(|&g| {
                let v = tape.value(g).clone();
                tape.constant(v)
            })
            .collect();
        let lr_ids = problem.lr_nodes(tape, &eta_ids);
        let (theta_next, _state_next) = opt.step(
            tape, &theta_ids, &state_ids, &lr_ids, &g_const, last,
        );

        // Population: θ_i = θ_T + ε_i, each scored by its inner loss
        // (the softmax weighting input) and its outer loss.
        let mut member_losses: Vec<NodeId> =
            Vec::with_capacity(population);
        let mut member_outers: Vec<NodeId> =
            Vec::with_capacity(population);
        for member in eps.iter() {
            let theta_i: Vec<NodeId> = theta_next
                .iter()
                .zip(member.iter())
                .map(|(&th, e)| {
                    let e_id = tape.constant(e.clone());
                    tape.add(th, e_id)
                })
                .collect();
            member_losses
                .push(problem.inner_loss(tape, &theta_i, &eta_ids, last));
            member_outers.push(problem.outer_loss(tape, &theta_i));
        }

        // w = softmax(−ℓ), shifted by the host-side minimum for
        // stability (softmax is shift-invariant, and the shift is a
        // per-step immediate the plan signature ignores).
        let m = member_losses
            .iter()
            .map(|&id| tape.value(id).item())
            .fold(f64::INFINITY, f64::min);
        let shift = if m.is_finite() { m } else { 0.0 };
        let z: Vec<NodeId> = member_losses
            .iter()
            .map(|&id| {
                let shifted = tape.offset(id, -shift);
                let neg = tape.scale(shifted, -1.0);
                tape.exp(neg)
            })
            .collect();
        let mut norm = z[0];
        for &zi in &z[1..] {
            norm = tape.add(norm, zi);
        }
        // L = Σ w_i · L_val(θ_i), then one reverse sweep for dη.
        let mut total: Option<NodeId> = None;
        for (&zi, &oi) in z.iter().zip(member_outers.iter()) {
            let wi = tape.div(zi, norm);
            let term = tape.mul(wi, oi);
            total = Some(match total {
                Some(prev) => tape.add(prev, term),
                None => term,
            });
        }
        let total = total.expect("population is at least 2");
        let d_eta_ids = tape.grad(total, &eta_ids);
        let d_eta: Vec<Tensor> = d_eta_ids
            .iter()
            .map(|&id| tape.value(id).clone())
            .collect();
        // Report the *unperturbed* outer loss, comparable across modes.
        let outer0 = problem.outer_loss(tape, &theta_next);
        let stats = tape.stats();
        peak_tape = peak_tape.max(stats.bytes);
        peak_nodes = peak_nodes.max(stats.nodes);
        kv_peak = kv_peak.max(stats.kv_bytes);
        (d_eta, tape.value(outer0).item())
    });
    tape.obs_mut().phase_end(Phase::BackwardVjp);
    let backward_seconds = t_bwd.elapsed().as_secs_f64();

    let arena = tape.arena_stats();
    Hypergrad {
        d_eta,
        outer_loss,
        memory: MemoryReport {
            tape_bytes: peak_tape,
            checkpoint_bytes: 0,
            nodes: peak_nodes,
            peak_bytes: peak_tape,
            arena_allocs: arena.allocs - arena_before.allocs,
            arena_reuses: arena.reuses - arena_before.reuses,
            forward_seconds,
            backward_seconds,
            // No adjoint sweep: K/V lives one step tape at a time and
            // nothing is rebuilt or carried as tangents.
            kv_peak_bytes: kv_peak,
            kv_ckpt_alias_bytes: 0,
            kv_remat_bytes: 0,
            kv_tangent_bytes: 0,
        },
    }
}

/// Central finite differences over every η element — the slow oracle the
/// tests compare both hypergradient paths against, and the engine's
/// `--mode fd` cross-check path.  Uses the same in-graph update builder
/// (on one reused tape), so stateful optimisers are held to the same
/// oracle as SGD.  Thin shim over [`FdStrategy`]; hold a persistent
/// engine ([`HypergradMode::Fd`]) to amortise the tape across calls.
pub fn fd_hypergrad(
    problem: &dyn BilevelProblem,
    theta0: &[Tensor],
    eta: &[Tensor],
    h: f64,
) -> Vec<Tensor> {
    use super::engine::HypergradStrategy;
    FdStrategy::new(h)
        .run(&mut Tape::new(), problem, theta0, eta)
        .d_eta
}

/// Max |Δ| between two η-gradient pytrees, normalised by the largest
/// reference entry (for tolerance checks).
pub fn rel_err(a: &[Tensor], b: &[Tensor]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num: f64 = 0.0;
    let mut den: f64 = 1.0;
    for (x, y) in a.iter().zip(b.iter()) {
        num = num.max(x.max_abs_diff(y));
        den = den.max(1.0 + y.max_abs());
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_policy_parses_like_the_other_cli_enums() {
        assert_eq!(CheckpointPolicy::parse("full"), Some(CheckpointPolicy::Full));
        assert_eq!(CheckpointPolicy::parse("1"), Some(CheckpointPolicy::Full));
        assert_eq!(
            CheckpointPolicy::parse(" FULL\n"),
            Some(CheckpointPolicy::Full)
        );
        assert_eq!(CheckpointPolicy::parse("auto"), Some(CheckpointPolicy::Auto));
        assert_eq!(
            CheckpointPolicy::parse(" Auto\t"),
            Some(CheckpointPolicy::Auto)
        );
        assert_eq!(
            CheckpointPolicy::parse("4"),
            Some(CheckpointPolicy::Remat { segment: 4 })
        );
        assert_eq!(
            CheckpointPolicy::parse("  16\t"),
            Some(CheckpointPolicy::Remat { segment: 16 })
        );
        assert_eq!(CheckpointPolicy::parse("0"), None);
        assert_eq!(CheckpointPolicy::parse("-2"), None);
        assert_eq!(CheckpointPolicy::parse("remat"), None);
        assert_eq!(CheckpointPolicy::parse("remat0"), None);
        assert_eq!(CheckpointPolicy::parse("1.5"), None);
        // The printed names round-trip, like the other CLI enums.
        for policy in [
            CheckpointPolicy::Full,
            CheckpointPolicy::Auto,
            CheckpointPolicy::Remat { segment: 4 },
            CheckpointPolicy::Remat { segment: 16 },
        ] {
            assert_eq!(CheckpointPolicy::parse(&policy.name()), Some(policy));
        }
        assert_eq!(
            CheckpointPolicy::parse("Remat1"),
            Some(CheckpointPolicy::Full)
        );
    }

    #[test]
    fn checkpoint_policy_names_and_segments() {
        assert_eq!(CheckpointPolicy::Full.segment_for(16), 1);
        assert_eq!(CheckpointPolicy::Remat { segment: 4 }.segment_for(16), 4);
        assert_eq!(CheckpointPolicy::Remat { segment: 0 }.segment_for(16), 1);
        assert_eq!(CheckpointPolicy::Full.name(), "full");
        assert_eq!(CheckpointPolicy::Remat { segment: 8 }.name(), "remat8");
        assert_eq!(CheckpointPolicy::Auto.name(), "auto");
        assert_eq!(CheckpointPolicy::default(), CheckpointPolicy::Full);
    }

    #[test]
    fn auto_policy_resolves_sqrt_t_at_run_time() {
        // T ≤ 2 keeps full checkpointing; larger unrolls get ~√T.
        assert_eq!(CheckpointPolicy::Auto.segment_for(0), 1);
        assert_eq!(CheckpointPolicy::Auto.segment_for(1), 1);
        assert_eq!(CheckpointPolicy::Auto.segment_for(2), 1);
        assert_eq!(CheckpointPolicy::Auto.segment_for(4), 2);
        assert_eq!(CheckpointPolicy::Auto.segment_for(9), 3);
        assert_eq!(CheckpointPolicy::Auto.segment_for(16), 4);
        assert_eq!(CheckpointPolicy::Auto.segment_for(32), 6);
    }
}

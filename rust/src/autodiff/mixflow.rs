//! Hypergradients for bilevel problems: naive reverse-over-reverse vs
//! MixFlow-MG forward-over-reverse (the paper's core contribution, Eq. 8).
//!
//! The inner loop is `T` steps of SGD with a per-leaf learning-rate tensor
//! produced by the problem (constant, or a function of η):
//!
//! ```text
//! θ_{t+1} = θ_t − P(η) ⊙ ∇_θ L_t(θ_t, η)
//! F(η)    = L_val(θ_T)
//! ```
//!
//! [`naive_hypergrad`] records all `T` steps — each containing its own
//! in-graph gradient — on ONE tape and backpropagates through everything:
//! the reverse-over-reverse baseline whose live tape grows ∝ T (plus the
//! appended second-order subgraphs).
//!
//! [`mixflow_hypergrad`] checkpoints only θ_t values on the way forward,
//! then walks the unroll backwards with the adjoint recursion
//!
//! ```text
//! u    = P(η) ⊙ λ_{t+1}
//! λ_t  = λ_{t+1} − (∂²L/∂θ²) u                 (HVP)
//! dη  −=  (∂²L/∂θ∂η)ᵀ u  +  (∂P/∂η)ᵀ (∇_θL ⊙ λ_{t+1})
//! ```
//!
//! where both second-order products come from ONE forward-over-reverse
//! dual sweep ([`Tape::jvp`] seeded with `u` over the step's gradient
//! nodes).  Each step's tape is dropped before the next is built, so peak
//! memory is one step's tape + tangents + the θ checkpoints.

use super::tape::{NodeId, Tape};
use super::tensor::Tensor;

/// A bilevel (meta-learning) problem: builds inner/outer losses as tape
/// graphs over θ and η leaf nodes.  `step` indexes the inner batch.
pub trait BilevelProblem {
    /// Initial inner parameters θ₀ (leaf templates).
    fn theta0(&self) -> Vec<Tensor>;
    /// Initial meta-parameters η₀.
    fn eta0(&self) -> Vec<Tensor>;
    /// Inner unroll length T.
    fn unroll(&self) -> usize;
    /// Training loss at inner step `step` (scalar node).
    fn inner_loss(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        eta: &[NodeId],
        step: usize,
    ) -> NodeId;
    /// Validation loss at θ_T (scalar node).
    fn outer_loss(&self, tape: &mut Tape, theta: &[NodeId]) -> NodeId;
    /// Per-leaf learning-rate tensors P(η), broadcast to each θ leaf's
    /// shape.  Constant nodes for η-independent inner optimisers.
    fn lr_nodes(&self, tape: &mut Tape, eta: &[NodeId]) -> Vec<NodeId>;
    /// Draw fresh train/val batches (between outer steps).
    fn resample(&mut self);
}

/// Where the bytes went, for the naive-vs-MixFlow comparison.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemoryReport {
    /// Peak live tape bytes (naive: the single monolithic tape; mixflow:
    /// the largest per-step tape + its JVP tangent overlay).
    pub tape_bytes: usize,
    /// θ checkpoint bytes (mixflow only).
    pub checkpoint_bytes: usize,
    /// Node count of the biggest live tape.
    pub nodes: usize,
}

impl MemoryReport {
    /// Total live-memory proxy: tape + checkpoints.
    pub fn total_bytes(&self) -> usize {
        self.tape_bytes + self.checkpoint_bytes
    }
}

/// A hypergradient result.
#[derive(Debug, Clone)]
pub struct Hypergrad {
    /// dF/dη, one tensor per η leaf.
    pub d_eta: Vec<Tensor>,
    /// F(η) = validation loss after the unroll.
    pub outer_loss: f64,
    pub memory: MemoryReport,
}

fn leaves(tape: &mut Tape, values: &[Tensor]) -> Vec<NodeId> {
    values.iter().map(|v| tape.leaf(v.clone())).collect()
}

/// Reverse-over-reverse baseline: one monolithic tape through the whole
/// unroll, then `grad` straight through every per-step gradient subgraph.
pub fn naive_hypergrad<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta0: &[Tensor],
    eta: &[Tensor],
) -> Hypergrad {
    let mut tape = Tape::new();
    let mut theta = leaves(&mut tape, theta0);
    let eta_ids = leaves(&mut tape, eta);
    for t in 0..problem.unroll() {
        let loss = problem.inner_loss(&mut tape, &theta, &eta_ids, t);
        let grads = tape.grad(loss, &theta);
        let lrs = problem.lr_nodes(&mut tape, &eta_ids);
        theta = theta
            .iter()
            .zip(lrs.iter().zip(grads.iter()))
            .map(|(&th, (&lr, &g))| {
                let step = tape.mul(lr, g);
                tape.sub(th, step)
            })
            .collect();
    }
    let outer = problem.outer_loss(&mut tape, &theta);
    let d_eta_ids = tape.grad(outer, &eta_ids);
    let d_eta = d_eta_ids.iter().map(|&id| tape.value(id).clone()).collect();
    let stats = tape.stats();
    Hypergrad {
        d_eta,
        outer_loss: tape.value(outer).item(),
        memory: MemoryReport {
            tape_bytes: stats.bytes,
            checkpoint_bytes: 0,
            nodes: stats.nodes,
        },
    }
}

/// One inner SGD step on a throwaway tape; returns (θ_{t+1} values, tape
/// stats of the step).
fn inner_step_values<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta: &[Tensor],
    eta: &[Tensor],
    step: usize,
) -> (Vec<Tensor>, usize) {
    let mut tape = Tape::new();
    let theta_ids = leaves(&mut tape, theta);
    let eta_ids = leaves(&mut tape, eta);
    let loss = problem.inner_loss(&mut tape, &theta_ids, &eta_ids, step);
    let grads = tape.grad(loss, &theta_ids);
    let lrs = problem.lr_nodes(&mut tape, &eta_ids);
    let mut next = Vec::with_capacity(theta.len());
    for ((&th, &lr), &g) in theta_ids.iter().zip(lrs.iter()).zip(grads.iter())
    {
        let delta = tape.mul(lr, g);
        let id = tape.sub(th, delta);
        next.push(tape.value(id).clone());
    }
    let bytes = tape.stats().bytes;
    (next, bytes)
}

/// MixFlow-MG: forward-over-reverse mixed-mode hypergradient with
/// per-step tape reuse (the paper's Algorithm 1 shape).
pub fn mixflow_hypergrad<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta0: &[Tensor],
    eta: &[Tensor],
) -> Hypergrad {
    let unroll = problem.unroll();

    // Forward: checkpoint θ_t values only; every step tape is dropped.
    let mut checkpoints: Vec<Vec<Tensor>> = vec![theta0.to_vec()];
    let mut peak_tape = 0usize;
    let mut peak_nodes = 0usize;
    for t in 0..unroll {
        let (next, bytes) =
            inner_step_values(problem, &checkpoints[t], eta, t);
        peak_tape = peak_tape.max(bytes);
        checkpoints.push(next);
    }
    let checkpoint_bytes: usize = checkpoints
        .iter()
        .map(|c| c.iter().map(Tensor::bytes).sum::<usize>())
        .sum();

    // λ = ∇_θ L_val(θ_T) from a small outer tape.
    let (mut lambda, outer_loss) = {
        let mut tape = Tape::new();
        let theta_ids = leaves(&mut tape, &checkpoints[unroll]);
        let outer = problem.outer_loss(&mut tape, &theta_ids);
        let grads = tape.grad(outer, &theta_ids);
        peak_tape = peak_tape.max(tape.stats().bytes);
        peak_nodes = peak_nodes.max(tape.stats().nodes);
        (
            grads
                .iter()
                .map(|&id| tape.value(id).clone())
                .collect::<Vec<_>>(),
            tape.value(outer).item(),
        )
    };

    let mut d_eta: Vec<Tensor> =
        eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();

    // Backward sweep: rebuild one step's tape at a time.
    for t in (0..unroll).rev() {
        let mut tape = Tape::new();
        let theta_ids = leaves(&mut tape, &checkpoints[t]);
        let eta_ids = leaves(&mut tape, eta);
        let loss = problem.inner_loss(&mut tape, &theta_ids, &eta_ids, t);
        // One reverse sweep for both ∇_θL and ∇_ηL.
        let mut wrt = theta_ids.clone();
        wrt.extend(eta_ids.iter().copied());
        let grads = tape.grad(loss, &wrt);
        let (g_theta_ids, g_eta_ids) = grads.split_at(theta_ids.len());
        let lr_ids = problem.lr_nodes(&mut tape, &eta_ids);

        // u = P(η) ⊙ λ
        let u: Vec<Tensor> = lr_ids
            .iter()
            .zip(lambda.iter())
            .map(|(&lr, la)| tape.value(lr).zip(la, |p, q| p * q))
            .collect();

        // Forward-over-reverse: tangents of the gradient nodes, seeded
        // with tangent(θ) = u.  Tangent of ∇_θL is the HVP; tangent of
        // ∇_ηL is the mixed ∂² product.
        let seeds: Vec<(NodeId, Tensor)> = theta_ids
            .iter()
            .copied()
            .zip(u.iter().cloned())
            .collect();
        let mut targets: Vec<NodeId> = g_theta_ids.to_vec();
        targets.extend(g_eta_ids.iter().copied());
        let (tangents, tangent_bytes) = tape.jvp(&seeds, &targets);
        let (hvp, mixed) = tangents.split_at(theta_ids.len());

        // lr-path term: (∂P/∂η)ᵀ (∇_θL ⊙ λ), a micro reverse sweep over
        // the (tiny) P(η) subgraph.  Zero when P is constant.
        let gl: Vec<Tensor> = g_theta_ids
            .iter()
            .zip(lambda.iter())
            .map(|(&g, la)| tape.value(g).zip(la, |p, q| p * q))
            .collect();
        let mut s_lr: Option<NodeId> = None;
        for (&lr, glv) in lr_ids.iter().zip(gl.iter()) {
            let c = tape.constant(glv.clone());
            let prod = tape.mul(lr, c);
            let dot = tape.sum(prod);
            s_lr = Some(match s_lr {
                Some(prev) => tape.add(prev, dot),
                None => dot,
            });
        }
        let lr_eta: Vec<Tensor> = match s_lr {
            Some(s) => {
                let ids = tape.grad(s, &eta_ids);
                ids.iter().map(|&id| tape.value(id).clone()).collect()
            }
            None => eta.iter().map(|e| Tensor::zeros(&e.shape)).collect(),
        };

        for i in 0..d_eta.len() {
            let updated = d_eta[i]
                .zip(&mixed[i], |p, q| p - q)
                .zip(&lr_eta[i], |p, q| p - q);
            d_eta[i] = updated;
        }
        lambda = lambda
            .iter()
            .zip(hvp.iter())
            .map(|(la, h)| la.zip(h, |p, q| p - q))
            .collect();

        peak_tape = peak_tape.max(tape.stats().bytes + tangent_bytes);
        peak_nodes = peak_nodes.max(tape.stats().nodes);
    }

    Hypergrad {
        d_eta,
        outer_loss,
        memory: MemoryReport {
            tape_bytes: peak_tape,
            checkpoint_bytes,
            nodes: peak_nodes,
        },
    }
}

/// Central finite differences over every η element — the slow oracle the
/// tests compare both hypergradient paths against.
pub fn fd_hypergrad<P: BilevelProblem + ?Sized>(
    problem: &P,
    theta0: &[Tensor],
    eta: &[Tensor],
    h: f64,
) -> Vec<Tensor> {
    let outer_at = |eta_v: &[Tensor]| -> f64 {
        let mut theta: Vec<Tensor> = theta0.to_vec();
        for t in 0..problem.unroll() {
            theta = inner_step_values(problem, &theta, eta_v, t).0;
        }
        let mut tape = Tape::new();
        let ids = leaves(&mut tape, &theta);
        let outer = problem.outer_loss(&mut tape, &ids);
        tape.value(outer).item()
    };
    let mut out = Vec::with_capacity(eta.len());
    for (li, leaf) in eta.iter().enumerate() {
        let mut g = Tensor::zeros(&leaf.shape);
        for j in 0..leaf.elements() {
            let mut plus: Vec<Tensor> = eta.to_vec();
            plus[li].data[j] += h;
            let mut minus: Vec<Tensor> = eta.to_vec();
            minus[li].data[j] -= h;
            g.data[j] = (outer_at(&plus) - outer_at(&minus)) / (2.0 * h);
        }
        out.push(g);
    }
    out
}

/// Max |Δ| between two η-gradient pytrees, normalised by the largest
/// reference entry (for tolerance checks).
pub fn rel_err(a: &[Tensor], b: &[Tensor]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num: f64 = 0.0;
    let mut den: f64 = 1.0;
    for (x, y) in a.iter().zip(b.iter()) {
        num = num.max(x.max_abs_diff(y));
        den = den.max(1.0 + y.max_abs());
    }
    num / den
}

//! Differentiable inner-loop optimisers.
//!
//! MixFlow-MG's Eq. (8) composition must carry the adjoint through the
//! *whole* inner transition `s_{t+1} = Φ_t(s_t, η)`, where the state
//! `s_t = (θ_t, state_t)` includes optimiser moments — the paper's
//! headline workloads run Adam inside the unroll, not plain SGD.  So the
//! per-step update here is built **in-graph** on the step tape: every
//! moment update, bias correction and the `m̂/(√v̂+ε)` quotient are tape
//! nodes, which makes them differentiable by both hypergradient paths
//! with no special cases — `naive_hypergrad` backpropagates straight
//! through them, and `mixflow_hypergrad` takes their φ-level VJP.
//!
//! State is stored slot-major: `state[slot · n_leaves + leaf]`, i.e. all
//! first moments, then all second moments.  Checkpoints in the MixFlow
//! backward sweep use the same layout.

use super::tape::{NodeId, Tape};
use super::tensor::Tensor;
use crate::util::args::CliEnum;

/// A differentiable inner-loop optimiser: `θ_{t+1} = θ_t − P(η) ⊙ u_t`
/// where the update direction `u_t` may depend on moment state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InnerOptimiser {
    /// `u = ∇L` — stateless.
    Sgd,
    /// Heavy-ball: `m' = β·m + ∇L`, `u = m'` — one state slot.
    Momentum { beta: f64 },
    /// Adam with bias correction: `u = m̂/(√v̂ + ε)` — two state slots.
    Adam { beta1: f64, beta2: f64, eps: f64 },
}

impl InnerOptimiser {
    /// Momentum with the conventional β = 0.9.
    pub fn momentum() -> InnerOptimiser {
        InnerOptimiser::Momentum { beta: 0.9 }
    }

    /// Adam with the conventional (0.9, 0.999, 1e-8).
    pub fn adam() -> InnerOptimiser {
        InnerOptimiser::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            InnerOptimiser::Sgd => "sgd",
            InnerOptimiser::Momentum { .. } => "momentum",
            InnerOptimiser::Adam { .. } => "adam",
        }
    }

    /// Case- and whitespace-insensitive name lookup.
    pub fn parse(s: &str) -> Option<InnerOptimiser> {
        match s.trim().to_lowercase().as_str() {
            "sgd" => Some(InnerOptimiser::Sgd),
            "momentum" | "sgdm" => Some(InnerOptimiser::momentum()),
            "adam" => Some(InnerOptimiser::adam()),
            _ => None,
        }
    }

    /// Number of per-leaf state tensors (0 for SGD, 1 momentum, 2 Adam).
    pub fn state_slots(&self) -> usize {
        match self {
            InnerOptimiser::Sgd => 0,
            InnerOptimiser::Momentum { .. } => 1,
            InnerOptimiser::Adam { .. } => 2,
        }
    }

    /// Zero-initialised state, slot-major over the θ leaf shapes.
    pub fn init_state(&self, theta0: &[Tensor]) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(self.state_slots() * theta0.len());
        for _ in 0..self.state_slots() {
            out.extend(theta0.iter().map(|t| Tensor::zeros(&t.shape)));
        }
        out
    }

    /// Build one update step in-graph.  `t` is the 0-based unroll index
    /// (Adam's bias correction uses `t + 1`).  Returns
    /// `(θ_{t+1}, state_{t+1})` with the state slot-major like `state`.
    pub fn step(
        &self,
        tape: &mut Tape,
        theta: &[NodeId],
        state: &[NodeId],
        lrs: &[NodeId],
        grads: &[NodeId],
        t: usize,
    ) -> (Vec<NodeId>, Vec<NodeId>) {
        let n = theta.len();
        assert_eq!(lrs.len(), n, "one lr node per θ leaf");
        assert_eq!(grads.len(), n, "one gradient node per θ leaf");
        assert_eq!(
            state.len(),
            self.state_slots() * n,
            "state must be slot-major over θ leaves"
        );
        match *self {
            InnerOptimiser::Sgd => {
                let mut new_theta = Vec::with_capacity(n);
                for i in 0..n {
                    let delta = tape.mul(lrs[i], grads[i]);
                    new_theta.push(tape.sub(theta[i], delta));
                }
                (new_theta, Vec::new())
            }
            InnerOptimiser::Momentum { beta } => {
                let mut new_theta = Vec::with_capacity(n);
                let mut new_m = Vec::with_capacity(n);
                for i in 0..n {
                    let decayed = tape.scale(state[i], beta);
                    let m_new = tape.add(decayed, grads[i]);
                    let delta = tape.mul(lrs[i], m_new);
                    new_theta.push(tape.sub(theta[i], delta));
                    new_m.push(m_new);
                }
                (new_theta, new_m)
            }
            InnerOptimiser::Adam { beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(t as i32 + 1);
                let bc2 = 1.0 - beta2.powi(t as i32 + 1);
                let mut new_theta = Vec::with_capacity(n);
                let mut new_m = Vec::with_capacity(n);
                let mut new_v = Vec::with_capacity(n);
                for i in 0..n {
                    let (m, v) = (state[i], state[n + i]);
                    let m_decayed = tape.scale(m, beta1);
                    let g_scaled = tape.scale(grads[i], 1.0 - beta1);
                    let m_new = tape.add(m_decayed, g_scaled);
                    let v_decayed = tape.scale(v, beta2);
                    let g_sq = tape.mul(grads[i], grads[i]);
                    let g_sq_scaled = tape.scale(g_sq, 1.0 - beta2);
                    let v_new = tape.add(v_decayed, g_sq_scaled);
                    let m_hat = tape.scale(m_new, 1.0 / bc1);
                    let v_hat = tape.scale(v_new, 1.0 / bc2);
                    // ε_root inside the sqrt keeps the update
                    // differentiable at v̂ = 0 (a zero gradient element
                    // would otherwise send Sqrt's VJP to 0/0 = NaN) —
                    // the standard guard for unrolled/meta Adam.
                    let v_hat_safe = tape.offset(v_hat, 1e-12);
                    let root = tape.sqrt(v_hat_safe);
                    let denom = tape.offset(root, eps);
                    let update = tape.div(m_hat, denom);
                    let delta = tape.mul(lrs[i], update);
                    new_theta.push(tape.sub(theta[i], delta));
                    new_m.push(m_new);
                    new_v.push(v_new);
                }
                new_m.extend(new_v);
                (new_theta, new_m)
            }
        }
    }
}

impl CliEnum for InnerOptimiser {
    fn name(&self) -> String {
        // Method-call syntax resolves to the inherent `name` above.
        self.name().to_string()
    }

    fn parse(s: &str) -> Option<InnerOptimiser> {
        InnerOptimiser::parse(s)
    }

    fn variants() -> &'static [&'static str] {
        &["sgd", "momentum", "adam"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_step(
        opt: InnerOptimiser,
        theta0: f64,
        g: f64,
        lr: f64,
        t: usize,
    ) -> (Vec<Tensor>, Vec<Tensor>) {
        let mut tape = Tape::new();
        let th = tape.leaf(Tensor::scalar(theta0));
        let state_t = opt.init_state(&[Tensor::scalar(theta0)]);
        let state: Vec<NodeId> =
            state_t.iter().map(|s| tape.leaf(s.clone())).collect();
        let lr_id = tape.constant(Tensor::scalar(lr));
        let g_id = tape.constant(Tensor::scalar(g));
        let (nt, ns) = opt.step(&mut tape, &[th], &state, &[lr_id], &[g_id], t);
        (
            nt.iter().map(|&id| tape.value(id).clone()).collect(),
            ns.iter().map(|&id| tape.value(id).clone()).collect(),
        )
    }

    #[test]
    fn sgd_step_matches_closed_form() {
        let (theta, state) = one_step(InnerOptimiser::Sgd, 1.0, 0.5, 0.1, 0);
        assert!((theta[0].item() - 0.95).abs() < 1e-12);
        assert!(state.is_empty());
    }

    #[test]
    fn momentum_first_step_equals_sgd() {
        // m₀ = 0 → m₁ = g, so step 0 matches SGD exactly.
        let (theta, state) =
            one_step(InnerOptimiser::momentum(), 1.0, 0.5, 0.1, 0);
        assert!((theta[0].item() - 0.95).abs() < 1e-12);
        assert!((state[0].item() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // Bias correction makes m̂ = g and v̂ = g² at t = 0, so the first
        // update is lr·g/(|g| + ε) ≈ lr·sign(g).
        let (theta, state) = one_step(InnerOptimiser::adam(), 1.0, 0.5, 0.1, 0);
        assert!((theta[0].item() - 0.9).abs() < 1e-6);
        assert!((state[0].item() - 0.05).abs() < 1e-12, "m = (1−β1)g");
        assert!((state[1].item() - 0.00025).abs() < 1e-12, "v = (1−β2)g²");
    }

    #[test]
    fn parse_is_case_and_space_insensitive() {
        assert_eq!(InnerOptimiser::parse("sgd"), Some(InnerOptimiser::Sgd));
        assert_eq!(
            InnerOptimiser::parse(" Adam\n"),
            Some(InnerOptimiser::adam())
        );
        assert_eq!(
            InnerOptimiser::parse("MOMENTUM"),
            Some(InnerOptimiser::momentum())
        );
        assert_eq!(InnerOptimiser::parse("rmsprop"), None);
    }

    #[test]
    fn state_layout_is_slot_major() {
        let theta = vec![Tensor::zeros(&[2]), Tensor::zeros(&[3])];
        let s = InnerOptimiser::adam().init_state(&theta);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].shape, vec![2]); // m for leaf 0
        assert_eq!(s[1].shape, vec![3]); // m for leaf 1
        assert_eq!(s[2].shape, vec![2]); // v for leaf 0
        assert_eq!(s[3].shape, vec![3]); // v for leaf 1
    }
}

//! Declarative CLI flag parser (`clap` substitute, DESIGN.md §5).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults, and generated `--help` text.

use std::collections::BTreeMap;

/// A CLI-facing enumeration: a closed set of named values a flag can
/// take.  Implementors promise that
///
/// * every string in [`CliEnum::variants`] parses (`parse(v).is_some()`),
/// * the canonical printed name round-trips
///   (`parse(&x.name()) == Some(x)` for canonically-constructed values).
///
/// `main.rs` derives its `--flag ... valid values: a|b|c` error lists
/// from [`CliEnum::valid_values`] instead of hardcoding them, so adding
/// a variant to an enum automatically fixes every error message (the
/// drift that once hid new modes from `--mode`'s error text).
pub trait CliEnum: Sized {
    /// Canonical printed name (re-parses via [`CliEnum::parse`]).
    fn name(&self) -> String;
    /// Case- and whitespace-insensitive lookup.
    fn parse(s: &str) -> Option<Self>;
    /// Accepted spellings, every one of which parses.  Open-ended types
    /// (e.g. a remat segment accepting any integer K ≥ 2) list
    /// exemplars here and override [`CliEnum::valid_values`] with the
    /// general form.
    fn variants() -> &'static [&'static str];
    /// The `a|b|c` list shown in `--flag` error messages.
    fn valid_values() -> String {
        Self::variants().join("|")
    }
}

/// One declared flag.
#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// A tiny declarative argument parser.
#[derive(Debug, Default)]
pub struct ArgSpec {
    program: String,
    about: String,
    flags: Vec<Spec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: &str, about: &str) -> ArgSpec {
        ArgSpec {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a value flag with an optional default.
    pub fn flag(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.flags.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(str::to_string),
            is_bool: false,
        });
        self
    }

    /// Declare a boolean switch (false unless present).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    /// Declare a positional argument (required, in order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [flags]\n\nFLAGS:\n");
        for f in &self.flags {
            let head = if f.is_bool {
                format!("  --{}", f.name)
            } else {
                format!("  --{} <v>", f.name)
            };
            let dflt = f
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:28} {}{dflt}\n", f.help));
        }
        for (p, h) in &self.positionals {
            s.push_str(&format!("  <{p}>  {h}\n"));
        }
        s
    }

    /// Parse from an iterator (std::env::args().skip(1) in main).
    pub fn parse<I: IntoIterator<Item = String>>(
        &self,
        argv: I,
    ) -> Result<Args, String> {
        let mut out = Args::default();
        for f in &self.flags {
            if f.is_bool {
                out.bools.insert(f.name.clone(), false);
            } else if let Some(d) = &f.default {
                out.values.insert(f.name.clone(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}"))?;
                if spec.is_bool {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    out.bools.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?,
                    };
                    out.values.insert(name, v);
                }
            } else {
                out.positionals.push(arg);
            }
        }
        if out.positionals.len() < self.positionals.len() {
            return Err(format!(
                "missing positional <{}>\n\n{}",
                self.positionals[out.positionals.len()].0,
                self.help_text()
            ));
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .ok_or_else(|| format!("--{name} not set"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or_else(|| format!("--{name} not set"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum Fruit {
        Apple,
        Pear,
    }

    impl CliEnum for Fruit {
        fn name(&self) -> String {
            match self {
                Fruit::Apple => "apple".to_string(),
                Fruit::Pear => "pear".to_string(),
            }
        }
        fn parse(s: &str) -> Option<Fruit> {
            match s.trim().to_lowercase().as_str() {
                "apple" => Some(Fruit::Apple),
                "pear" => Some(Fruit::Pear),
                _ => None,
            }
        }
        fn variants() -> &'static [&'static str] {
            &["apple", "pear"]
        }
    }

    #[test]
    fn cli_enum_contract() {
        for v in Fruit::variants() {
            let parsed = Fruit::parse(v).expect("every variant parses");
            assert_eq!(Fruit::parse(&parsed.name()), Some(parsed));
        }
        assert_eq!(Fruit::valid_values(), "apple|pear");
        assert_eq!(Fruit::parse("banana"), None);
    }

    fn spec() -> ArgSpec {
        ArgSpec::new("prog", "test")
            .flag("steps", Some("10"), "number of steps")
            .flag("name", None, "a name")
            .switch("verbose", "talk more")
            .positional("cmd", "command")
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_values() {
        let a = spec().parse(sv(&["run"])).unwrap();
        assert_eq!(a.get("steps"), Some("10"));
        assert_eq!(a.get("name"), None);
        assert!(!a.get_bool("verbose"));
        assert_eq!(a.positional(0), Some("run"));
    }

    #[test]
    fn parses_all_forms() {
        let a = spec()
            .parse(sv(&["run", "--steps", "5", "--name=x", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 5);
        assert_eq!(a.get("name"), Some("x"));
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn errors() {
        assert!(spec().parse(sv(&["run", "--nope"])).is_err());
        assert!(spec().parse(sv(&["run", "--steps"])).is_err());
        assert!(spec().parse(sv(&[])).is_err()); // missing positional
        assert!(spec().parse(sv(&["run", "--verbose=1"])).is_err());
    }

    #[test]
    fn help_lists_flags() {
        let h = spec().parse(sv(&["--help"])).unwrap_err();
        assert!(h.contains("--steps"));
        assert!(h.contains("default: 10"));
    }
}

//! Offline-environment substrates (DESIGN.md §5).
//!
//! The build image has no crates.io access beyond the vendored set, so the
//! pieces a production coordinator would normally pull in (`serde_json`,
//! `clap`, `rand`, `criterion`, `proptest`) are implemented here, each with
//! its own unit/property tests.

pub mod args;
pub mod bench;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;

//! ASCII table rendering for paper-style report rows.

/// A simple left/right-aligned column table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    /// True = right-align (numeric) column.
    numeric: Vec<bool>,
}

impl Table {
    /// Create with header names; columns default to left-aligned.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            numeric: vec![false; header.len()],
        }
    }

    /// Mark columns (by index) right-aligned.
    pub fn numeric_cols(mut self, cols: &[usize]) -> Table {
        for &c in cols {
            if c < self.numeric.len() {
                self.numeric[c] = true;
            }
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep = |l: char, m: char, r: char| {
            let mut s = String::new();
            s.push(l);
            for (i, w) in widths.iter().enumerate() {
                s.push_str(&"─".repeat(w + 2));
                s.push(if i + 1 == ncols { r } else { m });
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("│");
            for (i, cell) in cells.iter().enumerate() {
                let pad = widths[i] - cell.chars().count();
                if self.numeric[i] {
                    s.push_str(&format!(" {}{} │", " ".repeat(pad), cell));
                } else {
                    s.push_str(&format!(" {}{} │", cell, " ".repeat(pad)));
                }
            }
            s.push('\n');
            s
        };
        let mut out = sep('┌', '┬', '┐');
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep('├', '┼', '┤'));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep('└', '┴', '┘'));
        out
    }
}

/// Render a ratio as the paper does ("4.2x (76%)": factor + reduction).
pub fn ratio_cell(ratio: f64) -> String {
    if !ratio.is_finite() || ratio <= 0.0 {
        return "n/a".to_string();
    }
    let reduction = (1.0 - 1.0 / ratio) * 100.0;
    format!("{ratio:.2}x ({reduction:.0}%)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]).numeric_cols(&[1]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["long-name".into(), "123.45".into()]);
        let r = t.render();
        assert!(r.contains("long-name"));
        assert!(r.contains("123.45"));
        // All lines equal width.
        let lens: Vec<usize> =
            r.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{r}");
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(ratio_cell(4.0), "4.00x (75%)");
        assert_eq!(ratio_cell(f64::NAN), "n/a");
        assert_eq!(ratio_cell(0.0), "n/a");
    }
}

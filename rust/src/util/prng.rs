//! Deterministic PRNG (SplitMix64 + xoshiro256**) — `rand` substitute.
//!
//! Used for synthetic token corpora and float inputs; determinism across
//! runs is required so default/mixflow artifact pairs see identical data
//! (DESIGN.md §6 item 2).

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Prng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (`jax.random.fold_in` analogue).
    pub fn fold_in(&self, data: u64) -> Prng {
        let mut h = 0xcbf29ce484222325u64; // FNV offset
        for &w in &self.s {
            h = (h ^ w).wrapping_mul(0x100000001b3);
        }
        Prng::new(h ^ data.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire-style, unbiased enough for
    /// synthetic data; bound must be > 0).
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        ((self.next_u64() >> 32) as u32) % bound
    }

    /// Standard normal via Box–Muller, full f64 precision (the native
    /// autodiff engine runs in f64).
    pub fn next_normal_f64(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of f64 normals scaled by `std`.
    pub fn normal_vec_f64(&mut self, n: usize, std: f64) -> Vec<f64> {
        (0..n).map(|_| self.next_normal_f64() * std).collect()
    }

    /// Standard normal via Box–Muller (f32 view of the same f64 stream).
    pub fn next_normal(&mut self) -> f32 {
        self.next_normal_f64() as f32
    }

    /// Vector of normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_normal() * std).collect()
    }

    /// Vector of token ids in `[0, vocab)`.
    pub fn token_vec(&mut self, n: usize, vocab: u32) -> Vec<i32> {
        (0..n).map(|_| self.next_below(vocab) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fold_in_independent() {
        let base = Prng::new(7);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        assert_ne!(a.next_u64(), b.next_u64());
        // and reproducible
        let mut a2 = base.fold_in(0);
        assert_eq!(Prng::new(7).fold_in(0).next_u64(), a2.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut p = Prng::new(3);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| p.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn tokens_in_range() {
        let mut p = Prng::new(9);
        for t in p.token_vec(1000, 128) {
            assert!((0..128).contains(&t));
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let v = p.normal_vec(20_000, 1.0);
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 =
            v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                / v.len() as f32;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }
}

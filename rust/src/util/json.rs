//! Minimal-but-complete JSON parser/serialiser (`serde_json` substitute).
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes (incl. `\uXXXX` and surrogate pairs), numbers, booleans, null.
//! Object key order is preserved (insertion order) so manifests round-trip
//! deterministically.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key → value plus the original insertion order of the keys.
    Obj(BTreeMap<String, Json>, Vec<String>),
}

impl Json {
    /// Empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new(), Vec::new())
    }

    /// Insert into an object (panics on non-objects — programmer error).
    pub fn insert(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(map, order) => {
                if map.insert(key.to_string(), value).is_none() {
                    order.push(key.to_string());
                }
            }
            _ => panic!("insert on non-object"),
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map, _) => map.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Keys of an object in insertion order.
    pub fn keys(&self) -> &[String] {
        match self {
            Json::Obj(_, order) => order,
            _ => &[],
        }
    }

    /// `obj.path(&["a","b"])` — nested lookup.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialise with 1-space indentation (matches Python's `indent=1`
    /// closely enough for diffing).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Compact serialisation.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&format_number(*n)),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    item.write(out, depth + 1, pretty);
                }
                out.push(']');
            }
            Json::Obj(map, order) => {
                out.push('{');
                for (i, key) in order.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..=depth {
                            out.push(' ');
                        }
                    }
                    write_string(out, key);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    map[key].write(out, depth + 1, pretty);
                }
                if pretty && !order.is_empty() {
                    out.push('\n');
                    for _ in 0..depth {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

/// Format numbers the way JSON expects (integers without `.0`).
fn format_number(n: f64) -> String {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else if n.is_finite() {
        format!("{n}")
    } else {
        "null".to_string() // JSON has no Inf/NaN
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = Json::obj();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(obj);
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(&key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(obj),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair.
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(
                                    self.err("bad surrogate pair")
                                );
                            }
                            let lo = self.hex4()?;
                            0x10000
                                + ((hi - 0xD800) << 10)
                                + (lo.wrapping_sub(0xDC00))
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.src.len());
                    let s = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": {"d": false}}"#,
        )
        .unwrap();
        assert_eq!(v.path(&["c", "d"]), Some(&Json::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 🌍\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 🌍");
    }

    #[test]
    fn roundtrip_preserves_key_order() {
        let src = r#"{"z": 1, "a": 2, "m": [true, "x"]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.keys(), ["z", "a", "m"]);
        let v2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "\"", "tru", "{\"a\" 1}", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn integers_serialise_without_fraction() {
        assert_eq!(Json::Num(5.0).compact(), "5");
        assert_eq!(Json::Num(5.5).compact(), "5.5");
    }

    #[test]
    fn non_finite_numbers_serialise_as_null() {
        // JSON has no NaN/Infinity tokens: a bare `NaN` in a sink would
        // make the whole document unparseable, so the writer must
        // degrade non-finite values to null in every mode.
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).compact(), "null");
            assert_eq!(Json::Num(v).pretty(), "null");
        }
        let mut o = Json::obj();
        o.insert("bad", Json::Num(f64::NAN));
        o.insert("worse", Json::Arr(vec![Json::Num(f64::INFINITY)]));
        let text = o.pretty();
        let back = Json::parse(&text).expect("document stays valid JSON");
        assert!(back.get("bad").unwrap().is_null());
        assert!(back.get("worse").unwrap().as_arr().unwrap()[0].is_null());
        // The degradation is one-way: null does not parse back as a
        // number, so readers see Option::None rather than a bogus 0.
        assert_eq!(back.get("bad").unwrap().as_f64(), None);
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.insert("k", Json::Num(1.0));
        o.insert("k", Json::Num(2.0)); // overwrite keeps single key
        assert_eq!(o.keys().len(), 1);
        assert_eq!(o.get("k").unwrap().as_f64(), Some(2.0));
    }
}

//! Micro-benchmark harness (criterion substitute, DESIGN.md §5).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary that builds a
//! [`Bench`] and calls [`Bench::run`] per measured closure.  The harness
//! does warmup, adaptive iteration counts, and reports mean/median/p95 —
//! enough fidelity for the paper's step-time *ratios*.

use std::time::Instant;

use super::stats::{human_secs, Summary};

/// Configuration for one benchmark binary.
pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard cap on wall-clock per measurement (seconds); once exceeded the
    /// sample set is truncated (PJRT executions can be slow).
    pub max_seconds: f64,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup_iters: 1,
            measure_iters: 10,
            max_seconds: 30.0,
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: usize, measure: usize) -> Bench {
        self.warmup_iters = warmup;
        self.measure_iters = measure;
        self
    }

    pub fn with_budget(mut self, seconds: f64) -> Bench {
        self.max_seconds = seconds;
        self
    }

    /// Measure `f` and record under `label`. Returns the summary.
    pub fn run<F: FnMut()>(&mut self, label: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        let budget_start = Instant::now();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if budget_start.elapsed().as_secs_f64() > self.max_seconds
                && samples.len() >= 3
            {
                break;
            }
        }
        let summary = Summary::of(&samples);
        eprintln!(
            "[bench {}] {label}: median={} mean={} p95={} (n={})",
            self.name,
            human_secs(summary.median),
            human_secs(summary.mean),
            human_secs(summary.p95),
            summary.n,
        );
        self.results.push((label.to_string(), summary.clone()));
        summary
    }

    /// Record an externally-measured summary (e.g. timed PJRT executions).
    pub fn record(&mut self, label: &str, summary: Summary) {
        self.results.push((label.to_string(), summary));
    }

    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }

    /// Final report block (also what `cargo bench` output captures).
    pub fn report(&self) {
        println!("\n== bench: {} ==", self.name);
        for (label, s) in &self.results {
            println!(
                "{label:48} median {:>12} mean {:>12} p95 {:>12} n={}",
                human_secs(s.median),
                human_secs(s.mean),
                human_secs(s.p95),
                s.n
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_records() {
        let mut b = Bench::new("t").with_iters(0, 5);
        let s = b.run("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.n, 5);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn respects_budget() {
        let mut b = Bench::new("t").with_iters(0, 1000).with_budget(0.05);
        let s = b.run("sleepy", || {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        assert!(s.n < 1000);
        assert!(s.n >= 3);
    }
}

//! Summary statistics over timing samples (criterion-lite backend).

/// Summary of a sample set (times in seconds or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; returns zeros for an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                median: 0.0,
                min: 0.0,
                max: 0.0,
                p95: 0.0,
                stddev: 0.0,
            };
        }
        // total_cmp: NaN samples (e.g. a seed sweep over empty loss
        // curves) must degrade to NaN statistics, never panic the sort.
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        Summary {
            n,
            mean,
            median: percentile(&sorted, 50.0),
            min: sorted[0],
            max: sorted[n - 1],
            p95: percentile(&sorted, 95.0),
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for aggregate ratio reporting, paper §5.2).
///
/// Defined only for strictly positive inputs: a zero, negative, or NaN
/// value propagates NaN so corrupt ratios are visible in the report
/// instead of being silently clamped into a plausible-looking number.
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    if values.iter().any(|&v| !(v > 0.0)) {
        return f64::NAN;
    }
    let s: f64 = values.iter().map(|v| v.ln()).sum();
    (s / values.len() as f64).exp()
}

/// Human-readable byte count.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable seconds.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn summary_tolerates_nan_samples() {
        // Regression: a multi-seed sweep over empty loss curves feeds
        // NaN finals; the sort must not panic.
        let s = Summary::of(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_propagates_nan_for_non_positive() {
        // Regression: zero/negative ratios used to be silently clamped
        // to 1e-300, deflating the aggregate toward zero while still
        // printing as a finite number.  They must poison the result.
        assert!(geomean(&[2.0, 0.0]).is_nan());
        assert!(geomean(&[2.0, -1.0]).is_nan());
        assert!(geomean(&[2.0, f64::NAN]).is_nan());
        // Positive-only inputs are unaffected by the guard.
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert!(human_secs(0.0025).contains("ms"));
        assert!(human_secs(2.5).contains("s"));
    }
}

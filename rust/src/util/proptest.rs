//! Property-testing harness (`proptest` substitute, DESIGN.md §5).
//!
//! Seeded generators + bounded shrinking: on failure the runner retries the
//! failing case with "smaller" regenerations (halved size parameter) and
//! reports the smallest reproduction seed.  Coordinator invariants
//! (routing, batching, parser round-trips, liveness) use this.

use super::prng::Prng;

/// Context handed to each property case.
pub struct Gen<'a> {
    pub rng: &'a mut Prng,
    /// Size hint in `[0, 100]`; shrinking lowers it.
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Integer in `[lo, hi]`, biased smaller as `size` shrinks.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        let scaled =
            ((span as f64) * (self.size.max(1) as f64 / 100.0)).ceil() as u64;
        let span = scaled.clamp(1, span);
        lo + (self.rng.next_u64() % span) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick an element from a slice.
    pub fn choose<'b, T>(&mut self, items: &'b [T]) -> &'b T {
        assert!(!items.is_empty());
        &items[(self.rng.next_u64() as usize) % items.len()]
    }

    /// Vector with size-scaled length.
    pub fn vec<T, F: FnMut(&mut Gen) -> T>(
        &mut self,
        max_len: usize,
        mut f: F,
    ) -> Vec<T> {
        let len = self.usize(0, max_len);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(f(self));
        }
        out
    }

    /// Lowercase identifier (for generated HLO names etc).
    pub fn ident(&mut self, max_len: usize) -> String {
        let len = self.usize(1, max_len.max(1));
        (0..len)
            .map(|_| (b'a' + self.rng.next_below(26) as u8) as char)
            .collect()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop` over `cases` generated cases.  On failure, attempts to find a
/// smaller failing size and panics with the reproduction seed.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed: u64 = match std::env::var("MIXFLOW_PROPTEST_SEED") {
        Ok(s) => s.parse().unwrap_or(0xfeed),
        Err(_) => 0xfeed,
    };
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64 * 0x9E3779B9);
        if let Some(failure) = run_case(seed, 100, &prop) {
            // Shrink: retry with smaller sizes; keep the smallest failure.
            let mut smallest = failure;
            let mut size = 50;
            while size >= 1 {
                // Scan a few seeds at this size for a failure.
                let mut found = None;
                for s in 0..20u64 {
                    if let Some(f) =
                        run_case(seed.wrapping_add(s), size, &prop)
                    {
                        found = Some(f);
                        break;
                    }
                }
                match found {
                    Some(f) => {
                        smallest = f;
                        size /= 2;
                    }
                    None => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={}, size={}): {}\n\
                 reproduce with MIXFLOW_PROPTEST_SEED={}",
                smallest.seed, smallest.size, smallest.message, smallest.seed
            );
        }
    }
}

fn run_case<F>(seed: u64, size: usize, prop: &F) -> Option<Failure>
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut rng = Prng::new(seed);
    let mut g = Gen { rng: &mut rng, size };
    match prop(&mut g) {
        Ok(()) => None,
        Err(message) => Some(Failure { seed, size, message }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |g| {
            let a = g.int(-1000, 1000);
            let b = g.int(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let v = g.int(3, 7);
            if (3..=7).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    fn ident_is_lowercase() {
        check("ident", 50, |g| {
            let id = g.ident(12);
            if !id.is_empty()
                && id.chars().all(|c| c.is_ascii_lowercase())
            {
                Ok(())
            } else {
                Err(id)
            }
        });
    }
}

//! # MixFlow-MG — Scalable Meta-Learning via Mixed-Mode Differentiation
//!
//! Rust Layer-3 coordinator for the ICML 2025 paper's system.  The crate
//! loads HLO-text artifacts AOT-compiled from the JAX/Pallas layers
//! (`python/compile/`), executes them on the PJRT CPU client, analyses
//! their memory behaviour with a buffer-liveness simulator, and regenerates
//! every table and figure of the paper's evaluation (DESIGN.md §4).
//!
//! Module map:
//! * [`util`] — offline-environment substrates: JSON, CLI args, PRNG,
//!   ASCII tables, micro-bench harness, property-test harness.
//! * [`hlo`] — HLO text parser → IR, shapes, scheduling, buffer liveness,
//!   the peak-memory simulator (static/dynamic split, Fig. 2 timelines)
//!   and a FLOP cost model.
//! * [`autodiff`] — the native differentiation engine: copy-on-write f64
//!   tensors over an arena-recycled buffer pool, a Wengert-list tape with
//!   graph-mode reverse (so grad-of-grad works), an arena-aware
//!   forward-mode JVP overlay (including batched 3-D matmul and column
//!   concat/split for head-stacking), differentiable inner optimisers
//!   (SGD, momentum, Adam — updates built in-graph), the naive / mixflow
//!   bilevel paths with block rematerialisation and a KV-reuse analysis
//!   for the attention workloads, compiled step plans
//!   (`autodiff::plan`: static tape schedules with liveness-driven
//!   buffer-slot assignment, compiled once per cycle topology and
//!   replayed every steady-state step, with dynamic fallback on
//!   topology changes), and
//!   `autodiff::engine::HypergradEngine` — the unified persistent solver
//!   API (one tape + arena reused across outer steps; naive, mixflow and
//!   fd strategies behind a fluent builder) that every native driver
//!   constructs hypergradients through.  The first path in the repo
//!   where the whole meta-gradient is computed by Rust alone.
//! * [`kernels`] — the compute subsystem under `autodiff`:
//!   cache-blocked matmul/bmm with packed operand panels and
//!   branch-free auto-vectorisable inner loops, fused elementwise
//!   map/zip kernels, fused softmax/logsumexp/layernorm row kernels,
//!   and `kernels::pool::DetPool` — a deterministic scoped thread pool
//!   (one per engine; `--threads` / `MIXFLOW_THREADS`, default 1) that
//!   parallelises only disjoint-output axes (batch·head groups in
//!   `BatchMatmul`, row/element chunks elsewhere), keeping results
//!   bit-for-bit identical to the serial path at every thread count.
//! * [`obs`] — engine observability: the `MetricsRegistry` of counters,
//!   gauges and per-phase wall-time histograms, the span-scoped
//!   `Telemetry` recorder threaded through tape/arena/engine, and the
//!   trace sinks (JSON-lines, Chrome trace-event for Perfetto, CLI
//!   summary table).  Off by default; the disabled path is a no-op.
//! * [`runtime`] — artifact manifest (always available) + the PJRT client
//!   wrapper: compile cache, literal construction, timed execution
//!   (feature `pjrt`).
//! * [`coordinator`] — experiment configs, sweep grids, the threaded
//!   runner, results store, and the paper-style report renderer (the
//!   executing runner needs feature `pjrt`).
//! * [`meta`] — the end-to-end meta-training drivers: `trainer` over
//!   `train_step` artifacts (feature `pjrt`) and `native` over one
//!   persistent `HypergradEngine` (always available), plus the
//!   `SweepSpec` grid (task × inner-optimiser × mode × heads × seed)
//!   fanned over the coordinator's worker pool with a mean ± std JSON
//!   report.
//! * [`serve`] — fault-tolerant hypergradient serving: a bounded job
//!   queue with reject/block backpressure over a supervised pool of
//!   warm engines, with typed errors, per-attempt deadlines, bounded
//!   retries with jittered backoff, graceful degradation (non-finite →
//!   fd, remat escalation under memory pressure), quarantine-and-
//!   rebuild of corrupted engines, and a deterministic fault-injection
//!   harness; `mixflow serve` is its JSONL front end.
//!
//! Feature `pjrt` links an `xla` crate for artifact execution; without it
//! the crate builds, tests and serves the native path on any toolchain.

pub mod autodiff;
pub mod coordinator;
pub mod hlo;
pub mod kernels;
pub mod meta;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default artifacts directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory,
/// walking up so examples/benches work from any workspace subdir.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(env) = std::env::var("MIXFLOW_ARTIFACTS") {
        let p = std::path::PathBuf::from(env);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

//! The compute subsystem: cache-blocked, auto-vectorisable micro-
//! kernels behind every hot tensor operation, plus the deterministic
//! thread pool that fans their disjoint-output axes across cores.
//!
//! Layering: `kernels` sits *below* `autodiff` — every function here
//! operates on plain `&[f64]` slices plus dimensions, with no
//! knowledge of tensors, tapes or arenas.  `Tensor`'s
//! `matmul_into`/`bmm_into`/`map_into`/`zip_into` are shape-checking
//! wrappers over these kernels, and the tape routes its builders, VJP
//! and JVP arms through them with the engine's pool.
//!
//! * [`pool`] — [`pool::DetPool`], the deterministic scoped thread
//!   pool (built once per engine; `--threads` / `MIXFLOW_THREADS`,
//!   default 1).  Parallelises only disjoint-output axes, so results
//!   are bit-for-bit identical to the serial path at every thread
//!   count.
//! * [`gemm`] — cache-blocked matmul/bmm with packed operand panels
//!   and a branch-free unit-stride inner loop; per-output-element
//!   accumulation order is exactly the scalar reference's.  The batch
//!   kernel parallelises over batch·head groups.
//! * [`elementwise`] — fused map/zip sweeps, chunked by index range.
//! * [`rows`] — fused softmax / log-sum-exp / layernorm row kernels
//!   and the generic [`rows::for_each_row`] driver, chunked by row.
//!
//! The determinism contract, blocking scheme and pool lifecycle are
//! documented in `docs/perf/kernels.md`.

pub mod elementwise;
pub mod gemm;
pub mod pool;
pub mod rows;

pub use pool::{DetPool, PoolStats};

/// A raw `*mut f64` that may cross threads.  The kernels hand each
/// pool chunk a disjoint sub-slice of one output buffer; Rust cannot
/// prove the disjointness through a shared closure, so the pointer is
/// wrapped and the slices rebuilt per chunk.  Safety rests on the
/// pool's exactly-once chunk execution plus the kernels' disjoint
/// chunk geometry.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f64);

// SAFETY: see the type docs — only ever used for disjoint writes
// inside one `DetPool::run` region, which the caller outlives.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

//! Fused row kernels: softmax, log-sum-exp, layernorm, and the
//! generic disjoint-row driver they (and the tape's fused JVP rules)
//! are built on.
//!
//! Rows are independent — every output row is a pure function of the
//! matching input row — so the pool may hand row chunks to different
//! threads while each row's *internal* float-op order stays exactly
//! the serial reference's: results are bit-identical at every thread
//! count.  The per-row orders here deliberately mirror the tape's
//! scalar helpers (`t_softmax_rows_into`, `t_logsumexp_rows_into`, the
//! `layernorm_rows` composite) operation for operation; the kernel
//! test suite pins those equivalences bit for bit.

use super::pool::DetPool;
use super::SendPtr;

/// Target elements per row chunk: rows are grouped so one chunk
/// carries roughly this many f64s (≥ 1 row), amortising pool dispatch
/// on skinny matrices while still splitting tall ones.
pub const ROW_CHUNK_ELEMS: usize = 4096;

/// Run `f(i, out_row)` for every row `i in 0..m`, where `out_row` is
/// the `i`-th length-`stride` slice of `out`.  Rows are grouped into
/// chunks of `max(1, ROW_CHUNK_ELEMS / max(n_hint, 1))` rows and the
/// chunks fanned across the pool; chunk geometry depends only on the
/// shape, never the thread count.  `f` must treat rows independently
/// (it only ever sees disjoint `out` slices).
pub fn for_each_row<F: Fn(usize, &mut [f64]) + Sync>(
    pool: &DetPool,
    m: usize,
    stride: usize,
    n_hint: usize,
    out: &mut [f64],
    f: F,
) {
    assert_eq!(out.len(), m * stride, "row kernel output length");
    let rows_per_chunk = (ROW_CHUNK_ELEMS / n_hint.max(1)).max(1);
    let nchunks = m.div_ceil(rows_per_chunk).max(1);
    if pool.threads() == 1 || nchunks <= 1 {
        for i in 0..m {
            f(i, &mut out[i * stride..(i + 1) * stride]);
        }
        return;
    }
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(nchunks, &|c| {
        let lo = c * rows_per_chunk;
        let hi = (lo + rows_per_chunk).min(m);
        for i in lo..hi {
            // SAFETY: chunks run exactly once each and row slices are
            // disjoint by construction.
            let row = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(i * stride), stride)
            };
            f(i, row);
        }
    });
}

/// Row softmax of an `m × n` matrix: max-shifted exp, one denominator
/// accumulation pass (ascending `j`), one divide pass — the exact
/// per-row order of the tape's scalar helper.
pub fn softmax_rows_into(
    pool: &DetPool,
    z: &[f64],
    m: usize,
    n: usize,
    out: &mut [f64],
) {
    assert_eq!(z.len(), m * n, "softmax input length");
    for_each_row(pool, m, n, n, out, |i, orow| {
        let row = &z[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut denom = 0.0;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            orow[j] = e;
            denom += e;
        }
        for o in orow.iter_mut() {
            *o /= denom;
        }
    });
}

/// Row log-sum-exp of an `m × n` matrix into a length-`m` vector:
/// `mx + ln(Σ_j exp(z_ij − mx))`, sum ascending in `j`.
pub fn logsumexp_rows_into(
    pool: &DetPool,
    z: &[f64],
    m: usize,
    n: usize,
    out: &mut [f64],
) {
    assert_eq!(z.len(), m * n, "logsumexp input length");
    for_each_row(pool, m, 1, n, out, |i, orow| {
        let row = &z[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        orow[0] =
            mx + row.iter().map(|x| (x - mx).exp()).sum::<f64>().ln();
    });
}

/// Fused row layernorm: `(z − μ) / √(σ² + eps)` per row, with μ and
/// σ² the mean and (biased) variance of the row.  The per-row float-op
/// order replicates the tape's `layernorm_rows` composite exactly —
/// sum, `· (1/n)`, centre, square-sum, `· (1/n)`, `+ eps`, sqrt,
/// divide — so the fused value is bit-identical to the op-by-op graph
/// (pinned by the kernel tests).
pub fn layernorm_rows_into(
    pool: &DetPool,
    z: &[f64],
    m: usize,
    n: usize,
    eps: f64,
    out: &mut [f64],
) {
    assert_eq!(z.len(), m * n, "layernorm input length");
    let inv_n = 1.0 / n as f64;
    for_each_row(pool, m, n, n, out, |i, orow| {
        let row = &z[i * n..(i + 1) * n];
        let mu = row.iter().sum::<f64>() * inv_n;
        for (o, x) in orow.iter_mut().zip(row) {
            *o = x - mu;
        }
        let var = orow.iter().map(|c| c * c).sum::<f64>() * inv_n;
        let std = (var + eps).sqrt();
        for o in orow.iter_mut() {
            *o /= std;
        }
    });
}

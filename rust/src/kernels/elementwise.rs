//! Fused elementwise map/zip kernels.
//!
//! Every output element is a pure function of the input element(s) at
//! the same index, so any chunking of the index space is bit-identical
//! to the serial sweep — the pool only decides which thread writes
//! which disjoint range.  Chunk geometry depends solely on the input
//! length (never on the thread count), and chunks below [`CHUNK`]
//! elements collapse to the serial path, so tiny tensors never pay
//! pool dispatch.

use super::pool::DetPool;
use super::SendPtr;

/// Elements per parallel chunk.  One chunk of f64s is 64 KiB — big
/// enough to amortise a pool wake, small enough to split the repo's
/// larger tensors across a few cores.
pub const CHUNK: usize = 8192;

/// `out[i] = f(src[i])`.  `out` must be exactly `src.len()` long.
pub fn map_into<F: Fn(f64) -> f64 + Sync>(
    pool: &DetPool,
    src: &[f64],
    f: F,
    out: &mut [f64],
) {
    assert_eq!(src.len(), out.len(), "map kernel length mismatch");
    let n = src.len();
    let nchunks = n.div_ceil(CHUNK.max(1)).max(1);
    if pool.threads() == 1 || nchunks <= 1 {
        for (o, s) in out.iter_mut().zip(src) {
            *o = f(*s);
        }
        return;
    }
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(nchunks, &|c| {
        let lo = c * CHUNK;
        let hi = (lo + CHUNK).min(n);
        // SAFETY: chunks run exactly once each over disjoint ranges.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(optr.0.add(lo), hi - lo)
        };
        for (o, s) in dst.iter_mut().zip(&src[lo..hi]) {
            *o = f(*s);
        }
    });
}

/// `out[i] = f(i)` — the fully general fused elementwise form, used
/// by the tape's multi-operand JVP rules (e.g. the fused
/// `ẋ·b + a·ẏ` product dual) where `f` indexes several captured
/// slices at once.  Same chunking and determinism story as
/// [`map_into`]: every element independent, chunk geometry a function
/// of `n` alone.
pub fn fill_indexed<F: Fn(usize) -> f64 + Sync>(
    pool: &DetPool,
    n: usize,
    f: F,
    out: &mut [f64],
) {
    assert_eq!(n, out.len(), "fill kernel length mismatch");
    let nchunks = n.div_ceil(CHUNK.max(1)).max(1);
    if pool.threads() == 1 || nchunks <= 1 {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(nchunks, &|c| {
        let lo = c * CHUNK;
        let hi = (lo + CHUNK).min(n);
        // SAFETY: chunks run exactly once each over disjoint ranges.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(optr.0.add(lo), hi - lo)
        };
        for (i, o) in dst.iter_mut().enumerate() {
            *o = f(lo + i);
        }
    });
}

/// `out[i] = f(a[i], b[i])`.  All three slices must share a length.
pub fn zip_into<F: Fn(f64, f64) -> f64 + Sync>(
    pool: &DetPool,
    a: &[f64],
    b: &[f64],
    f: F,
    out: &mut [f64],
) {
    assert_eq!(a.len(), b.len(), "zip kernel operand length mismatch");
    assert_eq!(a.len(), out.len(), "zip kernel output length mismatch");
    let n = a.len();
    let nchunks = n.div_ceil(CHUNK.max(1)).max(1);
    if pool.threads() == 1 || nchunks <= 1 {
        for i in 0..n {
            out[i] = f(a[i], b[i]);
        }
        return;
    }
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(nchunks, &|c| {
        let lo = c * CHUNK;
        let hi = (lo + CHUNK).min(n);
        // SAFETY: chunks run exactly once each over disjoint ranges.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(optr.0.add(lo), hi - lo)
        };
        for (i, o) in dst.iter_mut().enumerate() {
            *o = f(a[lo + i], b[lo + i]);
        }
    });
}

//! Deterministic scoped thread pool for the kernel subsystem.
//!
//! [`DetPool`] is a tiny persistent worker pool built once per engine
//! (see `EngineBuilder::threads`, CLI `--threads`, env
//! `MIXFLOW_THREADS`; default 1 = fully serial).  It parallelises only
//! **disjoint-output** axes — batch·head groups in `BatchMatmul`, row
//! or element chunks in the map/zip/softmax/layernorm kernels — so the
//! floating-point accumulation order *per output element* never
//! depends on the thread count.  Results are bit-for-bit identical to
//! the serial reference at every `threads` value; the only thing the
//! pool changes is which core writes which disjoint slice.
//!
//! ## How a parallel region runs
//!
//! [`DetPool::run`]`(nchunks, f)` executes `f(0), f(1), …,
//! f(nchunks-1)`, each chunk exactly once.  Chunks are claimed from a
//! shared atomic counter by the caller *and* the workers, so the
//! caller is never idle; the call returns only after every chunk has
//! finished and every worker has gone back to sleep (a full barrier —
//! this is what makes the lifetime-erased borrow of `f` sound).  With
//! `threads == 1` (no workers) or `nchunks <= 1` the region degrades
//! to a plain serial loop with no locking at all.
//!
//! ## Panics
//!
//! A panic inside a chunk is caught, the first payload is kept, the
//! region is drained, and the payload is re-raised on the calling
//! thread via `resume_unwind` — so the typed panic payloads the
//! serving layer's error taxonomy relies on cross the pool intact.
//!
//! ## Invariants
//!
//! * One region at a time: a `DetPool` must not receive concurrent
//!   `run` calls.  Each engine owns its pool exclusively (the serial
//!   singleton never dispatches, so sharing it is safe).
//! * Not reentrant: a chunk closure must not call back into the same
//!   pool.  Kernels keep nested work (e.g. the blocked GEMM inside a
//!   `BatchMatmul` group) serial for this reason.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Upper clamp for `--threads` / `MIXFLOW_THREADS`: enough for any
/// machine this repo targets, small enough that a typo ("1000") cannot
/// spawn an absurd worker herd.
pub const MAX_THREADS: usize = 64;

/// Resolve the default thread count: `MIXFLOW_THREADS` when set to a
/// positive integer (clamped to [`MAX_THREADS`]), else 1 (serial — the
/// bit-identity-by-construction default).
pub fn default_threads() -> usize {
    match std::env::var("MIXFLOW_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n.min(MAX_THREADS),
            _ => 1,
        },
        Err(_) => 1,
    }
}

/// Lifetime-erased pointer to the caller's chunk closure.  Only ever
/// dereferenced between the moment `run` publishes it and the barrier
/// at the end of the same `run` call, so the erased borrow is live for
/// every use.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are
// fine) and `run` keeps it alive until every worker is done with it.
unsafe impl Send for JobPtr {}

/// Mutex-guarded pool state.  User code never runs under this lock —
/// only small field updates do — so the mutex cannot be poisoned by a
/// kernel panic.
struct Slot {
    /// Current region's closure, `None` between regions.
    job: Option<JobPtr>,
    /// Chunk count of the current region.
    nchunks: usize,
    /// Region sequence number; workers run each epoch exactly once.
    epoch: u64,
    /// Workers that have not yet finished the current epoch.
    running: usize,
    /// First panic payload raised inside a chunk this region.
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Tells sleeping workers to exit (pool drop).
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers sleep here between regions.
    work_cv: Condvar,
    /// The caller sleeps here waiting for `running == 0`.
    done_cv: Condvar,
    /// Next unclaimed chunk index of the current region.
    next: AtomicUsize,
}

/// Cumulative dispatch counters, mirrored into the obs registry
/// (`pool.jobs` / `pool.chunks`) by the engine after each run.  Serial
/// fallbacks (one-chunk regions, `threads == 1`) are *not* counted:
/// zero here means the pool genuinely never engaged.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Parallel regions dispatched to the workers.
    pub jobs: u64,
    /// Chunks executed within those regions.
    pub chunks: u64,
}

/// The deterministic worker pool.  See the module docs for the
/// execution and determinism contract.
pub struct DetPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
    jobs: AtomicU64,
    chunks: AtomicU64,
}

impl DetPool {
    /// Build a pool driving `threads` threads total: the caller plus
    /// `threads - 1` persistent workers.  `threads` is clamped to
    /// `1..=MAX_THREADS`; 1 spawns nothing and every region runs
    /// serially.
    pub fn new(threads: usize) -> DetPool {
        let threads = threads.clamp(1, MAX_THREADS);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                job: None,
                nchunks: 0,
                epoch: 0,
                running: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let workers = (1..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        DetPool {
            shared,
            workers,
            threads,
            jobs: AtomicU64::new(0),
            chunks: AtomicU64::new(0),
        }
    }

    /// The process-wide serial pool — what `Tensor`'s plain kernel
    /// wrappers use when no engine pool is in play.  Never dispatches,
    /// so it is freely shared between threads.
    pub fn serial_ref() -> &'static DetPool {
        static SERIAL: OnceLock<DetPool> = OnceLock::new();
        SERIAL.get_or_init(|| DetPool::new(1))
    }

    /// Total thread count this pool drives (caller included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Cumulative dispatch counters since construction.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            jobs: self.jobs.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
        }
    }

    /// Execute `f(0) .. f(nchunks - 1)`, each chunk exactly once,
    /// across the pool's threads; returns after all chunks finished.
    /// Chunks must write disjoint outputs — the pool guarantees
    /// exactly-once execution, not any particular assignment of chunk
    /// to thread.  Panics in chunks are re-raised here with their
    /// original payload.
    pub fn run(&self, nchunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if self.workers.is_empty() || nchunks <= 1 {
            for c in 0..nchunks {
                f(c);
            }
            return;
        }
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.chunks.fetch_add(nchunks as u64, Ordering::Relaxed);

        // Publish the region and wake the workers.
        {
            let mut slot = self.shared.slot.lock().unwrap();
            debug_assert!(slot.job.is_none(), "DetPool::run is not reentrant");
            self.shared.next.store(0, Ordering::Relaxed);
            slot.job = Some(JobPtr(f as *const _));
            slot.nchunks = nchunks;
            slot.epoch += 1;
            slot.running = self.workers.len();
            self.shared.work_cv.notify_all();
        }

        // The caller drains chunks too; its panics must be caught so
        // the stack frame holding `f` survives until the barrier.
        let caller_panic = drain_chunks(&self.shared, f, nchunks);

        // Barrier: wait for every worker to finish this epoch.  Only
        // after this is the borrow of `f` (and of everything the
        // chunks captured) dead on all threads.
        let payload = {
            let mut slot = self.shared.slot.lock().unwrap();
            while slot.running > 0 {
                slot = self.shared.done_cv.wait(slot).unwrap();
            }
            slot.job = None;
            let mut payload = slot.panic.take();
            if payload.is_none() {
                payload = caller_panic;
            }
            payload
        };
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for DetPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Claim-and-run chunks until the shared counter passes `nchunks`.
/// Returns the first panic payload seen on *this* thread (already
/// recorded payloads from other threads stay in the slot).  After a
/// panic the thread stops claiming — the region is unwinding anyway —
/// but the remaining chunks are still claimed (and skipped) so the
/// counter drains and no thread spins forever.
fn drain_chunks(
    shared: &Shared,
    f: &(dyn Fn(usize) + Sync),
    nchunks: usize,
) -> Option<Box<dyn std::any::Any + Send>> {
    let mut first: Option<Box<dyn std::any::Any + Send>> = None;
    loop {
        let c = shared.next.fetch_add(1, Ordering::Relaxed);
        if c >= nchunks {
            return first;
        }
        if first.is_some() {
            continue;
        }
        if let Err(p) =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(c)))
        {
            first = Some(p);
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        // Sleep until a region we have not run yet (or shutdown).
        let (job, nchunks) = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.job.is_some() && slot.epoch != last_epoch {
                    last_epoch = slot.epoch;
                    break (slot.job.unwrap(), slot.nchunks);
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        // SAFETY: `run` blocks until `running == 0`, which we only
        // signal below — the closure is alive for the whole drain.
        let f = unsafe { &*job.0 };
        let panic = drain_chunks(shared, f, nchunks);
        {
            let mut slot = shared.slot.lock().unwrap();
            if let Some(p) = panic {
                slot.panic.get_or_insert(p);
            }
            slot.running -= 1;
            if slot.running == 0 {
                shared.done_cv.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn serial_pool_runs_every_chunk_in_order() {
        let pool = DetPool::new(1);
        let order = Mutex::new(Vec::new());
        pool.run(5, &|c| order.lock().unwrap().push(c));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
        // Serial fallback never counts as a dispatch.
        assert_eq!(pool.stats(), PoolStats::default());
    }

    #[test]
    fn every_chunk_runs_exactly_once_at_every_thread_count() {
        for threads in [1usize, 2, 3, 4, 8] {
            let pool = DetPool::new(threads);
            let nchunks = 97;
            let marks: Vec<AtomicUsize> =
                (0..nchunks).map(|_| AtomicUsize::new(0)).collect();
            // Several regions back to back: epochs must not bleed.
            for _ in 0..10 {
                pool.run(nchunks, &|c| {
                    marks[c].fetch_add(1, Ordering::Relaxed);
                });
            }
            for (c, m) in marks.iter().enumerate() {
                assert_eq!(
                    m.load(Ordering::Relaxed),
                    10,
                    "chunk {c} at threads={threads}"
                );
            }
        }
    }

    #[test]
    fn disjoint_writes_cover_the_output() {
        let pool = DetPool::new(4);
        let n = 10_000usize;
        let mut out = vec![0.0f64; n];
        let chunk = 64;
        let nchunks = n.div_ceil(chunk);
        {
            let ptr = crate::kernels::SendPtr(out.as_mut_ptr());
            pool.run(nchunks, &|c| {
                let lo = c * chunk;
                let hi = (lo + chunk).min(n);
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo)
                };
                for (i, d) in dst.iter_mut().enumerate() {
                    *d = (lo + i) as f64;
                }
            });
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
        let stats = pool.stats();
        assert_eq!(stats.jobs, 1);
        assert_eq!(stats.chunks, nchunks as u64);
    }

    #[test]
    fn chunk_panic_payload_crosses_the_pool_typed() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        for threads in [1usize, 4] {
            let pool = DetPool::new(threads);
            let caught = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    pool.run(16, &|c| {
                        if c == 7 {
                            std::panic::panic_any(Typed(42));
                        }
                    });
                }),
            )
            .expect_err("the chunk panic must surface");
            let typed = caught
                .downcast_ref::<Typed>()
                .expect("payload must stay typed through the pool");
            assert_eq!(*typed, Typed(42));
            // The pool must stay usable after a panicked region.
            let ran = AtomicUsize::new(0);
            pool.run(8, &|_| {
                ran.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(ran.load(Ordering::Relaxed), 8);
        }
    }

    #[test]
    fn env_default_parses_and_clamps() {
        // Not touching the real env (tests run in parallel); exercise
        // the clamp via new() instead.
        assert_eq!(DetPool::new(0).threads(), 1);
        assert_eq!(DetPool::new(3).threads(), 3);
        assert_eq!(DetPool::new(10_000).threads(), MAX_THREADS);
        assert_eq!(DetPool::serial_ref().threads(), 1);
    }
}

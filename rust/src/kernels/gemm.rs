//! Cache-blocked GEMM / batched-GEMM micro-kernels.
//!
//! The contract every kernel here honours (and the tests pin):
//! **per-output-element accumulation order is exactly the scalar
//! reference's** — `out[i][j] += Σ_l a[i][l]·b[l][j]` with `l` strictly
//! ascending — so the blocked, packed and (for the batch kernel)
//! pooled paths are bit-for-bit identical to [`gemm_ref_into`] on any
//! input, non-finites included.
//!
//! ## Blocking scheme
//!
//! The classic three-loop blocking: the `n` axis in panels of
//! [`NC`], the `k` axis in depth blocks of [`KC`] (visited in
//! ascending order — this is what preserves the accumulation order),
//! the `m` axis in blocks of [`MC`].  For each (k, n) block the
//! operand panels are **packed** into contiguous row-major scratch
//! (`apack`: mc×kc, `bpack`: kc×nc), which turns every transpose
//! combination into the same unit-stride inner loop:
//!
//! ```text
//! for i in 0..mc           // rows of the A block
//!   for l in 0..kc         // ascending depth within the block
//!     out_row[j] += apack[i][l] * bpack_row[j]   // j = 0..nc, branch-free
//! ```
//!
//! The inner `j` loop is a pure `slice[j] += scalar * slice[j]` sweep
//! over contiguous memory with no data-dependent branches — exactly
//! the shape LLVM auto-vectorises.  (The old `Tensor::matmul_into`
//! zero-skip `if ail == 0.0 { continue }` is deliberately gone: it
//! broke vectorisation *and* silently turned `0·NaN` / `0·Inf`
//! contributions into `0` instead of propagating them.)
//!
//! Packing scratch lives in thread-locals, so steady-state GEMMs
//! allocate nothing; the pool's persistent workers each keep their
//! own scratch warm for the batched kernel.
//!
//! ## Parallelism
//!
//! Rank-2 GEMM is always single-threaded — its output rows share the
//! packed B panel and the repo's shapes are small.  The batched kernel
//! [`bmm_into`] parallelises over the batch·head **group** axis (one
//! chunk per group, disjoint output slices) once the region clears
//! [`MIN_PAR_FLOPS`]; below that, dispatch overhead would dwarf the
//! work.  Thresholds never affect results, only scheduling.

use super::pool::DetPool;
use super::SendPtr;
use std::cell::RefCell;

/// Row-block size of the packed A panel (`mc × kc` f64 ≈ 32 KiB —
/// comfortably L1-resident alongside one B-panel row).
pub const MC: usize = 32;
/// Depth-block size; `k` blocks are visited in ascending order to
/// preserve the per-output accumulation order.
pub const KC: usize = 128;
/// Column-panel width of the packed B panel (`kc × nc` f64 = 128 KiB,
/// L2-resident).
pub const NC: usize = 128;

/// Don't fan a batched GEMM out to the pool below this many
/// multiply-adds (`g·m·k·n`): a pool region costs a couple of
/// microseconds of wake/barrier, which only pays for itself once the
/// groups carry real work.
pub const MIN_PAR_FLOPS: usize = 65_536;

thread_local! {
    /// Per-thread packing scratch: (apack, bpack).  Workers are
    /// persistent, so this amortises to zero allocations per step.
    static SCRATCH: RefCell<(Vec<f64>, Vec<f64>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Effective (rows, cols) of an operand stored as `rows × cols`
/// row-major once the transpose flag is applied.
#[inline]
fn eff(rows: usize, cols: usize, t: bool) -> (usize, usize) {
    if t {
        (cols, rows)
    } else {
        (rows, cols)
    }
}

/// The scalar reference kernel: the exact loop nest the blocked paths
/// must match bit for bit.  `out` must be `m·n` long and pre-zeroed
/// (or hold the values to accumulate onto).
pub fn gemm_ref_into(
    a: &[f64],
    a_rows: usize,
    a_cols: usize,
    ta: bool,
    b: &[f64],
    b_rows: usize,
    b_cols: usize,
    tb: bool,
    out: &mut [f64],
) {
    let (m, k) = eff(a_rows, a_cols, ta);
    let (kb, n) = eff(b_rows, b_cols, tb);
    assert_eq!(k, kb, "gemm inner dims {k} vs {kb}");
    assert_eq!(a.len(), a_rows * a_cols, "gemm lhs buffer length");
    assert_eq!(b.len(), b_rows * b_cols, "gemm rhs buffer length");
    assert_eq!(out.len(), m * n, "gemm out buffer length");
    let av = |i: usize, l: usize| {
        if ta {
            a[l * a_cols + i]
        } else {
            a[i * a_cols + l]
        }
    };
    let bv = |l: usize, j: usize| {
        if tb {
            b[j * b_cols + l]
        } else {
            b[l * b_cols + j]
        }
    };
    for i in 0..m {
        for l in 0..k {
            let ail = av(i, l);
            for j in 0..n {
                out[i * n + j] += ail * bv(l, j);
            }
        }
    }
}

/// Pack the `mc × kc` block of A starting at `(i0, l0)` (post-
/// transpose coordinates) into row-major `apack`.
#[inline]
fn pack_a(
    a: &[f64],
    a_cols: usize,
    ta: bool,
    i0: usize,
    mc: usize,
    l0: usize,
    kc: usize,
    apack: &mut [f64],
) {
    if ta {
        // A is stored k-major: element (i, l) lives at a[l·lda + i].
        for i in 0..mc {
            for l in 0..kc {
                apack[i * kc + l] = a[(l0 + l) * a_cols + (i0 + i)];
            }
        }
    } else {
        for i in 0..mc {
            let src = &a[(i0 + i) * a_cols + l0..(i0 + i) * a_cols + l0 + kc];
            apack[i * kc..i * kc + kc].copy_from_slice(src);
        }
    }
}

/// Pack the `kc × nc` panel of B starting at `(l0, j0)` (post-
/// transpose coordinates) into row-major `bpack`.
#[inline]
fn pack_b(
    b: &[f64],
    b_cols: usize,
    tb: bool,
    l0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    bpack: &mut [f64],
) {
    if tb {
        // B is stored n-major: element (l, j) lives at b[j·ldb + l].
        for l in 0..kc {
            for j in 0..nc {
                bpack[l * nc + j] = b[(j0 + j) * b_cols + (l0 + l)];
            }
        }
    } else {
        for l in 0..kc {
            let src = &b[(l0 + l) * b_cols + j0..(l0 + l) * b_cols + j0 + nc];
            bpack[l * nc..l * nc + nc].copy_from_slice(src);
        }
    }
}

/// Cache-blocked `out += A(ta)·B(tb)`; bit-identical to
/// [`gemm_ref_into`].  Single-threaded by design (see module docs);
/// `out` must be `m·n` long and pre-zeroed or carrying accumulands.
pub fn gemm_into(
    a: &[f64],
    a_rows: usize,
    a_cols: usize,
    ta: bool,
    b: &[f64],
    b_rows: usize,
    b_cols: usize,
    tb: bool,
    out: &mut [f64],
) {
    let (m, k) = eff(a_rows, a_cols, ta);
    let (kb, n) = eff(b_rows, b_cols, tb);
    assert_eq!(k, kb, "gemm inner dims {k} vs {kb}");
    assert_eq!(a.len(), a_rows * a_cols, "gemm lhs buffer length");
    assert_eq!(b.len(), b_rows * b_cols, "gemm rhs buffer length");
    assert_eq!(out.len(), m * n, "gemm out buffer length");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    SCRATCH.with(|s| {
        let (apack, bpack) = &mut *s.borrow_mut();
        apack.resize(MC * KC, 0.0);
        bpack.resize(KC * NC, 0.0);
        for j0 in (0..n).step_by(NC) {
            let nc = NC.min(n - j0);
            // Ascending k blocks: the accumulation-order keystone.
            for l0 in (0..k).step_by(KC) {
                let kc = KC.min(k - l0);
                pack_b(b, b_cols, tb, l0, kc, j0, nc, bpack);
                for i0 in (0..m).step_by(MC) {
                    let mc = MC.min(m - i0);
                    pack_a(a, a_cols, ta, i0, mc, l0, kc, apack);
                    for i in 0..mc {
                        let orow = &mut out
                            [(i0 + i) * n + j0..(i0 + i) * n + j0 + nc];
                        for l in 0..kc {
                            let ail = apack[i * kc + l];
                            let brow = &bpack[l * nc..l * nc + nc];
                            for (o, bb) in orow.iter_mut().zip(brow) {
                                *o += ail * bb;
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Batched `out[g] += A[g](ta)·B[g](tb)` over `g` independent groups
/// (batch·head pairs), parallelised across the pool one group per
/// chunk.  Group outputs are disjoint slices of `out`, and each group
/// runs the same serial blocked kernel, so results are bit-identical
/// to a `gemm_into` per group at every thread count.  Dims are per
/// group; `out` must be `g·m·n` long, pre-zeroed or accumulating.
#[allow(clippy::too_many_arguments)]
pub fn bmm_into(
    pool: &DetPool,
    g: usize,
    a: &[f64],
    a_rows: usize,
    a_cols: usize,
    ta: bool,
    b: &[f64],
    b_rows: usize,
    b_cols: usize,
    tb: bool,
    out: &mut [f64],
) {
    let (m, k) = eff(a_rows, a_cols, ta);
    let (kb, n) = eff(b_rows, b_cols, tb);
    assert_eq!(k, kb, "bmm inner dims {k} vs {kb}");
    assert_eq!(a.len(), g * a_rows * a_cols, "bmm lhs buffer length");
    assert_eq!(b.len(), g * b_rows * b_cols, "bmm rhs buffer length");
    assert_eq!(out.len(), g * m * n, "bmm out buffer length");
    let (asz, bsz, osz) = (a_rows * a_cols, b_rows * b_cols, m * n);
    let flops = g * m * k * n;
    let group = |gi: usize, og: &mut [f64]| {
        gemm_into(
            &a[gi * asz..(gi + 1) * asz],
            a_rows,
            a_cols,
            ta,
            &b[gi * bsz..(gi + 1) * bsz],
            b_rows,
            b_cols,
            tb,
            og,
        );
    };
    if pool.threads() == 1 || g <= 1 || flops < MIN_PAR_FLOPS {
        for gi in 0..g {
            group(gi, &mut out[gi * osz..(gi + 1) * osz]);
        }
        return;
    }
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(g, &|gi| {
        // SAFETY: chunk indices are executed exactly once each, and
        // group output slices are disjoint by construction.
        let og = unsafe {
            std::slice::from_raw_parts_mut(optr.0.add(gi * osz), osz)
        };
        group(gi, og);
    });
}

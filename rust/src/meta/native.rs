//! Native end-to-end meta-training: the paper's bilevel tasks served by
//! [`crate::autodiff`] alone — no PJRT, no artifacts, no Python anywhere.
//!
//! Mirrors the artifact driver's surface: an outer Adam loop over η whose
//! per-step hypergradient comes from either `mixflow_hypergrad_with`
//! (forward-over-reverse, the default, with a configurable
//! [`CheckpointPolicy`] remat segment) or `naive_hypergrad`
//! (reverse-over-reverse baseline), producing the same
//! [`super::TrainReport`].  Multi-seed sweeps fan the whole outer loop
//! out over the coordinator's worker pool
//! ([`crate::coordinator::scheduler::run_pool`]).

use std::time::Instant;

use crate::autodiff::mixflow::{
    mixflow_hypergrad_with, naive_hypergrad, BilevelProblem,
    CheckpointPolicy, MemoryReport,
};
use crate::autodiff::optim::InnerOptimiser;
use crate::autodiff::problems::{
    AttentionProblem, HyperLrProblem, LossWeightingProblem,
};
use crate::autodiff::tensor::Tensor;
use crate::coordinator::scheduler::{run_pool, Job};

use super::TrainReport;

/// Which hypergradient path drives the outer loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HypergradMode {
    /// Reverse-over-reverse over one monolithic tape.
    Naive,
    /// Forward-over-reverse with per-step tape reuse (MixFlow-MG).
    Mixflow,
}

impl HypergradMode {
    pub fn name(&self) -> &'static str {
        match self {
            HypergradMode::Naive => "naive",
            HypergradMode::Mixflow => "mixflow",
        }
    }

    /// Case- and whitespace-insensitive (`--mode Mixflow` must work).
    pub fn parse(s: &str) -> Option<HypergradMode> {
        match s.trim().to_lowercase().as_str() {
            "naive" => Some(HypergradMode::Naive),
            "mixflow" => Some(HypergradMode::Mixflow),
            _ => None,
        }
    }
}

/// The native bilevel tasks (paper §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeTask {
    HyperLr,
    LossWeighting,
    Attention,
}

impl NativeTask {
    pub fn name(&self) -> &'static str {
        match self {
            NativeTask::HyperLr => "hyperlr",
            NativeTask::LossWeighting => "loss_weighting",
            NativeTask::Attention => "attention",
        }
    }

    /// Accepts both the native names and the artifact task names,
    /// case- and whitespace-insensitively.
    pub fn parse(s: &str) -> Option<NativeTask> {
        match s.trim().to_lowercase().as_str() {
            "hyperlr" | "learning_lr" => Some(NativeTask::HyperLr),
            "loss_weighting" => Some(NativeTask::LossWeighting),
            "attention" | "attn" => Some(NativeTask::Attention),
            _ => None,
        }
    }
}

/// Outer-loop driver: Adam on η over native hypergradients.
pub struct NativeMetaTrainer {
    problem: Box<dyn BilevelProblem>,
    task: NativeTask,
    mode: HypergradMode,
    remat: CheckpointPolicy,
    meta_lr: f64,
    eta: Vec<Tensor>,
    adam_m: Vec<Tensor>,
    adam_v: Vec<Tensor>,
    adam_t: i32,
    /// Memory report of the most recent hypergradient computation.
    pub last_memory: Option<MemoryReport>,
}

impl NativeMetaTrainer {
    pub fn new(task: NativeTask, seed: u64) -> NativeMetaTrainer {
        NativeMetaTrainer::with_unroll(task, seed, 8)
    }

    /// Build with an explicit inner-unroll length.
    pub fn with_unroll(
        task: NativeTask,
        seed: u64,
        unroll: usize,
    ) -> NativeMetaTrainer {
        let problem: Box<dyn BilevelProblem> = match task {
            NativeTask::HyperLr => {
                Box::new(HyperLrProblem::with_unroll(seed, unroll))
            }
            NativeTask::LossWeighting => {
                Box::new(LossWeightingProblem::with_unroll(seed, unroll))
            }
            NativeTask::Attention => {
                Box::new(AttentionProblem::with_unroll(seed, unroll))
            }
        };
        let eta = problem.eta0();
        let adam_m = eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        let adam_v = eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        NativeMetaTrainer {
            problem,
            task,
            mode: HypergradMode::Mixflow,
            remat: CheckpointPolicy::Full,
            meta_lr: 0.05,
            eta,
            adam_m,
            adam_v,
            adam_t: 0,
            last_memory: None,
        }
    }

    pub fn with_mode(mut self, mode: HypergradMode) -> NativeMetaTrainer {
        self.mode = mode;
        self
    }

    /// Select the inner-loop optimiser (SGD default, momentum, Adam).
    pub fn with_inner_opt(mut self, opt: InnerOptimiser) -> NativeMetaTrainer {
        self.problem.set_optimiser(opt);
        self
    }

    /// Checkpoint policy for the mixflow path (ignored by `--mode naive`,
    /// which has no checkpoints to thin out).
    pub fn with_remat(mut self, policy: CheckpointPolicy) -> NativeMetaTrainer {
        self.remat = policy;
        self
    }

    pub fn with_meta_lr(mut self, lr: f64) -> NativeMetaTrainer {
        self.meta_lr = lr;
        self
    }

    /// Current meta-parameters.
    pub fn eta(&self) -> &[Tensor] {
        &self.eta
    }

    /// Run `steps` outer updates; each draws fresh batches, computes the
    /// hypergradient and applies one Adam step to η.
    pub fn train(&mut self, steps: usize) -> TrainReport {
        let mut losses = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for _ in 0..steps {
            self.problem.resample();
            let theta0 = self.problem.theta0();
            let h = match self.mode {
                HypergradMode::Mixflow => mixflow_hypergrad_with(
                    self.problem.as_ref(),
                    &theta0,
                    &self.eta,
                    self.remat,
                ),
                HypergradMode::Naive => {
                    naive_hypergrad(self.problem.as_ref(), &theta0, &self.eta)
                }
            };
            losses.push(h.outer_loss);
            self.last_memory = Some(h.memory);
            self.adam_step(&h.d_eta);
        }
        let seconds = t0.elapsed().as_secs_f64();
        let mut artifact = format!(
            "native/{}/{}/{}",
            self.task.name(),
            self.mode.name(),
            self.problem.optimiser().name()
        );
        // The naive path has no checkpoints to thin, so only a mixflow
        // run is labelled with its remat policy.
        if self.mode == HypergradMode::Mixflow && self.remat.segment() > 1 {
            artifact.push('/');
            artifact.push_str(&self.remat.name());
        }
        TrainReport {
            artifact,
            steps,
            steps_per_second: steps as f64 / seconds.max(1e-9),
            seconds,
            losses,
        }
    }

    fn adam_step(&mut self, grad: &[Tensor]) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.adam_t += 1;
        let bc1 = 1.0 - B1.powi(self.adam_t);
        let bc2 = 1.0 - B2.powi(self.adam_t);
        for i in 0..self.eta.len() {
            for j in 0..self.eta[i].data.len() {
                let g = grad[i].data[j];
                self.adam_m[i].data[j] =
                    B1 * self.adam_m[i].data[j] + (1.0 - B1) * g;
                self.adam_v[i].data[j] =
                    B2 * self.adam_v[i].data[j] + (1.0 - B2) * g * g;
                let mh = self.adam_m[i].data[j] / bc1;
                let vh = self.adam_v[i].data[j] / bc2;
                self.eta[i].data[j] -= self.meta_lr * mh / (vh.sqrt() + EPS);
            }
        }
    }
}

/// Configuration of one native multi-seed sweep (everything but the
/// seeds themselves).
#[derive(Debug, Clone, Copy)]
pub struct NativeSweepConfig {
    pub task: NativeTask,
    pub mode: HypergradMode,
    pub inner_opt: InnerOptimiser,
    pub remat: CheckpointPolicy,
    pub unroll: usize,
    pub steps: usize,
}

/// One seed's result from [`run_seed_sweep`].
#[derive(Debug, Clone)]
pub struct SeedRun {
    pub seed: u64,
    pub report: TrainReport,
    pub memory: Option<MemoryReport>,
}

/// Fan one native meta-training configuration out over
/// `base_seed .. base_seed + n_seeds` on the coordinator's worker pool.
/// Each seed gets its own trainer (and therefore its own tape + arena)
/// on a pool thread; results come back sorted by seed.  Native step
/// tapes are tiny next to the scheduler's usual HLO artifacts, so the
/// admission budget is effectively unbounded and the pool degenerates to
/// plain `min(seeds, cores)` parallelism.
pub fn run_seed_sweep(
    cfg: NativeSweepConfig,
    base_seed: u64,
    n_seeds: usize,
) -> Vec<SeedRun> {
    let jobs: Vec<Job<SeedRun>> = (0..n_seeds as u64)
        .map(|i| {
            let seed = base_seed.wrapping_add(i);
            Job {
                name: format!("seed{seed}"),
                cost_bytes: (cfg.unroll as u64 + 2) * 64 * 1024,
                work: Box::new(move || {
                    let mut trainer = NativeMetaTrainer::with_unroll(
                        cfg.task, seed, cfg.unroll,
                    )
                    .with_mode(cfg.mode)
                    .with_inner_opt(cfg.inner_opt)
                    .with_remat(cfg.remat);
                    let report = trainer.train(cfg.steps);
                    SeedRun { seed, report, memory: trainer.last_memory }
                }),
            }
        })
        .collect();
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(n_seeds.max(1));
    let mut runs: Vec<SeedRun> = run_pool(jobs, workers, u64::MAX / 2)
        .into_iter()
        .map(|(_, run)| run)
        .collect();
    runs.sort_by_key(|r| r.seed);
    runs
}

/// Render a native run the way the examples and the `native` CLI command
/// present it: sampled loss curve, throughput, head→tail improvement, and
/// the hypergradient memory split.  One implementation so the three call
/// sites cannot drift apart.
pub fn print_train_summary(
    report: &TrainReport,
    memory: Option<&MemoryReport>,
) {
    use crate::util::stats::{human_bytes, human_secs};
    let n = report.losses.len();
    for (i, l) in report.losses.iter().enumerate() {
        if i % (n / 15).max(1) == 0 || i + 1 == n {
            println!("  step {i:>4}  val_loss {l:.4}");
        }
    }
    let (head, tail) = report.improvement(10);
    println!(
        "\n{} outer steps in {} ({:.2} steps/s); loss {head:.4} → {tail:.4}",
        report.steps,
        human_secs(report.seconds),
        report.steps_per_second
    );
    if let Some(mem) = memory {
        println!(
            "hypergrad memory: tape {} + checkpoints {} = {} (peak live {})",
            human_bytes(mem.tape_bytes as u64),
            human_bytes(mem.checkpoint_bytes as u64),
            human_bytes(mem.total_bytes() as u64),
            human_bytes(mem.peak_bytes as u64)
        );
        println!(
            "hypergrad timing: fwd {} + bwd {}; arena {} reuses / {} allocs",
            human_secs(mem.forward_seconds),
            human_secs(mem.backward_seconds),
            mem.arena_reuses,
            mem.arena_allocs
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(NativeTask::parse("hyperlr"), Some(NativeTask::HyperLr));
        assert_eq!(
            NativeTask::parse("learning_lr"),
            Some(NativeTask::HyperLr)
        );
        assert_eq!(
            NativeTask::parse("loss_weighting"),
            Some(NativeTask::LossWeighting)
        );
        assert_eq!(
            NativeTask::parse("attention"),
            Some(NativeTask::Attention)
        );
        assert_eq!(NativeTask::parse("nope"), None);
        assert_eq!(
            HypergradMode::parse("mixflow"),
            Some(HypergradMode::Mixflow)
        );
        assert_eq!(HypergradMode::parse("naive"), Some(HypergradMode::Naive));
    }

    #[test]
    fn parse_is_case_and_whitespace_insensitive() {
        // Regression: `--mode Mixflow` / padded values used to be
        // rejected by the exact-match parsers.
        assert_eq!(
            HypergradMode::parse("Mixflow"),
            Some(HypergradMode::Mixflow)
        );
        assert_eq!(
            HypergradMode::parse(" NAIVE\t"),
            Some(HypergradMode::Naive)
        );
        assert_eq!(NativeTask::parse("HyperLR"), Some(NativeTask::HyperLr));
        assert_eq!(
            NativeTask::parse("  Attention\n"),
            Some(NativeTask::Attention)
        );
        assert_eq!(
            NativeTask::parse("Loss_Weighting"),
            Some(NativeTask::LossWeighting)
        );
        assert_eq!(HypergradMode::parse("mix flow"), None);
    }

    #[test]
    fn attention_adam_outer_step_updates_eta() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::Attention, 5, 2)
                .with_inner_opt(InnerOptimiser::adam());
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(1);
        assert!(report.losses[0].is_finite());
        assert!(report.artifact.ends_with("attention/mixflow/adam"));
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "Adam step must move eta");
    }

    #[test]
    fn one_outer_step_updates_eta() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 2);
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(1);
        assert_eq!(report.losses.len(), 1);
        assert!(report.losses[0].is_finite());
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "Adam step must move eta");
        assert!(trainer.last_memory.is_some());
    }

    #[test]
    fn remat_policy_shows_up_in_the_artifact_name() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 4)
                .with_remat(CheckpointPolicy::Remat { segment: 2 });
        let report = trainer.train(1);
        assert!(report.losses[0].is_finite());
        assert!(
            report.artifact.ends_with("hyperlr/mixflow/sgd/remat2"),
            "got {:?}",
            report.artifact
        );
    }

    #[test]
    fn seed_sweep_runs_on_the_pool_and_sorts_by_seed() {
        let cfg = NativeSweepConfig {
            task: NativeTask::HyperLr,
            mode: HypergradMode::Mixflow,
            inner_opt: InnerOptimiser::Sgd,
            remat: CheckpointPolicy::Full,
            unroll: 2,
            steps: 2,
        };
        let runs = run_seed_sweep(cfg, 11, 3);
        assert_eq!(runs.len(), 3);
        let seeds: Vec<u64> = runs.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![11, 12, 13]);
        for run in &runs {
            assert_eq!(run.report.losses.len(), 2);
            assert!(run.report.losses.iter().all(|l| l.is_finite()));
            assert!(run.memory.is_some(), "sweep must record memory");
        }
        // Different seeds draw different data: the loss curves should
        // not be byte-identical across the whole sweep.
        assert!(
            runs.windows(2).any(|w| w[0].report.losses != w[1].report.losses),
            "all seeds produced identical losses"
        );
    }
}

//! Native end-to-end meta-training: the paper's bilevel tasks served by
//! [`crate::autodiff`] alone — no PJRT, no artifacts, no Python anywhere.
//!
//! Mirrors the artifact driver's surface: an outer Adam loop over η whose
//! per-step hypergradient comes from either `mixflow_hypergrad`
//! (forward-over-reverse, the default) or `naive_hypergrad`
//! (reverse-over-reverse baseline), producing the same
//! [`super::TrainReport`].

use std::time::Instant;

use crate::autodiff::mixflow::{
    mixflow_hypergrad, naive_hypergrad, BilevelProblem, MemoryReport,
};
use crate::autodiff::optim::InnerOptimiser;
use crate::autodiff::problems::{
    AttentionProblem, HyperLrProblem, LossWeightingProblem,
};
use crate::autodiff::tensor::Tensor;

use super::TrainReport;

/// Which hypergradient path drives the outer loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HypergradMode {
    /// Reverse-over-reverse over one monolithic tape.
    Naive,
    /// Forward-over-reverse with per-step tape reuse (MixFlow-MG).
    Mixflow,
}

impl HypergradMode {
    pub fn name(&self) -> &'static str {
        match self {
            HypergradMode::Naive => "naive",
            HypergradMode::Mixflow => "mixflow",
        }
    }

    /// Case- and whitespace-insensitive (`--mode Mixflow` must work).
    pub fn parse(s: &str) -> Option<HypergradMode> {
        match s.trim().to_lowercase().as_str() {
            "naive" => Some(HypergradMode::Naive),
            "mixflow" => Some(HypergradMode::Mixflow),
            _ => None,
        }
    }
}

/// The native bilevel tasks (paper §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeTask {
    HyperLr,
    LossWeighting,
    Attention,
}

impl NativeTask {
    pub fn name(&self) -> &'static str {
        match self {
            NativeTask::HyperLr => "hyperlr",
            NativeTask::LossWeighting => "loss_weighting",
            NativeTask::Attention => "attention",
        }
    }

    /// Accepts both the native names and the artifact task names,
    /// case- and whitespace-insensitively.
    pub fn parse(s: &str) -> Option<NativeTask> {
        match s.trim().to_lowercase().as_str() {
            "hyperlr" | "learning_lr" => Some(NativeTask::HyperLr),
            "loss_weighting" => Some(NativeTask::LossWeighting),
            "attention" | "attn" => Some(NativeTask::Attention),
            _ => None,
        }
    }
}

/// Outer-loop driver: Adam on η over native hypergradients.
pub struct NativeMetaTrainer {
    problem: Box<dyn BilevelProblem>,
    task: NativeTask,
    mode: HypergradMode,
    meta_lr: f64,
    eta: Vec<Tensor>,
    adam_m: Vec<Tensor>,
    adam_v: Vec<Tensor>,
    adam_t: i32,
    /// Memory report of the most recent hypergradient computation.
    pub last_memory: Option<MemoryReport>,
}

impl NativeMetaTrainer {
    pub fn new(task: NativeTask, seed: u64) -> NativeMetaTrainer {
        NativeMetaTrainer::with_unroll(task, seed, 8)
    }

    /// Build with an explicit inner-unroll length.
    pub fn with_unroll(
        task: NativeTask,
        seed: u64,
        unroll: usize,
    ) -> NativeMetaTrainer {
        let problem: Box<dyn BilevelProblem> = match task {
            NativeTask::HyperLr => {
                Box::new(HyperLrProblem::with_unroll(seed, unroll))
            }
            NativeTask::LossWeighting => {
                Box::new(LossWeightingProblem::with_unroll(seed, unroll))
            }
            NativeTask::Attention => {
                Box::new(AttentionProblem::with_unroll(seed, unroll))
            }
        };
        let eta = problem.eta0();
        let adam_m = eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        let adam_v = eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        NativeMetaTrainer {
            problem,
            task,
            mode: HypergradMode::Mixflow,
            meta_lr: 0.05,
            eta,
            adam_m,
            adam_v,
            adam_t: 0,
            last_memory: None,
        }
    }

    pub fn with_mode(mut self, mode: HypergradMode) -> NativeMetaTrainer {
        self.mode = mode;
        self
    }

    /// Select the inner-loop optimiser (SGD default, momentum, Adam).
    pub fn with_inner_opt(mut self, opt: InnerOptimiser) -> NativeMetaTrainer {
        self.problem.set_optimiser(opt);
        self
    }

    pub fn with_meta_lr(mut self, lr: f64) -> NativeMetaTrainer {
        self.meta_lr = lr;
        self
    }

    /// Current meta-parameters.
    pub fn eta(&self) -> &[Tensor] {
        &self.eta
    }

    /// Run `steps` outer updates; each draws fresh batches, computes the
    /// hypergradient and applies one Adam step to η.
    pub fn train(&mut self, steps: usize) -> TrainReport {
        let mut losses = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for _ in 0..steps {
            self.problem.resample();
            let theta0 = self.problem.theta0();
            let h = match self.mode {
                HypergradMode::Mixflow => {
                    mixflow_hypergrad(self.problem.as_ref(), &theta0, &self.eta)
                }
                HypergradMode::Naive => {
                    naive_hypergrad(self.problem.as_ref(), &theta0, &self.eta)
                }
            };
            losses.push(h.outer_loss);
            self.last_memory = Some(h.memory);
            self.adam_step(&h.d_eta);
        }
        let seconds = t0.elapsed().as_secs_f64();
        TrainReport {
            artifact: format!(
                "native/{}/{}/{}",
                self.task.name(),
                self.mode.name(),
                self.problem.optimiser().name()
            ),
            steps,
            steps_per_second: steps as f64 / seconds.max(1e-9),
            seconds,
            losses,
        }
    }

    fn adam_step(&mut self, grad: &[Tensor]) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.adam_t += 1;
        let bc1 = 1.0 - B1.powi(self.adam_t);
        let bc2 = 1.0 - B2.powi(self.adam_t);
        for i in 0..self.eta.len() {
            for j in 0..self.eta[i].data.len() {
                let g = grad[i].data[j];
                self.adam_m[i].data[j] =
                    B1 * self.adam_m[i].data[j] + (1.0 - B1) * g;
                self.adam_v[i].data[j] =
                    B2 * self.adam_v[i].data[j] + (1.0 - B2) * g * g;
                let mh = self.adam_m[i].data[j] / bc1;
                let vh = self.adam_v[i].data[j] / bc2;
                self.eta[i].data[j] -= self.meta_lr * mh / (vh.sqrt() + EPS);
            }
        }
    }
}

/// Render a native run the way the examples and the `native` CLI command
/// present it: sampled loss curve, throughput, head→tail improvement, and
/// the hypergradient memory split.  One implementation so the three call
/// sites cannot drift apart.
pub fn print_train_summary(
    report: &TrainReport,
    memory: Option<&MemoryReport>,
) {
    use crate::util::stats::{human_bytes, human_secs};
    let n = report.losses.len();
    for (i, l) in report.losses.iter().enumerate() {
        if i % (n / 15).max(1) == 0 || i + 1 == n {
            println!("  step {i:>4}  val_loss {l:.4}");
        }
    }
    let (head, tail) = report.improvement(10);
    println!(
        "\n{} outer steps in {} ({:.2} steps/s); loss {head:.4} → {tail:.4}",
        report.steps,
        human_secs(report.seconds),
        report.steps_per_second
    );
    if let Some(mem) = memory {
        println!(
            "hypergrad memory: tape {} + checkpoints {} = {}",
            human_bytes(mem.tape_bytes as u64),
            human_bytes(mem.checkpoint_bytes as u64),
            human_bytes(mem.total_bytes() as u64)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(NativeTask::parse("hyperlr"), Some(NativeTask::HyperLr));
        assert_eq!(
            NativeTask::parse("learning_lr"),
            Some(NativeTask::HyperLr)
        );
        assert_eq!(
            NativeTask::parse("loss_weighting"),
            Some(NativeTask::LossWeighting)
        );
        assert_eq!(
            NativeTask::parse("attention"),
            Some(NativeTask::Attention)
        );
        assert_eq!(NativeTask::parse("nope"), None);
        assert_eq!(
            HypergradMode::parse("mixflow"),
            Some(HypergradMode::Mixflow)
        );
        assert_eq!(HypergradMode::parse("naive"), Some(HypergradMode::Naive));
    }

    #[test]
    fn parse_is_case_and_whitespace_insensitive() {
        // Regression: `--mode Mixflow` / padded values used to be
        // rejected by the exact-match parsers.
        assert_eq!(
            HypergradMode::parse("Mixflow"),
            Some(HypergradMode::Mixflow)
        );
        assert_eq!(
            HypergradMode::parse(" NAIVE\t"),
            Some(HypergradMode::Naive)
        );
        assert_eq!(NativeTask::parse("HyperLR"), Some(NativeTask::HyperLr));
        assert_eq!(
            NativeTask::parse("  Attention\n"),
            Some(NativeTask::Attention)
        );
        assert_eq!(
            NativeTask::parse("Loss_Weighting"),
            Some(NativeTask::LossWeighting)
        );
        assert_eq!(HypergradMode::parse("mix flow"), None);
    }

    #[test]
    fn attention_adam_outer_step_updates_eta() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::Attention, 5, 2)
                .with_inner_opt(InnerOptimiser::adam());
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(1);
        assert!(report.losses[0].is_finite());
        assert!(report.artifact.ends_with("attention/mixflow/adam"));
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "Adam step must move eta");
    }

    #[test]
    fn one_outer_step_updates_eta() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 2);
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(1);
        assert_eq!(report.losses.len(), 1);
        assert!(report.losses[0].is_finite());
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "Adam step must move eta");
        assert!(trainer.last_memory.is_some());
    }
}

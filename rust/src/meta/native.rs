//! Native end-to-end meta-training: the paper's bilevel tasks served by
//! [`crate::autodiff`] alone — no PJRT, no artifacts, no Python anywhere.
//!
//! Mirrors the artifact driver's surface: an outer Adam loop over η whose
//! per-step hypergradient comes from one persistent
//! [`HypergradEngine`] — naive, mixflow (with a configurable
//! [`CheckpointPolicy`] remat segment, `auto` included), fd,
//! `truncated:<K>` (the mixflow adjoint over only the last K inner
//! steps) or evograd (population estimate, no second-order terms),
//! selected by [`HypergradMode`] — producing the same
//! [`super::TrainReport`].
//! Because the engine, its tape and its arena live as long as the
//! trainer, every outer step after the first draws its buffers from the
//! previous step's recycled storage.
//!
//! Sweeps fan out over the coordinator's worker pool
//! ([`crate::coordinator::scheduler::run_pool`]): [`run_seed_sweep`]
//! for the classic one-configuration × N-seeds case, [`run_sweep`] for a
//! full [`SweepSpec`] grid (task × inner-optimiser × mode × heads ×
//! seed), with [`sweep_report_json`] folding the seed axis into
//! per-configuration mean ± std for the `SWEEP_native.json` dump.

use std::time::Instant;

use crate::autodiff::engine::HypergradEngine;
pub use crate::autodiff::engine::HypergradMode;
use crate::autodiff::mixflow::{BilevelProblem, CheckpointPolicy, MemoryReport};
use crate::autodiff::optim::InnerOptimiser;
use crate::autodiff::problems::{
    HyperLrProblem, LossWeightingProblem, MultiHeadAttentionProblem,
};
use crate::autodiff::tensor::Tensor;
use crate::coordinator::scheduler::{run_pool, Job};
use crate::obs::StepTrace;
use crate::util::args::CliEnum;
use crate::util::json::Json;
use crate::util::stats::Summary;

use super::TrainReport;

/// The native bilevel tasks (paper §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeTask {
    HyperLr,
    LossWeighting,
    Attention,
}

impl NativeTask {
    pub fn name(&self) -> &'static str {
        match self {
            NativeTask::HyperLr => "hyperlr",
            NativeTask::LossWeighting => "loss_weighting",
            NativeTask::Attention => "attention",
        }
    }

    /// Accepts both the native names and the artifact task names,
    /// case- and whitespace-insensitively.  The artifact default `maml`
    /// maps to the native engine's nearest equivalent workload, the
    /// hyper-LR task (hosting that alias here keeps `main.rs` free of
    /// string rewriting).
    pub fn parse(s: &str) -> Option<NativeTask> {
        match s.trim().to_lowercase().as_str() {
            "hyperlr" | "learning_lr" | "maml" => Some(NativeTask::HyperLr),
            "loss_weighting" => Some(NativeTask::LossWeighting),
            "attention" | "attn" => Some(NativeTask::Attention),
            _ => None,
        }
    }
}

impl CliEnum for NativeTask {
    fn name(&self) -> String {
        // Method-call syntax resolves to the inherent `name` above.
        self.name().to_string()
    }

    fn parse(s: &str) -> Option<NativeTask> {
        NativeTask::parse(s)
    }

    fn variants() -> &'static [&'static str] {
        &["hyperlr", "learning_lr", "loss_weighting", "attention"]
    }
}

/// Outer-loop driver: Adam on η over native hypergradients, all computed
/// by one persistent [`HypergradEngine`].
pub struct NativeMetaTrainer {
    problem: Box<dyn BilevelProblem>,
    task: NativeTask,
    seed: u64,
    unroll: usize,
    heads: usize,
    batch: usize,
    engine: HypergradEngine,
    meta_lr: f64,
    eta: Vec<Tensor>,
    adam_m: Vec<Tensor>,
    adam_v: Vec<Tensor>,
    adam_t: i32,
    /// Memory report of the most recent hypergradient computation.
    pub last_memory: Option<MemoryReport>,
}

impl NativeMetaTrainer {
    pub fn new(task: NativeTask, seed: u64) -> NativeMetaTrainer {
        NativeMetaTrainer::with_unroll(task, seed, 8)
    }

    /// The one place a `(task, seed, unroll, heads, batch)` tuple turns
    /// into a problem, so the `with_*` shape knobs rebuild exactly what
    /// the constructor built.  `heads`/`batch` only shape the attention
    /// task; its d_model is the base width 6 rounded up to the nearest
    /// multiple of `heads` so any head count divides evenly.  Public
    /// because the serving layer ([`crate::serve`]) materialises the
    /// same problems from job specs.
    pub fn build_problem(
        task: NativeTask,
        seed: u64,
        unroll: usize,
        heads: usize,
        batch: usize,
    ) -> Box<dyn BilevelProblem> {
        match task {
            NativeTask::HyperLr => {
                Box::new(HyperLrProblem::with_unroll(seed, unroll))
            }
            NativeTask::LossWeighting => {
                Box::new(LossWeightingProblem::with_unroll(seed, unroll))
            }
            NativeTask::Attention => {
                let d_model = 6usize.div_ceil(heads) * heads;
                Box::new(MultiHeadAttentionProblem::with_config(
                    seed, d_model, heads, batch, 8, 4, unroll, 0.01,
                ))
            }
        }
    }

    /// Build with an explicit inner-unroll length (single-head,
    /// single-sequence attention; see [`NativeMetaTrainer::with_heads`]
    /// and [`NativeMetaTrainer::with_batch`]).
    pub fn with_unroll(
        task: NativeTask,
        seed: u64,
        unroll: usize,
    ) -> NativeMetaTrainer {
        let problem = Self::build_problem(task, seed, unroll, 1, 1);
        let eta = problem.eta0();
        let adam_m = eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        let adam_v = eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        NativeMetaTrainer {
            problem,
            task,
            seed,
            unroll,
            heads: 1,
            batch: 1,
            // The EvoGrad perturbation stream is keyed by the trainer
            // seed, so sweep cells that differ only in seed draw
            // different populations (and replays stay deterministic).
            engine: HypergradEngine::builder().evo_seed(seed).build(),
            meta_lr: 0.05,
            eta,
            adam_m,
            adam_v,
            adam_t: 0,
            last_memory: None,
        }
    }

    /// Rebuild the problem after a shape knob changed, reinstalling the
    /// engine's inner optimiser and resetting the meta-level state (η
    /// and its Adam moments restart from the fresh problem's η₀).
    fn rebuild_problem(&mut self) {
        self.problem = Self::build_problem(
            self.task, self.seed, self.unroll, self.heads, self.batch,
        );
        self.engine.configure_problem(self.problem.as_mut());
        self.eta = self.problem.eta0();
        self.adam_m =
            self.eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        self.adam_v =
            self.eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
        self.adam_t = 0;
    }

    /// Both attention shape knobs — head count and sequences per batch
    /// — with at most one problem rebuild (ignored by the other tasks).
    /// The attention d_model is rounded up to the nearest multiple of
    /// `heads`.
    pub fn with_attention_shape(
        mut self,
        heads: usize,
        batch: usize,
    ) -> NativeMetaTrainer {
        let heads = heads.max(1);
        let batch = batch.max(1);
        if heads != self.heads || batch != self.batch {
            self.heads = heads;
            self.batch = batch;
            self.rebuild_problem();
        }
        self
    }

    /// Attention head count (ignored by the other tasks).
    pub fn with_heads(self, heads: usize) -> NativeMetaTrainer {
        let batch = self.batch;
        self.with_attention_shape(heads, batch)
    }

    /// Sequences per attention batch (ignored by the other tasks).
    pub fn with_batch(self, batch: usize) -> NativeMetaTrainer {
        let heads = self.heads;
        self.with_attention_shape(heads, batch)
    }

    /// Rebuild the engine from an updated builder, carrying over every
    /// previously configured knob (mode, policy, fd epsilon, EvoGrad
    /// population/σ/seed, inner optimiser, telemetry, plans, threads —
    /// the engine's stored [`HypergradEngine::config`] builder *is* the
    /// knob set).  Cheap before training; mid-training it would drop
    /// the warm arena, so the `with_*` knobs are meant for construction
    /// time.
    fn reconfigure(
        &mut self,
        f: impl FnOnce(
            crate::autodiff::engine::EngineBuilder,
        ) -> crate::autodiff::engine::EngineBuilder,
    ) {
        self.engine = f(self.engine.config()).build();
    }

    pub fn with_mode(mut self, mode: HypergradMode) -> NativeMetaTrainer {
        self.reconfigure(|b| b.mode(mode));
        self
    }

    /// Select the inner-loop optimiser (SGD default, momentum, Adam).
    pub fn with_inner_opt(mut self, opt: InnerOptimiser) -> NativeMetaTrainer {
        self.reconfigure(|b| b.inner_opt(opt));
        self.engine.configure_problem(self.problem.as_mut());
        self
    }

    /// Checkpoint policy for the mixflow path (`auto` resolves K ≈ √T at
    /// run time; ignored by `--mode naive|fd`, which have no checkpoints
    /// to thin out).
    pub fn with_remat(mut self, policy: CheckpointPolicy) -> NativeMetaTrainer {
        self.reconfigure(|b| b.checkpoint(policy));
        self
    }

    /// Central-difference step for the fd path.
    pub fn with_fd_epsilon(mut self, epsilon: f64) -> NativeMetaTrainer {
        self.reconfigure(|b| b.fd_epsilon(epsilon));
        self
    }

    /// Kernel threads for the engine's deterministic pool (default:
    /// `MIXFLOW_THREADS` or 1).  Hypergradients are bit-for-bit
    /// identical at every thread count, so this is purely a walltime
    /// knob.
    pub fn with_threads(mut self, threads: usize) -> NativeMetaTrainer {
        if threads.max(1) != self.engine.threads() {
            self.reconfigure(|b| b.threads(threads));
        }
        self
    }

    /// Enable/disable compiled step plans on the engine tape (on by
    /// default; see `autodiff::plan`).  Off means every cycle records
    /// dynamically against the free-list arena — the pre-plan behaviour,
    /// kept reachable for A/B timing in the walltime bench.
    pub fn with_plan(mut self, on: bool) -> NativeMetaTrainer {
        if on != self.engine.plan_enabled() {
            self.reconfigure(|b| b.plan(on));
        }
        self
    }

    pub fn with_meta_lr(mut self, lr: f64) -> NativeMetaTrainer {
        self.meta_lr = lr;
        self
    }

    /// Enable/disable engine telemetry (off by default).  With telemetry
    /// on, every outer step leaves a [`StepTrace`] on the engine —
    /// drained via [`NativeMetaTrainer::take_traces`].
    pub fn with_telemetry(mut self, on: bool) -> NativeMetaTrainer {
        if on != self.engine.telemetry_enabled() {
            self.reconfigure(|b| b.telemetry(on));
        }
        self
    }

    /// Drain the per-outer-step traces the engine recorded (empty when
    /// telemetry is off).
    pub fn take_traces(&mut self) -> Vec<StepTrace> {
        self.engine.take_step_traces()
    }

    /// Current meta-parameters.
    pub fn eta(&self) -> &[Tensor] {
        &self.eta
    }

    /// The persistent engine driving this trainer's hypergradients.
    pub fn engine(&self) -> &HypergradEngine {
        &self.engine
    }

    /// Run `steps` outer updates; each draws fresh batches, computes the
    /// hypergradient on the persistent engine and applies one Adam step
    /// to η.
    pub fn train(&mut self, steps: usize) -> TrainReport {
        let mut losses = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for _ in 0..steps {
            self.problem.resample();
            let theta0 = self.problem.theta0();
            let h = self.engine.run(self.problem.as_ref(), &theta0, &self.eta);
            losses.push(h.outer_loss);
            self.last_memory = Some(h.memory);
            self.adam_step(&h.d_eta);
        }
        let seconds = t0.elapsed().as_secs_f64();
        let mode = self.engine.mode();
        let mut artifact = format!(
            "native/{}/{}/{}",
            self.task.name(),
            mode.name(),
            self.problem.optimiser().name()
        );
        // Only the checkpointing paths (mixflow, and truncated inside
        // its window) have checkpoints to thin, so only their runs are
        // labelled with a remat policy.
        if matches!(
            mode,
            HypergradMode::Mixflow | HypergradMode::Truncated { .. }
        ) && self.engine.policy() != CheckpointPolicy::Full
        {
            artifact.push('/');
            artifact.push_str(&self.engine.policy().name());
        }
        // Multi-head / batched attention shapes label their runs; the
        // degenerate h1/b1 default keeps the historical label.
        if self.task == NativeTask::Attention && self.heads > 1 {
            artifact.push_str(&format!("/h{}", self.heads));
        }
        if self.task == NativeTask::Attention && self.batch > 1 {
            artifact.push_str(&format!("/b{}", self.batch));
        }
        TrainReport {
            artifact,
            steps,
            steps_per_second: steps as f64 / seconds.max(1e-9),
            seconds,
            losses,
        }
    }

    fn adam_step(&mut self, grad: &[Tensor]) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.adam_t += 1;
        let bc1 = 1.0 - B1.powi(self.adam_t);
        let bc2 = 1.0 - B2.powi(self.adam_t);
        for i in 0..self.eta.len() {
            for j in 0..self.eta[i].data.len() {
                let g = grad[i].data[j];
                self.adam_m[i].data[j] =
                    B1 * self.adam_m[i].data[j] + (1.0 - B1) * g;
                self.adam_v[i].data[j] =
                    B2 * self.adam_v[i].data[j] + (1.0 - B2) * g * g;
                let mh = self.adam_m[i].data[j] / bc1;
                let vh = self.adam_v[i].data[j] / bc2;
                self.eta[i].data[j] -= self.meta_lr * mh / (vh.sqrt() + EPS);
            }
        }
    }
}

/// A full native sweep grid: every
/// `task × inner-optimiser × mode × heads` combination over `n_seeds`
/// consecutive seeds, all sharing one unroll length, attention batch
/// width, outer-step budget and checkpoint policy.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub tasks: Vec<NativeTask>,
    pub inner_opts: Vec<InnerOptimiser>,
    pub modes: Vec<HypergradMode>,
    /// Attention head counts — a sweep axis like the others (the
    /// non-attention tasks ignore the value but still occupy the grid
    /// cell, keeping grid order uniform).
    pub heads: Vec<usize>,
    /// Sequences per attention batch (shared by every cell).
    pub batch: usize,
    pub remat: CheckpointPolicy,
    /// Central-difference step for any fd-mode cells.
    pub fd_epsilon: f64,
    pub unroll: usize,
    pub steps: usize,
    pub base_seed: u64,
    pub n_seeds: usize,
    /// Record per-outer-step telemetry traces on every cell's engine
    /// (each [`SweepRun`] then carries its [`SweepRun::traces`]).
    pub telemetry: bool,
    /// Kernel threads per cell engine (shared by every cell; results
    /// are bit-identical at any value — a walltime knob only).  Note
    /// cells already fan out across the coordinator pool, so >1 only
    /// pays off when the grid is narrower than the machine.
    pub threads: usize,
}

impl SweepSpec {
    /// One configuration over a seed range — the classic
    /// [`run_seed_sweep`] shape.
    pub fn single(
        cfg: NativeSweepConfig,
        base_seed: u64,
        n_seeds: usize,
    ) -> SweepSpec {
        SweepSpec {
            tasks: vec![cfg.task],
            inner_opts: vec![cfg.inner_opt],
            modes: vec![cfg.mode],
            heads: vec![cfg.heads.max(1)],
            batch: cfg.batch.max(1),
            remat: cfg.remat,
            fd_epsilon: crate::autodiff::engine::DEFAULT_FD_EPSILON,
            unroll: cfg.unroll,
            steps: cfg.steps,
            base_seed,
            n_seeds,
            telemetry: false,
            threads: crate::kernels::pool::default_threads(),
        }
    }

    /// The grid, flattened in
    /// task → inner-optimiser → mode → heads → seed order.
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::with_capacity(
            self.tasks.len()
                * self.inner_opts.len()
                * self.modes.len()
                * self.heads.len()
                * self.n_seeds,
        );
        for &task in &self.tasks {
            for &inner_opt in &self.inner_opts {
                for &mode in &self.modes {
                    for &heads in &self.heads {
                        for i in 0..self.n_seeds as u64 {
                            out.push(SweepCell {
                                task,
                                inner_opt,
                                mode,
                                heads,
                                seed: self.base_seed.wrapping_add(i),
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

/// One point of a [`SweepSpec`] grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepCell {
    pub task: NativeTask,
    pub inner_opt: InnerOptimiser,
    pub mode: HypergradMode,
    pub heads: usize,
    pub seed: u64,
}

impl SweepCell {
    /// `task/opt/mode/hH/seedN` — the pool job name and report row label.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/h{}/seed{}",
            self.task.name(),
            self.inner_opt.name(),
            self.mode.name(),
            self.heads,
            self.seed
        )
    }

    /// The cell's configuration key with the seed stripped —
    /// `task/opt/mode/hH` — used to aggregate seeds in
    /// [`sweep_report_json`].
    pub fn config_label(&self) -> String {
        format!(
            "{}/{}/{}/h{}",
            self.task.name(),
            self.inner_opt.name(),
            self.mode.name(),
            self.heads
        )
    }
}

/// One grid cell's result from [`run_sweep`].
#[derive(Debug, Clone)]
pub struct SweepRun {
    pub cell: SweepCell,
    pub report: TrainReport,
    pub memory: Option<MemoryReport>,
    /// Per-outer-step telemetry traces, drained off the cell's engine
    /// after training (empty unless [`SweepSpec::telemetry`] was set).
    /// This is the per-cell aggregation point: each pool worker records
    /// on its own engine-private recorder, and the traces ride back
    /// through `run_pool` with the rest of the result.
    pub traces: Vec<StepTrace>,
    /// `Some(message)` when the cell's trainer panicked (divergence
    /// guard, bad knob, injected fault): the grid keeps its full shape —
    /// one run per cell — with the failure recorded in place instead of
    /// poisoning the whole sweep.  A failed cell carries an empty report
    /// and no memory split.
    pub error: Option<String>,
}

/// Configuration of one native multi-seed sweep (everything but the
/// seeds themselves) — the single-cell ancestor of [`SweepSpec`].
#[derive(Debug, Clone, Copy)]
pub struct NativeSweepConfig {
    pub task: NativeTask,
    pub mode: HypergradMode,
    pub inner_opt: InnerOptimiser,
    pub remat: CheckpointPolicy,
    pub unroll: usize,
    pub steps: usize,
    /// Attention head count (first-class sweep knob; the non-attention
    /// tasks ignore it but carry it in their labels' `hH` segment).
    pub heads: usize,
    /// Sequences per attention batch (ignored by the other tasks).
    pub batch: usize,
}

impl NativeSweepConfig {
    /// The single-head, single-sequence baseline for `task × mode ×
    /// opt`: the historical constructor shape, so call sites that never
    /// cared about attention geometry keep their one-liner.
    pub fn new(
        task: NativeTask,
        mode: HypergradMode,
        inner_opt: InnerOptimiser,
        remat: CheckpointPolicy,
        unroll: usize,
        steps: usize,
    ) -> NativeSweepConfig {
        NativeSweepConfig {
            task,
            mode,
            inner_opt,
            remat,
            unroll,
            steps,
            heads: 1,
            batch: 1,
        }
    }

    /// Attention geometry in one call (clamped to ≥ 1 like the trainer
    /// knobs).
    pub fn with_attention_shape(
        mut self,
        heads: usize,
        batch: usize,
    ) -> NativeSweepConfig {
        self.heads = heads.max(1);
        self.batch = batch.max(1);
        self
    }
}

/// One seed's result from [`run_seed_sweep`].
#[derive(Debug, Clone)]
pub struct SeedRun {
    pub seed: u64,
    pub report: TrainReport,
    pub memory: Option<MemoryReport>,
    /// Panic message when this seed's trainer failed (see
    /// [`SweepRun::error`]).
    pub error: Option<String>,
}

/// Fan a [`SweepSpec`] grid out over the coordinator's worker pool.
/// Each cell gets its own trainer — and therefore its own persistent
/// engine, tape and arena — on a pool thread; results come back sorted
/// in grid order (task → inner-optimiser → mode → seed).  Native step
/// tapes are tiny next to the scheduler's usual HLO artifacts, so the
/// admission budget is effectively unbounded and the pool degenerates to
/// plain `min(cells, cores)` parallelism.
pub fn run_sweep(spec: &SweepSpec) -> Vec<SweepRun> {
    let cells = spec.cells();
    let unroll = spec.unroll;
    let steps = spec.steps;
    let remat = spec.remat;
    let fd_epsilon = spec.fd_epsilon;
    let batch = spec.batch;
    let telemetry = spec.telemetry;
    let threads = spec.threads;
    let jobs: Vec<Job<SweepRun>> = cells
        .iter()
        .map(|&cell| Job {
            name: cell.label(),
            cost_bytes: (unroll as u64 + 2) * 64 * 1024,
            work: Box::new(move || {
                let mut trainer = NativeMetaTrainer::with_unroll(
                    cell.task, cell.seed, unroll,
                )
                .with_mode(cell.mode)
                .with_inner_opt(cell.inner_opt)
                .with_remat(remat)
                .with_fd_epsilon(fd_epsilon)
                .with_attention_shape(cell.heads, batch)
                .with_telemetry(telemetry)
                .with_threads(threads);
                let report = trainer.train(steps);
                let traces = trainer.take_traces();
                SweepRun {
                    cell,
                    report,
                    memory: trainer.last_memory,
                    traces,
                    error: None,
                }
            }),
        })
        .collect();
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cells.len().max(1));
    // The pool catches per-cell panics; a failed cell is reconstructed
    // from its label (pool job names are cell labels) so the grid comes
    // back complete — one run per cell, failures tagged in place.
    let by_label: std::collections::HashMap<String, SweepCell> =
        cells.iter().map(|c| (c.label(), *c)).collect();
    let mut runs: Vec<SweepRun> = run_pool(jobs, workers, u64::MAX / 2)
        .into_iter()
        .map(|(label, outcome)| match outcome {
            Ok(run) => run,
            Err(p) => SweepRun {
                cell: by_label[&label],
                report: TrainReport {
                    artifact: label,
                    losses: Vec::new(),
                    steps: 0,
                    seconds: 0.0,
                    steps_per_second: 0.0,
                },
                memory: None,
                traces: Vec::new(),
                error: Some(p.message),
            },
        })
        .collect();
    // Back into grid order (the pool returns completion order); labels
    // are unique per cell, so they key the ordering.
    let order: std::collections::HashMap<String, usize> =
        cells.iter().map(SweepCell::label).zip(0..).collect();
    runs.sort_by_key(|r| order[&r.cell.label()]);
    runs
}

/// Fan one native meta-training configuration out over
/// `base_seed .. base_seed + n_seeds` on the coordinator's worker pool —
/// a single-cell [`run_sweep`]; results come back sorted by seed.
pub fn run_seed_sweep(
    cfg: NativeSweepConfig,
    base_seed: u64,
    n_seeds: usize,
) -> Vec<SeedRun> {
    run_sweep(&SweepSpec::single(cfg, base_seed, n_seeds))
        .into_iter()
        .map(|run| SeedRun {
            seed: run.cell.seed,
            report: run.report,
            memory: run.memory,
            error: run.error,
        })
        .collect()
}

/// `BENCH_native`-style JSON document for one [`run_sweep`] result set:
/// a `cells` array in exact grid order (task → opt → mode → heads →
/// seed) with per-cell loss-curve mean ± std, and an `aggregates` array
/// folding the seed axis into per-configuration mean ± std of the final
/// validation loss.  The golden-file test in `rust/tests/sweep.rs`
/// parses this dump and checks grid-order completeness, so the schema
/// is pinned: renaming a field is a breaking change.
pub fn sweep_report_json(spec: &SweepSpec, runs: &[SweepRun]) -> Json {
    let mut doc = Json::obj();
    doc.insert("bench", Json::Str("sweep_native".to_string()));
    doc.insert("unroll", Json::Num(spec.unroll as f64));
    doc.insert("steps", Json::Num(spec.steps as f64));
    doc.insert("batch", Json::Num(spec.batch as f64));
    doc.insert("remat", Json::Str(spec.remat.name()));
    doc.insert("threads", Json::Num(spec.threads as f64));
    doc.insert("base_seed", Json::Num(spec.base_seed as f64));
    doc.insert("n_seeds", Json::Num(spec.n_seeds as f64));

    let mut cells = Vec::with_capacity(runs.len());
    for run in runs {
        let losses = &run.report.losses;
        let s = Summary::of(losses);
        let mut row = Json::obj();
        row.insert("task", Json::Str(run.cell.task.name().to_string()));
        row.insert(
            "inner_opt",
            Json::Str(run.cell.inner_opt.name().to_string()),
        );
        row.insert("mode", Json::Str(run.cell.mode.name()));
        row.insert("heads", Json::Num(run.cell.heads as f64));
        row.insert("seed", Json::Num(run.cell.seed as f64));
        row.insert("label", Json::Str(run.cell.label()));
        row.insert(
            "final_loss",
            Json::Num(losses.last().copied().unwrap_or(f64::NAN)),
        );
        row.insert("loss_mean", Json::Num(s.mean));
        row.insert("loss_std", Json::Num(s.stddev));
        row.insert(
            "steps_per_second",
            Json::Num(run.report.steps_per_second),
        );
        if let Some(mem) = &run.memory {
            row.insert("peak_bytes", Json::Num(mem.peak_bytes as f64));
            row.insert(
                "kv_peak_bytes",
                Json::Num(mem.kv_peak_bytes as f64),
            );
        }
        // Failed cells keep their row (grid-order completeness) with the
        // panic message attached; their numeric fields emit as null.
        if let Some(err) = &run.error {
            row.insert("error", Json::Str(err.clone()));
        }
        cells.push(row);
    }
    doc.insert("cells", Json::Arr(cells));

    // Seed-axis aggregation: runs arrive in grid order with the seed
    // varying fastest, so consecutive chunks of `n_seeds` share one
    // configuration.
    let mut aggregates = Vec::new();
    let n = spec.n_seeds.max(1);
    for chunk in runs.chunks(n) {
        // Failed seeds drop out of the aggregate instead of NaN-ing the
        // whole configuration; `n_failed` records how many were lost.
        let finals: Vec<f64> = chunk
            .iter()
            .filter(|r| r.error.is_none())
            .map(|r| r.report.losses.last().copied().unwrap_or(f64::NAN))
            .collect();
        let s = Summary::of(&finals);
        let mut row = Json::obj();
        row.insert("config", Json::Str(chunk[0].cell.config_label()));
        row.insert("n_seeds", Json::Num(chunk.len() as f64));
        row.insert(
            "n_failed",
            Json::Num(chunk.iter().filter(|r| r.error.is_some()).count() as f64),
        );
        row.insert("final_mean", Json::Num(s.mean));
        row.insert("final_std", Json::Num(s.stddev));
        aggregates.push(row);
    }
    doc.insert("aggregates", Json::Arr(aggregates));
    doc
}

/// Render a native run the way the examples and the `native` CLI command
/// present it: sampled loss curve, throughput, head→tail improvement, and
/// the hypergradient memory split.  One implementation so the three call
/// sites cannot drift apart.
pub fn print_train_summary(
    report: &TrainReport,
    memory: Option<&MemoryReport>,
) {
    use crate::util::stats::{human_bytes, human_secs};
    let n = report.losses.len();
    for (i, l) in report.losses.iter().enumerate() {
        if i % (n / 15).max(1) == 0 || i + 1 == n {
            println!("  step {i:>4}  val_loss {l:.4}");
        }
    }
    let (head, tail) = report.improvement(10);
    println!(
        "\n{} outer steps in {} ({:.2} steps/s); loss {head:.4} → {tail:.4}",
        report.steps,
        human_secs(report.seconds),
        report.steps_per_second
    );
    if let Some(mem) = memory {
        println!(
            "hypergrad memory: tape {} + checkpoints {} = {} (peak live {})",
            human_bytes(mem.tape_bytes as u64),
            human_bytes(mem.checkpoint_bytes as u64),
            human_bytes(mem.total_bytes() as u64),
            human_bytes(mem.peak_bytes as u64)
        );
        println!(
            "hypergrad timing: fwd {} + bwd {}; arena {} reuses / {} allocs",
            human_secs(mem.forward_seconds),
            human_secs(mem.backward_seconds),
            mem.arena_reuses,
            mem.arena_allocs
        );
        if mem.kv_peak_bytes > 0 {
            println!(
                "KV reuse: peak {} live; rebuilt {} from checkpoint \
                 aliases + {} from remat",
                human_bytes(mem.kv_peak_bytes as u64),
                human_bytes(mem.kv_ckpt_alias_bytes as u64),
                human_bytes(mem.kv_remat_bytes as u64)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(NativeTask::parse("hyperlr"), Some(NativeTask::HyperLr));
        assert_eq!(
            NativeTask::parse("learning_lr"),
            Some(NativeTask::HyperLr)
        );
        assert_eq!(NativeTask::parse("maml"), Some(NativeTask::HyperLr));
        assert_eq!(
            NativeTask::parse("loss_weighting"),
            Some(NativeTask::LossWeighting)
        );
        assert_eq!(
            NativeTask::parse("attention"),
            Some(NativeTask::Attention)
        );
        assert_eq!(NativeTask::parse("nope"), None);
        assert_eq!(
            HypergradMode::parse("mixflow"),
            Some(HypergradMode::Mixflow)
        );
        assert_eq!(HypergradMode::parse("naive"), Some(HypergradMode::Naive));
        assert_eq!(HypergradMode::parse("fd"), Some(HypergradMode::Fd));
        assert_eq!(
            HypergradMode::parse("truncated:4"),
            Some(HypergradMode::Truncated { horizon: 4 })
        );
        assert_eq!(
            HypergradMode::parse(" Truncated:12 "),
            Some(HypergradMode::Truncated { horizon: 12 })
        );
        assert_eq!(
            HypergradMode::parse("evograd"),
            Some(HypergradMode::Evograd)
        );
        assert_eq!(HypergradMode::parse("truncated:0"), None);
        assert_eq!(HypergradMode::parse("truncated:"), None);
        assert_eq!(HypergradMode::parse("truncated"), None);
    }

    #[test]
    fn parse_is_case_and_whitespace_insensitive() {
        // Regression: `--mode Mixflow` / padded values used to be
        // rejected by the exact-match parsers.
        assert_eq!(
            HypergradMode::parse("Mixflow"),
            Some(HypergradMode::Mixflow)
        );
        assert_eq!(
            HypergradMode::parse(" NAIVE\t"),
            Some(HypergradMode::Naive)
        );
        assert_eq!(HypergradMode::parse(" FD\n"), Some(HypergradMode::Fd));
        assert_eq!(NativeTask::parse("HyperLR"), Some(NativeTask::HyperLr));
        assert_eq!(
            NativeTask::parse("  Attention\n"),
            Some(NativeTask::Attention)
        );
        assert_eq!(
            NativeTask::parse("Loss_Weighting"),
            Some(NativeTask::LossWeighting)
        );
        assert_eq!(HypergradMode::parse("mix flow"), None);
    }

    #[test]
    fn attention_adam_outer_step_updates_eta() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::Attention, 5, 2)
                .with_inner_opt(InnerOptimiser::adam());
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(1);
        assert!(report.losses[0].is_finite());
        assert!(report.artifact.ends_with("attention/mixflow/adam"));
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "Adam step must move eta");
    }

    #[test]
    fn multihead_attention_trainer_labels_and_reports_kv() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::Attention, 5, 3)
                .with_inner_opt(InnerOptimiser::adam())
                .with_heads(2)
                .with_batch(2);
        let report = trainer.train(1);
        assert!(report.losses[0].is_finite());
        assert!(
            report.artifact.ends_with("attention/mixflow/adam/h2/b2"),
            "got {:?}",
            report.artifact
        );
        let mem = trainer.last_memory.expect("memory recorded");
        assert!(mem.kv_peak_bytes > 0, "KV projections must be tagged");
        assert!(
            mem.kv_ckpt_alias_bytes > 0,
            "full checkpointing rebuilds every backward step's K/V from \
             checkpoint aliases"
        );
        assert_eq!(
            mem.kv_remat_bytes, 0,
            "no remat under full checkpointing"
        );
    }

    #[test]
    fn attention_d_model_rounds_up_to_heads() {
        // heads=4 does not divide the base d_model 6; the trainer must
        // widen the model instead of panicking.
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::Attention, 5, 2)
                .with_heads(4)
                .with_batch(2);
        let report = trainer.train(1);
        assert!(report.losses[0].is_finite());
        assert!(
            report.artifact.ends_with("attention/mixflow/sgd/h4/b2"),
            "got {:?}",
            report.artifact
        );
    }

    #[test]
    fn one_outer_step_updates_eta() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 2);
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(1);
        assert_eq!(report.losses.len(), 1);
        assert!(report.losses[0].is_finite());
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "Adam step must move eta");
        assert!(trainer.last_memory.is_some());
        assert_eq!(trainer.engine().outer_steps(), 1);
    }

    #[test]
    fn trainer_engine_persists_across_outer_steps() {
        // The whole point of the engine rebuild: the second outer step
        // must find the first step's buffers in the persistent arena.
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 4);
        trainer.train(1);
        let first = trainer.last_memory.expect("memory recorded");
        trainer.train(1);
        let second = trainer.last_memory.expect("memory recorded");
        assert!(
            second.arena_reuses > first.arena_reuses,
            "second outer step must reuse more than the first \
             ({} vs {})",
            second.arena_reuses,
            first.arena_reuses
        );
        assert!(
            second.arena_allocs < first.arena_allocs,
            "second outer step must allocate less than the first \
             ({} vs {})",
            second.arena_allocs,
            first.arena_allocs
        );
        assert_eq!(trainer.engine().outer_steps(), 2);
    }

    #[test]
    fn fd_mode_trains_and_labels_the_artifact() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 2)
                .with_mode(HypergradMode::Fd);
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(2);
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert!(
            report.artifact.ends_with("hyperlr/fd/sgd"),
            "got {:?}",
            report.artifact
        );
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "fd hypergradients must move eta");
        let mem = trainer.last_memory.expect("fd memory recorded");
        assert_eq!(mem.checkpoint_bytes, 0);
        assert!(mem.arena_reuses > 0, "fd reuses the engine tape");
    }

    #[test]
    fn truncated_mode_trains_and_labels_the_artifact() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 4)
                .with_mode(HypergradMode::Truncated { horizon: 2 });
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(2);
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert!(
            report.artifact.ends_with("hyperlr/truncated:2/sgd"),
            "got {:?}",
            report.artifact
        );
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "truncated hypergradients must move eta");
        // Truncated is a checkpointing mode: a non-full policy labels
        // the artifact just like mixflow's does.
        let remat = NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 4)
            .with_mode(HypergradMode::Truncated { horizon: 4 })
            .with_remat(CheckpointPolicy::Remat { segment: 2 })
            .train(1);
        assert!(
            remat.artifact.ends_with("hyperlr/truncated:4/sgd/remat2"),
            "got {:?}",
            remat.artifact
        );
    }

    #[test]
    fn evograd_mode_trains_and_labels_the_artifact() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 3)
                .with_mode(HypergradMode::Evograd);
        let before: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        let report = trainer.train(2);
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert!(
            report.artifact.ends_with("hyperlr/evograd/sgd"),
            "got {:?}",
            report.artifact
        );
        let after: Vec<f64> =
            trainer.eta().iter().map(|e| e.data[0]).collect();
        assert_ne!(before, after, "evograd hypergradients must move eta");
        let mem = trainer.last_memory.expect("evograd memory recorded");
        assert_eq!(mem.checkpoint_bytes, 0, "evograd stores no checkpoints");
    }

    #[test]
    fn remat_policy_shows_up_in_the_artifact_name() {
        let mut trainer =
            NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 4)
                .with_remat(CheckpointPolicy::Remat { segment: 2 });
        let report = trainer.train(1);
        assert!(report.losses[0].is_finite());
        assert!(
            report.artifact.ends_with("hyperlr/mixflow/sgd/remat2"),
            "got {:?}",
            report.artifact
        );
        let auto = NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 3, 4)
            .with_remat(CheckpointPolicy::Auto)
            .train(1);
        assert!(
            auto.artifact.ends_with("hyperlr/mixflow/sgd/auto"),
            "got {:?}",
            auto.artifact
        );
    }

    #[test]
    fn seed_sweep_runs_on_the_pool_and_sorts_by_seed() {
        let cfg = NativeSweepConfig::new(
            NativeTask::HyperLr,
            HypergradMode::Mixflow,
            InnerOptimiser::Sgd,
            CheckpointPolicy::Full,
            2,
            2,
        );
        let runs = run_seed_sweep(cfg, 11, 3);
        assert_eq!(runs.len(), 3);
        let seeds: Vec<u64> = runs.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![11, 12, 13]);
        for run in &runs {
            assert_eq!(run.report.losses.len(), 2);
            assert!(run.report.losses.iter().all(|l| l.is_finite()));
            assert!(run.memory.is_some(), "sweep must record memory");
        }
        // Different seeds draw different data: the loss curves should
        // not be byte-identical across the whole sweep.
        assert!(
            runs.windows(2).any(|w| w[0].report.losses != w[1].report.losses),
            "all seeds produced identical losses"
        );
    }

    #[test]
    fn seed_sweep_carries_the_attention_geometry() {
        // Satellite of the plan PR: the heads axis is a first-class
        // NativeSweepConfig knob, so a seed sweep can cover multi-head
        // batched attention without graduating to a full SweepSpec.
        let cfg = NativeSweepConfig::new(
            NativeTask::Attention,
            HypergradMode::Mixflow,
            InnerOptimiser::adam(),
            CheckpointPolicy::Full,
            2,
            1,
        )
        .with_attention_shape(2, 2);
        assert_eq!((cfg.heads, cfg.batch), (2, 2));
        let runs = run_seed_sweep(cfg, 5, 2);
        assert_eq!(runs.len(), 2);
        for run in &runs {
            assert!(run.report.losses[0].is_finite());
            assert!(
                run.report.artifact.ends_with("attention/mixflow/adam/h2/b2"),
                "got {:?}",
                run.report.artifact
            );
            let mem = run.memory.as_ref().expect("memory recorded");
            assert!(mem.kv_peak_bytes > 0, "multi-head K/V must be tagged");
        }
    }

    #[test]
    fn sweep_spec_grid_covers_the_product_in_order() {
        let spec = SweepSpec {
            tasks: vec![NativeTask::HyperLr, NativeTask::Attention],
            inner_opts: vec![InnerOptimiser::Sgd, InnerOptimiser::adam()],
            modes: vec![HypergradMode::Mixflow, HypergradMode::Naive],
            heads: vec![1, 2],
            batch: 1,
            remat: CheckpointPolicy::Full,
            fd_epsilon: 1e-5,
            unroll: 2,
            steps: 1,
            base_seed: 7,
            n_seeds: 2,
            telemetry: false,
            threads: 1,
        };
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2 * 2);
        assert_eq!(
            cells[0],
            SweepCell {
                task: NativeTask::HyperLr,
                inner_opt: InnerOptimiser::Sgd,
                mode: HypergradMode::Mixflow,
                heads: 1,
                seed: 7,
            }
        );
        // Seed varies fastest, then heads, then mode; task slowest.
        assert_eq!(cells[1].seed, 8);
        assert_eq!(cells[2].heads, 2);
        assert_eq!(cells[4].mode, HypergradMode::Naive);
        assert_eq!(cells.last().unwrap().task, NativeTask::Attention);
        assert_eq!(cells[0].label(), "hyperlr/sgd/mixflow/h1/seed7");
        assert_eq!(cells[0].config_label(), "hyperlr/sgd/mixflow/h1");
    }

    #[test]
    fn grid_sweep_runs_every_cell_on_the_pool() {
        let spec = SweepSpec {
            tasks: vec![NativeTask::HyperLr],
            inner_opts: vec![InnerOptimiser::Sgd, InnerOptimiser::momentum()],
            modes: vec![HypergradMode::Mixflow, HypergradMode::Naive],
            heads: vec![1],
            batch: 1,
            remat: CheckpointPolicy::Full,
            fd_epsilon: 1e-5,
            unroll: 2,
            steps: 2,
            base_seed: 11,
            n_seeds: 1,
            telemetry: true,
            threads: 1,
        };
        let runs = run_sweep(&spec);
        assert_eq!(runs.len(), 4);
        // Grid order preserved despite pool completion order.
        let labels: Vec<String> =
            runs.iter().map(|r| r.cell.label()).collect();
        assert_eq!(
            labels,
            vec![
                "hyperlr/sgd/mixflow/h1/seed11",
                "hyperlr/sgd/naive/h1/seed11",
                "hyperlr/momentum/mixflow/h1/seed11",
                "hyperlr/momentum/naive/h1/seed11",
            ]
        );
        for run in &runs {
            assert!(run.report.losses.iter().all(|l| l.is_finite()));
            assert!(run.memory.is_some());
            let mode = run.cell.mode.name();
            assert!(
                run.report.artifact.contains(&format!("/{mode}/")),
                "artifact {:?} must carry mode {mode}",
                run.report.artifact
            );
            // spec.telemetry = true: each cell's engine recorded one
            // trace per outer step on its pool thread, and the traces
            // came back through run_pool with the result.
            assert_eq!(run.traces.len(), spec.steps);
            for tr in &run.traces {
                assert_eq!(tr.strategy, mode);
                assert!(tr.phase(crate::obs::Phase::Forward).is_some());
                assert!(tr.counter("tape.nodes").unwrap_or(0) > 0);
            }
        }
        // Same seed + task + mode, different optimiser ⇒ different curves.
        assert_ne!(runs[0].report.losses, runs[2].report.losses);
    }

    #[test]
    fn failed_cells_are_tagged_without_poisoning_the_sweep() {
        // fd mode with a negative epsilon panics inside the cell job
        // (the engine builder asserts epsilon > 0); mixflow cells share
        // the grid and must come back intact.
        let spec = SweepSpec {
            tasks: vec![NativeTask::HyperLr],
            inner_opts: vec![InnerOptimiser::Sgd],
            modes: vec![HypergradMode::Mixflow, HypergradMode::Fd],
            heads: vec![1],
            batch: 1,
            remat: CheckpointPolicy::Full,
            fd_epsilon: -1.0,
            unroll: 2,
            steps: 1,
            base_seed: 11,
            n_seeds: 2,
            telemetry: false,
            threads: 1,
        };
        let runs = run_sweep(&spec);
        assert_eq!(runs.len(), 4, "failed cells keep their grid slots");
        for run in &runs {
            match run.cell.mode {
                HypergradMode::Mixflow => {
                    assert!(run.error.is_none(), "{}", run.cell.label());
                    assert!(run.report.losses[0].is_finite());
                }
                _ => {
                    let err = run.error.as_ref().expect("fd cell must fail");
                    assert!(
                        err.contains("epsilon"),
                        "panic message preserved, got {err:?}"
                    );
                    assert!(run.report.losses.is_empty());
                    assert!(run.memory.is_none());
                }
            }
        }
        // The JSON dump keeps grid-order completeness, tags the failed
        // cells, and drops them from the seed aggregates.
        let doc = sweep_report_json(&spec, &runs);
        let aggs = doc.get("aggregates").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(aggs.len(), 2);
        assert_eq!(
            aggs[1].get("n_failed").and_then(|v| v.as_f64()),
            Some(2.0)
        );
        // The serialised dump must stay valid JSON (NaN → null) and keep
        // grid-order completeness with failed cells tagged.
        let parsed = Json::parse(&doc.pretty()).expect("dump re-parses");
        let cells = parsed.get("cells").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(cells.len(), 4);
        for cell in cells {
            let is_fd = cell.get("mode").and_then(|m| m.as_str())
                == Some("fd");
            assert_eq!(cell.get("error").is_some(), is_fd);
            if is_fd {
                // Empty loss curve: final_loss round-trips as null, not
                // a bare NaN (invalid JSON).
                assert!(cell.get("final_loss").is_some_and(Json::is_null));
            }
        }
    }
}

//! Outer-loop meta-training over a `train_step` artifact.
//!
//! One artifact = one full outer update (inner unroll + MixFlow-MG
//! backward + meta-Adam on η), so this loop is the entire serving surface:
//! feed state + fresh synthetic batches, read back (η', meta-opt', loss).
//! Python is nowhere on this path — the initial state comes from the
//! `.init.npz` the AOT pipeline wrote.

use anyhow::{anyhow, Result};
use xla::Literal;

use super::TrainReport;
use crate::runtime::inputs::corpus_batch;
use crate::runtime::Runtime;
use crate::util::prng::Prng;

/// Drives the outer loop for one train-step artifact.
pub struct MetaTrainer<'r> {
    runtime: &'r Runtime,
    key: String,
    rng: Prng,
}

impl<'r> MetaTrainer<'r> {
    pub fn new(runtime: &'r Runtime, key: &str, seed: u64) -> Self {
        MetaTrainer { runtime, key: key.to_string(), rng: Prng::new(seed) }
    }

    /// Run `steps` outer updates, logging the validation loss each step.
    pub fn train(&mut self, steps: usize) -> Result<TrainReport> {
        let loaded = self.runtime.load(&self.key)?;
        let meta = &loaded.meta;
        if meta.kind != "train_step" {
            return Err(anyhow!("{} is not a train_step artifact", self.key));
        }
        let n_state = meta
            .extra_u64("num_state_leaves")
            .ok_or_else(|| anyhow!("missing num_state_leaves"))?
            as usize;
        let n_eta = meta.extra_u64("num_eta_leaves").unwrap_or(0) as usize;
        let n_meta_opt =
            meta.extra_u64("num_meta_opt_leaves").unwrap_or(0) as usize;
        if meta.inputs.len() != n_state + 2 {
            return Err(anyhow!(
                "expected {} state leaves + xs + val, manifest has {} inputs",
                n_state,
                meta.inputs.len()
            ));
        }

        // State: η, meta-opt, θ₀, inner-opt — from the AOT init dump.
        let mut state = self.runtime.load_init_state(meta)?;
        if state.len() != n_state {
            return Err(anyhow!(
                "init npz has {} leaves, manifest says {n_state}",
                state.len()
            ));
        }
        let xs_spec = meta.inputs[n_state].clone();
        let val_spec = meta.inputs[n_state + 1].clone();
        let vocab = meta.vocab_size as u32;

        // Leaf-segment boundaries derived from the manifest counts; the
        // debug dump below walks these instead of hardcoded indices so it
        // stays correct for artifacts with any leaf layout.
        let segments: [(&str, usize, usize); 5] = [
            ("eta", 0, n_eta),
            ("meta_opt", n_eta, n_eta + n_meta_opt),
            ("theta0/inner_opt", n_eta + n_meta_opt, n_state),
            ("xs", n_state, n_state + 1),
            ("val", n_state + 1, n_state + 2),
        ];

        let mut losses = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for _step in 0..steps {
            let xs = corpus_batch(&xs_spec, &mut self.rng, vocab)?;
            let val = corpus_batch(&val_spec, &mut self.rng, vocab)?;
            let mut inputs: Vec<Literal> = Vec::with_capacity(state.len() + 2);
            inputs.append(&mut state);
            inputs.push(xs);
            inputs.push(val);
            let mut outputs = loaded.execute(&inputs)?;
            if std::env::var("MIXFLOW_TRAIN_DEBUG").is_ok() && _step == 0 {
                for &(name, lo, hi) in &segments {
                    if lo >= hi {
                        continue;
                    }
                    // First and last leaf of each manifest segment.
                    let mut picks = vec![lo];
                    if hi - 1 > lo {
                        picks.push(hi - 1);
                    }
                    for i in picks {
                        let Some(lit) = inputs.get(i) else { continue };
                        let v = lit.to_vec::<f32>().unwrap_or_default();
                        let vi = lit.to_vec::<i32>().unwrap_or_default();
                        eprintln!(
                            "[debug] in[{i}] ({name}) n={} f32head={:?} \
                             i32head={:?}",
                            lit.element_count(),
                            &v[..v.len().min(3)],
                            &vi[..vi.len().min(4)]
                        );
                    }
                }
                for (i, lit) in outputs.iter().enumerate() {
                    if let Ok(v) = lit.to_vec::<f32>() {
                        let nan = v.iter().filter(|x| x.is_nan()).count();
                        if nan > 0 || i < 3 {
                            eprintln!(
                                "[debug] out[{i}] n={} nan={nan} head={:?}",
                                v.len(),
                                &v[..v.len().min(3)]
                            );
                        }
                    }
                }
            }
            // Outputs: η' (n_eta), meta-opt' (n_meta_opt), loss.
            let loss = outputs
                .last()
                .ok_or_else(|| anyhow!("empty outputs"))?
                .to_vec::<f32>()?
                .first()
                .copied()
                .ok_or_else(|| anyhow!("empty loss literal"))? as f64;
            losses.push(loss);
            // Re-assemble state: updated η + meta-opt, constant θ₀/opt₀.
            let mut new_state: Vec<Literal> =
                outputs.drain(..n_eta + n_meta_opt).collect();
            // θ₀ and inner-opt leaves are inputs[n_eta+n_meta_opt..n_state]
            // — recover them from the consumed inputs vector.
            let tail = inputs.drain(n_eta + n_meta_opt..n_state);
            new_state.extend(tail);
            state = new_state;
        }
        let seconds = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            artifact: self.key.clone(),
            steps,
            steps_per_second: steps as f64 / seconds.max(1e-9),
            seconds,
            losses,
        })
    }
}

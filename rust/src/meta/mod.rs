//! End-to-end meta-training drivers (DESIGN.md S18).
//!
//! Two serving surfaces produce the same [`TrainReport`]:
//! * [`trainer`] (feature `pjrt`) — outer loop over AOT-compiled
//!   `train_step` artifacts executed on the PJRT client.
//! * [`native`] — the pure-Rust path: bilevel tasks differentiated by
//!   [`crate::autodiff`], no Python toolchain or artifacts anywhere.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use native::{
    print_train_summary, run_seed_sweep, run_sweep, sweep_report_json,
    HypergradMode, NativeMetaTrainer, NativeSweepConfig, NativeTask,
    SeedRun, SweepCell, SweepRun, SweepSpec,
};
#[cfg(feature = "pjrt")]
pub use trainer::MetaTrainer;

/// Result of a training run (shared by the artifact and native drivers).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub artifact: String,
    pub losses: Vec<f64>,
    pub steps: usize,
    pub seconds: f64,
    pub steps_per_second: f64,
}

impl TrainReport {
    /// Mean loss over the first/last `k` steps — the E2E success signal.
    /// NaN for an empty run (no steps executed).
    pub fn improvement(&self, k: usize) -> (f64, f64) {
        if self.losses.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let k = k.min(self.losses.len() / 2).max(1);
        let head: f64 = self.losses[..k].iter().sum::<f64>() / k as f64;
        let tail: f64 = self.losses[self.losses.len() - k..]
            .iter()
            .sum::<f64>()
            / k as f64;
        (head, tail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improvement_splits_head_tail() {
        let r = TrainReport {
            artifact: "a".into(),
            losses: vec![4.0, 4.0, 2.0, 1.0],
            steps: 4,
            seconds: 1.0,
            steps_per_second: 4.0,
        };
        let (head, tail) = r.improvement(2);
        assert_eq!(head, 4.0);
        assert_eq!(tail, 1.5);
    }

    #[test]
    fn improvement_empty_is_nan() {
        let r = TrainReport {
            artifact: "a".into(),
            losses: vec![],
            steps: 0,
            seconds: 0.0,
            steps_per_second: 0.0,
        };
        let (head, tail) = r.improvement(10);
        assert!(head.is_nan() && tail.is_nan());
    }

    #[test]
    fn improvement_short_series() {
        let r = TrainReport {
            artifact: "a".into(),
            losses: vec![3.0, 1.0],
            steps: 2,
            seconds: 1.0,
            steps_per_second: 2.0,
        };
        let (head, tail) = r.improvement(10);
        assert_eq!(head, 3.0);
        assert_eq!(tail, 1.0);
    }
}

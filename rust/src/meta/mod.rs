//! End-to-end meta-training driver (DESIGN.md S18).

pub mod trainer;

pub use trainer::{MetaTrainer, TrainReport};

//! Layer-3 coordinator (DESIGN.md S15–S17): experiment configs, the
//! per-artifact runner, a threaded memory-aware scheduler, the results
//! store and the paper-style report renderer.

pub mod report;
pub mod results;
pub mod runner;
pub mod scheduler;

pub use results::{Measurement, ResultsStore};
#[cfg(feature = "pjrt")]
pub use runner::{ExperimentRunner, RunOptions};

//! Threaded memory-aware sweep scheduler.
//!
//! HLO parsing + liveness simulation is CPU-bound and embarrassingly
//! parallel across artifacts; PJRT executions, by contrast, must be
//! serialised on one client.  The scheduler therefore runs the *analysis*
//! phase on a worker pool with an admission budget on resident HLO text
//! bytes (big ladder artifacts are 8 MB+ each), then hands exec-tier
//! artifacts to the caller's single-threaded PJRT loop.
//!
//! (On a 1-core CI box the pool degenerates gracefully to sequential.)

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// A unit of analysis work.
pub struct Job<T: Send + 'static> {
    pub name: String,
    /// Estimated resident bytes while the job runs (admission control).
    pub cost_bytes: u64,
    pub work: Box<dyn FnOnce() -> T + Send + 'static>,
}

/// A job whose closure panicked.  The pool catches the unwind, releases
/// the job's admission budget, and returns this in the job's result slot
/// — completed work is never dropped because a sibling blew up.
#[derive(Debug, Clone)]
pub struct JobPanic {
    /// The panic payload rendered to text (`&str`/`String` payloads
    /// verbatim; typed payloads fall back to a placeholder — callers
    /// that need to classify those catch the unwind themselves).
    pub message: String,
}

impl std::fmt::Display for JobPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job panicked: {}", self.message)
    }
}

/// Render a caught panic payload to text.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Pool state shared between workers.
struct Shared<T: Send + 'static> {
    queue: Mutex<SchedState<T>>,
    cv: Condvar,
}

struct SchedState<T: Send + 'static> {
    jobs: VecDeque<Job<T>>,
    in_flight_bytes: u64,
    in_flight_jobs: usize,
    results: Vec<(String, Result<T, JobPanic>)>,
    closed: bool,
}

/// Run all jobs on `workers` threads with at most `budget_bytes` of
/// estimated resident cost admitted simultaneously.  Returns results in
/// completion order tagged by job name — exactly one entry per job, with
/// a panicking job contributing `Err(JobPanic)` instead of aborting the
/// pool (the unwind is caught *before* the admission counters are
/// released, so a panicker cannot strand condvar waiters either).
pub fn run_pool<T: Send + 'static>(
    jobs: Vec<Job<T>>,
    workers: usize,
    budget_bytes: u64,
) -> Vec<(String, Result<T, JobPanic>)> {
    let workers = workers.max(1);
    let shared = Arc::new(Shared {
        queue: Mutex::new(SchedState {
            jobs: jobs.into(),
            in_flight_bytes: 0,
            in_flight_jobs: 0,
            results: Vec::new(),
            closed: false,
        }),
        cv: Condvar::new(),
    });

    let mut handles = Vec::new();
    for _ in 0..workers {
        let shared = Arc::clone(&shared);
        handles.push(thread::spawn(move || loop {
            let job = {
                let mut st = shared.queue.lock().unwrap();
                loop {
                    if st.jobs.is_empty() {
                        st.closed = true;
                        shared.cv.notify_all();
                        return;
                    }
                    // Admit the next job if it fits the budget (always
                    // admit when nothing is in flight so oversized jobs
                    // still run, just alone).
                    let fits = {
                        let next = st.jobs.front().unwrap();
                        st.in_flight_jobs == 0
                            || st.in_flight_bytes + next.cost_bytes
                                <= budget_bytes
                    };
                    if fits {
                        let job = st.jobs.pop_front().unwrap();
                        st.in_flight_bytes += job.cost_bytes;
                        st.in_flight_jobs += 1;
                        break job;
                    }
                    st = shared.cv.wait(st).unwrap();
                }
            };
            let name = job.name;
            let cost = job.cost_bytes;
            let work = job.work;
            let result = catch_unwind(AssertUnwindSafe(move || work()))
                .map_err(|payload| JobPanic {
                    message: panic_message(payload.as_ref()),
                });
            let mut st = shared.queue.lock().unwrap();
            st.in_flight_bytes -= cost;
            st.in_flight_jobs -= 1;
            st.results.push((name, result));
            shared.cv.notify_all();
        }));
    }
    // Worker bodies catch per-job unwinds, so a join error would mean a
    // panic in the pool plumbing itself; surface whatever results exist
    // rather than aborting the caller.
    for h in handles {
        let _ = h.join();
    }
    let mut st = shared
        .queue
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner);
    std::mem::take(&mut st.results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn job(name: &str, cost: u64, out: u64) -> Job<u64> {
        Job {
            name: name.to_string(),
            cost_bytes: cost,
            work: Box::new(move || out),
        }
    }

    #[test]
    fn runs_all_jobs() {
        let jobs = (0..20).map(|i| job(&format!("j{i}"), 1, i)).collect();
        let results = run_pool(jobs, 4, 100);
        assert_eq!(results.len(), 20);
        let sum: u64 = results
            .iter()
            .map(|(_, v)| *v.as_ref().expect("no job panicked"))
            .sum();
        assert_eq!(sum, (0..20).sum());
    }

    #[test]
    fn panicking_jobs_return_tagged_errors_without_losing_results() {
        // 8 jobs, 2 panickers: the pool must return 8 tagged results —
        // the panics contained to their own slots, every completed
        // sibling's value intact.
        let jobs: Vec<Job<u64>> = (0..8)
            .map(|i| {
                if i == 1 || i == 5 {
                    Job {
                        name: format!("j{i}"),
                        cost_bytes: 1,
                        work: Box::new(move || panic!("boom {i}")),
                    }
                } else {
                    job(&format!("j{i}"), 1, i)
                }
            })
            .collect();
        let mut results = run_pool(jobs, 3, 100);
        assert_eq!(results.len(), 8, "one result per job, panics included");
        results.sort_by(|a, b| a.0.cmp(&b.0));
        let failed: Vec<&str> = results
            .iter()
            .filter(|(_, r)| r.is_err())
            .map(|(n, _)| n.as_str())
            .collect();
        assert_eq!(failed, ["j1", "j5"]);
        let err = results
            .iter()
            .find_map(|(_, r)| r.as_ref().err())
            .expect("two panickers");
        assert!(err.message.contains("boom"), "payload text preserved");
        let sum: u64 = results
            .iter()
            .filter_map(|(_, r)| r.as_ref().ok().copied())
            .sum();
        assert_eq!(sum, 2 + 3 + 4 + 6 + 7);
    }

    #[test]
    fn panicker_releases_budget_for_condvar_waiters() {
        // The panicker is admitted holding 60 of a 100-byte budget; if
        // the unwind escaped before the in-flight counters were released
        // the remaining workers would block on the admission condvar
        // forever.  Completion of all 5 results is the pin.
        let mut jobs = vec![Job {
            name: "panicker".to_string(),
            cost_bytes: 60,
            work: Box::new(|| -> u64 { panic!("die holding budget") }),
        }];
        jobs.extend((0..4).map(|i| job(&format!("j{i}"), 60, i)));
        let results = run_pool(jobs, 2, 100);
        assert_eq!(results.len(), 5);
        assert_eq!(results.iter().filter(|(_, r)| r.is_err()).count(), 1);
    }

    #[test]
    fn oversized_job_still_runs() {
        let jobs = vec![job("big", 10_000, 1), job("small", 1, 2)];
        let results = run_pool(jobs, 2, 100);
        assert_eq!(results.len(), 2);
    }

    #[test]
    fn budget_limits_concurrency() {
        // Each job claims 60 of a 100 budget ⇒ max 1 in flight at a time
        // (after the first admission the second doesn't fit).
        static PEAK: AtomicU64 = AtomicU64::new(0);
        static CUR: AtomicU64 = AtomicU64::new(0);
        let jobs = (0..6)
            .map(|i| Job {
                name: format!("j{i}"),
                cost_bytes: 60,
                work: Box::new(|| {
                    let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
                    PEAK.fetch_max(c, Ordering::SeqCst);
                    std::thread::sleep(
                        std::time::Duration::from_millis(5),
                    );
                    CUR.fetch_sub(1, Ordering::SeqCst);
                    0u64
                }),
            })
            .collect();
        let results = run_pool(jobs, 4, 100);
        assert_eq!(results.len(), 6);
        assert_eq!(PEAK.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn property_all_names_returned() {
        crate::util::proptest::check("scheduler-complete", 20, |g| {
            let n = g.usize(0, 30);
            let jobs: Vec<Job<u64>> = (0..n)
                .map(|i| {
                    job(&format!("j{i}"), g.int(0, 50) as u64, i as u64)
                })
                .collect();
            let workers = g.usize(1, 4);
            let budget = g.int(1, 200) as u64;
            let results = run_pool(jobs, workers, budget);
            if results.len() == n {
                Ok(())
            } else {
                Err(format!("{} of {n} jobs returned", results.len()))
            }
        });
    }
}

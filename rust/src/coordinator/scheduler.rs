//! Threaded memory-aware sweep scheduler.
//!
//! HLO parsing + liveness simulation is CPU-bound and embarrassingly
//! parallel across artifacts; PJRT executions, by contrast, must be
//! serialised on one client.  The scheduler therefore runs the *analysis*
//! phase on a worker pool with an admission budget on resident HLO text
//! bytes (big ladder artifacts are 8 MB+ each), then hands exec-tier
//! artifacts to the caller's single-threaded PJRT loop.
//!
//! (On a 1-core CI box the pool degenerates gracefully to sequential.)

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// A unit of analysis work.
pub struct Job<T: Send + 'static> {
    pub name: String,
    /// Estimated resident bytes while the job runs (admission control).
    pub cost_bytes: u64,
    pub work: Box<dyn FnOnce() -> T + Send + 'static>,
}

/// Pool state shared between workers.
struct Shared<T: Send + 'static> {
    queue: Mutex<SchedState<T>>,
    cv: Condvar,
}

struct SchedState<T: Send + 'static> {
    jobs: VecDeque<Job<T>>,
    in_flight_bytes: u64,
    in_flight_jobs: usize,
    results: Vec<(String, T)>,
    closed: bool,
}

/// Run all jobs on `workers` threads with at most `budget_bytes` of
/// estimated resident cost admitted simultaneously.  Returns results in
/// completion order tagged by job name.
pub fn run_pool<T: Send + 'static>(
    jobs: Vec<Job<T>>,
    workers: usize,
    budget_bytes: u64,
) -> Vec<(String, T)> {
    let workers = workers.max(1);
    let shared = Arc::new(Shared {
        queue: Mutex::new(SchedState {
            jobs: jobs.into(),
            in_flight_bytes: 0,
            in_flight_jobs: 0,
            results: Vec::new(),
            closed: false,
        }),
        cv: Condvar::new(),
    });

    let mut handles = Vec::new();
    for _ in 0..workers {
        let shared = Arc::clone(&shared);
        handles.push(thread::spawn(move || loop {
            let job = {
                let mut st = shared.queue.lock().unwrap();
                loop {
                    if st.jobs.is_empty() {
                        st.closed = true;
                        shared.cv.notify_all();
                        return;
                    }
                    // Admit the next job if it fits the budget (always
                    // admit when nothing is in flight so oversized jobs
                    // still run, just alone).
                    let fits = {
                        let next = st.jobs.front().unwrap();
                        st.in_flight_jobs == 0
                            || st.in_flight_bytes + next.cost_bytes
                                <= budget_bytes
                    };
                    if fits {
                        let job = st.jobs.pop_front().unwrap();
                        st.in_flight_bytes += job.cost_bytes;
                        st.in_flight_jobs += 1;
                        break job;
                    }
                    st = shared.cv.wait(st).unwrap();
                }
            };
            let name = job.name;
            let cost = job.cost_bytes;
            let result = (job.work)();
            let mut st = shared.queue.lock().unwrap();
            st.in_flight_bytes -= cost;
            st.in_flight_jobs -= 1;
            st.results.push((name, result));
            shared.cv.notify_all();
        }));
    }
    for h in handles {
        h.join().expect("scheduler worker panicked");
    }
    let mut st = shared.queue.lock().unwrap();
    std::mem::take(&mut st.results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn job(name: &str, cost: u64, out: u64) -> Job<u64> {
        Job {
            name: name.to_string(),
            cost_bytes: cost,
            work: Box::new(move || out),
        }
    }

    #[test]
    fn runs_all_jobs() {
        let jobs = (0..20).map(|i| job(&format!("j{i}"), 1, i)).collect();
        let mut results = run_pool(jobs, 4, 100);
        results.sort();
        assert_eq!(results.len(), 20);
        let sum: u64 = results.iter().map(|(_, v)| v).sum();
        assert_eq!(sum, (0..20).sum());
    }

    #[test]
    fn oversized_job_still_runs() {
        let jobs = vec![job("big", 10_000, 1), job("small", 1, 2)];
        let results = run_pool(jobs, 2, 100);
        assert_eq!(results.len(), 2);
    }

    #[test]
    fn budget_limits_concurrency() {
        // Each job claims 60 of a 100 budget ⇒ max 1 in flight at a time
        // (after the first admission the second doesn't fit).
        static PEAK: AtomicU64 = AtomicU64::new(0);
        static CUR: AtomicU64 = AtomicU64::new(0);
        let jobs = (0..6)
            .map(|i| Job {
                name: format!("j{i}"),
                cost_bytes: 60,
                work: Box::new(|| {
                    let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
                    PEAK.fetch_max(c, Ordering::SeqCst);
                    std::thread::sleep(
                        std::time::Duration::from_millis(5),
                    );
                    CUR.fetch_sub(1, Ordering::SeqCst);
                    0u64
                }),
            })
            .collect();
        let results = run_pool(jobs, 4, 100);
        assert_eq!(results.len(), 6);
        assert_eq!(PEAK.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn property_all_names_returned() {
        crate::util::proptest::check("scheduler-complete", 20, |g| {
            let n = g.usize(0, 30);
            let jobs: Vec<Job<u64>> = (0..n)
                .map(|i| {
                    job(&format!("j{i}"), g.int(0, 50) as u64, i as u64)
                })
                .collect();
            let workers = g.usize(1, 4);
            let budget = g.int(1, 200) as u64;
            let results = run_pool(jobs, workers, budget);
            if results.len() == n {
                Ok(())
            } else {
                Err(format!("{} of {n} jobs returned", results.len()))
            }
        });
    }
}

//! Measurement records + a JSONL results store.
//!
//! Every bench/e2e run appends its measurements to `results/*.jsonl` so the
//! EXPERIMENTS.md numbers are regenerable and auditable.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One measured artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub key: String,
    pub group: String,
    pub task: String,
    pub variant: String,
    pub size_name: String,
    pub seq_len: usize,
    pub batch: usize,
    pub inner_steps: usize,
    pub n_layers: usize,
    pub param_count: u64,
    /// Simulated peak dynamic bytes (HLO liveness).
    pub sim_dynamic_bytes: u64,
    /// Simulated static bytes (params + constants + outputs).
    pub sim_static_bytes: u64,
    /// XLA CompiledMemoryStats temp bytes, when recorded at AOT time.
    pub xla_temp_bytes: Option<u64>,
    /// Median step seconds on the PJRT CPU client (exec tier only).
    pub step_seconds: Option<f64>,
    /// Cost-model FLOPs.
    pub flops: f64,
    /// Flattened instruction count.
    pub instructions: usize,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("key", Json::Str(self.key.clone()));
        o.insert("group", Json::Str(self.group.clone()));
        o.insert("task", Json::Str(self.task.clone()));
        o.insert("variant", Json::Str(self.variant.clone()));
        o.insert("size_name", Json::Str(self.size_name.clone()));
        o.insert("seq_len", Json::Num(self.seq_len as f64));
        o.insert("batch", Json::Num(self.batch as f64));
        o.insert("inner_steps", Json::Num(self.inner_steps as f64));
        o.insert("n_layers", Json::Num(self.n_layers as f64));
        o.insert("param_count", Json::Num(self.param_count as f64));
        o.insert(
            "sim_dynamic_bytes",
            Json::Num(self.sim_dynamic_bytes as f64),
        );
        o.insert(
            "sim_static_bytes",
            Json::Num(self.sim_static_bytes as f64),
        );
        o.insert(
            "xla_temp_bytes",
            match self.xla_temp_bytes {
                Some(b) => Json::Num(b as f64),
                None => Json::Null,
            },
        );
        o.insert(
            "step_seconds",
            match self.step_seconds {
                Some(s) => Json::Num(s),
                None => Json::Null,
            },
        );
        o.insert("flops", Json::Num(self.flops));
        o.insert("instructions", Json::Num(self.instructions as f64));
        o
    }

    pub fn from_json(j: &Json) -> Option<Measurement> {
        Some(Measurement {
            key: j.get("key")?.as_str()?.to_string(),
            group: j.get("group")?.as_str()?.to_string(),
            task: j.get("task")?.as_str()?.to_string(),
            variant: j.get("variant")?.as_str()?.to_string(),
            size_name: j.get("size_name")?.as_str()?.to_string(),
            seq_len: j.get("seq_len")?.as_u64()? as usize,
            batch: j.get("batch")?.as_u64()? as usize,
            inner_steps: j.get("inner_steps")?.as_u64()? as usize,
            n_layers: j.get("n_layers")?.as_u64()? as usize,
            param_count: j.get("param_count")?.as_u64()?,
            sim_dynamic_bytes: j.get("sim_dynamic_bytes")?.as_u64()?,
            sim_static_bytes: j.get("sim_static_bytes")?.as_u64()?,
            xla_temp_bytes: j
                .get("xla_temp_bytes")
                .and_then(Json::as_u64),
            step_seconds: j.get("step_seconds").and_then(Json::as_f64),
            flops: j.get("flops")?.as_f64()?,
            instructions: j.get("instructions")?.as_u64()? as usize,
        })
    }
}

/// Append-only JSONL store under `results/`.
pub struct ResultsStore {
    pub dir: PathBuf,
}

impl ResultsStore {
    pub fn new(dir: &Path) -> Result<ResultsStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(ResultsStore { dir: dir.to_path_buf() })
    }

    /// Default location: `<repo>/results`.
    pub fn discover() -> Result<ResultsStore> {
        let base = crate::find_artifacts_dir()
            .and_then(|a| a.parent().map(Path::to_path_buf))
            .unwrap_or_else(|| PathBuf::from("."));
        ResultsStore::new(&base.join("results"))
    }

    pub fn append(&self, stream: &str, m: &Measurement) -> Result<()> {
        let path = self.dir.join(format!("{stream}.jsonl"));
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)?;
        writeln!(f, "{}", m.to_json().compact())?;
        Ok(())
    }

    pub fn load(&self, stream: &str) -> Result<Vec<Measurement>> {
        let path = self.dir.join(format!("{stream}.jsonl"));
        if !path.exists() {
            return Ok(vec![]);
        }
        let text = std::fs::read_to_string(&path)?;
        Ok(text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .filter_map(|l| Json::parse(l).ok())
            .filter_map(|j| Measurement::from_json(&j))
            .collect())
    }

    /// Keep only the latest record per key (reruns overwrite logically).
    pub fn load_latest(&self, stream: &str) -> Result<Vec<Measurement>> {
        let all = self.load(stream)?;
        let mut latest: std::collections::HashMap<String, Measurement> =
            std::collections::HashMap::new();
        for m in all {
            latest.insert(m.key.clone(), m);
        }
        let mut out: Vec<Measurement> = latest.into_values().collect();
        out.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(key: &str) -> Measurement {
        Measurement {
            key: key.into(),
            group: "g".into(),
            task: "maml".into(),
            variant: "default".into(),
            size_name: "tiny".into(),
            seq_len: 32,
            batch: 2,
            inner_steps: 2,
            n_layers: 2,
            param_count: 100,
            sim_dynamic_bytes: 1000,
            sim_static_bytes: 500,
            xla_temp_bytes: Some(900),
            step_seconds: Some(0.01),
            flops: 1e6,
            instructions: 42,
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = sample("k1");
        let j = m.to_json();
        assert_eq!(Measurement::from_json(&j).unwrap(), m);
    }

    #[test]
    fn none_fields_roundtrip() {
        let mut m = sample("k2");
        m.xla_temp_bytes = None;
        m.step_seconds = None;
        let back = Measurement::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn store_append_load_latest() {
        let dir = std::env::temp_dir().join(format!(
            "mixflow_results_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let store = ResultsStore::new(&dir).unwrap();
        store.append("s", &sample("a")).unwrap();
        let mut newer = sample("a");
        newer.flops = 2e6;
        store.append("s", &newer).unwrap();
        store.append("s", &sample("b")).unwrap();
        assert_eq!(store.load("s").unwrap().len(), 3);
        let latest = store.load_latest("s").unwrap();
        assert_eq!(latest.len(), 2);
        assert_eq!(
            latest.iter().find(|m| m.key == "a").unwrap().flops,
            2e6
        );
        assert!(store.load("missing").unwrap().is_empty());
        std::fs::remove_dir_all(dir).ok();
    }
}

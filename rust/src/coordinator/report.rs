//! Paper-style report rendering: each function prints the rows/series of
//! one figure or table of the evaluation section (DESIGN.md §4 index).

use crate::coordinator::runner::PairRatios;
use crate::coordinator::Measurement;
use crate::util::stats::{geomean, human_bytes, human_secs, percentile};
use crate::util::table::{ratio_cell, Table};

/// Figure 4: sorted peak-dynamic-HBM and step-time ratio series.
pub fn fig4_sorted_ratios(pairs: &[PairRatios]) -> String {
    let mut out = String::from(
        "Figure 4 — joint sweep: ratios default/mixflow, sorted descending\n",
    );
    let mut t = Table::new(&[
        "rank", "task", "size", "S", "B", "T", "dyn HBM ratio",
        "step-time ratio",
    ])
    .numeric_cols(&[0, 3, 4, 5, 6, 7]);
    for (i, p) in pairs.iter().enumerate() {
        t.row(vec![
            format!("{}", i + 1),
            p.task.clone(),
            p.size_name.clone(),
            p.seq_len.to_string(),
            p.batch.to_string(),
            p.inner_steps.to_string(),
            format!("{:.2}", p.dynamic_ratio),
            p.time_ratio
                .map(|r| format!("{r:.2}"))
                .unwrap_or_else(|| "n/a".into()),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&aggregate_claims(pairs));
    out
}

/// The §5.2 headline aggregate claims over a sweep.
pub fn aggregate_claims(pairs: &[PairRatios]) -> String {
    if pairs.is_empty() {
        return "no pairs\n".into();
    }
    // total_cmp: NaN ratios (e.g. a 0/0 memory ratio from an empty
    // measurement) must sort deterministically, never panic.  NaN orders
    // after +inf under IEEE total order, so percentiles stay sane.
    let mut dyn_ratios: Vec<f64> =
        pairs.iter().map(|p| p.dynamic_ratio).collect();
    dyn_ratios.sort_by(f64::total_cmp);
    let time_ratios: Vec<f64> =
        pairs.iter().filter_map(|p| p.time_ratio).collect();
    let wins = pairs.iter().filter(|p| p.dynamic_ratio > 1.0).count();
    let frac_4x = dyn_ratios.iter().filter(|&&r| r >= 4.0).count() as f64
        / dyn_ratios.len() as f64;
    let mut s = String::new();
    s.push_str(&format!(
        "pairs={}  memory wins={}  geomean dyn ratio={:.2}x  median={:.2}x  p20={:.2}x  max={:.2}x\n",
        pairs.len(),
        wins,
        geomean(&dyn_ratios),
        percentile(&dyn_ratios, 50.0),
        percentile(&dyn_ratios, 20.0),
        dyn_ratios.last().copied().unwrap_or(0.0),
    ));
    s.push_str(&format!(
        "fraction of configs with ≥4x (75%) memory reduction: {:.0}%\n",
        frac_4x * 100.0
    ));
    if !time_ratios.is_empty() {
        let mut tr = time_ratios.clone();
        tr.sort_by(f64::total_cmp);
        s.push_str(&format!(
            "step-time: geomean={:.2}x  median={:.2}x  max={:.2}x (paper: up to 1.33x ≈ 25% reduction)\n",
            geomean(&tr),
            percentile(&tr, 50.0),
            tr.last().copied().unwrap_or(0.0),
        ));
    }
    s
}

/// Tables 2/3: the ablation cube.  `rows` are (label, measurement).
pub fn ablation_table(title: &str, rows: &[(String, &Measurement)]) -> String {
    let mut t = Table::new(&[
        "mixed mode", "block remat", "save grads", "sim dyn HBM",
        "XLA temp", "step time",
    ])
    .numeric_cols(&[3, 4, 5]);
    for (label, m) in rows {
        // label encodes "<mode>_br<0|1>_sg<0|1>".
        let mixed = if label.starts_with("default") { "-" } else { "+" };
        let br = if label.contains("br1") { "+" } else { "-" };
        let sg = if label.contains("sg1") { "+" } else { "-" };
        t.row(vec![
            mixed.into(),
            br.into(),
            sg.into(),
            human_bytes(m.sim_dynamic_bytes),
            m.xla_temp_bytes
                .map(human_bytes)
                .unwrap_or_else(|| "N/A".into()),
            m.step_seconds
                .map(human_secs)
                .unwrap_or_else(|| "N/A".into()),
        ]);
    }
    format!("{title}\n{}", t.render())
}

/// Figure 5/6/7 style: one swept axis → ratio series.
pub fn axis_series(
    title: &str,
    axis_name: &str,
    points: &[(String, &PairRatios)],
) -> String {
    let mut t = Table::new(&[
        axis_name, "layers", "params", "dyn HBM ratio", "time ratio",
        "default dyn", "mixflow dyn",
    ])
    .numeric_cols(&[1, 2, 3, 4, 5, 6]);
    for (axis_value, p) in points {
        t.row(vec![
            axis_value.clone(),
            p.n_layers.to_string(),
            p.param_count.to_string(),
            ratio_cell(p.dynamic_ratio),
            p.time_ratio
                .map(|r| format!("{r:.2}x"))
                .unwrap_or_else(|| "n/a".into()),
            human_bytes(p.default_dynamic),
            human_bytes(p.mixflow_dynamic),
        ]);
    }
    format!("{title}\n{}", t.render())
}

/// Figure 8: static vs dynamic decomposition per ladder rung.
pub fn static_dynamic_table(
    rows: &[(String, &Measurement, &Measurement)],
) -> String {
    let mut t = Table::new(&[
        "model", "variant", "static", "dynamic", "dyn/static",
        "total ratio",
    ])
    .numeric_cols(&[2, 3, 4, 5]);
    for (name, d, x) in rows {
        let total_ratio = (d.sim_dynamic_bytes + d.sim_static_bytes) as f64
            / ((x.sim_dynamic_bytes + x.sim_static_bytes).max(1)) as f64;
        for (variant, m) in [("default", d), ("mixflow", x)] {
            t.row(vec![
                name.clone(),
                variant.into(),
                human_bytes(m.sim_static_bytes),
                human_bytes(m.sim_dynamic_bytes),
                format!(
                    "{:.2}",
                    m.sim_dynamic_bytes as f64
                        / m.sim_static_bytes.max(1) as f64
                ),
                if variant == "mixflow" {
                    format!("{total_ratio:.2}x")
                } else {
                    String::new()
                },
            ]);
        }
    }
    format!("Figure 8 — static vs dynamic memory decomposition\n{}", t.render())
}

/// Figure 2: ASCII memory-over-instruction-number timeline.
pub fn timeline_plot(
    title: &str,
    timeline: &[(usize, u64)],
    width: usize,
    height: usize,
) -> String {
    if timeline.is_empty() {
        return format!("{title}\n(empty timeline)\n");
    }
    let max = timeline.iter().map(|(_, b)| *b).max().unwrap_or(0).max(1);
    // Downsample to `width` columns, keeping per-column maxima.
    let mut cols = vec![0u64; width];
    for (i, (_, b)) in timeline.iter().enumerate() {
        let c = i * width / timeline.len();
        cols[c] = cols[c].max(*b);
    }
    let mut s = format!("{title}  (peak {})\n", human_bytes(max));
    for row in (0..height).rev() {
        let threshold = max as f64 * (row as f64 + 0.5) / height as f64;
        let line: String = cols
            .iter()
            .map(|&b| if b as f64 >= threshold { '█' } else { ' ' })
            .collect();
        s.push_str(&format!("{:>10} │{line}\n", if row == height - 1 {
            human_bytes(max)
        } else if row == 0 {
            "0 B".to_string()
        } else {
            String::new()
        }));
    }
    s.push_str(&format!(
        "{:>10} └{}\n{:>12}instruction number →\n",
        "", "─".repeat(width), ""
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meas(variant: &str, dynb: u64) -> Measurement {
        Measurement {
            key: format!("k_{variant}_{dynb}"),
            group: "g".into(),
            task: "maml".into(),
            variant: variant.into(),
            size_name: "tiny".into(),
            seq_len: 32,
            batch: 2,
            inner_steps: 2,
            n_layers: 2,
            param_count: 100,
            sim_dynamic_bytes: dynb,
            sim_static_bytes: 50,
            xla_temp_bytes: None,
            step_seconds: Some(0.5),
            flops: 0.0,
            instructions: 3,
        }
    }

    fn pair(ratio: f64) -> PairRatios {
        PairRatios {
            workload: "w".into(),
            task: "maml".into(),
            size_name: "tiny".into(),
            seq_len: 32,
            batch: 2,
            inner_steps: 2,
            n_layers: 2,
            param_count: 100,
            dynamic_ratio: ratio,
            xla_ratio: None,
            time_ratio: Some(1.1),
            total_ratio: ratio / 2.0,
            default_dynamic: 1000,
            mixflow_dynamic: (1000.0 / ratio) as u64,
        }
    }

    #[test]
    fn fig4_renders() {
        let pairs = vec![pair(8.0), pair(2.0)];
        let s = fig4_sorted_ratios(&pairs);
        assert!(s.contains("Figure 4"));
        assert!(s.contains("8.00"));
        assert!(s.contains("geomean"));
    }

    #[test]
    fn aggregate_handles_empty() {
        assert_eq!(aggregate_claims(&[]), "no pairs\n");
    }

    #[test]
    fn aggregate_tolerates_nan_ratios() {
        // Regression: a NaN dynamic or time ratio (0/0 from an empty
        // measurement) used to panic the partial_cmp sort.  It must
        // render — NaN degrades the aggregates, never the process.
        let mut bad = pair(f64::NAN);
        bad.time_ratio = Some(f64::NAN);
        let pairs = vec![pair(4.0), bad, pair(2.0)];
        let s = aggregate_claims(&pairs);
        assert!(s.contains("pairs=3"), "{s}");
        assert!(s.contains("step-time"), "{s}");
    }

    #[test]
    fn timeline_plot_empty_degrades() {
        // Regression: an empty timeline must produce the empty-report
        // path, not unwrap an empty max().
        let s = timeline_plot("Fig 2", &[], 40, 8);
        assert!(s.contains("(empty timeline)"), "{s}");
        assert!(!s.contains('█'));
    }

    #[test]
    fn ablation_table_flags() {
        let m = meas("default", 100);
        let rows = vec![
            ("default_br1_sg0".to_string(), &m),
            ("fwdrev_br1_sg1".to_string(), &m),
        ];
        let s = ablation_table("Table 3", &rows);
        assert!(s.contains("Table 3"));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    fn timeline_plot_shape() {
        let tl: Vec<(usize, u64)> =
            (0..100).map(|i| (i, (i as u64 % 37) * 100)).collect();
        let s = timeline_plot("Fig 2", &tl, 40, 8);
        assert!(s.contains('█'));
        assert!(s.contains("instruction number"));
    }

    #[test]
    fn static_dynamic_renders() {
        let d = meas("default", 400);
        let x = meas("mixflow", 100);
        let rows = vec![("44M".to_string(), &d, &x)];
        let s = static_dynamic_table(&rows);
        assert!(s.contains("44M"));
        assert!(s.contains("mixflow"));
    }
}

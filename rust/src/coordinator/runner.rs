//! Per-artifact experiment runner: HLO analysis + (exec tier) timed runs.

use anyhow::Result;

use super::results::Measurement;
use crate::hlo::{flops::CostModel, parser, MemorySimulator};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::runtime::{ArtifactMeta, Manifest};

/// Analysis-only measurement (no PJRT, usable from worker threads).
pub fn analyze_artifact(
    manifest: &Manifest,
    meta: &ArtifactMeta,
    group: &str,
) -> Result<Measurement> {
    let path = manifest.hlo_path(meta);
    let text = std::fs::read_to_string(&path)?;
    let module = parser::parse_module(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", meta.key))?;
    let mem = MemorySimulator::without_timeline(&module).run();
    let cost = CostModel::new(&module).run();
    Ok(Measurement {
        key: meta.key.clone(),
        group: group.to_string(),
        task: meta.task.clone(),
        variant: meta.variant.clone(),
        size_name: meta.size_name.clone(),
        seq_len: meta.seq_len,
        batch: meta.batch,
        inner_steps: meta.inner_steps,
        n_layers: meta.n_layers,
        param_count: meta.param_count,
        sim_dynamic_bytes: mem.peak_dynamic,
        sim_static_bytes: mem.static_bytes(),
        xla_temp_bytes: meta.xla_stats.map(|s| s.temp_bytes),
        step_seconds: None,
        flops: if meta.flops > 0.0 { meta.flops } else { cost.flops },
        instructions: mem.instructions,
    })
}

/// Knobs for a run.
#[cfg(feature = "pjrt")]
#[derive(Debug, Clone, Copy)]
pub struct RunOptions {
    /// Timed iterations per exec-tier artifact.
    pub timing_iters: usize,
    /// Execute exec-tier artifacts (set false for analysis-only passes).
    pub execute: bool,
    /// Input seed (shared across a default/mixflow pair by construction).
    pub seed: u64,
}

#[cfg(feature = "pjrt")]
impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { timing_iters: 5, execute: true, seed: 0 }
    }
}

/// Runs artifacts and produces [`Measurement`]s.
#[cfg(feature = "pjrt")]
pub struct ExperimentRunner<'r> {
    pub runtime: &'r Runtime,
    pub options: RunOptions,
}

#[cfg(feature = "pjrt")]
impl<'r> ExperimentRunner<'r> {
    pub fn new(runtime: &'r Runtime, options: RunOptions) -> Self {
        ExperimentRunner { runtime, options }
    }

    /// Analyse (and maybe execute) one artifact.
    pub fn run_one(&self, meta: &ArtifactMeta, group: &str) -> Result<Measurement> {
        let mut m = analyze_artifact(&self.runtime.manifest, meta, group)?;
        if self.options.execute && meta.tier == "exec" {
            let loaded = self.runtime.load(&meta.key)?;
            let inputs = loaded.default_inputs(self.options.seed)?;
            let summary =
                loaded.time_steps(&inputs, self.options.timing_iters)?;
            m.step_seconds = Some(summary.median);
        }
        Ok(m)
    }

    /// Run a whole manifest group; skips artifacts that fail (logged) so a
    /// single bad lowering cannot sink a sweep.
    pub fn run_group(&self, group: &str) -> Vec<Measurement> {
        let metas = self.runtime.manifest.group(group);
        let mut out = Vec::with_capacity(metas.len());
        for meta in metas {
            match self.run_one(meta, group) {
                Ok(m) => out.push(m),
                Err(e) => eprintln!("[runner] {}: SKIP ({e})", meta.key),
            }
        }
        out
    }
}

/// Default-vs-mixflow ratios for one workload pair (the paper's Eqs. 10–11).
#[derive(Debug, Clone)]
pub struct PairRatios {
    pub workload: String,
    pub task: String,
    pub size_name: String,
    pub seq_len: usize,
    pub batch: usize,
    pub inner_steps: usize,
    pub n_layers: usize,
    pub param_count: u64,
    /// Simulated peak-dynamic-HBM ratio (default / mixflow), Eq. (10).
    pub dynamic_ratio: f64,
    /// XLA temp-bytes ratio when both sides have stats.
    pub xla_ratio: Option<f64>,
    /// Step-time ratio (default / mixflow), Eq. (11).
    pub time_ratio: Option<f64>,
    /// Total (static+dynamic) ratio — the Fig. 8(c) quantity.
    pub total_ratio: f64,
    pub default_dynamic: u64,
    pub mixflow_dynamic: u64,
}

/// Pair measurements by workload signature and compute ratios.
pub fn pair_ratios(measurements: &[Measurement]) -> Vec<PairRatios> {
    use std::collections::HashMap;
    let sig = |m: &Measurement| {
        format!(
            "{}|{}|{}|{}|{}",
            m.task, m.size_name, m.seq_len, m.batch, m.inner_steps
        )
    };
    let mut defaults: HashMap<String, &Measurement> = HashMap::new();
    let mut mixed: HashMap<String, &Measurement> = HashMap::new();
    for m in measurements {
        match m.variant.as_str() {
            "default" => {
                defaults.insert(sig(m), m);
            }
            "mixflow" => {
                mixed.insert(sig(m), m);
            }
            _ => {}
        }
    }
    let mut out = Vec::new();
    for (k, d) in &defaults {
        let Some(x) = mixed.get(k) else { continue };
        let dynamic_ratio =
            d.sim_dynamic_bytes as f64 / (x.sim_dynamic_bytes.max(1)) as f64;
        let total_ratio = (d.sim_dynamic_bytes + d.sim_static_bytes) as f64
            / ((x.sim_dynamic_bytes + x.sim_static_bytes).max(1)) as f64;
        let xla_ratio = match (d.xla_temp_bytes, x.xla_temp_bytes) {
            (Some(a), Some(b)) if b > 0 => Some(a as f64 / b as f64),
            _ => None,
        };
        let time_ratio = match (d.step_seconds, x.step_seconds) {
            (Some(a), Some(b)) if b > 0.0 => Some(a / b),
            _ => None,
        };
        out.push(PairRatios {
            workload: k.clone(),
            task: d.task.clone(),
            size_name: d.size_name.clone(),
            seq_len: d.seq_len,
            batch: d.batch,
            inner_steps: d.inner_steps,
            n_layers: d.n_layers,
            param_count: d.param_count,
            dynamic_ratio,
            xla_ratio,
            time_ratio,
            total_ratio,
            default_dynamic: d.sim_dynamic_bytes,
            mixflow_dynamic: x.sim_dynamic_bytes,
        });
    }
    out.sort_by(|a, b| {
        b.dynamic_ratio
            .partial_cmp(&a.dynamic_ratio)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meas(variant: &str, dynb: u64, secs: Option<f64>) -> Measurement {
        Measurement {
            key: format!("k_{variant}"),
            group: "g".into(),
            task: "maml".into(),
            variant: variant.into(),
            size_name: "tiny".into(),
            seq_len: 32,
            batch: 2,
            inner_steps: 2,
            n_layers: 2,
            param_count: 100,
            sim_dynamic_bytes: dynb,
            sim_static_bytes: 100,
            xla_temp_bytes: None,
            step_seconds: secs,
            flops: 0.0,
            instructions: 1,
        }
    }

    #[test]
    fn ratios_paired_and_sorted() {
        let ms = vec![
            meas("default", 1000, Some(2.0)),
            meas("mixflow", 100, Some(1.0)),
        ];
        let pairs = pair_ratios(&ms);
        assert_eq!(pairs.len(), 1);
        let p = &pairs[0];
        assert!((p.dynamic_ratio - 10.0).abs() < 1e-9);
        assert_eq!(p.time_ratio, Some(2.0));
        assert!((p.total_ratio - (1100.0 / 200.0)).abs() < 1e-9);
    }

    #[test]
    fn unpaired_measurements_dropped() {
        let ms = vec![meas("default", 1000, None)];
        assert!(pair_ratios(&ms).is_empty());
    }
}

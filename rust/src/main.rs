//! `mixflow` CLI — the Layer-3 coordinator entry point.
//!
//! Subcommands:
//! * `info`                 — manifest summary (artifacts, groups, sizes)
//! * `analyze <key>`        — HLO memory/cost analysis of one artifact
//! * `native --task <t>`    — native meta-training via one persistent
//!   `HypergradEngine` (no PJRT, no artifacts); `--mode`, `--task`,
//!   `--inner-opt` and `--heads` accept comma-separated lists and fan
//!   the full grid (task × inner-optimiser × mode × heads × seed) over
//!   the scheduler pool, printing per-config mean ± std and writing
//!   `SWEEP_native.json`; `--heads`/`--batch` shape the multi-head
//!   batched attention task (e.g. `mixflow native --task attention
//!   --heads 4 --batch 8 --inner-opt adam --mode naive,mixflow --remat
//!   auto`); `--mode fd` cross-checks with central differences,
//!   `--mode truncated:<K>` backprops through only the last K inner
//!   steps (K = T ≡ mixflow bit-for-bit), `--mode evograd` uses the
//!   population estimate with no second-order terms, and
//!   `--remat auto` resolves the remat segment K ≈ √T at run time.
//!   `--trace <path>` turns on the engine's telemetry and writes
//!   per-outer-step phase timings + counter deltas (`--trace-format
//!   jsonl|chrome`; chrome loads in Perfetto), plus a CLI phase
//!   breakdown table.
//!   Every valid-value error list is derived from the enums'
//!   `CliEnum::variants()`, so new modes can't silently go missing from
//!   the messages.
//! * `serve --jobs <f|->`   — fault-tolerant hypergradient serving: read
//!   JSONL job specs (file or stdin), drive them through the supervised
//!   warm-engine pool (`--workers`, bounded `--queue` with
//!   `--backpressure reject|block`, per-attempt `--deadline-ms`,
//!   `--max-retries` with jittered exponential `--backoff-ms`), and
//!   emit exactly one JSONL result record per job (stdout or `--out`)
//!   plus a counter summary on stderr.  `--chaos-rate`/`--chaos-seed`
//!   switch on the deterministic fault-injection harness (injected
//!   panics, NaNs, slowdowns, allocation spikes); `--no-guard` disables
//!   the tape's non-finite guard (bit-identical fast path).
//! * `run <key>`            — execute one exec-tier artifact (pjrt)
//! * `sweep --group <g>`    — run a figure group, print ratios (pjrt)
//! * `train --task <t>`     — artifact E2E meta-training loop (pjrt)
//! * `report --group <g>`   — re-render reports from stored results
//! * `verify`               — numerics cross-check default vs mixflow (pjrt)
//!
//! Commands marked (pjrt) need the `pjrt` cargo feature; without it they
//! exit with an explanatory error instead of failing to build.

use anyhow::{anyhow, Result};
use mixflow::autodiff::{CheckpointPolicy, InnerOptimiser};
use mixflow::coordinator::report as rpt;
use mixflow::coordinator::runner::pair_ratios;
use mixflow::coordinator::ResultsStore;
use mixflow::hlo::{flops::CostModel, parser, MemorySimulator};
use mixflow::meta::{
    print_train_summary, run_sweep, sweep_report_json, HypergradMode,
    NativeMetaTrainer, NativeTask, SweepRun, SweepSpec,
};
use mixflow::obs::{print_trace_summary, write_trace, TraceFormat};
use mixflow::runtime::Manifest;
use mixflow::util::args::{ArgSpec, Args, CliEnum};
use mixflow::util::stats::{human_bytes, Summary};
use mixflow::util::table::Table;

/// Parse one CLI enum value, deriving the valid-value list from the
/// type itself so error messages can never drift behind the enums.
fn parse_cli<T: CliEnum>(flag: &str, raw: &str) -> Result<T> {
    T::parse(raw).ok_or_else(|| {
        anyhow!(
            "--{flag} {raw:?} invalid; valid values: {}",
            T::valid_values()
        )
    })
}

/// Comma-separated list of CLI enum values, deduplicated in order.
fn parse_cli_list<T: CliEnum + PartialEq>(
    flag: &str,
    raw: &str,
) -> Result<Vec<T>> {
    let mut out = Vec::new();
    for part in raw.split(',') {
        let v: T = parse_cli(flag, part)?;
        if !out.contains(&v) {
            out.push(v);
        }
    }
    Ok(out)
}

/// Comma-separated list of positive integers, deduplicated in order
/// (`--heads 1,2,4`).
fn parse_usize_list(flag: &str, raw: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in raw.split(',') {
        let v: usize = part.trim().parse().map_err(|_| {
            anyhow!(
                "--{flag} {part:?} invalid; valid values: comma-separated \
                 integers >= 1"
            )
        })?;
        if v == 0 {
            return Err(anyhow!(
                "--{flag} 0 invalid; valid values: comma-separated \
                 integers >= 1"
            ));
        }
        if !out.contains(&v) {
            out.push(v);
        }
    }
    Ok(out)
}

fn main() {
    let spec = ArgSpec::new(
        "mixflow",
        "MixFlow-MG coordinator: run + analyse AOT meta-gradient artifacts",
    )
    .positional(
        "command",
        "info|analyze|native|serve|run|sweep|train|report|verify",
    )
    .flag("key", None, "artifact key (analyze/run)")
    .flag("group", None, "manifest group (sweep/report)")
    .flag(
        "task",
        Some("maml"),
        &format!(
            "task(s) for train/native, comma-separated (maml|{})",
            NativeTask::valid_values()
        ),
    )
    .flag("steps", Some("100"), "outer steps for train/native")
    .flag("unroll", Some("8"), "inner unroll length for native")
    .flag(
        "mode",
        Some("mixflow"),
        &format!(
            "hypergradient path(s) for native, comma-separated ({})",
            HypergradMode::valid_values()
        ),
    )
    .flag(
        "inner-opt",
        Some("sgd"),
        &format!(
            "inner-loop optimiser(s) for native, comma-separated ({})",
            InnerOptimiser::valid_values()
        ),
    )
    .flag(
        "remat",
        Some("1"),
        &format!(
            "checkpoint segment K for native mixflow: {}",
            CheckpointPolicy::valid_values()
        ),
    )
    .flag(
        "heads",
        Some("1"),
        "attention head count(s) for native, comma-separated (a sweep \
         axis; d_model rounds up to a multiple of the head count)",
    )
    .flag(
        "batch",
        Some("1"),
        "sequences per attention batch for native (ignored by other tasks)",
    )
    .flag("seeds", Some("1"), "native seed-sweep width; combined with multi-value --task/--mode/--inner-opt/--heads it fans the whole grid over the scheduler pool")
    .flag("fd-eps", Some("1e-5"), "central-difference epsilon for --mode fd")
    .flag(
        "threads",
        None,
        "kernel threads per native engine (default MIXFLOW_THREADS or 1; \
         results are bit-identical at any value)",
    )
    .flag(
        "trace",
        None,
        "write per-outer-step engine telemetry to this path (native); \
         enables phase spans + the metrics registry for every cell",
    )
    .flag(
        "trace-format",
        Some("jsonl"),
        &format!(
            "trace encoding for --trace: {}",
            TraceFormat::valid_values()
        ),
    )
    .flag("jobs", None, "JSONL job-spec file for serve ('-' = stdin)")
    .flag("workers", Some("2"), "serve worker threads")
    .flag("queue", Some("64"), "serve request-queue capacity")
    .flag(
        "backpressure",
        Some("block"),
        "serve policy when the queue is full: reject (shed) | block",
    )
    .flag("deadline-ms", None, "serve per-attempt deadline in ms")
    .flag(
        "max-retries",
        Some("2"),
        "serve retries beyond the first attempt",
    )
    .flag(
        "backoff-ms",
        Some("5"),
        "serve backoff base in ms (doubles per retry, jittered)",
    )
    .flag(
        "chaos-rate",
        None,
        "serve fault-injection rate per axis, 0..1 (off when unset)",
    )
    .flag("chaos-seed", Some("0"), "serve fault-injection stream seed")
    .flag(
        "out",
        None,
        "serve: write result JSONL to this path instead of stdout",
    )
    .flag("iters", Some("5"), "timing iterations")
    .flag("seed", Some("0"), "input seed")
    .switch(
        "no-guard",
        "serve: disable the tape non-finite guard (bit-identical fast path)",
    )
    .switch("no-exec", "analysis only (skip PJRT execution)")
    .switch("timeline", "print the Fig.2-style memory timeline (analyze)");

    let args = match spec.parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &mixflow::util::args::Args) -> Result<()> {
    match args.positional(0).unwrap_or("") {
        "info" => cmd_info(),
        "analyze" => cmd_analyze(
            args.get("key").ok_or_else(|| anyhow!("--key required"))?,
            args.get_bool("timeline"),
        ),
        "native" => cmd_native(args),
        "serve" => cmd_serve(args),
        "run" => cmd_run(
            args.get("key").ok_or_else(|| anyhow!("--key required"))?,
            args.get_usize("iters").map_err(|e| anyhow!(e))?,
            args.get_usize("seed").map_err(|e| anyhow!(e))? as u64,
        ),
        "sweep" => cmd_sweep(
            args.get("group")
                .ok_or_else(|| anyhow!("--group required"))?,
            !args.get_bool("no-exec"),
            args.get_usize("iters").map_err(|e| anyhow!(e))?,
        ),
        "train" => cmd_train(
            args.get("task").unwrap(),
            args.get_usize("steps").map_err(|e| anyhow!(e))?,
            args.get_usize("seed").map_err(|e| anyhow!(e))? as u64,
        ),
        "report" => cmd_report(
            args.get("group")
                .ok_or_else(|| anyhow!("--group required"))?,
        ),
        "verify" => cmd_verify(args.get_usize("seed").unwrap_or(0) as u64),
        "exec-file" => cmd_exec_file(
            args.get("key").ok_or_else(|| anyhow!("--key <path> required"))?,
        ),
        other => Err(anyhow!(
            "unknown command {other:?} (try --help)"
        )),
    }
}

fn cmd_info() -> Result<()> {
    let manifest = Manifest::discover()?;
    println!(
        "artifacts dir: {} (jax {})",
        manifest.dir.display(),
        manifest.jax_version
    );
    let mut t = Table::new(&["group", "artifacts", "exec", "pairs"])
        .numeric_cols(&[1, 2, 3]);
    let mut groups: Vec<_> = manifest.groups.keys().collect();
    groups.sort();
    for g in groups {
        let metas = manifest.group(g);
        let exec = metas.iter().filter(|m| m.tier == "exec").count();
        let pairs = manifest.pairs(&metas).len();
        t.row(vec![
            g.clone(),
            metas.len().to_string(),
            exec.to_string(),
            pairs.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("total artifacts: {}", manifest.artifacts.len());
    Ok(())
}

fn cmd_analyze(key: &str, timeline: bool) -> Result<()> {
    let manifest = Manifest::discover()?;
    let meta = manifest.get(key)?;
    let text = std::fs::read_to_string(manifest.hlo_path(meta))?;
    let module = parser::parse_module(&text).map_err(|e| anyhow!("{e}"))?;
    let mem = MemorySimulator::new(&module).run();
    let cost = CostModel::new(&module).run();
    println!("artifact: {key}");
    println!("  kind={} task={} variant={} tier={}", meta.kind, meta.task, meta.variant, meta.tier);
    println!("  instructions (flattened): {}", mem.instructions);
    println!("  params:    {}", human_bytes(mem.param_bytes));
    println!("  constants: {}", human_bytes(mem.const_bytes));
    println!("  outputs:   {}", human_bytes(mem.output_bytes));
    println!("  static:    {}", human_bytes(mem.static_bytes()));
    println!("  peak dynamic: {}", human_bytes(mem.peak_dynamic));
    println!("  peak total:   {}", human_bytes(mem.peak_total));
    println!("  est. flops: {:.3e}  bytes accessed: {:.3e}", cost.flops, cost.bytes);
    if let Some(stats) = meta.xla_stats {
        println!(
            "  XLA compiled stats: temp={} args={} out={}",
            human_bytes(stats.temp_bytes),
            human_bytes(stats.argument_bytes),
            human_bytes(stats.output_bytes)
        );
    }
    if timeline {
        println!(
            "{}",
            rpt::timeline_plot(
                &format!("Figure 2 — memory timeline for {key}"),
                &mem.timeline,
                100,
                16
            )
        );
    }
    Ok(())
}

/// Native meta-training: one persistent `HypergradEngine` end-to-end,
/// Python and PJRT nowhere on the path.  Multi-value `--task`, `--mode`,
/// `--inner-opt`, `--heads` (comma-separated) and/or `--seeds n > 1` fan
/// the full grid over the scheduler's worker pool, one trainer — and
/// therefore one engine + arena — per grid cell; grid runs print the
/// per-config mean ± std table and write `SWEEP_native.json`.
fn cmd_native(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps").map_err(|e| anyhow!(e))?;
    let unroll = args.get_usize("unroll").map_err(|e| anyhow!(e))?;
    let seed = args.get_usize("seed").map_err(|e| anyhow!(e))? as u64;
    // The flag's global default is the artifact task "maml";
    // NativeTask::parse aliases it to the hyper-LR task.
    let tasks: Vec<NativeTask> =
        parse_cli_list("task", args.get("task").unwrap())?;
    let modes: Vec<HypergradMode> =
        parse_cli_list("mode", args.get("mode").unwrap())?;
    let inner_opts: Vec<InnerOptimiser> =
        parse_cli_list("inner-opt", args.get("inner-opt").unwrap())?;
    let remat: CheckpointPolicy =
        parse_cli("remat", args.get("remat").unwrap())?;
    let heads = parse_usize_list("heads", args.get("heads").unwrap())?;
    let batch = args.get_usize("batch").map_err(|e| anyhow!(e))?;
    if batch == 0 {
        return Err(anyhow!(
            "--batch 0 invalid; valid values: an integer >= 1"
        ));
    }
    let fd_eps = args.get_f64("fd-eps").map_err(|e| anyhow!(e))?;
    if fd_eps <= 0.0 {
        return Err(anyhow!("--fd-eps must be positive, got {fd_eps}"));
    }
    let threads = match args.get("threads") {
        Some(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                return Err(anyhow!(
                    "--threads {s:?} invalid; valid values: an integer >= 1"
                ))
            }
        },
        None => mixflow::kernels::pool::default_threads(),
    };
    let seeds = args.get_usize("seeds").map_err(|e| anyhow!(e))?;
    if seeds == 0 {
        return Err(anyhow!(
            "--seeds 0 invalid; valid values: an integer >= 1"
        ));
    }
    let trace_path = args.get("trace");
    let trace_format: TraceFormat =
        parse_cli("trace-format", args.get("trace-format").unwrap())?;

    let names = |xs: &[String]| xs.join(",");
    println!(
        "native meta-training: task={} mode={} inner-opt={} remat={} \
         heads={} batch={batch} unroll={unroll} steps={steps}",
        names(&tasks.iter().map(|t| t.name().to_string()).collect::<Vec<_>>()),
        names(&modes.iter().map(|m| m.name().to_string()).collect::<Vec<_>>()),
        names(
            &inner_opts
                .iter()
                .map(|o| o.name().to_string())
                .collect::<Vec<_>>()
        ),
        remat.name(),
        names(&heads.iter().map(|h| h.to_string()).collect::<Vec<_>>()),
    );

    let cells =
        tasks.len() * modes.len() * inner_opts.len() * heads.len() * seeds;
    if cells == 1 {
        let mut trainer =
            NativeMetaTrainer::with_unroll(tasks[0], seed, unroll)
                .with_mode(modes[0])
                .with_inner_opt(inner_opts[0])
                .with_remat(remat)
                .with_fd_epsilon(fd_eps)
                .with_attention_shape(heads[0], batch)
                .with_telemetry(trace_path.is_some())
                .with_threads(threads);
        let report = trainer.train(steps);
        print_train_summary(&report, trainer.last_memory.as_ref());
        println!(
            "engine: {} hypergradients on one persistent tape",
            trainer.engine().outer_steps()
        );
        if let Some(path) = trace_path {
            let traced = vec![(report.artifact.clone(), trainer.take_traces())];
            print_trace_summary(&traced);
            write_trace(path, trace_format, &traced)
                .map_err(|e| anyhow!("could not write {path}: {e}"))?;
            println!(
                "trace ({}) written to {path}",
                trace_format.name()
            );
        }
        return Ok(());
    }

    println!(
        "grid sweep: {cells} cells ({} task × {} opt × {} mode × {} heads \
         × {seeds} seeds from {seed}), scheduler pool",
        tasks.len(),
        inner_opts.len(),
        modes.len(),
        heads.len()
    );
    let spec = SweepSpec {
        tasks,
        inner_opts,
        modes,
        heads,
        batch,
        remat,
        fd_epsilon: fd_eps,
        unroll,
        steps,
        base_seed: seed,
        n_seeds: seeds,
        telemetry: trace_path.is_some(),
        threads,
    };
    let runs = run_sweep(&spec);
    let mut t = Table::new(&[
        "task",
        "opt",
        "mode",
        "heads",
        "seed",
        "loss head",
        "loss tail",
        "final",
        "steps/s",
    ])
    .numeric_cols(&[3, 4, 5, 6, 7, 8]);
    let mut finals = Vec::with_capacity(runs.len());
    for run in &runs {
        if run.error.is_some() {
            // Failed cells keep their grid row but print distinctly;
            // their (empty) loss curves stay out of the summary stats.
            t.row(vec![
                run.cell.task.name().to_string(),
                run.cell.inner_opt.name().to_string(),
                run.cell.mode.name().to_string(),
                run.cell.heads.to_string(),
                run.cell.seed.to_string(),
                "-".to_string(),
                "-".to_string(),
                "FAILED".to_string(),
                "-".to_string(),
            ]);
            continue;
        }
        let (head, tail) = run.report.improvement(10);
        let last = run.report.losses.last().copied().unwrap_or(f64::NAN);
        finals.push(last);
        t.row(vec![
            run.cell.task.name().to_string(),
            run.cell.inner_opt.name().to_string(),
            run.cell.mode.name().to_string(),
            run.cell.heads.to_string(),
            run.cell.seed.to_string(),
            format!("{head:.4}"),
            format!("{tail:.4}"),
            format!("{last:.4}"),
            format!("{:.2}", run.report.steps_per_second),
        ]);
    }
    println!("{}", t.render());
    let failed: Vec<&SweepRun> =
        runs.iter().filter(|r| r.error.is_some()).collect();
    if !failed.is_empty() {
        println!("{} of {} cells FAILED:", failed.len(), runs.len());
        for run in &failed {
            println!(
                "  {}: {}",
                run.cell.label(),
                run.error.as_deref().unwrap_or("unknown")
            );
        }
    }

    // Per-configuration mean ± std over the seed axis (the same
    // aggregation the JSON dump carries).
    let doc = sweep_report_json(&spec, &runs);
    if let Some(aggs) = doc.get("aggregates").and_then(|a| a.as_arr()) {
        let mut at = Table::new(&["config", "seeds", "final mean", "± std"])
            .numeric_cols(&[1, 2, 3]);
        for agg in aggs {
            at.row(vec![
                agg.get("config")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string(),
                agg.get("n_seeds")
                    .and_then(|v| v.as_u64())
                    .unwrap_or(0)
                    .to_string(),
                format!(
                    "{:.4}",
                    agg.get("final_mean")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(f64::NAN)
                ),
                format!(
                    "{:.4}",
                    agg.get("final_std")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(f64::NAN)
                ),
            ]);
        }
        println!("{}", at.render());
    }
    let s = Summary::of(&finals);
    println!(
        "final val loss over {} runs: mean {:.4} ± {:.4} (min {:.4}, max \
         {:.4})",
        finals.len(),
        s.mean,
        s.stddev,
        s.min,
        s.max
    );
    if let Some(mem) = runs.iter().find_map(|r| r.memory) {
        println!(
            "per-cell hypergrad memory: tape {} + checkpoints {} (peak live \
             {})",
            human_bytes(mem.tape_bytes as u64),
            human_bytes(mem.checkpoint_bytes as u64),
            human_bytes(mem.peak_bytes as u64)
        );
    }
    let path = "SWEEP_native.json";
    std::fs::write(path, doc.pretty() + "\n")
        .map_err(|e| anyhow!("could not write {path}: {e}"))?;
    println!("sweep grid written to {path}");
    if let Some(tp) = trace_path {
        let traced: Vec<(String, Vec<mixflow::obs::StepTrace>)> = runs
            .iter()
            .map(|r| (r.cell.label(), r.traces.clone()))
            .collect();
        print_trace_summary(&traced);
        write_trace(tp, trace_format, &traced)
            .map_err(|e| anyhow!("could not write {tp}: {e}"))?;
        println!("trace ({}) written to {tp}", trace_format.name());
    }
    Ok(())
}

/// `mixflow serve` — JSONL front end over [`mixflow::serve::serve_jobs`].
///
/// Reads one job spec per line (blank lines and `#` comments skipped;
/// unparseable lines are reported on stderr and skipped, so one typo
/// cannot take down a batch), serves everything through the supervised
/// engine pool, writes exactly one result record per job, and prints
/// the supervisor's counter summary to stderr (stderr so that piping
/// stdout stays pure JSONL).
fn cmd_serve(args: &Args) -> Result<()> {
    use mixflow::obs::Counter;
    use mixflow::serve::{
        serve_jobs, BackpressurePolicy, ChaosConfig, JobSpec, ServeConfig,
    };
    use mixflow::util::json::Json;

    let jobs_path = args
        .get("jobs")
        .ok_or_else(|| anyhow!("--jobs <file|-> required for serve"))?;
    let raw = if jobs_path == "-" {
        use std::io::Read;
        let mut s = String::new();
        std::io::stdin()
            .read_to_string(&mut s)
            .map_err(|e| anyhow!("could not read stdin: {e}"))?;
        s
    } else {
        std::fs::read_to_string(jobs_path)
            .map_err(|e| anyhow!("could not read {jobs_path}: {e}"))?
    };
    let mut specs: Vec<JobSpec> = Vec::new();
    let mut skipped = 0usize;
    for (lineno, line) in raw.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fallback = format!("job-{}", specs.len());
        let parsed = Json::parse(line)
            .map_err(|e| e.to_string())
            .and_then(|doc| JobSpec::from_json(&doc, &fallback));
        match parsed {
            Ok(spec) => specs.push(spec),
            Err(e) => {
                skipped += 1;
                eprintln!("serve: skipping line {}: {e}", lineno + 1);
            }
        }
    }
    if specs.is_empty() {
        return Err(anyhow!(
            "no valid job specs in {jobs_path} ({skipped} skipped)"
        ));
    }

    let backpressure_raw = args.get("backpressure").unwrap_or("block");
    let backpressure = BackpressurePolicy::parse(backpressure_raw)
        .ok_or_else(|| {
            anyhow!(
                "--backpressure {backpressure_raw:?} invalid; valid \
                 values: reject|block"
            )
        })?;
    let chaos = match args.get("chaos-rate") {
        None => None,
        Some(raw) => {
            let rate: f64 = raw.parse().map_err(|_| {
                anyhow!("--chaos-rate {raw:?} invalid; expected 0..1")
            })?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(anyhow!(
                    "--chaos-rate {rate} out of range; expected 0..1"
                ));
            }
            Some(ChaosConfig::uniform(
                args.get_usize("chaos-seed").map_err(|e| anyhow!(e))?
                    as u64,
                rate,
            ))
        }
    };
    let deadline_ms = match args.get("deadline-ms") {
        None => None,
        Some(raw) => Some(raw.parse::<u64>().map_err(|_| {
            anyhow!("--deadline-ms {raw:?} invalid; expected ms >= 1")
        })?),
    };
    let cfg = ServeConfig {
        workers: args.get_usize("workers").map_err(|e| anyhow!(e))?,
        queue_capacity: args.get_usize("queue").map_err(|e| anyhow!(e))?,
        backpressure,
        deadline_ms,
        max_retries: args.get_usize("max-retries").map_err(|e| anyhow!(e))?
            as u64,
        backoff_base_ms: args
            .get_usize("backoff-ms")
            .map_err(|e| anyhow!(e))? as u64,
        seed: args.get_usize("seed").map_err(|e| anyhow!(e))? as u64,
        guard: !args.get_bool("no-guard"),
        chaos,
        ..ServeConfig::default()
    };

    let n_jobs = specs.len();
    let t0 = std::time::Instant::now();
    let outcome = serve_jobs(specs, &cfg);
    let elapsed = t0.elapsed().as_secs_f64();

    let mut lines = String::new();
    for record in &outcome.records {
        lines.push_str(&record.to_json().compact());
        lines.push('\n');
    }
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &lines)
                .map_err(|e| anyhow!("could not write {path}: {e}"))?;
            eprintln!("serve: {n_jobs} result records written to {path}");
        }
        None => print!("{lines}"),
    }
    eprintln!(
        "serve: {n_jobs} jobs in {elapsed:.2}s ({:.1} jobs/s) — ok {}, \
         failed {}, shed {}, retried {}, quarantines {}, deadline {}, \
         engines built {}",
        n_jobs as f64 / elapsed.max(1e-9),
        outcome.counter(Counter::ServeJobsOk),
        outcome.counter(Counter::ServeJobsFailed),
        outcome.counter(Counter::ServeJobsShed),
        outcome.counter(Counter::ServeJobsRetried),
        outcome.counter(Counter::ServeEngineQuarantines),
        outcome.counter(Counter::ServeDeadlineExceeded),
        outcome.engines_built,
    );
    Ok(())
}

fn cmd_report(group: &str) -> Result<()> {
    let store = ResultsStore::discover()?;
    let measurements = store.load_latest(group)?;
    if measurements.is_empty() {
        return Err(anyhow!(
            "no stored results for {group}; run `mixflow sweep --group {group}` first"
        ));
    }
    let pairs = pair_ratios(&measurements);
    println!("{}", rpt::fig4_sorted_ratios(&pairs));
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> Result<()> {
    Err(anyhow!(
        "`{cmd}` needs PJRT execution; rebuild with `--features pjrt` \
         (and a real xla toolchain, see rust/vendor/xla-stub/README.md)"
    ))
}

#[cfg(not(feature = "pjrt"))]
fn cmd_run(_key: &str, _iters: usize, _seed: u64) -> Result<()> {
    pjrt_unavailable("run")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_sweep(_group: &str, _execute: bool, _iters: usize) -> Result<()> {
    pjrt_unavailable("sweep")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_task: &str, _steps: usize, _seed: u64) -> Result<()> {
    pjrt_unavailable("train")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_verify(_seed: u64) -> Result<()> {
    pjrt_unavailable("verify")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_exec_file(_path: &str) -> Result<()> {
    pjrt_unavailable("exec-file")
}

#[cfg(feature = "pjrt")]
mod pjrt_cmds {
    use super::*;
    use anyhow::{anyhow, Result};
    use mixflow::coordinator::runner::{ExperimentRunner, RunOptions};
    use mixflow::meta::MetaTrainer;
    use mixflow::runtime::Runtime;
    use mixflow::util::stats::human_secs;

    pub fn cmd_run(key: &str, iters: usize, seed: u64) -> Result<()> {
        let runtime = Runtime::new()?;
        let loaded = runtime.load(key)?;
        println!(
            "compiled {key} in {} on {}",
            human_secs(loaded.compile_seconds),
            runtime.platform()
        );
        let inputs = loaded.default_inputs(seed)?;
        // Sanity: surface NaN/Inf in the outputs (a silent-corruption guard).
        let outputs = loaded.execute(&inputs)?;
        let mut nan = 0usize;
        let mut total = 0usize;
        for lit in &outputs {
            if let Ok(v) = lit.to_vec::<f32>() {
                nan += v.iter().filter(|x| !x.is_finite()).count();
                total += v.len();
            }
        }
        println!(
            "outputs: {} literals, {} / {total} non-finite f32 values{}",
            outputs.len(),
            nan,
            if nan > 0 { "  <-- NUMERICS PROBLEM" } else { "" }
        );
        let summary = loaded.time_steps(&inputs, iters)?;
        println!(
            "step time: median={} mean={} p95={} (n={})",
            human_secs(summary.median),
            human_secs(summary.mean),
            human_secs(summary.p95),
            summary.n
        );
        Ok(())
    }

    pub fn cmd_sweep(group: &str, execute: bool, iters: usize) -> Result<()> {
        let runtime = Runtime::new()?;
        let runner = ExperimentRunner::new(
            &runtime,
            RunOptions { timing_iters: iters, execute, seed: 0 },
        );
        let measurements = runner.run_group(group);
        let store = ResultsStore::discover()?;
        for m in &measurements {
            store.append(group, m)?;
        }
        let pairs = pair_ratios(&measurements);
        println!("{}", rpt::fig4_sorted_ratios(&pairs));
        Ok(())
    }

    pub fn cmd_train(task: &str, steps: usize, seed: u64) -> Result<()> {
        let runtime = Runtime::new()?;
        // Find the e2e train artifact for this task.
        let key = runtime
            .manifest
            .group("e2e")
            .iter()
            .find(|m| m.task == task)
            .map(|m| m.key.clone())
            .ok_or_else(|| anyhow!("no e2e train_step artifact for {task}"))?;
        println!("training {key} for {steps} outer steps...");
        let mut trainer = MetaTrainer::new(&runtime, &key, seed);
        let report = trainer.train(steps)?;
        print_train_summary(&report, None);
        Ok(())
    }

    /// Debug tool: compile an arbitrary HLO text file, synthesise inputs from
    /// its entry parameter shapes (f32 → 0.05·N(0,1), s32 → tokens <128), run
    /// once and report output finiteness.
    pub fn cmd_exec_file(path: &str) -> Result<()> {
        use mixflow::hlo::parser;
        use mixflow::util::prng::Prng;
        let text = std::fs::read_to_string(path)?;
        let module = parser::parse_module(&text).map_err(|e| anyhow!("{e}"))?;
        let entry = module.entry();
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let mut rng = Prng::new(0);
        let mut inputs = Vec::new();
        for p in entry.parameters() {
            let dims: Vec<i64> =
                p.shape.dims().iter().map(|&d| d as i64).collect();
            let n: usize = p.shape.elements() as usize;
            let lit = match p.shape.dtype() {
                Some(mixflow::hlo::shape::DType::F32) => {
                    xla::Literal::vec1(&rng.normal_vec(n, 0.05)).reshape(&dims)?
                }
                Some(mixflow::hlo::shape::DType::S32) => {
                    xla::Literal::vec1(&rng.token_vec(n, 128)).reshape(&dims)?
                }
                other => return Err(anyhow!("unhandled dtype {other:?}")),
            };
            inputs.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        for (i, o) in outs.iter().enumerate() {
            if let Ok(v) = o.to_vec::<f32>() {
                let bad = v.iter().filter(|x| !x.is_finite()).count();
                println!(
                    "out[{i}] n={} nonfinite={bad} head={:?}",
                    v.len(),
                    &v[..v.len().min(4)]
                );
            } else {
                println!("out[{i}] (non-f32)");
            }
        }
        Ok(())
    }

    pub fn cmd_verify(seed: u64) -> Result<()> {
        let runtime = Runtime::new()?;
        let metas = runtime.manifest.group("fig4_sweep");
        let pairs = runtime.manifest.pairs(&metas);
        let take = pairs.len().min(3);
        println!("verifying {take} default/mixflow pairs produce identical meta-gradients...");
        for (d, x) in pairs.into_iter().take(take) {
            let ld = runtime.load(&d.key)?;
            let lx = runtime.load(&x.key)?;
            let inputs = ld.default_inputs(seed)?;
            let od = ld.execute(&inputs)?;
            let ox = lx.execute(&inputs)?;
            let mut max_diff = 0f32;
            for (a, b) in od.iter().zip(ox.iter()) {
                let va = a.to_vec::<f32>()?;
                let vb = b.to_vec::<f32>()?;
                for (x, y) in va.iter().zip(vb.iter()) {
                    max_diff = max_diff.max((x - y).abs());
                }
            }
            let ok = max_diff < 1e-3;
            println!(
                "  {} vs {}: max |Δ| = {max_diff:.2e} {}",
                d.key,
                x.key,
                if ok { "OK" } else { "MISMATCH" }
            );
            if !ok {
                return Err(anyhow!("meta-gradient mismatch"));
            }
        }
        println!("verify OK");
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
use pjrt_cmds::{cmd_exec_file, cmd_run, cmd_sweep, cmd_train, cmd_verify};

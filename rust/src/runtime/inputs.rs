//! Synthesise PJRT input literals from manifest tensor specs.
//!
//! Float leaves get small-scale normals (parameters/optimiser state — the
//! values do not change the memory/step-time structure, DESIGN.md §2);
//! int32 leaves are token batches drawn uniformly from `[0, vocab)`.
//! Deterministic per (artifact key, seed) so default/mixflow pairs see
//! identical inputs — required by the numerics cross-check test.

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

use super::artifacts::{ArtifactMeta, TensorSpec};
use crate::util::prng::Prng;

/// Map numpy dtype names to the xla crate's element types.
pub fn element_type(dtype: &str) -> Result<ElementType> {
    Ok(match dtype {
        "float32" => ElementType::F32,
        "float64" => ElementType::F64,
        "float16" => ElementType::F16,
        "bfloat16" => ElementType::Bf16,
        "int32" => ElementType::S32,
        "int64" => ElementType::S64,
        "uint32" => ElementType::U32,
        "uint8" => ElementType::U8,
        "bool" => ElementType::Pred,
        other => return Err(anyhow!("unsupported dtype {other}")),
    })
}

/// Build one literal for `spec`.
pub fn literal_for_spec(
    spec: &TensorSpec,
    rng: &mut Prng,
    vocab: u32,
    float_std: f32,
) -> Result<Literal> {
    let n = spec.elements();
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    match spec.dtype.as_str() {
        "float32" => {
            // |N(0,σ)|: some float leaves are Adam second-moment state,
            // which must be non-negative (√v) — and parameters don't care.
            let mut data = rng.normal_vec(n, float_std);
            for x in &mut data {
                *x = x.abs();
            }
            reshape(Literal::vec1(&data), &dims)
        }
        "int32" => {
            let vocab = vocab.max(2);
            let data = rng.token_vec(n, vocab);
            reshape(Literal::vec1(&data), &dims)
        }
        other => Err(anyhow!("unsupported input dtype {other}")),
    }
}

fn reshape(lit: Literal, dims: &[i64]) -> Result<Literal> {
    if dims.is_empty() {
        // vec1 of length 1 → scalar via reshape to [].
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(dims)?)
    }
}

/// All inputs for an artifact, deterministic in `seed`.
pub fn inputs_for(meta: &ArtifactMeta, seed: u64) -> Result<Vec<Literal>> {
    // Seed from the *workload* (not the variant!) so a default/mixflow
    // pair receives identical data.
    let workload = format!(
        "{}_{}_{}_{}_{}",
        meta.task, meta.size_name, meta.seq_len, meta.batch,
        meta.inner_steps
    );
    let mut h = 0xcbf29ce484222325u64;
    for b in workload.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    let mut rng = Prng::new(h ^ seed);
    meta.inputs
        .iter()
        .map(|spec| {
            literal_for_spec(spec, &mut rng, meta.vocab_size as u32, 0.05)
        })
        .collect()
}

/// Fresh token batches for a train-step artifact's data inputs
/// (`xs [T,B,S+1]` and `val [B,S+1]`, the trailing int32 leaves).
pub fn token_batch(
    spec: &TensorSpec,
    rng: &mut Prng,
    vocab: u32,
) -> Result<Literal> {
    literal_for_spec(spec, rng, vocab, 0.0)
}

/// A *learnable* synthetic batch: windows of the deterministic corpus
/// `tok[t] = (a·t + b·(t/7) + phase) mod vocab` — structured enough that
/// the E2E meta-training loss curve must fall (DESIGN.md E2E deliverable).
pub fn corpus_batch(
    spec: &TensorSpec,
    rng: &mut Prng,
    vocab: u32,
) -> Result<Literal> {
    let vocab = vocab.max(2);
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let seq = *spec.shape.last().unwrap_or(&1);
    let rows = spec.elements() / seq.max(1);
    let mut data = Vec::with_capacity(spec.elements());
    for _ in 0..rows {
        let start = rng.next_below(vocab * 4) as u64;
        let stride = 1 + rng.next_below(3) as u64;
        for t in 0..seq as u64 {
            data.push(((start + stride * t) % vocab as u64) as i32);
        }
    }
    Ok(Literal::vec1(&data).reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: &str) -> TensorSpec {
        TensorSpec { shape: shape.to_vec(), dtype: dtype.into() }
    }

    #[test]
    fn float_literal_shape_and_determinism() {
        let s = spec(&[2, 3], "float32");
        let mut r1 = Prng::new(1);
        let mut r2 = Prng::new(1);
        let a = literal_for_spec(&s, &mut r1, 0, 1.0).unwrap();
        let b = literal_for_spec(&s, &mut r2, 0, 1.0).unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        assert_eq!(a.element_count(), 6);
    }

    #[test]
    fn int_literal_in_vocab() {
        let s = spec(&[4, 8], "int32");
        let mut r = Prng::new(2);
        let l = literal_for_spec(&s, &mut r, 16, 0.0).unwrap();
        for t in l.to_vec::<i32>().unwrap() {
            assert!((0..16).contains(&t));
        }
    }

    #[test]
    fn scalar_spec() {
        let s = spec(&[], "float32");
        let mut r = Prng::new(3);
        let l = literal_for_spec(&s, &mut r, 0, 1.0).unwrap();
        assert_eq!(l.element_count(), 1);
    }

    #[test]
    fn corpus_rows_are_arithmetic() {
        let s = spec(&[2, 10], "int32");
        let mut r = Prng::new(4);
        let l = corpus_batch(&s, &mut r, 32).unwrap();
        let v = l.to_vec::<i32>().unwrap();
        for row in v.chunks(10) {
            let d = (row[1] - row[0]).rem_euclid(32);
            for w in row.windows(2) {
                assert_eq!((w[1] - w[0]).rem_euclid(32), d);
            }
        }
    }

    #[test]
    fn unsupported_dtype_errors() {
        let s = spec(&[2], "complex64");
        let mut r = Prng::new(5);
        assert!(literal_for_spec(&s, &mut r, 0, 1.0).is_err());
    }
}

//! `artifacts/manifest.json` loader — the contract with `python/compile/aot.py`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape+dtype of one flattened input/output leaf.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // numpy name: "float32", "int32", ...
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        let per = match self.dtype.as_str() {
            "float64" | "int64" | "uint64" => 8,
            "float32" | "int32" | "uint32" => 4,
            "float16" | "bfloat16" | "int16" => 2,
            "int8" | "uint8" | "bool" => 1,
            _ => 4,
        };
        self.elements() * per
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|d| d.as_u64().unwrap_or(0) as usize)
            .collect();
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// XLA `CompiledMemoryStats` recorded at AOT time (stats groups only).
#[derive(Debug, Clone, Copy, Default)]
pub struct XlaStats {
    pub temp_bytes: u64,
    pub argument_bytes: u64,
    pub output_bytes: u64,
    pub alias_bytes: u64,
}

/// One artifact's metadata (mirrors `compile.aot.Artifact`).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub key: String,
    pub kind: String,
    pub task: String,
    pub variant: String,
    pub mode: String,
    pub block_remat: bool,
    pub save_inner_grads: bool,
    pub tier: String,
    pub file: String,
    pub inner_steps: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab_size: usize,
    pub param_count: u64,
    pub size_name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub xla_stats: Option<XlaStats>,
    pub flops: f64,
    pub extra: HashMap<String, Json>,
}

impl ArtifactMeta {
    pub fn is_mixflow(&self) -> bool {
        self.mode != "default"
    }

    /// `extra` field as u64 (train_step leaf counts etc).
    pub fn extra_u64(&self, key: &str) -> Option<u64> {
        self.extra.get(key).and_then(Json::as_u64)
    }

    pub fn extra_str(&self, key: &str) -> Option<&str> {
        self.extra.get(key).and_then(Json::as_str)
    }

    fn from_json(key: &str, j: &Json) -> Result<ArtifactMeta> {
        let s = |k: &str| -> String {
            j.get(k).and_then(Json::as_str).unwrap_or("").to_string()
        };
        let b = |k: &str| j.get(k).and_then(Json::as_bool).unwrap_or(false);
        let u = |k: &str| j.get(k).and_then(Json::as_u64).unwrap_or(0) as usize;
        let inputs = j
            .get("inputs")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .get("outputs")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let xla_stats = j.get("xla_stats").and_then(|x| {
            if x.is_null() {
                None
            } else {
                Some(XlaStats {
                    temp_bytes: x.get("temp_bytes")?.as_u64()?,
                    argument_bytes: x.get("argument_bytes")?.as_u64()?,
                    output_bytes: x.get("output_bytes")?.as_u64()?,
                    alias_bytes: x
                        .get("alias_bytes")
                        .and_then(Json::as_u64)
                        .unwrap_or(0),
                })
            }
        });
        let model = j.get("model");
        let model_u = |k: &str| -> usize {
            model
                .and_then(|m| m.get(k))
                .and_then(Json::as_u64)
                .unwrap_or(0) as usize
        };
        let extra = match j.get("extra") {
            Some(Json::Obj(map, order)) => order
                .iter()
                .map(|k| (k.clone(), map[k].clone()))
                .collect(),
            _ => HashMap::new(),
        };
        Ok(ArtifactMeta {
            key: key.to_string(),
            kind: s("kind"),
            task: s("task"),
            variant: s("variant"),
            mode: s("mode"),
            block_remat: b("block_remat"),
            save_inner_grads: b("save_inner_grads"),
            tier: s("tier"),
            file: s("file"),
            inner_steps: u("inner_steps"),
            batch: u("batch"),
            seq_len: u("seq_len"),
            vocab_size: u("vocab_size"),
            param_count: model
                .and_then(|m| m.get("param_count"))
                .and_then(Json::as_u64)
                .unwrap_or(0),
            size_name: model
                .and_then(|m| m.get("size_name"))
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            n_layers: model_u("n_layers"),
            d_model: model_u("d_model"),
            inputs,
            outputs,
            xla_stats,
            flops: j
                .path(&["cost", "flops"])
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            extra,
        })
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactMeta>,
    /// Figure/table group → artifact keys.
    pub groups: HashMap<String, Vec<String>>,
    pub jax_version: String,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let mut artifacts = HashMap::new();
        if let Some(arts) = j.get("artifacts") {
            for key in arts.keys() {
                artifacts.insert(
                    key.clone(),
                    ArtifactMeta::from_json(key, arts.get(key).unwrap())?,
                );
            }
        }
        let mut groups = HashMap::new();
        if let Some(gs) = j.get("groups") {
            for g in gs.keys() {
                let keys = gs
                    .get(g)
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|k| k.as_str().map(str::to_string))
                    .collect();
                groups.insert(g.clone(), keys);
            }
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            groups,
            jax_version: j
                .get("jax_version")
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string(),
        })
    }

    /// Load from the auto-discovered artifacts directory.
    pub fn discover() -> Result<Manifest> {
        let dir = crate::find_artifacts_dir().ok_or_else(|| {
            anyhow!(
                "no artifacts/manifest.json found — run `make artifacts` \
                 (or set MIXFLOW_ARTIFACTS)"
            )
        })?;
        Manifest::load(&dir)
    }

    pub fn get(&self, key: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(key)
            .ok_or_else(|| anyhow!("artifact {key} not in manifest"))
    }

    /// Artifact keys in a group, sorted for determinism.
    pub fn group(&self, name: &str) -> Vec<&ArtifactMeta> {
        let mut keys = self.groups.get(name).cloned().unwrap_or_default();
        keys.sort();
        keys.dedup();
        keys.iter().filter_map(|k| self.artifacts.get(k)).collect()
    }

    /// Absolute path to an artifact's HLO file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Pair up default/mixflow variants within a group by their workload
    /// signature (everything but the variant fields).
    pub fn pairs<'a>(
        &self,
        metas: &[&'a ArtifactMeta],
    ) -> Vec<(&'a ArtifactMeta, &'a ArtifactMeta)> {
        let sig = |m: &ArtifactMeta| {
            (
                m.task.clone(),
                m.size_name.clone(),
                m.seq_len,
                m.batch,
                m.inner_steps,
                m.extra_str("use_pallas").map(|_| 0),
            )
        };
        let mut defaults: HashMap<_, &ArtifactMeta> = HashMap::new();
        let mut mixed: HashMap<_, &ArtifactMeta> = HashMap::new();
        for m in metas {
            if m.variant == "default" {
                defaults.insert(sig(m), *m);
            } else if m.variant == "mixflow" {
                mixed.insert(sig(m), *m);
            }
        }
        let mut out: Vec<_> = defaults
            .into_iter()
            .filter_map(|(k, d)| mixed.get(&k).map(|m| (d, *m)))
            .collect();
        out.sort_by(|a, b| a.0.key.cmp(&b.0.key));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
 "jax_version": "0.8.2",
 "artifacts": {
  "a_default": {
   "kind": "meta_grad", "task": "maml", "variant": "default",
   "mode": "default", "block_remat": true, "save_inner_grads": false,
   "tier": "exec", "file": "a.hlo.txt",
   "model": {"size_name": "tiny", "param_count": 100, "n_layers": 2, "d_model": 32},
   "inner_steps": 2, "batch": 2, "seq_len": 32, "vocab_size": 128,
   "inputs": [{"shape": [4, 33], "dtype": "int32"}],
   "outputs": [{"shape": [128, 32], "dtype": "float32"}],
   "xla_stats": {"temp_bytes": 1000, "argument_bytes": 10, "output_bytes": 5},
   "cost": {"flops": 123.0},
   "extra": {"use_pallas": false}
  },
  "a_mixflow": {
   "kind": "meta_grad", "task": "maml", "variant": "mixflow",
   "mode": "fwdrev", "block_remat": true, "save_inner_grads": true,
   "tier": "exec", "file": "b.hlo.txt",
   "model": {"size_name": "tiny", "param_count": 100, "n_layers": 2, "d_model": 32},
   "inner_steps": 2, "batch": 2, "seq_len": 32, "vocab_size": 128,
   "inputs": [], "outputs": [], "xla_stats": null, "cost": null,
   "extra": {}
  }
 },
 "groups": {"g1": ["a_default", "a_mixflow"]}
}"#
    }

    fn load_sample() -> Manifest {
        let dir = std::env::temp_dir().join(format!(
            "mixflow_manifest_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest())
            .unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn loads_and_indexes() {
        let m = load_sample();
        assert_eq!(m.jax_version, "0.8.2");
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("a_default").unwrap();
        assert_eq!(a.task, "maml");
        assert!(!a.is_mixflow());
        assert_eq!(a.inputs[0].shape, vec![4, 33]);
        assert_eq!(a.inputs[0].bytes(), 4 * 33 * 4);
        assert_eq!(a.xla_stats.unwrap().temp_bytes, 1000);
        assert_eq!(a.flops, 123.0);
        assert_eq!(a.n_layers, 2);
    }

    #[test]
    fn groups_and_pairs() {
        let m = load_sample();
        let metas = m.group("g1");
        assert_eq!(metas.len(), 2);
        let pairs = m.pairs(&metas);
        assert_eq!(pairs.len(), 1);
        assert_eq!(pairs[0].0.variant, "default");
        assert_eq!(pairs[0].1.variant, "mixflow");
        assert!(pairs[0].1.is_mixflow());
    }

    #[test]
    fn missing_key_errors() {
        let m = load_sample();
        assert!(m.get("nope").is_err());
        assert!(m.group("nope").is_empty());
    }
}

//! PJRT runtime (DESIGN.md S14): artifact manifest, compile cache, input
//! synthesis, timed execution.

pub mod artifacts;
pub mod client;
pub mod inputs;

pub use artifacts::{ArtifactMeta, Manifest, TensorSpec};
pub use client::{LoadedArtifact, Runtime};

//! PJRT runtime (DESIGN.md S14): artifact manifest, compile cache, input
//! synthesis, timed execution.
//!
//! The manifest loader ([`artifacts`]) is pure host-side JSON and always
//! available; the PJRT client wrapper and literal synthesis need the `xla`
//! crate and are gated behind the `pjrt` feature.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod inputs;

pub use artifacts::{ArtifactMeta, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use client::{LoadedArtifact, Runtime};

//! PJRT client wrapper: HLO-text loading, compile caching, timed execution.
//!
//! Start-to-finish path (adapted from /opt/xla-example/load_hlo):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  Compiles are cached per artifact key
//! (XLA CPU compiles cost seconds-to-minutes; the hot path must never
//! recompile — §Perf L3).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::{ArtifactMeta, Manifest};
use super::inputs;
use crate::util::stats::Summary;

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    exe: PjRtLoadedExecutable,
    /// Wall-clock seconds spent in `client.compile`.
    pub compile_seconds: f64,
}

impl LoadedArtifact {
    /// Execute once; returns the flattened output literals.
    ///
    /// The AOT pipeline lowers with `return_tuple=True`, so PJRT hands back
    /// a single tuple literal which we decompose to match
    /// `meta.outputs` order.
    pub fn execute(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self.exe.execute::<Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute and time; returns (outputs, seconds).
    pub fn execute_timed(
        &self,
        inputs: &[Literal],
    ) -> Result<(Vec<Literal>, f64)> {
        let t0 = Instant::now();
        let result = self.exe.execute::<Literal>(inputs)?;
        // Block until the result is on host — PJRT executions are async.
        let lit = result[0][0].to_literal_sync()?;
        let secs = t0.elapsed().as_secs_f64();
        Ok((lit.to_tuple()?, secs))
    }

    /// Median-of-N step time with one warmup run (paper Eq. 11's
    /// denominator / numerator).
    pub fn time_steps(&self, inputs: &[Literal], iters: usize) -> Result<Summary> {
        let _ = self.execute(inputs)?; // warmup
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let (_, s) = self.execute_timed(inputs)?;
            samples.push(s);
        }
        Ok(Summary::of(&samples))
    }

    /// Synthesised default inputs for this artifact (seeded).
    pub fn default_inputs(&self, seed: u64) -> Result<Vec<Literal>> {
        inputs::inputs_for(&self.meta, seed)
    }
}

/// The runtime: one PJRT CPU client + a compile cache.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<LoadedArtifact>>>,
}

impl Runtime {
    /// Create against the discovered artifacts directory.
    pub fn new() -> Result<Runtime> {
        Runtime::with_manifest(Manifest::discover()?)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Runtime> {
        let client =
            PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by key (cached).
    pub fn load(&self, key: &str) -> Result<Rc<LoadedArtifact>> {
        if let Some(hit) = self.cache.borrow().get(key) {
            return Ok(hit.clone());
        }
        let meta = self.manifest.get(key)?.clone();
        let path = self.manifest.hlo_path(&meta);
        let loaded = Rc::new(self.compile_file(&path, meta)?);
        self.cache
            .borrow_mut()
            .insert(key.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Compile an HLO text file outside the manifest (tools/tests).
    pub fn compile_file(
        &self,
        path: &Path,
        meta: ArtifactMeta,
    ) -> Result<LoadedArtifact> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedArtifact {
            meta,
            exe,
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Load the initial state npz of a train-step artifact.
    pub fn load_init_state(&self, meta: &ArtifactMeta) -> Result<Vec<Literal>> {
        use xla::FromRawBytes;
        let file = meta
            .extra_str("init_file")
            .ok_or_else(|| anyhow!("{} has no init_file", meta.key))?;
        let path = self.manifest.dir.join(file);
        let mut named = Literal::read_npz(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            &(),
        )?;
        // Keys are "in_0000"... — sort restores leaf order.
        named.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(named.into_iter().map(|(_, l)| l).collect())
    }

    /// Number of artifacts compiled so far (cache introspection).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

//! Deterministic fault injection for the serving supervisor.
//!
//! Chaos is a *pure function* of `(chaos seed, job index, attempt)`:
//! the per-attempt [`FaultPlan`] is drawn from a
//! [`Prng`](crate::util::prng::Prng) stream folded over both indices,
//! so a failing fault mix replays bit-for-bit from its seed — the
//! integration suite pins supervisor behaviour (no job loss, retry
//! counts, quarantine bookkeeping) against exact injected histories
//! instead of flaky timing.
//!
//! Four independent fault axes, drawn in a fixed order so adding a rate
//! never perturbs the other axes' draws:
//!
//! 1. `panic` — the attempt panics with [`PANIC_MESSAGE`] before it
//!    touches the engine (models a driver bug).
//! 2. `nan` — the job's η is corrupted with a NaN before the run
//!    (models numerically divergent upstream state; trips the tape's
//!    non-finite guard mid-phase, so the engine quarantines).
//! 3. `slow` — the attempt sleeps `slow_ms` before running (models a
//!    stalled host; drives deadline coverage).
//! 4. `alloc` — the attempt holds a `alloc_bytes` ballast allocation
//!    across the run (models memory pressure; a failure under this
//!    fault escalates the remat policy).

use crate::util::prng::Prng;

/// Panic payload text of an injected chaos panic (distinctive so test
/// assertions and humans reading JSONL can tell chaos from real bugs).
pub const PANIC_MESSAGE: &str = "chaos: injected panic";

/// Fault-injection configuration: per-axis Bernoulli rates plus the
/// magnitudes of the slow/alloc faults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    /// Root seed of the chaos stream (independent of job seeds).
    pub seed: u64,
    /// P(injected panic) per attempt.
    pub panic_rate: f64,
    /// P(NaN-corrupted η) per attempt.
    pub nan_rate: f64,
    /// P(pre-run sleep) per attempt.
    pub slow_rate: f64,
    /// P(held ballast allocation) per attempt.
    pub alloc_rate: f64,
    /// Sleep length of a `slow` fault.
    pub slow_ms: u64,
    /// Ballast size of an `alloc` fault.
    pub alloc_bytes: usize,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0,
            panic_rate: 0.0,
            nan_rate: 0.0,
            slow_rate: 0.0,
            alloc_rate: 0.0,
            slow_ms: 20,
            alloc_bytes: 8 << 20,
        }
    }
}

impl ChaosConfig {
    /// A config injecting every axis at `rate` (test/bench convenience).
    pub fn uniform(seed: u64, rate: f64) -> ChaosConfig {
        ChaosConfig {
            seed,
            panic_rate: rate,
            nan_rate: rate,
            slow_rate: rate,
            alloc_rate: rate,
            ..ChaosConfig::default()
        }
    }

    /// The faults injected into attempt `attempt` (1-based) of job
    /// `job_index`.  Deterministic: same `(seed, job, attempt)` → same
    /// plan, independent of thread scheduling or wall clock.
    pub fn plan(&self, job_index: u64, attempt: u64) -> FaultPlan {
        let mut p =
            Prng::new(self.seed).fold_in(job_index).fold_in(attempt);
        // Fixed draw order — panic, nan, slow, alloc — so one axis's
        // rate never shifts another axis's randomness.
        FaultPlan {
            panic: p.next_f64() < self.panic_rate,
            nan: p.next_f64() < self.nan_rate,
            slow: p.next_f64() < self.slow_rate,
            alloc: p.next_f64() < self.alloc_rate,
        }
    }
}

/// The faults chosen for one attempt of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub panic: bool,
    pub nan: bool,
    pub slow: bool,
    pub alloc: bool,
}

impl FaultPlan {
    /// No faults (what attempts run under when chaos is off).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn any(&self) -> bool {
        self.panic || self.nan || self.slow || self.alloc
    }

    /// `"panic+nan"` / `"clean"` — the degradation-chain label segment.
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.panic {
            parts.push("panic");
        }
        if self.nan {
            parts.push("nan");
        }
        if self.slow {
            parts.push("slow");
        }
        if self.alloc {
            parts.push("alloc");
        }
        if parts.is_empty() {
            "clean".to_string()
        } else {
            parts.join("+")
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_in_seed_job_attempt() {
        let c = ChaosConfig::uniform(42, 0.5);
        for job in 0..20u64 {
            for attempt in 1..=4u64 {
                assert_eq!(
                    c.plan(job, attempt),
                    c.plan(job, attempt),
                    "replaying the same coordinates must replay the plan"
                );
            }
        }
    }

    #[test]
    fn rate_extremes_are_exact() {
        let off = ChaosConfig::uniform(7, 0.0);
        let on = ChaosConfig::uniform(7, 1.0);
        for job in 0..10u64 {
            assert!(!off.plan(job, 1).any(), "rate 0 injects nothing");
            let all = on.plan(job, 1);
            assert!(
                all.panic && all.nan && all.slow && all.alloc,
                "rate 1 injects everything"
            );
        }
    }

    #[test]
    fn attempts_draw_independent_faults() {
        // At rate 0.5 over 64 (job, attempt) coordinates, seeing the
        // same plan everywhere would mean the fold_in stream is stuck.
        let c = ChaosConfig::uniform(3, 0.5);
        let mut distinct = std::collections::BTreeSet::new();
        for job in 0..16u64 {
            for attempt in 1..=4u64 {
                distinct.insert(c.plan(job, attempt).label());
            }
        }
        assert!(
            distinct.len() > 2,
            "fault mix should vary across coordinates, got {distinct:?}"
        );
    }

    #[test]
    fn labels_read_as_fault_lists() {
        assert_eq!(FaultPlan::none().label(), "clean");
        let p = FaultPlan { panic: true, nan: false, slow: true, alloc: false };
        assert_eq!(p.label(), "panic+slow");
    }
}

//! Job specs and result records — the serving layer's JSONL wire types.
//!
//! A [`JobSpec`] names one hypergradient request (task, mode, shape,
//! seed); a [`JobRecord`] is its single terminal result: exactly one
//! record per submitted job, whatever mix of retries, degradations and
//! quarantines happened on the way.  Both sides round-trip through the
//! repo's own [`Json`] so `mixflow serve` needs no external formats.

use crate::autodiff::{
    CheckpointPolicy, HypergradMode, InnerOptimiser,
};
use crate::meta::native::NativeTask;
use crate::util::json::Json;

use super::error::HypergradError;

/// One hypergradient request.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Caller-chosen id, echoed into the result record.
    pub id: String,
    pub task: NativeTask,
    pub mode: HypergradMode,
    pub inner_opt: InnerOptimiser,
    pub remat: CheckpointPolicy,
    /// Attention head count (non-attention tasks carry it inertly).
    pub heads: usize,
    /// Sequences per attention batch.
    pub batch: usize,
    pub unroll: usize,
    /// Problem seed — data and initialisation.
    pub seed: u64,
}

impl Default for JobSpec {
    fn default() -> JobSpec {
        JobSpec {
            id: String::new(),
            task: NativeTask::HyperLr,
            mode: HypergradMode::Mixflow,
            inner_opt: InnerOptimiser::Sgd,
            remat: CheckpointPolicy::Full,
            heads: 1,
            batch: 1,
            unroll: 4,
            seed: 0,
        }
    }
}

impl JobSpec {
    /// The engine-pool coalescing key for this spec under (possibly
    /// degraded) `mode`/`remat`.  Two jobs with equal keys can reuse
    /// one warm engine: same task topology and shape means the tape's
    /// compiled step plans replay instead of recompiling.
    pub fn engine_key(
        &self,
        mode: HypergradMode,
        remat: CheckpointPolicy,
    ) -> String {
        format!(
            "{}/{}/{}/h{}/b{}/u{}/{}",
            self.task.name(),
            self.inner_opt.name(),
            mode.name(),
            self.heads,
            self.batch,
            self.unroll,
            remat.name()
        )
    }

    /// Parse one JSONL request object.  Every field except `id` has a
    /// default (the [`JobSpec::default`] values); `fallback_id` fills a
    /// missing `id` so line N of a job file is addressable as `job-N`.
    /// Unknown enum values are errors, not silent defaults — a typoed
    /// `"mode":"mixfow"` must not quietly serve the wrong path.
    pub fn from_json(doc: &Json, fallback_id: &str) -> Result<JobSpec, String> {
        let mut spec = JobSpec {
            id: doc
                .get("id")
                .and_then(Json::as_str)
                .unwrap_or(fallback_id)
                .to_string(),
            ..JobSpec::default()
        };
        if let Some(v) = doc.get("task") {
            let s = v.as_str().ok_or("task must be a string")?;
            spec.task = NativeTask::parse(s)
                .ok_or_else(|| format!("unknown task {s:?}"))?;
        }
        if let Some(v) = doc.get("mode") {
            let s = v.as_str().ok_or("mode must be a string")?;
            spec.mode = HypergradMode::parse(s)
                .ok_or_else(|| format!("unknown mode {s:?}"))?;
        }
        if let Some(v) = doc.get("inner_opt") {
            let s = v.as_str().ok_or("inner_opt must be a string")?;
            spec.inner_opt = InnerOptimiser::parse(s)
                .ok_or_else(|| format!("unknown inner_opt {s:?}"))?;
        }
        if let Some(v) = doc.get("remat") {
            let s = v.as_str().ok_or("remat must be a string")?;
            spec.remat = CheckpointPolicy::parse(s)
                .ok_or_else(|| format!("unknown remat policy {s:?}"))?;
        }
        for (key, slot) in [
            ("heads", &mut spec.heads as &mut usize),
            ("batch", &mut spec.batch),
            ("unroll", &mut spec.unroll),
        ] {
            if let Some(v) = doc.get(key) {
                let n = v
                    .as_u64()
                    .ok_or_else(|| format!("{key} must be a number"))?;
                if n == 0 {
                    return Err(format!("{key} must be >= 1"));
                }
                *slot = n as usize;
            }
        }
        if let Some(v) = doc.get("seed") {
            spec.seed =
                v.as_u64().ok_or("seed must be a number".to_string())?;
        }
        Ok(spec)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("id", Json::Str(self.id.clone()));
        o.insert("task", Json::Str(self.task.name().to_string()));
        o.insert("mode", Json::Str(self.mode.name().to_string()));
        o.insert(
            "inner_opt",
            Json::Str(self.inner_opt.name().to_string()),
        );
        o.insert("remat", Json::Str(self.remat.name()));
        o.insert("heads", Json::Num(self.heads as f64));
        o.insert("batch", Json::Num(self.batch as f64));
        o.insert("unroll", Json::Num(self.unroll as f64));
        o.insert("seed", Json::Num(self.seed as f64));
        o
    }
}

/// A job's terminal state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    /// A hypergradient was produced (possibly after retries/degradation).
    Ok,
    /// Every admissible attempt failed; `error` holds the last failure.
    Failed,
    /// Rejected at admission by queue backpressure — never ran.
    Shed,
}

impl JobStatus {
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Ok => "ok",
            JobStatus::Failed => "failed",
            JobStatus::Shed => "shed",
        }
    }
}

/// The single terminal result record for one job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: String,
    pub status: JobStatus,
    /// Engine attempts actually run (0 for shed jobs).
    pub attempts: u64,
    /// Mode the caller asked for.
    pub mode_requested: HypergradMode,
    /// Mode of the final attempt (differs after a non-finite → fd
    /// degradation).
    pub mode_used: HypergradMode,
    /// Remat policy of the final attempt (escalates under alloc faults).
    pub remat_used: CheckpointPolicy,
    /// Human-readable degradation chain, oldest first, e.g.
    /// `["nonfinite:mixflow->fd"]`.
    pub degradation: Vec<String>,
    /// Engine generation serving each attempt, in order.
    pub generations: Vec<u64>,
    /// Generations quarantined while serving this job.
    pub quarantined: Vec<u64>,
    /// Total backoff slept between this job's attempts.
    pub backoff_ms: u64,
    /// Last error (present for `failed` and `shed`).
    pub error: Option<HypergradError>,
    pub outer_loss: Option<f64>,
    /// ‖dF/dη‖₂ of the served hypergradient.
    pub hypergrad_norm: Option<f64>,
    /// Wall time from dequeue to terminal state (backoff included).
    pub seconds: f64,
    /// Per-phase wall time of the successful attempt (telemetry on).
    pub phases: Vec<(String, f64)>,
}

impl JobRecord {
    /// One JSONL result line.  Optional numeric fields are omitted when
    /// absent rather than set to NaN — the JSON layer would serialise
    /// NaN as `null`, but an absent key is cheaper for consumers to
    /// test and cannot be confused with "ran and produced non-finite".
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("id", Json::Str(self.id.clone()));
        o.insert("status", Json::Str(self.status.name().to_string()));
        o.insert("attempts", Json::Num(self.attempts as f64));
        o.insert(
            "mode_requested",
            Json::Str(self.mode_requested.name().to_string()),
        );
        o.insert("mode_used", Json::Str(self.mode_used.name().to_string()));
        o.insert("remat_used", Json::Str(self.remat_used.name()));
        o.insert(
            "degradation",
            Json::Arr(
                self.degradation
                    .iter()
                    .map(|d| Json::Str(d.clone()))
                    .collect(),
            ),
        );
        o.insert(
            "generations",
            Json::Arr(
                self.generations
                    .iter()
                    .map(|g| Json::Num(*g as f64))
                    .collect(),
            ),
        );
        o.insert(
            "quarantined",
            Json::Arr(
                self.quarantined
                    .iter()
                    .map(|g| Json::Num(*g as f64))
                    .collect(),
            ),
        );
        o.insert("backoff_ms", Json::Num(self.backoff_ms as f64));
        if let Some(err) = &self.error {
            o.insert("error", err.to_json());
        }
        if let Some(loss) = self.outer_loss {
            o.insert("outer_loss", Json::Num(loss));
        }
        if let Some(norm) = self.hypergrad_norm {
            o.insert("hypergrad_norm", Json::Num(norm));
        }
        o.insert("seconds", Json::Num(self.seconds));
        if !self.phases.is_empty() {
            let mut ph = Json::obj();
            for (name, secs) in &self.phases {
                ph.insert(name, Json::Num(*secs));
            }
            o.insert("phases", ph);
        }
        o
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips_through_json() {
        let spec = JobSpec {
            id: "j7".to_string(),
            task: NativeTask::Attention,
            mode: HypergradMode::Naive,
            inner_opt: InnerOptimiser::adam(),
            remat: CheckpointPolicy::Remat { segment: 2 },
            heads: 2,
            batch: 3,
            unroll: 6,
            seed: 99,
        };
        let round =
            JobSpec::from_json(&spec.to_json(), "fallback").unwrap();
        assert_eq!(round, spec);
    }

    #[test]
    fn new_modes_round_trip_and_key_their_own_engine_pools() {
        for (mode, key_piece) in [
            (HypergradMode::Truncated { horizon: 3 }, "truncated:3"),
            (HypergradMode::Evograd, "evograd"),
        ] {
            let spec = JobSpec {
                id: "m".to_string(),
                mode,
                ..JobSpec::default()
            };
            let round =
                JobSpec::from_json(&spec.to_json(), "fallback").unwrap();
            assert_eq!(round, spec);
            let key = spec.engine_key(spec.mode, spec.remat);
            assert_eq!(key, format!("hyperlr/sgd/{key_piece}/h1/b1/u4/full"));
        }
        // Different horizons must not share a warm engine: their
        // backward plans cover different step counts.
        let a = JobSpec::default().engine_key(
            HypergradMode::Truncated { horizon: 2 },
            CheckpointPolicy::Full,
        );
        let b = JobSpec::default().engine_key(
            HypergradMode::Truncated { horizon: 4 },
            CheckpointPolicy::Full,
        );
        assert_ne!(a, b);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let doc = Json::parse(r#"{"task":"hyperlr"}"#).unwrap();
        let spec = JobSpec::from_json(&doc, "job-3").unwrap();
        assert_eq!(spec.id, "job-3", "fallback id fills a missing id");
        assert_eq!(spec.mode, HypergradMode::Mixflow);
        assert_eq!(spec.unroll, 4);
    }

    #[test]
    fn unknown_enums_and_bad_shapes_are_rejected() {
        let bad_mode = Json::parse(r#"{"mode":"mixfow"}"#).unwrap();
        assert!(JobSpec::from_json(&bad_mode, "x")
            .unwrap_err()
            .contains("unknown mode"));
        let zero_unroll = Json::parse(r#"{"unroll":0}"#).unwrap();
        assert!(JobSpec::from_json(&zero_unroll, "x")
            .unwrap_err()
            .contains(">= 1"));
    }

    #[test]
    fn engine_key_tracks_degraded_mode_and_remat() {
        let spec = JobSpec { id: "a".to_string(), ..JobSpec::default() };
        let warm = spec.engine_key(spec.mode, spec.remat);
        let degraded =
            spec.engine_key(HypergradMode::Fd, CheckpointPolicy::Auto);
        assert_eq!(warm, "hyperlr/sgd/mixflow/h1/b1/u4/full");
        assert_ne!(warm, degraded, "degraded attempts use a different pool");
    }

    #[test]
    fn record_json_has_one_terminal_status() {
        let rec = JobRecord {
            id: "j0".to_string(),
            status: JobStatus::Failed,
            attempts: 3,
            mode_requested: HypergradMode::Mixflow,
            mode_used: HypergradMode::Fd,
            remat_used: CheckpointPolicy::Full,
            degradation: vec!["nonfinite:mixflow->fd".to_string()],
            generations: vec![1, 4, 5],
            quarantined: vec![1],
            backoff_ms: 15,
            error: Some(HypergradError::Panic {
                message: "boom".to_string(),
            }),
            outer_loss: None,
            hypergrad_norm: None,
            seconds: 0.5,
            phases: Vec::new(),
        };
        let j = Json::parse(&rec.to_json().compact()).unwrap();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("failed"));
        assert_eq!(j.get("attempts").and_then(Json::as_u64), Some(3));
        assert_eq!(
            j.path(&["error", "kind"]).and_then(Json::as_str),
            Some("panic")
        );
        assert!(j.get("outer_loss").is_none(), "failed jobs omit the loss");
        assert_eq!(j.get("generations").unwrap().as_arr().unwrap().len(), 3);
    }
}

//! Fault-tolerant hypergradient serving.
//!
//! A long-running job-queue service over a supervised pool of warm
//! [`HypergradEngine`](crate::autodiff::HypergradEngine)s: callers
//! submit [`JobSpec`]s (task, mode, shape, seed), the supervisor drives
//! each to exactly one terminal [`JobRecord`] through bounded retries,
//! per-attempt deadlines, graceful degradation and engine quarantine.
//! The `mixflow serve` CLI command is a thin JSONL front end over
//! [`serve_jobs`].
//!
//! * [`error`] — the typed [`HypergradError`] taxonomy and the single
//!   place the tape's unwind payloads are classified.
//! * [`queue`] — bounded request queue with reject/block backpressure.
//! * [`chaos`] — deterministic fault injection (Prng-seeded panics,
//!   NaNs, slowdowns, allocation spikes), a pure function of
//!   `(seed, job, attempt)` so failures replay bit-for-bit.
//! * [`job`] — JSONL wire types: job specs and result records.
//! * [`supervisor`] — the worker pool, warm-engine coalescing,
//!   retry/backoff/degradation policy, quarantine-and-rebuild, and the
//!   `serve.*` registry counters.
//!
//! Design rule: the autodiff layer never depends on `serve`.  The tape
//! raises typed signals
//! ([`NonFiniteSignal`](crate::autodiff::tape::NonFiniteSignal),
//! [`CancelSignal`](crate::autodiff::tape::CancelSignal)); only this
//! module interprets them.  See `docs/serve.md` for the full lifecycle
//! and the JSONL schemas.

// A serving layer must not abort the process it serves from: every
// panic path has to be a typed error or a supervised unwind.  Deny the
// footguns outright (tests opt back in locally).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod chaos;
pub mod error;
pub mod job;
pub mod queue;
pub mod supervisor;

pub use chaos::{ChaosConfig, FaultPlan};
pub use error::{classify_unwind, HypergradError};
pub use job::{JobRecord, JobSpec, JobStatus};
pub use queue::{BackpressurePolicy, BoundedQueue};
pub use supervisor::{serve_jobs, ServeConfig, ServeOutcome};

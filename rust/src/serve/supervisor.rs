//! The serving supervisor: a worker pool over warm, pooled
//! [`HypergradEngine`]s with retries, deadlines, degradation and
//! quarantine.
//!
//! ## Lifecycle of one job
//!
//! 1. **Admission** — the producer pushes the job into the
//!    [`BoundedQueue`]; a full queue under the reject policy sheds it
//!    with a [`HypergradError::QueueFull`] record (status `shed`).
//! 2. **Attempts** — a worker checks a warm engine out of the pool
//!    (coalesced by [`JobSpec::engine_key`]: same task/shape/mode jobs
//!    share engines, so compiled step plans and arena buffers stay
//!    warm), arms the per-attempt deadline token, and runs the
//!    hypergradient under `catch_unwind`.  Failures are classified into
//!    the typed [`HypergradError`] taxonomy.
//! 3. **Quarantine** — after a failed attempt the engine's structural
//!    invariants are checked; a violated engine (e.g. an unwind left a
//!    phase open mid-sweep) is quarantined: its generation is retired,
//!    it never serves again, and the next attempt builds a fresh
//!    engine.  A per-key circuit breaker stops rebuilding after
//!    [`ServeConfig::quarantine_limit`] quarantines.
//! 4. **Degradation** — a non-finite failure on a non-fd mode retries
//!    as finite differences (`nonfinite:<mode>->fd`): slower but
//!    numerically decoupled from the taped path.  A failure while an
//!    allocation-spike fault was held escalates the remat policy one
//!    rung (`full → auto → remat{T}`, `remat{k} → remat{min(2k, T)}`)
//!    on the checkpointing modes (mixflow and truncated), trading
//!    recompute for a smaller live set under memory pressure.
//! 5. **Retry pacing** — between attempts the worker sleeps an
//!    exponential backoff (`base·2^(n−1)`) plus a jitter drawn from a
//!    deterministic per-job [`Prng`] stream; `backoff_cap_ms` bounds
//!    the total per-retry delay, jitter included.
//! 6. **Terminal record** — exactly one [`JobRecord`] per submitted
//!    job, whatever happened: `ok`, `failed` or `shed`, carrying the
//!    attempt count, degradation chain, engine generations and error.
//!
//! The registry counters (`serve.jobs.*`, `serve.engine.quarantines`,
//! `serve.deadline.exceeded`) are updated so they always reconcile with
//! the records: `ok + failed + shed == jobs`, `retried == Σ(attempts−1)`.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use crate::autodiff::tape::CancelToken;
use crate::autodiff::{
    CheckpointPolicy, HypergradEngine, HypergradMode,
};
use crate::meta::native::NativeMetaTrainer;
use crate::obs::{Counter, MetricsRegistry};
use crate::util::prng::Prng;

use super::chaos::{ChaosConfig, FaultPlan, PANIC_MESSAGE};
use super::error::{classify_unwind, HypergradError};
use super::job::{JobRecord, JobSpec, JobStatus};
use super::queue::{BackpressurePolicy, BoundedQueue};

/// Supervisor configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeConfig {
    /// Worker threads (min 1).
    pub workers: usize,
    /// Queue bound; what happens when it fills is `backpressure`.
    pub queue_capacity: usize,
    pub backpressure: BackpressurePolicy,
    /// Per-attempt deadline; `None` = unbounded.
    pub deadline_ms: Option<u64>,
    /// Retries beyond the first attempt.
    pub max_retries: u64,
    /// First backoff sleep; doubles per retry.
    pub backoff_base_ms: u64,
    /// Backoff ceiling (jitter rides on top).
    pub backoff_cap_ms: u64,
    /// Seed of the backoff-jitter stream (folded per job).
    pub seed: u64,
    /// Engine telemetry (phase timings in records).
    pub telemetry: bool,
    /// Tape non-finite guard (off = bit-identical fast path; non-finite
    /// results are then only caught by the terminal result check).
    pub guard: bool,
    /// Quarantines per engine key before the circuit breaker opens.
    pub quarantine_limit: usize,
    /// Deterministic fault injection; `None` = no chaos.
    pub chaos: Option<ChaosConfig>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 2,
            queue_capacity: 64,
            backpressure: BackpressurePolicy::Block,
            deadline_ms: None,
            max_retries: 2,
            backoff_base_ms: 5,
            backoff_cap_ms: 50,
            seed: 0,
            telemetry: true,
            guard: true,
            quarantine_limit: 8,
            chaos: None,
        }
    }
}

impl ServeConfig {
    fn build_engine(
        &self,
        mode: HypergradMode,
        remat: CheckpointPolicy,
        inner_opt: crate::autodiff::InnerOptimiser,
    ) -> HypergradEngine {
        HypergradEngine::builder()
            .mode(mode)
            .checkpoint(remat)
            .inner_opt(inner_opt)
            .telemetry(self.telemetry)
            .guard(self.guard)
            .build()
    }
}

/// Everything `serve_jobs` returns: one record per job plus the
/// supervisor-wide ledgers the integration suite reconciles.
#[derive(Debug)]
pub struct ServeOutcome {
    /// One terminal record per submitted job, in submission order.
    pub records: Vec<JobRecord>,
    /// Supervisor-wide counters (`serve.jobs.*`, quarantines, …).
    pub metrics: MetricsRegistry,
    /// Every quarantined engine generation, supervisor-wide.
    pub quarantined_generations: Vec<u64>,
    /// Engines built over the run (warm reuse keeps this below the
    /// attempt count).
    pub engines_built: u64,
}

impl ServeOutcome {
    pub fn counter(&self, c: Counter) -> u64 {
        self.metrics.counter(c)
    }
}

/// A warm engine plus its immutable generation tag.
struct PooledEngine {
    engine: HypergradEngine,
    generation: u64,
}

struct PoolState {
    idle: HashMap<String, Vec<PooledEngine>>,
    /// Retired generations, in quarantine order.
    quarantined: Vec<u64>,
    /// Per-key: (quarantine count, last quarantined generation).
    breaker: HashMap<String, (usize, u64)>,
}

/// The warm-engine pool: coalesces jobs by engine key, retires
/// quarantined generations, and opens a per-key circuit breaker once a
/// key keeps corrupting engines.
struct EnginePool {
    state: Mutex<PoolState>,
    next_generation: AtomicU64,
    quarantine_limit: usize,
}

impl EnginePool {
    fn new(quarantine_limit: usize) -> EnginePool {
        EnginePool {
            state: Mutex::new(PoolState {
                idle: HashMap::new(),
                quarantined: Vec::new(),
                breaker: HashMap::new(),
            }),
            next_generation: AtomicU64::new(1),
            quarantine_limit: quarantine_limit.max(1),
        }
    }

    /// Check out a warm engine for `key`, or build a fresh one.  Errs
    /// with [`HypergradError::EngineQuarantined`] when the key's
    /// breaker is open.
    fn checkout(
        &self,
        key: &str,
        build: impl FnOnce() -> HypergradEngine,
    ) -> Result<PooledEngine, HypergradError> {
        {
            let mut st = self
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            if let Some(&(count, last)) = st.breaker.get(key) {
                if count >= self.quarantine_limit {
                    return Err(HypergradError::EngineQuarantined {
                        generation: last,
                    });
                }
            }
            if let Some(engine) =
                st.idle.get_mut(key).and_then(Vec::pop)
            {
                return Ok(engine);
            }
        }
        // Build outside the lock — engine construction is not free and
        // siblings should keep checking warm engines out meanwhile.
        let generation =
            self.next_generation.fetch_add(1, Ordering::SeqCst);
        Ok(PooledEngine { engine: build(), generation })
    }

    fn check_in(&self, key: &str, engine: PooledEngine) {
        let mut st =
            self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.idle.entry(key.to_string()).or_default().push(engine);
    }

    /// Retire an engine whose invariants no longer hold.  The engine is
    /// dropped here — a quarantined generation can never serve again
    /// because the pool is the only path to an engine.
    fn quarantine(&self, key: &str, engine: PooledEngine) {
        let mut st =
            self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.quarantined.push(engine.generation);
        let entry = st.breaker.entry(key.to_string()).or_insert((0, 0));
        entry.0 += 1;
        entry.1 = engine.generation;
        drop(engine);
    }

    fn quarantined(&self) -> Vec<u64> {
        self.state
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .quarantined
            .clone()
    }

    fn engines_built(&self) -> u64 {
        self.next_generation.load(Ordering::SeqCst) - 1
    }
}

/// What a successful attempt hands back to the job loop.
struct AttemptOk {
    outer_loss: f64,
    hypergrad_norm: f64,
    phases: Vec<(String, f64)>,
}

/// One engine attempt: inject faults, arm the deadline, run under
/// `catch_unwind`, classify any failure.
fn run_attempt(
    spec: &JobSpec,
    engine: &mut HypergradEngine,
    cfg: &ServeConfig,
    fault: FaultPlan,
) -> Result<AttemptOk, HypergradError> {
    // The deadline covers the whole attempt, so an injected slowdown
    // eats into the budget exactly like a real stall would.
    let token = cfg.deadline_ms.map(|ms| {
        Arc::new(CancelToken::with_deadline(
            Instant::now() + Duration::from_millis(ms),
        ))
    });
    let chaos = cfg.chaos.unwrap_or_default();
    if fault.slow {
        thread::sleep(Duration::from_millis(chaos.slow_ms));
    }
    // Ballast held across the run models memory pressure; volatile
    // writes keep the allocation from being optimised away.
    let _ballast: Option<Vec<u8>> = if fault.alloc {
        let mut v = vec![0u8; chaos.alloc_bytes.max(1)];
        v[0] = 1;
        Some(v)
    } else {
        None
    };
    let mut problem = NativeMetaTrainer::build_problem(
        spec.task,
        spec.seed,
        spec.unroll,
        spec.heads,
        spec.batch,
    );
    engine.configure_problem(problem.as_mut());
    // Re-key per-run randomness (evograd's perturbation stream) to the
    // job's seed: a warm pooled engine may have served any number of
    // jobs before this one, and replay determinism requires the stream
    // to depend only on the spec.
    engine.reseed(spec.seed);
    let theta0 = problem.theta0();
    let mut eta = problem.eta0();
    if fault.nan {
        eta[0].data[0] = f64::NAN;
    }
    engine.set_cancel(token.clone());
    let result = catch_unwind(AssertUnwindSafe(|| {
        if fault.panic {
            std::panic::panic_any(PANIC_MESSAGE.to_string());
        }
        engine.run(problem.as_ref(), &theta0, &eta)
    }));
    engine.set_cancel(None);
    let h = match result {
        Ok(h) => h,
        Err(payload) => {
            return Err(classify_unwind(payload, cfg.deadline_ms))
        }
    };
    // Guard-off safety net: a NaN that flowed through untripped must
    // still never be served as a valid hypergradient.
    let finite = h.outer_loss.is_finite()
        && h.d_eta
            .iter()
            .all(|g| g.data.iter().all(|v| v.is_finite()));
    if !finite {
        return Err(HypergradError::NonFinite {
            phase: "result".to_string(),
            node: 0,
        });
    }
    let norm = h
        .d_eta
        .iter()
        .flat_map(|g| g.data.iter())
        .map(|v| v * v)
        .sum::<f64>()
        .sqrt();
    let phases = engine
        .take_step_traces()
        .last()
        .map(|t| {
            t.phases
                .iter()
                .map(|p| (p.phase.name().to_string(), p.seconds))
                .collect()
        })
        .unwrap_or_default();
    Ok(AttemptOk {
        outer_loss: h.outer_loss,
        hypergrad_norm: norm,
        phases,
    })
}

/// One rung down the memory-pressure ladder: fewer live checkpoints,
/// more recompute.  `None` once fully degraded.
fn escalate_remat(
    policy: CheckpointPolicy,
    unroll: usize,
) -> Option<CheckpointPolicy> {
    let max_seg = unroll.max(2);
    match policy {
        CheckpointPolicy::Full => Some(CheckpointPolicy::Auto),
        CheckpointPolicy::Auto => {
            Some(CheckpointPolicy::Remat { segment: max_seg })
        }
        CheckpointPolicy::Remat { segment } => {
            let next = (segment * 2).min(max_seg);
            (next > segment)
                .then_some(CheckpointPolicy::Remat { segment: next })
        }
    }
}

fn count(metrics: &Mutex<MetricsRegistry>, c: Counter, delta: u64) {
    if delta > 0 {
        metrics
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .add(c, delta);
    }
}

/// Drive one job to its terminal state (everything but admission).
fn process_job(
    index: u64,
    spec: &JobSpec,
    cfg: &ServeConfig,
    pool: &EnginePool,
    metrics: &Mutex<MetricsRegistry>,
) -> JobRecord {
    let t0 = Instant::now();
    let mut mode = spec.mode;
    let mut remat = spec.remat;
    let mut degradation: Vec<String> = Vec::new();
    let mut generations: Vec<u64> = Vec::new();
    let mut quarantined: Vec<u64> = Vec::new();
    let mut backoff_ms = 0u64;
    let mut last_err: Option<HypergradError> = None;
    let mut success: Option<AttemptOk> = None;
    // Jitter stream: deterministic per (supervisor seed, job index),
    // deliberately decoupled from the chaos stream.
    let mut jitter = Prng::new(cfg.seed ^ 0x6a_17_7e_72).fold_in(index);
    let max_attempts = cfg.max_retries + 1;

    for attempt in 1..=max_attempts {
        let fault = cfg
            .chaos
            .as_ref()
            .map(|c| c.plan(index, attempt))
            .unwrap_or_else(FaultPlan::none);
        let key = spec.engine_key(mode, remat);
        let mut pooled = match pool.checkout(&key, || {
            cfg.build_engine(mode, remat, spec.inner_opt)
        }) {
            Ok(p) => p,
            Err(err) => {
                // Circuit breaker open: terminal, no attempt consumed.
                last_err = Some(err);
                break;
            }
        };
        generations.push(pooled.generation);
        match run_attempt(spec, &mut pooled.engine, cfg, fault) {
            Ok(ok) => {
                pool.check_in(&key, pooled);
                success = Some(ok);
                break;
            }
            Err(err) => {
                if matches!(
                    err,
                    HypergradError::DeadlineExceeded { .. }
                ) {
                    count(metrics, Counter::ServeDeadlineExceeded, 1);
                }
                if pooled.engine.invariants_ok() {
                    // Structurally sound: drain any half-recorded
                    // telemetry and keep the engine warm.
                    let _ = pooled.engine.take_step_traces();
                    pool.check_in(&key, pooled);
                } else {
                    quarantined.push(pooled.generation);
                    pool.quarantine(&key, pooled);
                    count(metrics, Counter::ServeEngineQuarantines, 1);
                }
                let retrying =
                    attempt < max_attempts && err.retryable();
                if retrying {
                    // Graceful degradation before the next attempt.
                    if matches!(err, HypergradError::NonFinite { .. })
                        && mode != HypergradMode::Fd
                    {
                        degradation.push(format!(
                            "nonfinite:{}->fd",
                            mode.name()
                        ));
                        mode = HypergradMode::Fd;
                    } else if fault.alloc
                        && matches!(
                            mode,
                            HypergradMode::Mixflow
                                | HypergradMode::Truncated { .. }
                        )
                    {
                        if let Some(next) =
                            escalate_remat(remat, spec.unroll)
                        {
                            degradation.push(format!(
                                "alloc:{}->{}",
                                remat.name(),
                                next.name()
                            ));
                            remat = next;
                        }
                    }
                    let exp = cfg
                        .backoff_base_ms
                        .saturating_mul(1u64 << (attempt - 1).min(20))
                        .min(cfg.backoff_cap_ms);
                    // One jitter draw per retry, unconditionally, so the
                    // deterministic replay stream is identical whether or
                    // not the cap bites.
                    let jit = jitter
                        .next_below(
                            cfg.backoff_base_ms.clamp(1, u32::MAX as u64)
                                as u32,
                        ) as u64;
                    // backoff_cap_ms bounds the *total* per-retry delay;
                    // jitter must never push a capped exponential term
                    // past the configured ceiling.
                    let delay = exp
                        .saturating_add(jit)
                        .min(cfg.backoff_cap_ms);
                    backoff_ms += delay;
                    thread::sleep(Duration::from_millis(delay));
                }
                last_err = Some(err);
                if !retrying {
                    break;
                }
            }
        }
    }

    let attempts = generations.len() as u64;
    count(metrics, Counter::ServeJobsRetried, attempts.saturating_sub(1));
    let status = if success.is_some() {
        count(metrics, Counter::ServeJobsOk, 1);
        JobStatus::Ok
    } else {
        count(metrics, Counter::ServeJobsFailed, 1);
        JobStatus::Failed
    };
    let (error, outer_loss, hypergrad_norm, phases) = match success {
        Some(ok) => {
            (None, Some(ok.outer_loss), Some(ok.hypergrad_norm), ok.phases)
        }
        None => (last_err, None, None, Vec::new()),
    };
    JobRecord {
        id: spec.id.clone(),
        status,
        attempts,
        mode_requested: spec.mode,
        mode_used: mode,
        remat_used: remat,
        degradation,
        generations,
        quarantined,
        backoff_ms,
        error,
        outer_loss,
        hypergrad_norm,
        seconds: t0.elapsed().as_secs_f64(),
        phases,
    }
}

/// Serve every job to a terminal state and return the records in
/// submission order plus the supervisor's ledgers.
pub fn serve_jobs(specs: Vec<JobSpec>, cfg: &ServeConfig) -> ServeOutcome {
    let metrics = Mutex::new(MetricsRegistry::new());
    let pool = EnginePool::new(cfg.quarantine_limit);
    let queue: BoundedQueue<(u64, JobSpec)> =
        BoundedQueue::new(cfg.queue_capacity, cfg.backpressure);
    let results: Mutex<Vec<(u64, JobRecord)>> = Mutex::new(Vec::new());

    thread::scope(|s| {
        for _ in 0..cfg.workers.max(1) {
            s.spawn(|| {
                while let Some((index, spec)) = queue.pop() {
                    let record = process_job(
                        index, &spec, cfg, &pool, &metrics,
                    );
                    results
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner)
                        .push((index, record));
                }
            });
        }
        // Admission runs on the scope's own thread; under the block
        // policy a full queue parks us here while workers drain.
        for (index, spec) in specs.into_iter().enumerate() {
            let index = index as u64;
            if let Err((_, spec)) = queue.push((index, spec)) {
                count(&metrics, Counter::ServeJobsShed, 1);
                let record = JobRecord {
                    id: spec.id.clone(),
                    status: JobStatus::Shed,
                    attempts: 0,
                    mode_requested: spec.mode,
                    mode_used: spec.mode,
                    remat_used: spec.remat,
                    degradation: Vec::new(),
                    generations: Vec::new(),
                    quarantined: Vec::new(),
                    backoff_ms: 0,
                    error: Some(HypergradError::QueueFull {
                        capacity: queue.capacity(),
                    }),
                    outer_loss: None,
                    hypergrad_norm: None,
                    seconds: 0.0,
                    phases: Vec::new(),
                };
                results
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .push((index, record));
            }
        }
        queue.close();
    });

    let mut records = results
        .into_inner()
        .unwrap_or_else(PoisonError::into_inner);
    records.sort_by_key(|(index, _)| *index);
    ServeOutcome {
        records: records.into_iter().map(|(_, r)| r).collect(),
        metrics: metrics
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner),
        quarantined_generations: pool.quarantined(),
        engines_built: pool.engines_built(),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn quick_spec(id: &str, seed: u64) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            unroll: 3,
            seed,
            ..JobSpec::default()
        }
    }

    fn quick_cfg() -> ServeConfig {
        ServeConfig {
            workers: 2,
            max_retries: 1,
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn clean_jobs_all_serve_ok_with_warm_reuse() {
        let specs: Vec<JobSpec> =
            (0..4).map(|i| quick_spec(&format!("j{i}"), i)).collect();
        let cfg = ServeConfig { workers: 1, ..quick_cfg() };
        let out = serve_jobs(specs, &cfg);
        assert_eq!(out.records.len(), 4);
        assert!(out
            .records
            .iter()
            .all(|r| r.status == JobStatus::Ok && r.attempts == 1));
        assert!(out
            .records
            .iter()
            .all(|r| r.hypergrad_norm.unwrap() > 0.0));
        assert_eq!(out.counter(Counter::ServeJobsOk), 4);
        assert_eq!(out.counter(Counter::ServeJobsFailed), 0);
        assert_eq!(out.counter(Counter::ServeJobsRetried), 0);
        // Single worker + identical engine keys ⇒ one engine serves all
        // four jobs warm.
        assert_eq!(out.engines_built, 1, "warm engine coalescing");
        assert!(out.quarantined_generations.is_empty());
        // Telemetry is on by default: phase timings surface per record.
        assert!(out.records[0]
            .phases
            .iter()
            .any(|(name, _)| name == "forward"));
    }

    #[test]
    fn injected_panics_retry_then_fail_without_quarantine() {
        let chaos = ChaosConfig {
            seed: 5,
            panic_rate: 1.0,
            ..ChaosConfig::default()
        };
        let cfg = ServeConfig { chaos: Some(chaos), ..quick_cfg() };
        let out = serve_jobs(vec![quick_spec("p0", 0)], &cfg);
        let rec = &out.records[0];
        assert_eq!(rec.status, JobStatus::Failed);
        assert_eq!(rec.attempts, 2, "first attempt + one retry");
        match rec.error.as_ref().unwrap() {
            HypergradError::Panic { message } => {
                assert!(message.contains("chaos"))
            }
            other => panic!("expected Panic, got {other:?}"),
        }
        // The panic fired before the engine was touched: invariants
        // hold, nothing to quarantine.
        assert!(out.quarantined_generations.is_empty());
        assert_eq!(out.counter(Counter::ServeJobsRetried), 1);
        assert_eq!(out.counter(Counter::ServeJobsFailed), 1);
    }

    #[test]
    fn nan_injection_quarantines_and_degrades_to_fd() {
        let chaos = ChaosConfig {
            seed: 9,
            nan_rate: 1.0,
            ..ChaosConfig::default()
        };
        let cfg = ServeConfig { chaos: Some(chaos), ..quick_cfg() };
        let out = serve_jobs(vec![quick_spec("n0", 1)], &cfg);
        let rec = &out.records[0];
        assert_eq!(rec.status, JobStatus::Failed);
        assert_eq!(rec.attempts, 2);
        assert_eq!(rec.degradation, ["nonfinite:mixflow->fd"]);
        assert_eq!(rec.mode_used, HypergradMode::Fd);
        match rec.error.as_ref().unwrap() {
            HypergradError::NonFinite { .. } => {}
            other => panic!("expected NonFinite, got {other:?}"),
        }
        // Both attempts unwound mid-phase ⇒ both engines quarantined,
        // and the record's ledger matches the pool's.
        assert_eq!(rec.quarantined, out.quarantined_generations);
        assert_eq!(
            out.counter(Counter::ServeEngineQuarantines),
            out.quarantined_generations.len() as u64
        );
        assert!(!out.quarantined_generations.is_empty());
    }

    #[test]
    fn slow_jobs_exceed_their_deadline() {
        let chaos = ChaosConfig {
            seed: 2,
            slow_rate: 1.0,
            slow_ms: 40,
            ..ChaosConfig::default()
        };
        let cfg = ServeConfig {
            chaos: Some(chaos),
            deadline_ms: Some(5),
            max_retries: 0,
            ..quick_cfg()
        };
        let out = serve_jobs(vec![quick_spec("s0", 3)], &cfg);
        let rec = &out.records[0];
        assert_eq!(rec.status, JobStatus::Failed);
        assert_eq!(
            rec.error,
            Some(HypergradError::DeadlineExceeded { deadline_ms: 5 })
        );
        assert_eq!(out.counter(Counter::ServeDeadlineExceeded), 1);
        // The pre-run stall means the cancel fires at the first between-
        // steps check, before any phase opens: a clean unwind, engine
        // stays serviceable.
        assert!(out.quarantined_generations.is_empty());
    }

    #[test]
    fn reject_backpressure_sheds_into_records() {
        let chaos = ChaosConfig {
            seed: 4,
            slow_rate: 1.0,
            slow_ms: 60,
            ..ChaosConfig::default()
        };
        let cfg = ServeConfig {
            workers: 1,
            queue_capacity: 1,
            backpressure: BackpressurePolicy::Reject,
            max_retries: 0,
            chaos: Some(chaos),
            ..ServeConfig::default()
        };
        let specs: Vec<JobSpec> =
            (0..5).map(|i| quick_spec(&format!("q{i}"), i)).collect();
        let out = serve_jobs(specs, &cfg);
        assert_eq!(out.records.len(), 5, "shed jobs still get records");
        let shed = out
            .records
            .iter()
            .filter(|r| r.status == JobStatus::Shed)
            .count() as u64;
        assert!(shed >= 1, "a 60 ms/job single worker must shed some of 5");
        assert_eq!(out.counter(Counter::ServeJobsShed), shed);
        assert_eq!(
            out.counter(Counter::ServeJobsOk)
                + out.counter(Counter::ServeJobsFailed)
                + shed,
            5,
            "every job reaches exactly one terminal counter"
        );
        for r in out.records.iter().filter(|r| r.status == JobStatus::Shed) {
            assert_eq!(r.attempts, 0);
            assert_eq!(
                r.error,
                Some(HypergradError::QueueFull { capacity: 1 })
            );
        }
    }

    #[test]
    fn circuit_breaker_opens_after_repeated_quarantines() {
        let chaos = ChaosConfig {
            seed: 11,
            nan_rate: 1.0,
            ..ChaosConfig::default()
        };
        // Limit 1: the first quarantine opens the breaker; the retry
        // (degraded to fd ⇒ different key) still runs, but a second
        // mixflow job on the same key is refused outright.
        let cfg = ServeConfig {
            workers: 1,
            quarantine_limit: 1,
            chaos: Some(chaos),
            ..quick_cfg()
        };
        let specs = vec![quick_spec("a", 0), quick_spec("b", 1)];
        let out = serve_jobs(specs, &cfg);
        let second = &out.records[1];
        assert_eq!(second.status, JobStatus::Failed);
        assert_eq!(
            second.attempts, 0,
            "an open breaker refuses before any engine is built"
        );
        match second.error.as_ref().unwrap() {
            HypergradError::EngineQuarantined { generation } => {
                assert!(out.quarantined_generations.contains(generation));
            }
            other => panic!("expected EngineQuarantined, got {other:?}"),
        }
    }

    #[test]
    fn backoff_delay_never_exceeds_the_cap() {
        // Regression: jitter used to be added *after* the cap, so each
        // retry could sleep up to backoff_base_ms past backoff_cap_ms.
        // base=8/cap=10 makes the old bug visible: the capped
        // exponential term alone reaches 10, so any non-zero jitter
        // (drawn from [0, 8)) pushed the old sum over the ceiling.
        let chaos = ChaosConfig {
            seed: 7,
            panic_rate: 1.0,
            ..ChaosConfig::default()
        };
        let cfg = ServeConfig {
            workers: 1,
            max_retries: 3,
            backoff_base_ms: 8,
            backoff_cap_ms: 10,
            chaos: Some(chaos),
            ..ServeConfig::default()
        };
        let out = serve_jobs(vec![quick_spec("c0", 0)], &cfg);
        let rec = &out.records[0];
        assert_eq!(rec.attempts, 1 + cfg.max_retries);
        assert!(rec.backoff_ms > 0, "retries must actually back off");
        assert!(
            rec.backoff_ms <= cfg.max_retries * cfg.backoff_cap_ms,
            "total backoff {} ms must respect the {} ms per-retry cap",
            rec.backoff_ms,
            cfg.backoff_cap_ms
        );
        // The jitter stream is deterministic: a replay of the same
        // seed/job sleeps the identical schedule.
        let out2 = serve_jobs(vec![quick_spec("c0", 0)], &cfg);
        assert_eq!(out2.records[0].backoff_ms, rec.backoff_ms);
    }

    #[test]
    fn escalation_ladder_is_monotone() {
        let u = 8;
        let a = escalate_remat(CheckpointPolicy::Full, u).unwrap();
        assert_eq!(a, CheckpointPolicy::Auto);
        let b = escalate_remat(a, u).unwrap();
        assert_eq!(b, CheckpointPolicy::Remat { segment: 8 });
        assert_eq!(escalate_remat(b, u), None, "ladder bottoms out");
        assert_eq!(
            escalate_remat(CheckpointPolicy::Remat { segment: 2 }, u),
            Some(CheckpointPolicy::Remat { segment: 4 })
        );
    }
}

//! Typed failure taxonomy for the serving layer.
//!
//! Every way a hypergradient job can fail is a [`HypergradError`]
//! variant, so the supervisor's retry/degradation policy dispatches on
//! structure instead of string-matching panic text.  The autodiff layer
//! stays ignorant of serving: the tape unwinds with its own typed
//! payloads ([`NonFiniteSignal`], [`CancelSignal`]) and
//! [`classify_unwind`] is the single place those payloads are turned
//! into serve-level errors.

use std::any::Any;

use crate::autodiff::tape::{CancelSignal, NonFiniteSignal};
use crate::coordinator::scheduler::panic_message;
use crate::util::json::Json;

/// Why a job attempt (or the job as a whole) failed.
#[derive(Debug, Clone, PartialEq)]
pub enum HypergradError {
    /// The tape's non-finite guard tripped: node `node` was about to be
    /// pushed with a NaN/inf value during `phase`.  With the guard off,
    /// the supervisor still raises this (phase `"result"`, node 0) when
    /// the finished hypergradient itself contains non-finite values.
    NonFinite { phase: String, node: usize },
    /// The job's closure panicked with an untyped payload (a bug or an
    /// injected chaos panic); `message` is the rendered payload.
    Panic { message: String },
    /// The per-attempt deadline fired and the tape unwound at the next
    /// cooperative cancellation point.
    DeadlineExceeded { deadline_ms: u64 },
    /// The request queue was full under the reject backpressure policy;
    /// the job was shed without ever running.
    QueueFull { capacity: usize },
    /// The circuit breaker for this job's engine key is open: at least
    /// `generation`'s engine (and the per-key quarantine limit in total)
    /// was quarantined, so the supervisor refuses to build more engines
    /// for a configuration that keeps corrupting them.
    EngineQuarantined { generation: u64 },
}

impl HypergradError {
    /// Stable machine-readable discriminant (the `error.kind` JSONL
    /// field).
    pub fn kind(&self) -> &'static str {
        match self {
            HypergradError::NonFinite { .. } => "non_finite",
            HypergradError::Panic { .. } => "panic",
            HypergradError::DeadlineExceeded { .. } => "deadline_exceeded",
            HypergradError::QueueFull { .. } => "queue_full",
            HypergradError::EngineQuarantined { .. } => "engine_quarantined",
        }
    }

    /// Whether the supervisor should spend another attempt on the job.
    /// Shed jobs never ran and an open circuit breaker will not close by
    /// retrying, so both are terminal; everything else may be transient
    /// (chaos faults are per-attempt) or degradable (non-finite → fd).
    pub fn retryable(&self) -> bool {
        !matches!(
            self,
            HypergradError::QueueFull { .. }
                | HypergradError::EngineQuarantined { .. }
        )
    }

    /// The `error` object of a JSONL result record.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("kind", Json::Str(self.kind().to_string()));
        match self {
            HypergradError::NonFinite { phase, node } => {
                o.insert("phase", Json::Str(phase.clone()));
                o.insert("node", Json::Num(*node as f64));
            }
            HypergradError::Panic { message } => {
                o.insert("message", Json::Str(message.clone()));
            }
            HypergradError::DeadlineExceeded { deadline_ms } => {
                o.insert("deadline_ms", Json::Num(*deadline_ms as f64));
            }
            HypergradError::QueueFull { capacity } => {
                o.insert("capacity", Json::Num(*capacity as f64));
            }
            HypergradError::EngineQuarantined { generation } => {
                o.insert("generation", Json::Num(*generation as f64));
            }
        }
        o
    }
}

impl std::fmt::Display for HypergradError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HypergradError::NonFinite { phase, node } => {
                write!(f, "non-finite value at node {node} during {phase}")
            }
            HypergradError::Panic { message } => {
                write!(f, "job panicked: {message}")
            }
            HypergradError::DeadlineExceeded { deadline_ms } => {
                write!(f, "deadline of {deadline_ms} ms exceeded")
            }
            HypergradError::QueueFull { capacity } => {
                write!(f, "queue full (capacity {capacity}), job shed")
            }
            HypergradError::EngineQuarantined { generation } => {
                write!(
                    f,
                    "engine key quarantined (last generation {generation})"
                )
            }
        }
    }
}

/// Classify a payload caught from a job attempt's unwind.  The tape's
/// typed signals map to their dedicated variants; anything else is a
/// plain [`HypergradError::Panic`] with the payload rendered to text.
/// `deadline_ms` is the attempt's configured deadline, recorded into
/// [`HypergradError::DeadlineExceeded`] (0 when a cancellation fired
/// without a configured deadline — an explicit `CancelToken::cancel`).
pub fn classify_unwind(
    payload: Box<dyn Any + Send>,
    deadline_ms: Option<u64>,
) -> HypergradError {
    let payload = match payload.downcast::<NonFiniteSignal>() {
        Ok(sig) => {
            return HypergradError::NonFinite {
                phase: sig.phase.to_string(),
                node: sig.node,
            }
        }
        Err(other) => other,
    };
    let payload = match payload.downcast::<CancelSignal>() {
        Ok(_) => {
            return HypergradError::DeadlineExceeded {
                deadline_ms: deadline_ms.unwrap_or(0),
            }
        }
        Err(other) => other,
    };
    HypergradError::Panic { message: panic_message(payload.as_ref()) }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, panic_any};

    #[test]
    fn classifies_typed_tape_signals() {
        let payload = catch_unwind(|| {
            panic_any(NonFiniteSignal { node: 7, phase: "forward" })
        })
        .unwrap_err();
        let err = classify_unwind(payload, None);
        assert_eq!(
            err,
            HypergradError::NonFinite { phase: "forward".to_string(), node: 7 }
        );
        assert_eq!(err.kind(), "non_finite");

        let payload = catch_unwind(|| panic_any(CancelSignal)).unwrap_err();
        let err = classify_unwind(payload, Some(250));
        assert_eq!(err, HypergradError::DeadlineExceeded { deadline_ms: 250 });
        assert!(err.retryable());
    }

    #[test]
    fn untyped_panics_keep_their_message() {
        let payload =
            catch_unwind(|| panic!("boom at step {}", 3)).unwrap_err();
        let err = classify_unwind(payload, None);
        match &err {
            HypergradError::Panic { message } => {
                assert!(message.contains("boom at step 3"));
            }
            other => panic!("expected Panic, got {other:?}"),
        }
    }

    #[test]
    fn terminal_errors_are_not_retryable() {
        assert!(!HypergradError::QueueFull { capacity: 4 }.retryable());
        assert!(
            !HypergradError::EngineQuarantined { generation: 2 }.retryable()
        );
        assert!(
            HypergradError::NonFinite { phase: "x".into(), node: 0 }
                .retryable()
        );
        assert!(HypergradError::Panic { message: "m".into() }.retryable());
    }

    #[test]
    fn json_carries_kind_and_fields() {
        let e = HypergradError::NonFinite {
            phase: "backward_vjp".to_string(),
            node: 42,
        };
        let j = e.to_json();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("non_finite"));
        assert_eq!(j.get("node").and_then(Json::as_u64), Some(42));
        let round = Json::parse(&j.compact()).unwrap();
        assert_eq!(
            round.get("phase").and_then(Json::as_str),
            Some("backward_vjp")
        );
    }
}

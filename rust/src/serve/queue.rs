//! Bounded MPMC request queue with configurable backpressure.
//!
//! The serving front end pushes jobs, the worker pool pops them.  The
//! queue is deliberately tiny — a mutex-guarded `VecDeque` with two
//! condvars — because the jobs it carries are seconds-scale engine
//! runs, not microsecond messages; contention on the lock is noise.
//!
//! Backpressure is a policy, not an accident: under
//! [`BackpressurePolicy::Reject`] a full queue bounces the push back to
//! the caller (the supervisor sheds the job with
//! [`crate::serve::HypergradError::QueueFull`]); under
//! [`BackpressurePolicy::Block`] the producer parks until a worker
//! drains a slot, so admission is lossless and the bound caps memory,
//! not throughput.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, PoisonError};

/// What a full queue does to the producer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// Bounce the push back immediately (lossy shed, bounded latency).
    Reject,
    /// Park the producer until space frees (lossless, bounded memory).
    Block,
}

impl BackpressurePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            BackpressurePolicy::Reject => "reject",
            BackpressurePolicy::Block => "block",
        }
    }

    /// Case- and whitespace-insensitive name lookup.
    pub fn parse(s: &str) -> Option<BackpressurePolicy> {
        match s.trim().to_lowercase().as_str() {
            "reject" | "shed" => Some(BackpressurePolicy::Reject),
            "block" | "wait" => Some(BackpressurePolicy::Block),
            _ => None,
        }
    }
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded FIFO shared between one producer and N worker threads.
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    /// Signalled when a slot frees (push-side waiters under `Block`).
    space: Condvar,
    /// Signalled when an item arrives or the queue closes (pop-side).
    items: Condvar,
    capacity: usize,
    policy: BackpressurePolicy,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `capacity` (min 1) queued items.
    pub fn new(capacity: usize, policy: BackpressurePolicy) -> BoundedQueue<T> {
        BoundedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            space: Condvar::new(),
            items: Condvar::new(),
            capacity: capacity.max(1),
            policy,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn policy(&self) -> BackpressurePolicy {
        self.policy
    }

    /// Enqueue `item`.  Returns it back via `Err` when it cannot be
    /// admitted: the queue is full under [`BackpressurePolicy::Reject`],
    /// or the queue has been closed (any policy — a closed queue never
    /// admits, even for a blocked producer, so shutdown cannot deadlock).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.capacity {
                st.items.push_back(item);
                self.items.notify_one();
                return Ok(());
            }
            match self.policy {
                BackpressurePolicy::Reject => return Err(item),
                BackpressurePolicy::Block => {
                    st = self
                        .space
                        .wait(st)
                        .unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }

    /// Dequeue the next item, blocking while the queue is open but
    /// empty.  `None` means closed-and-drained: the worker's signal to
    /// exit its loop.
    pub fn pop(&self) -> Option<T> {
        let mut st = self
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(item) = st.items.pop_front() {
                self.space.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self
                .items
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Close the queue: queued items still drain, new pushes bounce,
    /// and idle workers wake to observe the shutdown.
    pub fn close(&self) {
        let mut st = self
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        st.closed = true;
        self.items.notify_all();
        self.space.notify_all();
    }

    /// Currently queued (not in-flight) items.
    pub fn len(&self) -> usize {
        self.state
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .items
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order_and_close_drain() {
        let q: BoundedQueue<u32> =
            BoundedQueue::new(8, BackpressurePolicy::Reject);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        q.close();
        let drained: Vec<u32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, [0, 1, 2, 3, 4]);
        assert!(q.pop().is_none(), "closed and drained stays None");
    }

    #[test]
    fn reject_policy_bounces_when_full() {
        let q: BoundedQueue<u32> =
            BoundedQueue::new(2, BackpressurePolicy::Reject);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(3), "full queue returns the item");
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn block_policy_waits_for_a_consumer() {
        let q: Arc<BoundedQueue<u32>> =
            Arc::new(BoundedQueue::new(1, BackpressurePolicy::Block));
        q.push(0).unwrap();
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.push(1).is_ok())
        };
        // The producer is parked on the full queue until this pop.
        assert_eq!(q.pop(), Some(0));
        assert!(producer.join().unwrap(), "blocked push completes");
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_unblocks_a_parked_producer() {
        let q: Arc<BoundedQueue<u32>> =
            Arc::new(BoundedQueue::new(1, BackpressurePolicy::Block));
        q.push(0).unwrap();
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.push(1))
        };
        // Give the producer a moment to park, then close underneath it.
        thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert_eq!(
            producer.join().unwrap(),
            Err(1),
            "closing hands the item back instead of deadlocking"
        );
    }

    #[test]
    fn parse_accepts_aliases() {
        assert_eq!(
            BackpressurePolicy::parse(" Reject\n"),
            Some(BackpressurePolicy::Reject)
        );
        assert_eq!(
            BackpressurePolicy::parse("BLOCK"),
            Some(BackpressurePolicy::Block)
        );
        assert_eq!(
            BackpressurePolicy::parse("shed"),
            Some(BackpressurePolicy::Reject)
        );
        assert_eq!(BackpressurePolicy::parse("drop"), None);
    }
}

//! Engine observability: metrics registry, phase tracing, trace sinks.
//!
//! The paper's claims are observability claims — "over 10× memory and up
//! to 25% wall-clock improvements" — so the native engine carries a
//! zero-dependency telemetry layer that can say *where inside a
//! hypergradient step* the bytes and seconds go:
//!
//! * [`registry`] — named counters / peak gauges / per-phase wall-time
//!   histograms ([`MetricsRegistry`]), array-backed so the tape hot path
//!   pays one branch + one array add when enabled and one branch when
//!   not.
//! * [`trace`] — the [`Telemetry`] recorder (owned by `Tape`, bracketed
//!   by `HypergradEngine` per outer step and by the strategies per
//!   [`Phase`]), the [`StepTrace`] record, and the sinks: JSON-lines
//!   ([`trace_jsonl`]), Chrome trace-event ([`chrome_trace`], loads in
//!   Perfetto), and the CLI table ([`print_trace_summary`]).
//!
//! Telemetry is off by default.  The disabled path takes no timestamps
//! and writes no counters, so it cannot perturb hypergradients — the
//! bit-identity and ≤5% overhead pins live in `rust/tests/trace.rs`.
//! `MemoryReport` stays the strategies' own accounting; every
//! [`StepTrace`] carries both that report and the registry's counter
//! deltas so the two paths are conformance-checked against each other
//! (see `fig_native_memory` and the warm-engine tests).

pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, MetricsRegistry};
pub use trace::{
    chrome_trace, print_trace_summary, trace_jsonl, write_trace, Phase,
    PhaseStat, SpanEvent, StepTrace, Telemetry, TraceCells, TraceFormat,
};

//! The metrics registry: named counters, peak gauges and per-phase
//! wall-time histograms for the native hypergradient engine.
//!
//! Metric identities are closed enums ([`Counter`], [`Gauge`]) backed by
//! fixed-size arrays, so recording a sample on the tape's hot path is an
//! array index — no string hashing, no allocation.  The printable names
//! (`tape.nodes`, `arena.alloc_bytes`, ...) exist only at the reporting
//! boundary; see the "Telemetry" section of `rust/src/autodiff/README.md`
//! for the full name table and which subsystem feeds each metric.
//!
//! The registry itself has no enabled/disabled switch — that lives in
//! [`super::trace::Telemetry`], whose disabled path returns before ever
//! touching the registry.

use std::collections::BTreeMap;

/// A monotonically increasing count (events or bytes since the registry
/// was created).  Per-outer-step deltas are captured by
/// [`super::trace::StepTrace::counters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Nodes pushed onto the tape (aliased nodes included).
    TapeNodes,
    /// Bytes of owning node buffers pushed onto the tape.
    TapeBytes,
    /// Bytes tagged as K/V projections via `Tape::mark_kv`.
    KvBytes,
    /// Arena buffers drawn fresh from the system allocator.
    ArenaAllocs,
    /// Arena buffers served from the free list.
    ArenaReuses,
    /// Arena buffers returned to the free list.
    ArenaRecycled,
    /// Bytes of freshly allocated arena buffers.
    ArenaAllocBytes,
    /// Bytes served from the arena free list.
    ArenaReuseBytes,
    /// Bytes returned to the arena free list.
    ArenaRecycleBytes,
    /// `(θ_t, s_t)` checkpoint pairs stored by the mixflow forward sweep.
    CheckpointStores,
    /// Bytes of stored checkpoint pairs.
    CheckpointBytes,
    /// Inner steps re-run by the mixflow backward sweep to rebuild
    /// intra-segment states (0 under full checkpointing).
    RematRebuilds,
    /// Bytes of JVP tangents flowing through K/V-marked nodes
    /// (the tangent-overlay extension of `KvBytes`).
    KvTangentBytes,
    /// Step plans compiled from a recorded cycle (`autodiff::plan`).
    PlanCompiles,
    /// Cycles replayed under an armed plan that validated cleanly.
    PlanReplays,
    /// Armed replays whose topology diverged, forcing a recompile.
    PlanFallbacks,
    /// Serving jobs that reached a successful terminal state.
    ServeJobsOk,
    /// Extra attempts spent retrying serving jobs (attempts − 1, summed).
    ServeJobsRetried,
    /// Serving jobs that exhausted their retries and failed terminally.
    ServeJobsFailed,
    /// Serving jobs shed at admission (bounded queue full).
    ServeJobsShed,
    /// Engines quarantined after a failure violated tape/arena invariants.
    ServeEngineQuarantines,
    /// Per-attempt deadline expiries observed by the serving supervisor.
    ServeDeadlineExceeded,
    /// Rank-2/rank-3 GEMM kernel dispatches (value + JVP dual passes).
    KernelGemmCalls,
    /// Fused elementwise map kernel dispatches.
    KernelMapCalls,
    /// Fused elementwise zip kernel dispatches.
    KernelZipCalls,
    /// Fused row kernel dispatches (softmax / log-sum-exp and their
    /// JVP duals).
    KernelRowsCalls,
    /// Parallel regions executed by the engine's `DetPool` (serial
    /// fast-path dispatches are not counted).
    PoolJobs,
    /// Work chunks executed inside those parallel regions.
    PoolChunks,
    /// Inner steps outside the truncation window — unrolled forward but
    /// never differentiated by the truncated backward sweep.
    TruncatedSkippedSteps,
    /// Population perturbations drawn by the EvoGrad estimator.
    EvogradPerturbations,
}

impl Counter {
    /// Every counter, in reporting order.
    pub const ALL: [Counter; 30] = [
        Counter::TapeNodes,
        Counter::TapeBytes,
        Counter::KvBytes,
        Counter::ArenaAllocs,
        Counter::ArenaReuses,
        Counter::ArenaRecycled,
        Counter::ArenaAllocBytes,
        Counter::ArenaReuseBytes,
        Counter::ArenaRecycleBytes,
        Counter::CheckpointStores,
        Counter::CheckpointBytes,
        Counter::RematRebuilds,
        Counter::KvTangentBytes,
        Counter::PlanCompiles,
        Counter::PlanReplays,
        Counter::PlanFallbacks,
        Counter::ServeJobsOk,
        Counter::ServeJobsRetried,
        Counter::ServeJobsFailed,
        Counter::ServeJobsShed,
        Counter::ServeEngineQuarantines,
        Counter::ServeDeadlineExceeded,
        Counter::KernelGemmCalls,
        Counter::KernelMapCalls,
        Counter::KernelZipCalls,
        Counter::KernelRowsCalls,
        Counter::PoolJobs,
        Counter::PoolChunks,
        Counter::TruncatedSkippedSteps,
        Counter::EvogradPerturbations,
    ];

    /// Number of counters (array backing size).
    pub const COUNT: usize = Counter::ALL.len();

    /// The dotted metric name used in traces and reports.
    pub fn name(self) -> &'static str {
        match self {
            Counter::TapeNodes => "tape.nodes",
            Counter::TapeBytes => "tape.bytes",
            Counter::KvBytes => "tape.kv_bytes",
            Counter::ArenaAllocs => "arena.allocs",
            Counter::ArenaReuses => "arena.reuses",
            Counter::ArenaRecycled => "arena.recycled",
            Counter::ArenaAllocBytes => "arena.alloc_bytes",
            Counter::ArenaReuseBytes => "arena.reuse_bytes",
            Counter::ArenaRecycleBytes => "arena.recycle_bytes",
            Counter::CheckpointStores => "checkpoint.stores",
            Counter::CheckpointBytes => "checkpoint.bytes",
            Counter::RematRebuilds => "remat.rebuilds",
            Counter::KvTangentBytes => "kv.tangent_bytes",
            Counter::PlanCompiles => "plan.compiles",
            Counter::PlanReplays => "plan.replays",
            Counter::PlanFallbacks => "plan.fallbacks",
            Counter::ServeJobsOk => "serve.jobs.ok",
            Counter::ServeJobsRetried => "serve.jobs.retried",
            Counter::ServeJobsFailed => "serve.jobs.failed",
            Counter::ServeJobsShed => "serve.jobs.shed",
            Counter::ServeEngineQuarantines => "serve.engine.quarantines",
            Counter::ServeDeadlineExceeded => "serve.deadline.exceeded",
            Counter::KernelGemmCalls => "kernels.gemm.calls",
            Counter::KernelMapCalls => "kernels.map.calls",
            Counter::KernelZipCalls => "kernels.zip.calls",
            Counter::KernelRowsCalls => "kernels.rows.calls",
            Counter::PoolJobs => "pool.jobs",
            Counter::PoolChunks => "pool.chunks",
            Counter::TruncatedSkippedSteps => "truncated.skipped_steps",
            Counter::EvogradPerturbations => "evograd.perturbations",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// A high-water mark: `record` keeps the maximum ever seen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Peak live bytes on any single tape recording.
    TapePeakBytes,
    /// Peak K/V-projection bytes live on any single tape recording.
    KvPeakBytes,
    /// Peak live checkpoint bytes reported by any one hypergradient.
    CheckpointPeakBytes,
}

impl Gauge {
    /// Every gauge, in reporting order.
    pub const ALL: [Gauge; 3] = [
        Gauge::TapePeakBytes,
        Gauge::KvPeakBytes,
        Gauge::CheckpointPeakBytes,
    ];

    /// Number of gauges (array backing size).
    pub const COUNT: usize = Gauge::ALL.len();

    /// The dotted metric name used in traces and reports.
    pub fn name(self) -> &'static str {
        match self {
            Gauge::TapePeakBytes => "tape.peak_bytes",
            Gauge::KvPeakBytes => "tape.kv_peak_bytes",
            Gauge::CheckpointPeakBytes => "checkpoint.peak_bytes",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// Running summary of observed samples (per-phase wall time, seconds).
/// Count/sum/min/max is all the sinks need; full distributions stay in
/// the per-step traces.
#[derive(Debug, Clone, Copy)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { count: 0, sum: 0.0, min: f64::INFINITY, max: 0.0 }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Mean sample, or 0 for an empty histogram.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// One engine's worth of metrics.  Owned by the tape's
/// [`super::trace::Telemetry`], so every `HypergradEngine` (and
/// therefore every sweep cell) gets its own registry — no global state,
/// no locks on pool threads.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: [u64; Counter::COUNT],
    gauges: [u64; Gauge::COUNT],
    /// Wall-time histograms keyed by span phase name.
    hists: BTreeMap<&'static str, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    #[inline]
    pub fn add(&mut self, c: Counter, delta: u64) {
        self.counters[c.idx()] += delta;
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c.idx()]
    }

    /// Raise a gauge to `v` if `v` is a new high-water mark.
    #[inline]
    pub fn gauge_max(&mut self, g: Gauge, v: u64) {
        let slot = &mut self.gauges[g.idx()];
        *slot = (*slot).max(v);
    }

    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g.idx()]
    }

    /// Record one wall-time sample under `name` (span phase names).
    pub fn observe(&mut self, name: &'static str, seconds: f64) {
        self.hists.entry(name).or_default().observe(seconds);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// All histograms in name order.
    pub fn histograms(
        &self,
    ) -> impl Iterator<Item = (&'static str, &Histogram)> {
        self.hists.iter().map(|(k, v)| (*k, v))
    }

    /// Snapshot of every counter, for later [`MetricsRegistry::delta`].
    pub fn snapshot(&self) -> [u64; Counter::COUNT] {
        self.counters
    }

    /// `(name, delta)` for every counter since `since` — the per-step
    /// counter deltas the trace records carry.
    pub fn delta(
        &self,
        since: &[u64; Counter::COUNT],
    ) -> Vec<(&'static str, u64)> {
        Counter::ALL
            .iter()
            .map(|&c| {
                (c.name(), self.counters[c.idx()] - since[c.idx()])
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_delta() {
        let mut r = MetricsRegistry::new();
        r.add(Counter::TapeNodes, 3);
        let snap = r.snapshot();
        r.add(Counter::TapeNodes, 4);
        r.add(Counter::ArenaAllocs, 2);
        assert_eq!(r.counter(Counter::TapeNodes), 7);
        let d = r.delta(&snap);
        assert_eq!(d.len(), Counter::COUNT);
        let lookup = |name: &str| {
            d.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
        };
        assert_eq!(lookup("tape.nodes"), Some(4));
        assert_eq!(lookup("arena.allocs"), Some(2));
        assert_eq!(lookup("tape.bytes"), Some(0));
    }

    #[test]
    fn gauges_keep_the_high_water_mark() {
        let mut r = MetricsRegistry::new();
        r.gauge_max(Gauge::TapePeakBytes, 10);
        r.gauge_max(Gauge::TapePeakBytes, 4);
        assert_eq!(r.gauge(Gauge::TapePeakBytes), 10);
        r.gauge_max(Gauge::TapePeakBytes, 11);
        assert_eq!(r.gauge(Gauge::TapePeakBytes), 11);
    }

    #[test]
    fn histograms_summarise_samples() {
        let mut r = MetricsRegistry::new();
        r.observe("forward", 0.5);
        r.observe("forward", 1.5);
        let h = r.histogram("forward").expect("recorded");
        assert_eq!(h.count, 2);
        assert_eq!(h.sum, 2.0);
        assert_eq!(h.min, 0.5);
        assert_eq!(h.max, 1.5);
        assert_eq!(h.mean(), 1.0);
        assert!(r.histogram("backward_vjp").is_none());
        assert_eq!(r.histograms().count(), 1);
    }

    #[test]
    fn metric_names_are_unique_and_dotted() {
        let mut names: Vec<&str> =
            Counter::ALL.iter().map(|c| c.name()).collect();
        names.extend(Gauge::ALL.iter().map(|g| g.name()));
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate metric name");
        assert!(names.iter().all(|n| n.contains('.')));
    }
}

//! Span-scoped phase tracing and the trace sinks.
//!
//! [`Telemetry`] is the engine-facing recorder: the `HypergradEngine`
//! brackets each outer step with [`Telemetry::step_begin`] /
//! [`Telemetry::step_end`], and the strategies bracket their internal
//! phases ([`Phase`]) with [`Telemetry::phase_begin`] /
//! [`Telemetry::phase_end`].  Spans may nest (a `jvp` span runs inside
//! `backward_vjp`); each closed span feeds the per-step [`StepTrace`]
//! and the registry's per-phase wall-time histogram.
//!
//! The recorder is **disabled by default** and every entry point returns
//! immediately in that state — no `Instant::now()`, no counter writes —
//! which is what makes the telemetry-off bit-identity + overhead pin in
//! `rust/tests/trace.rs` hold trivially: the disabled path never touches
//! the computation or the clock.
//!
//! Two sinks serialise collected traces:
//!
//! * [`trace_jsonl`] — one JSON object per line per outer step, with
//!   nested phase timings, registry counter deltas, and the
//!   `MemoryReport` cross-check block (`TRACE_native.jsonl`).
//! * [`chrome_trace`] — a Chrome trace-event document (open in Perfetto
//!   or `chrome://tracing`); one process per traced cell, "X" complete
//!   events for steps and phase spans.
//!
//! plus [`print_trace_summary`], the CLI table.

use std::time::Instant;

use super::registry::{Counter, Gauge, MetricsRegistry};
use crate::util::args::CliEnum;
use crate::util::json::Json;
use crate::util::stats::human_secs;
use crate::util::table::Table;

/// The traced phases of one hypergradient computation.
///
/// Which phases appear depends on the strategy: `naive` emits
/// `forward` + `backward_vjp`; `mixflow` emits all seven (with
/// `remat_rebuild` only under a `Remat{segment ≥ 2}` policy, and
/// `plan_replay` whenever a compiled step plan is armed — from the
/// second inner step on); `fd` wraps its unrolled evaluations in
/// `forward` spans (one for the base point, one per ± pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Inner unroll(s): recording inner steps and the outer loss.
    Forward,
    /// Storing a `(θ_t, s_t)` segment-boundary checkpoint.
    CheckpointStore,
    /// Seeding λ = ∂L_outer/∂θ_T at the end of the unroll.
    LambdaSeed,
    /// Re-running inner steps to rebuild intra-segment states.
    RematRebuild,
    /// One backward step: re-record, VJP for the adjoint λᵀ∂Φ/∂(θ,η).
    BackwardVjp,
    /// The forward-over-reverse JVP that advances λ (nested inside
    /// `backward_vjp`).
    Jvp,
    /// A step cycle re-recorded under an armed compiled plan (nested
    /// inside whichever phase owns the cycle; see `autodiff::plan`).
    PlanReplay,
}

impl Phase {
    /// Every phase, in canonical reporting order.
    pub const ALL: [Phase; 7] = [
        Phase::Forward,
        Phase::CheckpointStore,
        Phase::LambdaSeed,
        Phase::RematRebuild,
        Phase::BackwardVjp,
        Phase::Jvp,
        Phase::PlanReplay,
    ];

    /// The snake_case phase name used in trace records and histograms.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Forward => "forward",
            Phase::CheckpointStore => "checkpoint_store",
            Phase::LambdaSeed => "lambda_seed",
            Phase::RematRebuild => "remat_rebuild",
            Phase::BackwardVjp => "backward_vjp",
            Phase::Jvp => "jvp",
            Phase::PlanReplay => "plan_replay",
        }
    }
}

/// One closed span: a phase occurrence with microsecond timestamps
/// relative to the recorder's epoch (Chrome trace `ts`/`dur`).
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub phase: Phase,
    pub start_us: u64,
    pub dur_us: u64,
}

/// Aggregated timing for one phase within one outer step.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub phase: Phase,
    /// Number of spans of this phase in the step.
    pub count: u64,
    /// Total wall time across those spans.
    pub seconds: f64,
}

/// The trace record for one outer step: phase timings, registry counter
/// deltas over the step, and the strategy's own `MemoryReport`-derived
/// numbers for conformance checking.
#[derive(Debug, Clone)]
pub struct StepTrace {
    /// Engine-lifetime outer-step index.
    pub step: usize,
    /// `HypergradStrategy::name()` of the strategy that ran.
    pub strategy: &'static str,
    /// Step start, µs since the recorder epoch.
    pub start_us: u64,
    /// Step wall time in µs.
    pub dur_us: u64,
    /// Per-phase aggregates, in order of first occurrence.
    pub phases: Vec<PhaseStat>,
    /// Registry counter deltas over the step (every [`Counter`], 0 when
    /// untouched).
    pub counters: Vec<(&'static str, u64)>,
    /// Independent per-step numbers from the strategy's `MemoryReport`,
    /// for conformance checks against `counters`.
    pub report: Vec<(&'static str, u64)>,
    /// Every closed span, for timeline export.
    pub events: Vec<SpanEvent>,
}

impl StepTrace {
    /// Aggregate for `phase`, if any span of it ran this step.
    pub fn phase(&self, phase: Phase) -> Option<&PhaseStat> {
        self.phases.iter().find(|p| p.phase == phase)
    }

    /// Registry counter delta by dotted name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    /// `MemoryReport` cross-check value by field name.
    pub fn report_counter(&self, name: &str) -> Option<u64> {
        self.report.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    /// Step wall time in seconds.
    pub fn total_seconds(&self) -> f64 {
        self.dur_us as f64 / 1e6
    }
}

/// An outer step still being recorded.
#[derive(Debug, Clone)]
struct OpenStep {
    step: usize,
    strategy: &'static str,
    start_us: u64,
    t0: Instant,
    phases: Vec<PhaseStat>,
    events: Vec<SpanEvent>,
    counters0: [u64; Counter::COUNT],
}

/// The per-engine telemetry recorder.  Lives inside `Tape`, so the
/// strategies (which already hold `&mut Tape`) and the tape/arena hot
/// paths all reach the same recorder without any signature changes.
#[derive(Debug, Clone)]
pub struct Telemetry {
    enabled: bool,
    /// Zero point for all `*_us` timestamps.
    epoch: Instant,
    registry: MetricsRegistry,
    steps: Vec<StepTrace>,
    current: Option<OpenStep>,
    /// Open phase spans, innermost last.
    stack: Vec<(Phase, Instant)>,
    /// Phase identities of the open spans, maintained even while the
    /// recorder is disabled (a `Copy` push/pop, no clock reads): the
    /// tape's non-finite guard attributes a bad value to the innermost
    /// open phase via [`Telemetry::current_phase`], and the serving
    /// layer's engine-invariant check uses emptiness between runs as a
    /// "no span was torn mid-flight by an unwind" witness.
    live: Vec<Phase>,
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::new()
    }
}

impl Telemetry {
    /// A disabled recorder (the default for every tape).
    pub fn new() -> Telemetry {
        Telemetry {
            enabled: false,
            epoch: Instant::now(),
            registry: MetricsRegistry::new(),
            steps: Vec::new(),
            current: None,
            stack: Vec::new(),
            live: Vec::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The innermost open phase, tracked whether or not the recorder is
    /// enabled (phase identity is maintained separately from timing).
    /// `None` outside any span.
    pub fn current_phase(&self) -> Option<Phase> {
        self.live.last().copied()
    }

    /// Number of phase spans currently open.  Between engine runs this
    /// must be 0; a non-zero count means an unwind tore through an open
    /// span, which the serving layer treats as an invariant violation.
    pub fn open_phases(&self) -> usize {
        self.live.len()
    }

    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Completed step traces, oldest first.
    pub fn steps(&self) -> &[StepTrace] {
        &self.steps
    }

    /// Drain completed step traces (leaves registry totals intact).
    pub fn take_steps(&mut self) -> Vec<StepTrace> {
        std::mem::take(&mut self.steps)
    }

    /// Bump a counter.  No-op while disabled.
    #[inline]
    pub fn count(&mut self, c: Counter, delta: u64) {
        if self.enabled {
            self.registry.add(c, delta);
        }
    }

    /// Raise a gauge high-water mark.  No-op while disabled.
    #[inline]
    pub fn gauge_max(&mut self, g: Gauge, v: u64) {
        if self.enabled {
            self.registry.gauge_max(g, v);
        }
    }

    /// Open the trace record for outer step `step` run by `strategy`.
    /// An unclosed previous step is finalised first.
    pub fn step_begin(&mut self, step: usize, strategy: &'static str) {
        if !self.enabled {
            return;
        }
        if self.current.is_some() {
            self.step_end(&[]);
        }
        self.current = Some(OpenStep {
            step,
            strategy,
            start_us: self.now_us(),
            t0: Instant::now(),
            phases: Vec::new(),
            events: Vec::new(),
            counters0: self.registry.snapshot(),
        });
    }

    /// Close the current step, attaching `report` (the strategy's
    /// `MemoryReport`-derived numbers) for conformance checking.
    pub fn step_end(&mut self, report: &[(&'static str, u64)]) {
        if !self.enabled {
            return;
        }
        let Some(open) = self.current.take() else {
            return;
        };
        self.stack.clear();
        self.steps.push(StepTrace {
            step: open.step,
            strategy: open.strategy,
            start_us: open.start_us,
            dur_us: open.t0.elapsed().as_micros() as u64,
            phases: open.phases,
            counters: self.registry.delta(&open.counters0),
            report: report.to_vec(),
            events: open.events,
        });
    }

    /// Open a phase span.  Spans may nest; a span opened outside any
    /// step (strategy run directly on an enabled tape) lazily opens an
    /// anonymous step so the span is never lost.
    pub fn phase_begin(&mut self, phase: Phase) {
        self.live.push(phase);
        if !self.enabled {
            return;
        }
        if self.current.is_none() {
            self.step_begin(self.steps.len(), "(direct)");
        }
        self.stack.push((phase, Instant::now()));
    }

    /// Close the innermost open span of `phase`.  A stray end (no
    /// matching begin) is ignored.
    pub fn phase_end(&mut self, phase: Phase) {
        if let Some(i) = self.live.iter().rposition(|p| *p == phase) {
            self.live.remove(i);
        }
        if !self.enabled {
            return;
        }
        let Some(i) = self.stack.iter().rposition(|(p, _)| *p == phase)
        else {
            debug_assert!(false, "phase_end({}) without begin", phase.name());
            return;
        };
        let (_, t0) = self.stack.remove(i);
        let dur = t0.elapsed();
        let seconds = dur.as_secs_f64();
        self.registry.observe(phase.name(), seconds);
        let end_us = self.now_us();
        let dur_us = dur.as_micros() as u64;
        if let Some(open) = self.current.as_mut() {
            open.events.push(SpanEvent {
                phase,
                start_us: end_us.saturating_sub(dur_us),
                dur_us,
            });
            match open.phases.iter_mut().find(|p| p.phase == phase) {
                Some(stat) => {
                    stat.count += 1;
                    stat.seconds += seconds;
                }
                None => open.phases.push(PhaseStat {
                    phase,
                    count: 1,
                    seconds,
                }),
            }
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// On-disk trace encodings for `--trace-format`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// One JSON object per line per outer step (`TRACE_native.jsonl`).
    Jsonl,
    /// Chrome trace-event JSON (Perfetto / `chrome://tracing`).
    Chrome,
}

impl CliEnum for TraceFormat {
    fn name(&self) -> String {
        match self {
            TraceFormat::Jsonl => "jsonl".to_string(),
            TraceFormat::Chrome => "chrome".to_string(),
        }
    }

    fn parse(s: &str) -> Option<TraceFormat> {
        match s.trim().to_lowercase().as_str() {
            "jsonl" => Some(TraceFormat::Jsonl),
            "chrome" | "perfetto" => Some(TraceFormat::Chrome),
            _ => None,
        }
    }

    fn variants() -> &'static [&'static str] {
        &["jsonl", "chrome"]
    }
}

/// Traces grouped by cell label — the unit both sinks consume.  A cell
/// is one traced engine: a sweep cell, a CLI run, or a bench variant.
pub type TraceCells = [(String, Vec<StepTrace>)];

fn pairs_obj(pairs: &[(&'static str, u64)]) -> Json {
    let mut o = Json::obj();
    for (name, v) in pairs {
        o.insert(name, Json::Num(*v as f64));
    }
    o
}

/// Serialise traces as JSON lines: one object per (cell, outer step)
/// with nested phase timings, counter deltas, and the report block.
pub fn trace_jsonl(cells: &TraceCells) -> String {
    let mut out = String::new();
    for (label, steps) in cells {
        for t in steps {
            let mut rec = Json::obj();
            rec.insert("cell", Json::Str(label.clone()));
            rec.insert("step", Json::Num(t.step as f64));
            rec.insert("strategy", Json::Str(t.strategy.to_string()));
            rec.insert("start_us", Json::Num(t.start_us as f64));
            rec.insert("dur_us", Json::Num(t.dur_us as f64));
            let mut phases = Json::obj();
            for p in &t.phases {
                let mut po = Json::obj();
                po.insert("count", Json::Num(p.count as f64));
                po.insert("seconds", Json::Num(p.seconds));
                phases.insert(p.phase.name(), po);
            }
            rec.insert("phases", phases);
            rec.insert("counters", pairs_obj(&t.counters));
            rec.insert("report", pairs_obj(&t.report));
            out.push_str(&rec.compact());
            out.push('\n');
        }
    }
    out
}

/// Serialise traces as a Chrome trace-event document.  Each cell maps
/// to one process (named via an "M" metadata event); outer steps and
/// phase spans become "X" complete events on that process's timeline.
pub fn chrome_trace(cells: &TraceCells) -> Json {
    let mut events = Vec::new();
    for (i, (label, steps)) in cells.iter().enumerate() {
        let pid = (i + 1) as f64;
        let mut meta = Json::obj();
        meta.insert("name", Json::Str("process_name".to_string()));
        meta.insert("ph", Json::Str("M".to_string()));
        meta.insert("pid", Json::Num(pid));
        meta.insert("tid", Json::Num(0.0));
        let mut margs = Json::obj();
        margs.insert("name", Json::Str(label.clone()));
        meta.insert("args", margs);
        events.push(meta);
        for t in steps {
            let mut step_ev = Json::obj();
            step_ev.insert(
                "name",
                Json::Str(format!("step {} ({})", t.step, t.strategy)),
            );
            step_ev.insert("cat", Json::Str("step".to_string()));
            step_ev.insert("ph", Json::Str("X".to_string()));
            step_ev.insert("pid", Json::Num(pid));
            step_ev.insert("tid", Json::Num(0.0));
            step_ev.insert("ts", Json::Num(t.start_us as f64));
            step_ev.insert("dur", Json::Num(t.dur_us.max(1) as f64));
            events.push(step_ev);
            for e in &t.events {
                let mut ev = Json::obj();
                ev.insert("name", Json::Str(e.phase.name().to_string()));
                ev.insert("cat", Json::Str("phase".to_string()));
                ev.insert("ph", Json::Str("X".to_string()));
                ev.insert("pid", Json::Num(pid));
                ev.insert("tid", Json::Num(0.0));
                ev.insert("ts", Json::Num(e.start_us as f64));
                ev.insert("dur", Json::Num(e.dur_us.max(1) as f64));
                events.push(ev);
            }
        }
    }
    let mut doc = Json::obj();
    doc.insert("displayTimeUnit", Json::Str("ms".to_string()));
    doc.insert("traceEvents", Json::Arr(events));
    doc
}

/// Write `cells` to `path` in the chosen format.
pub fn write_trace(
    path: &str,
    format: TraceFormat,
    cells: &TraceCells,
) -> std::io::Result<()> {
    let body = match format {
        TraceFormat::Jsonl => trace_jsonl(cells),
        TraceFormat::Chrome => chrome_trace(cells).pretty() + "\n",
    };
    std::fs::write(path, body)
}

/// Print the per-cell phase breakdown table (the CLI summary sink).
pub fn print_trace_summary(cells: &TraceCells) {
    let mut table = Table::new(&[
        "cell", "strategy", "steps", "phase", "spans", "time", "share",
    ])
    .numeric_cols(&[2, 4, 5, 6]);
    for (label, steps) in cells {
        if steps.is_empty() {
            continue;
        }
        let strategy = steps[0].strategy;
        let total: f64 = steps.iter().map(|s| s.total_seconds()).sum();
        for phase in Phase::ALL {
            let mut count = 0u64;
            let mut seconds = 0.0f64;
            for s in steps {
                if let Some(p) = s.phase(phase) {
                    count += p.count;
                    seconds += p.seconds;
                }
            }
            if count == 0 {
                continue;
            }
            let share = if total > 0.0 { 100.0 * seconds / total } else { 0.0 };
            table.row(vec![
                label.clone(),
                strategy.to_string(),
                steps.len().to_string(),
                phase.name().to_string(),
                count.to_string(),
                human_secs(seconds),
                format!("{share:.1}%"),
            ]);
        }
        table.row(vec![
            label.clone(),
            strategy.to_string(),
            steps.len().to_string(),
            "(step total)".to_string(),
            steps.len().to_string(),
            human_secs(total),
            "100.0%".to_string(),
        ]);
    }
    println!("\n== trace summary ==");
    println!("{}", table.render());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut t = Telemetry::new();
        assert!(!t.enabled());
        t.step_begin(0, "naive");
        t.phase_begin(Phase::Forward);
        t.count(Counter::TapeNodes, 5);
        t.phase_end(Phase::Forward);
        t.step_end(&[("nodes", 5)]);
        assert!(t.steps().is_empty());
        assert_eq!(t.registry().counter(Counter::TapeNodes), 0);
    }

    #[test]
    fn spans_nest_and_aggregate_per_step() {
        let mut t = Telemetry::new();
        t.set_enabled(true);
        t.step_begin(7, "mixflow");
        t.phase_begin(Phase::Forward);
        t.phase_end(Phase::Forward);
        t.phase_begin(Phase::BackwardVjp);
        t.phase_begin(Phase::Jvp); // nested
        t.phase_end(Phase::Jvp);
        t.phase_end(Phase::BackwardVjp);
        t.phase_begin(Phase::Forward);
        t.phase_end(Phase::Forward);
        t.count(Counter::TapeNodes, 3);
        t.step_end(&[("nodes", 3)]);

        let steps = t.steps();
        assert_eq!(steps.len(), 1);
        let s = &steps[0];
        assert_eq!(s.step, 7);
        assert_eq!(s.strategy, "mixflow");
        assert_eq!(s.phase(Phase::Forward).map(|p| p.count), Some(2));
        assert_eq!(s.phase(Phase::BackwardVjp).map(|p| p.count), Some(1));
        assert_eq!(s.phase(Phase::Jvp).map(|p| p.count), Some(1));
        assert!(s.phase(Phase::RematRebuild).is_none());
        assert_eq!(s.events.len(), 4);
        assert_eq!(s.counter("tape.nodes"), Some(3));
        assert_eq!(s.counter("remat.rebuilds"), Some(0));
        assert_eq!(s.report_counter("nodes"), Some(3));
        // Registry histogram saw every span.
        assert_eq!(
            t.registry().histogram("forward").map(|h| h.count),
            Some(2)
        );
        // A second step's counter delta starts from zero.
        t.step_begin(8, "mixflow");
        t.step_end(&[]);
        assert_eq!(t.steps()[1].counter("tape.nodes"), Some(0));
        let drained = t.take_steps();
        assert_eq!(drained.len(), 2);
        assert!(t.steps().is_empty());
    }

    #[test]
    fn orphan_spans_open_an_anonymous_step() {
        let mut t = Telemetry::new();
        t.set_enabled(true);
        t.phase_begin(Phase::Forward);
        t.phase_end(Phase::Forward);
        t.step_end(&[]);
        assert_eq!(t.steps().len(), 1);
        assert_eq!(t.steps()[0].strategy, "(direct)");
    }

    #[test]
    fn jsonl_sink_emits_one_parseable_line_per_step() {
        let mut t = Telemetry::new();
        t.set_enabled(true);
        for i in 0..2 {
            t.step_begin(i, "naive");
            t.phase_begin(Phase::Forward);
            t.phase_end(Phase::Forward);
            t.step_end(&[("arena_allocs", 4)]);
        }
        let cells = vec![("cellA".to_string(), t.take_steps())];
        let text = trace_jsonl(&cells);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let rec = Json::parse(line).expect("jsonl line parses");
            assert_eq!(rec.get("cell").and_then(Json::as_str), Some("cellA"));
            assert_eq!(
                rec.get("step").and_then(Json::as_u64),
                Some(i as u64)
            );
            assert!(rec
                .get("phases")
                .and_then(|p| p.get("forward"))
                .and_then(|f| f.get("count"))
                .and_then(Json::as_u64)
                .is_some());
            assert!(rec
                .get("counters")
                .and_then(|c| c.get("tape.nodes"))
                .is_some());
            assert_eq!(
                rec.get("report")
                    .and_then(|r| r.get("arena_allocs"))
                    .and_then(Json::as_u64),
                Some(4)
            );
        }
    }

    #[test]
    fn chrome_sink_emits_metadata_and_complete_events() {
        let mut t = Telemetry::new();
        t.set_enabled(true);
        t.step_begin(0, "mixflow");
        t.phase_begin(Phase::Forward);
        t.phase_end(Phase::Forward);
        t.step_end(&[]);
        let cells = vec![("cellA".to_string(), t.take_steps())];
        let doc = chrome_trace(&cells);
        let text = doc.pretty();
        let parsed = Json::parse(&text).expect("chrome trace parses");
        let events = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        // Metadata + step + 1 phase span.
        assert_eq!(events.len(), 3);
        assert_eq!(
            events[0].get("ph").and_then(Json::as_str),
            Some("M")
        );
        assert_eq!(
            events[0].path(&["args", "name"]).and_then(Json::as_str),
            Some("cellA")
        );
        for ev in &events[1..] {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
            assert!(ev.get("ts").and_then(Json::as_u64).is_some());
            assert!(ev.get("dur").and_then(Json::as_u64).unwrap_or(0) >= 1);
        }
    }

    #[test]
    fn trace_format_cli_enum_contract() {
        for v in TraceFormat::variants() {
            let parsed =
                TraceFormat::parse(v).expect("every variant parses");
            assert_eq!(TraceFormat::parse(&parsed.name()), Some(parsed));
        }
        assert_eq!(TraceFormat::valid_values(), "jsonl|chrome");
        assert_eq!(TraceFormat::parse(" JSONL "), Some(TraceFormat::Jsonl));
        assert_eq!(TraceFormat::parse("perfetto"), Some(TraceFormat::Chrome));
        assert_eq!(TraceFormat::parse("xml"), None);
    }
}

//! FLOP / bytes-accessed cost model over HLO modules (DESIGN.md S13).
//!
//! Rough but self-consistent: it exists to (a) rank configurations the way
//! the paper's step-time plots do, and (b) expose redundant-recompute
//! regressions between default and MixFlow artifacts (the §Perf L2 check).

use std::collections::HashMap;

use super::ir::{Computation, Instruction, Module};

/// Borrow a computation with the module's lifetime (no clones, §Perf L3).
fn lookup<'m>(module: &'m Module, name: &str) -> Option<&'m Computation> {
    module.comp_index.get(name).map(|&i| &module.computations[i])
}

/// Cost of a module or computation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Cost {
    pub flops: f64,
    /// Bytes read + written by non-alias ops (I/O pressure proxy).
    pub bytes: f64,
}

impl Cost {
    fn add(&mut self, other: Cost) {
        self.flops += other.flops;
        self.bytes += other.bytes;
    }

    fn scale(self, k: f64) -> Cost {
        Cost { flops: self.flops * k, bytes: self.bytes * k }
    }
}

/// Weight of transcendental elementwise ops relative to an add.
const TRANSCENDENTAL_WEIGHT: f64 = 4.0;

pub struct CostModel<'m> {
    module: &'m Module,
    cache: HashMap<String, Cost>,
}

impl<'m> CostModel<'m> {
    pub fn new(module: &'m Module) -> Self {
        CostModel { module, cache: HashMap::new() }
    }

    /// Cost of the entry computation (while bodies × trip count).
    pub fn run(&mut self) -> Cost {
        let entry = lookup(self.module, &self.module.entry().name)
            .expect("entry exists");
        self.computation_cost(entry)
    }

    fn computation_cost(&mut self, comp: &Computation) -> Cost {
        if let Some(c) = self.cache.get(&comp.name) {
            return *c;
        }
        let mut total = Cost::default();
        for ins in &comp.instructions {
            total.add(self.instruction_cost(comp, ins));
        }
        self.cache.insert(comp.name.clone(), total);
        total
    }

    fn instruction_cost(&mut self, comp: &Computation, ins: &Instruction) -> Cost {
        let out_elems = ins.shape.elements() as f64;
        let out_bytes = ins.shape.bytes() as f64;
        match ins.opcode.as_str() {
            "parameter" | "constant" | "tuple" | "get-tuple-element"
            | "reshape" | "bitcast" | "iota" => Cost::default(),
            "dot" => {
                let k = self.contracted_size(comp, ins);
                Cost {
                    flops: 2.0 * out_elems * k,
                    bytes: self.operand_bytes(comp, ins) + out_bytes,
                }
            }
            "reduce" | "reduce-window" => {
                let in_elems: f64 = ins
                    .operands
                    .first()
                    .and_then(|o| comp.get(o))
                    .map(|i| i.shape.elements() as f64)
                    .unwrap_or(out_elems);
                Cost {
                    flops: in_elems,
                    bytes: self.operand_bytes(comp, ins) + out_bytes,
                }
            }
            "while" => {
                let trips = self.trip_count(ins) as f64;
                let mut c = Cost::default();
                for callee in ins.called_computations() {
                    if let Some(cc) = lookup(self.module, callee) {
                        c.add(self.computation_cost(cc));
                    }
                }
                c.scale(trips)
            }
            "call" | "conditional" | "scatter" | "sort" | "map" => {
                let mut c = Cost {
                    flops: 0.0,
                    bytes: self.operand_bytes(comp, ins) + out_bytes,
                };
                for callee in ins.called_computations() {
                    if let Some(cc) = lookup(self.module, callee) {
                        c.add(self.computation_cost(cc));
                    }
                }
                c
            }
            "exponential" | "log" | "tanh" | "power" | "sqrt" | "rsqrt"
            | "sine" | "cosine" | "logistic" | "atan2" | "cbrt"
            | "exponential-minus-one" | "log-plus-one" | "erf" => Cost {
                flops: out_elems * TRANSCENDENTAL_WEIGHT,
                bytes: self.operand_bytes(comp, ins) + out_bytes,
            },
            // Data movement: bytes only.
            "broadcast" | "transpose" | "slice" | "dynamic-slice"
            | "dynamic-update-slice" | "concatenate" | "pad" | "gather"
            | "reverse" | "copy" => Cost {
                flops: 0.0,
                bytes: self.operand_bytes(comp, ins) + out_bytes,
            },
            // Default: one flop per output element (add/mul/select/...).
            _ => Cost {
                flops: out_elems,
                bytes: self.operand_bytes(comp, ins) + out_bytes,
            },
        }
    }

    fn operand_bytes(&self, comp: &Computation, ins: &Instruction) -> f64 {
        ins.operands
            .iter()
            .filter_map(|o| comp.get(o))
            .map(|i| i.shape.bytes() as f64)
            .sum()
    }

    /// Product of the LHS contracting-dim sizes of a dot.
    fn contracted_size(&self, comp: &Computation, ins: &Instruction) -> f64 {
        let lhs = ins
            .operands
            .first()
            .and_then(|o| comp.get(o))
            .map(|i| i.shape.dims().to_vec())
            .unwrap_or_default();
        let dims = ins
            .int_list_attr("lhs_contracting_dims")
            .unwrap_or_default();
        let mut k = 1f64;
        for d in dims {
            k *= lhs.get(d as usize).copied().unwrap_or(1) as f64;
        }
        k
    }

    /// Heuristic while trip count: the constant the loop counter is
    /// compared against in the condition computation (fallback 1).
    fn trip_count(&self, ins: &Instruction) -> u64 {
        let Some(cond_name) = ins.attrs.get("condition") else {
            return 1;
        };
        let Some(cond) = self.module.computation(cond_name) else {
            return 1;
        };
        for i in &cond.instructions {
            if i.opcode == "constant" && i.shape.dims().is_empty() {
                if let Some(v) = constant_scalar_value(i) {
                    if v > 0.0 && v < 1e9 {
                        return v as u64;
                    }
                }
            }
        }
        1
    }
}

/// Parse `constant(5)`-style scalar payloads from the raw attr-less text.
/// The parser stores no payload, so we re-derive from the name-matched
/// source line when available; here we fall back to the `value` attr some
/// printers emit, else scan the shape-free text in `attrs`.
fn constant_scalar_value(ins: &Instruction) -> Option<f64> {
    // jax prints `x = s32[] constant(8)` — the parser keeps the payload in
    // attrs under the sentinel key "__payload" if present.
    ins.attrs.get("__payload")?.trim().parse().ok()
}

/// Convenience: parse + cost.
pub fn cost_of_text(text: &str) -> Result<Cost, super::parser::ParseError> {
    let module = super::parser::parse_module(text)?;
    Ok(CostModel::new(&module).run())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parser::parse_module;

    #[test]
    fn dot_flops() {
        let src = "HloModule m\n\nENTRY e {\n  a = f32[8,16]{1,0} parameter(0)\n  b = f32[16,4]{1,0} parameter(1)\n  ROOT d = f32[8,4]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = parse_module(src).unwrap();
        let c = CostModel::new(&m).run();
        assert_eq!(c.flops, 2.0 * 8.0 * 4.0 * 16.0);
    }

    #[test]
    fn elementwise_and_transcendental() {
        let src = "HloModule m\n\nENTRY e {\n  a = f32[10]{0} parameter(0)\n  s = f32[10]{0} sine(a)\n  ROOT z = f32[10]{0} add(s, a)\n}\n";
        let m = parse_module(src).unwrap();
        let c = CostModel::new(&m).run();
        assert_eq!(c.flops, 10.0 * TRANSCENDENTAL_WEIGHT + 10.0);
    }

    #[test]
    fn call_includes_callee() {
        let src = "HloModule m\n\nh.1 {\n  p = f32[4]{0} parameter(0)\n  ROOT r = f32[4]{0} add(p, p)\n}\n\nENTRY e {\n  a = f32[4]{0} parameter(0)\n  ROOT k = f32[4]{0} call(a), to_apply=h.1\n}\n";
        let m = parse_module(src).unwrap();
        let c = CostModel::new(&m).run();
        assert!(c.flops >= 4.0);
    }

    #[test]
    fn reduce_counts_input() {
        let src = "HloModule m\n\nadd.1 {\n  x = f32[] parameter(0)\n  y = f32[] parameter(1)\n  ROOT s = f32[] add(x, y)\n}\n\nENTRY e {\n  a = f32[100]{0} parameter(0)\n  z = f32[] constant(0)\n  ROOT r = f32[] reduce(a, z), dimensions={0}, to_apply=add.1\n}\n";
        let m = parse_module(src).unwrap();
        let c = CostModel::new(&m).run();
        assert!(c.flops >= 100.0);
    }

    #[test]
    fn bytes_counted_for_data_movement() {
        let src = "HloModule m\n\nENTRY e {\n  a = f32[10]{0} parameter(0)\n  ROOT t = f32[10,10]{1,0} broadcast(a), dimensions={0}\n}\n";
        let m = parse_module(src).unwrap();
        let c = CostModel::new(&m).run();
        assert_eq!(c.flops, 0.0);
        assert_eq!(c.bytes, 40.0 + 400.0);
    }
}

//! HLO shape/dtype grammar and byte-size model.
//!
//! Grammar (as printed by `HloModule::ToString`):
//! `f32[4,32]{1,0}` — element type, dims, optional layout;
//! `(f32[2]{0}, s32[])` — tuples; `pred[]` — scalars; `token[]`.

use std::fmt;

/// Element types we encounter in jax-lowered modules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    BF16,
    F16,
    F32,
    F64,
    C64,
    C128,
    Token,
}

impl DType {
    pub fn parse(s: &str) -> Option<DType> {
        Some(match s {
            "pred" => DType::Pred,
            "s8" => DType::S8,
            "s16" => DType::S16,
            "s32" => DType::S32,
            "s64" => DType::S64,
            "u8" => DType::U8,
            "u16" => DType::U16,
            "u32" => DType::U32,
            "u64" => DType::U64,
            "bf16" => DType::BF16,
            "f16" => DType::F16,
            "f32" => DType::F32,
            "f64" => DType::F64,
            "c64" => DType::C64,
            "c128" => DType::C128,
            "token" => DType::Token,
            _ => return None,
        })
    }

    /// Bytes per element.
    pub fn size(self) -> u64 {
        match self {
            DType::Pred | DType::S8 | DType::U8 => 1,
            DType::S16 | DType::U16 | DType::BF16 | DType::F16 => 2,
            DType::S32 | DType::U32 | DType::F32 => 4,
            DType::S64 | DType::U64 | DType::F64 | DType::C64 => 8,
            DType::C128 => 16,
            DType::Token => 0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::Pred => "pred",
            DType::S8 => "s8",
            DType::S16 => "s16",
            DType::S32 => "s32",
            DType::S64 => "s64",
            DType::U8 => "u8",
            DType::U16 => "u16",
            DType::U32 => "u32",
            DType::U64 => "u64",
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::C64 => "c64",
            DType::C128 => "c128",
            DType::Token => "token",
        }
    }
}

/// An HLO shape: array or tuple.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Shape {
    Array { dtype: DType, dims: Vec<u64> },
    Tuple(Vec<Shape>),
}

impl Shape {
    pub fn scalar(dtype: DType) -> Shape {
        Shape::Array { dtype, dims: vec![] }
    }

    pub fn array(dtype: DType, dims: &[u64]) -> Shape {
        Shape::Array { dtype, dims: dims.to_vec() }
    }

    /// Number of elements (arrays only; tuples sum their members).
    pub fn elements(&self) -> u64 {
        match self {
            Shape::Array { dims, .. } => dims.iter().product(),
            Shape::Tuple(items) => items.iter().map(Shape::elements).sum(),
        }
    }

    /// Total payload bytes (tuple pointer tables ignored).
    pub fn bytes(&self) -> u64 {
        match self {
            Shape::Array { dtype, dims } => {
                dtype.size() * dims.iter().product::<u64>()
            }
            Shape::Tuple(items) => items.iter().map(Shape::bytes).sum(),
        }
    }

    pub fn is_tuple(&self) -> bool {
        matches!(self, Shape::Tuple(_))
    }

    pub fn tuple_element(&self, idx: usize) -> Option<&Shape> {
        match self {
            Shape::Tuple(items) => items.get(idx),
            _ => None,
        }
    }

    pub fn rank(&self) -> usize {
        match self {
            Shape::Array { dims, .. } => dims.len(),
            Shape::Tuple(_) => 0,
        }
    }

    pub fn dims(&self) -> &[u64] {
        match self {
            Shape::Array { dims, .. } => dims,
            Shape::Tuple(_) => &[],
        }
    }

    pub fn dtype(&self) -> Option<DType> {
        match self {
            Shape::Array { dtype, .. } => Some(*dtype),
            Shape::Tuple(_) => None,
        }
    }

    /// Parse a shape at the start of `s`; returns (shape, rest).
    ///
    /// Accepts optional layout `{...}` suffixes after arrays (ignored) and
    /// nested tuples.
    pub fn parse_prefix(s: &str) -> Option<(Shape, &str)> {
        let s = s.trim_start();
        if let Some(rest) = s.strip_prefix('(') {
            let mut items = Vec::new();
            let mut cur = rest.trim_start();
            if let Some(r) = cur.strip_prefix(')') {
                return Some((Shape::Tuple(items), r));
            }
            loop {
                // Tuple element indices can appear as comments.
                let trimmed = skip_index_comment(cur);
                let (shape, rest) = Shape::parse_prefix(trimmed)?;
                items.push(shape);
                let rest = rest.trim_start();
                if let Some(r) = rest.strip_prefix(',') {
                    cur = r.trim_start();
                } else if let Some(r) = rest.strip_prefix(')') {
                    return Some((Shape::Tuple(items), r));
                } else {
                    return None;
                }
            }
        }
        // Array: dtype ident then optional [dims] then optional {layout}.
        let end = s
            .find(|c: char| !c.is_ascii_alphanumeric())
            .unwrap_or(s.len());
        let dtype = DType::parse(&s[..end])?;
        let mut rest = &s[end..];
        let mut dims = Vec::new();
        if let Some(r) = rest.strip_prefix('[') {
            let close = r.find(']')?;
            let body = &r[..close];
            if !body.trim().is_empty() {
                for d in body.split(',') {
                    dims.push(d.trim().parse().ok()?);
                }
            }
            rest = &r[close + 1..];
        }
        if let Some(r) = rest.strip_prefix('{') {
            let close = r.find('}')?;
            rest = &r[close + 1..];
        }
        Some((Shape::Array { dtype, dims }, rest))
    }

    /// Parse a complete shape string.
    pub fn parse(s: &str) -> Option<Shape> {
        let (shape, rest) = Shape::parse_prefix(s)?;
        rest.trim().is_empty().then_some(shape)
    }
}

/// Skip `/*index=N*/` comments the HLO printer inserts in long tuples.
pub fn skip_index_comment(s: &str) -> &str {
    let t = s.trim_start();
    if let Some(rest) = t.strip_prefix("/*") {
        if let Some(end) = rest.find("*/") {
            return rest[end + 2..].trim_start();
        }
    }
    t
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Shape::Array { dtype, dims } => {
                write!(f, "{}[", dtype.name())?;
                for (i, d) in dims.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{d}")?;
                }
                write!(f, "]")
            }
            Shape::Tuple(items) => {
                write!(f, "(")?;
                for (i, s) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{s}")?;
                }
                write!(f, ")")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_arrays() {
        let s = Shape::parse("f32[4,32]{1,0}").unwrap();
        assert_eq!(s, Shape::array(DType::F32, &[4, 32]));
        assert_eq!(s.bytes(), 4 * 32 * 4);
        assert_eq!(Shape::parse("pred[]").unwrap().bytes(), 1);
        assert_eq!(Shape::parse("s32[]").unwrap().rank(), 0);
    }

    #[test]
    fn parses_tuples_with_comments() {
        let s = Shape::parse(
            "(f32[2]{0}, s32[], /*index=2*/f32[3,3]{1,0})",
        )
        .unwrap();
        assert_eq!(s.bytes(), 8 + 4 + 36);
        assert_eq!(s.tuple_element(2).unwrap().elements(), 9);
    }

    #[test]
    fn parses_nested_tuple() {
        let s = Shape::parse("((f32[2]{0}), (s32[], pred[]))").unwrap();
        assert!(s.is_tuple());
        assert_eq!(s.bytes(), 8 + 4 + 1);
    }

    #[test]
    fn empty_tuple() {
        assert_eq!(Shape::parse("()").unwrap(), Shape::Tuple(vec![]));
    }

    #[test]
    fn bf16_and_u8_sizes() {
        assert_eq!(Shape::parse("bf16[10]").unwrap().bytes(), 20);
        assert_eq!(Shape::parse("u8[10]{0}").unwrap().bytes(), 10);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Shape::parse("q99[3]").is_none());
        assert!(Shape::parse("f32[3").is_none());
        assert!(Shape::parse("f32[3] extra").is_none());
    }

    #[test]
    fn display_roundtrip() {
        for s in ["f32[4,32]", "(f32[2], s32[])", "pred[]"] {
            let shape = Shape::parse(s).unwrap();
            assert_eq!(Shape::parse(&shape.to_string()).unwrap(), shape);
        }
    }
}

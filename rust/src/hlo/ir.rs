//! HLO IR data structures produced by the parser.

use std::collections::HashMap;

use super::shape::Shape;

/// One HLO instruction.
#[derive(Debug, Clone)]
pub struct Instruction {
    pub name: String,
    pub shape: Shape,
    pub opcode: String,
    /// Operand instruction names (within the same computation).
    pub operands: Vec<String>,
    /// Raw attribute text: `key` → value (braces kept verbatim).
    pub attrs: HashMap<String, String>,
    pub is_root: bool,
    /// Line number in the source text (for timelines/diagnostics).
    pub line: usize,
}

impl Instruction {
    /// Names of computations this instruction calls (`to_apply`,
    /// `body`/`condition`, `branch_computations`).
    pub fn called_computations(&self) -> Vec<&str> {
        let mut out = Vec::new();
        for key in ["to_apply", "body", "condition"] {
            if let Some(v) = self.attrs.get(key) {
                out.push(v.as_str());
            }
        }
        if let Some(v) = self.attrs.get("branch_computations") {
            // `{comp_a, comp_b}`
            for name in v.trim_matches(['{', '}']).split(',') {
                let name = name.trim();
                if !name.is_empty() {
                    out.push(name);
                }
            }
        }
        // reduce/scatter/sort carry their combinator in to_apply (already
        // covered); `calls=` appears in some fusion prints.
        out
    }

    /// `index=N` attribute (get-tuple-element) if present.
    pub fn tuple_index(&self) -> Option<usize> {
        self.attrs.get("index")?.parse().ok()
    }

    /// Parameter ordinal for `parameter(N)` instructions.
    pub fn parameter_number(&self) -> Option<usize> {
        if self.opcode != "parameter" {
            return None;
        }
        self.operands.first()?.parse().ok()
    }

    /// Parse a `{a,b,c}` int-list attribute.
    pub fn int_list_attr(&self, key: &str) -> Option<Vec<u64>> {
        let v = self.attrs.get(key)?;
        let body = v.trim().trim_matches(['{', '}']);
        if body.trim().is_empty() {
            return Some(vec![]);
        }
        body.split(',').map(|s| s.trim().parse().ok()).collect()
    }
}

/// One computation (function) in the module.
#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub is_entry: bool,
    /// Program order (HLO text is topologically sorted).
    pub instructions: Vec<Instruction>,
    /// Name → index into `instructions`.
    pub index: HashMap<String, usize>,
}

impl Computation {
    pub fn get(&self, name: &str) -> Option<&Instruction> {
        self.index.get(name).map(|&i| &self.instructions[i])
    }

    pub fn root(&self) -> Option<&Instruction> {
        self.instructions
            .iter()
            .find(|i| i.is_root)
            .or_else(|| self.instructions.last())
    }

    /// Parameters sorted by ordinal.
    pub fn parameters(&self) -> Vec<&Instruction> {
        let mut params: Vec<&Instruction> = self
            .instructions
            .iter()
            .filter(|i| i.opcode == "parameter")
            .collect();
        params.sort_by_key(|i| i.parameter_number().unwrap_or(usize::MAX));
        params
    }
}

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub computations: Vec<Computation>,
    pub comp_index: HashMap<String, usize>,
}

impl Module {
    pub fn entry(&self) -> &Computation {
        self.computations
            .iter()
            .find(|c| c.is_entry)
            .unwrap_or_else(|| self.computations.last().expect("empty module"))
    }

    pub fn computation(&self, name: &str) -> Option<&Computation> {
        self.comp_index.get(name).map(|&i| &self.computations[i])
    }

    /// Total instruction count across all computations.
    pub fn instruction_count(&self) -> usize {
        self.computations.iter().map(|c| c.instructions.len()).sum()
    }

    /// Count of instructions per opcode (Fig.-9-style graph census).
    pub fn opcode_census(&self) -> HashMap<String, usize> {
        let mut census = HashMap::new();
        for c in &self.computations {
            for i in &c.instructions {
                *census.entry(i.opcode.clone()).or_insert(0) += 1;
            }
        }
        census
    }
}

//! Buffer-liveness memory simulator over HLO program order (DESIGN.md S11–S12).
//!
//! Model (documented approximations, each mirroring what XLA's allocator
//! does to the corresponding op):
//!
//! * every non-alias instruction allocates `shape.bytes()` at its program
//!   point and frees it after its last use (the ROOT survives to the end);
//! * **alias ops** allocate nothing and forward liveness to their inputs:
//!   `tuple`, `get-tuple-element`, `reshape`, `bitcast`, `copy-done`,
//!   `dynamic-update-slice` (in-place, as in XLA while-loop stacks),
//!   `while` (loops run in place on their carry), and non-entry
//!   `parameter`s (they alias the caller's operands);
//! * `call`/`while`/`conditional` add the callee's *dynamic peak* on top of
//!   the live set while they execute (loops re-use one iteration's worth);
//! * **static** memory = entry parameters + constants + the entry root's
//!   output + **loop state**: entry-level buffers threaded through a
//!   `while` carry (jax's scan checkpoints — the stacked per-inner-step
//!   θ/υ/∇L residuals).  This is exactly the paper's "inputs, parameters,
//!   states, checkpoints" class (§4): allocated once, written once,
//!   resident for the whole outer step.  Everything else is **dynamic** —
//!   the activations MixFlow-MG attacks.
//!
//! Because the modules come straight from `jax.lower` (no XLA memory
//! optimisation), the simulated dynamic peak measures the *structural*
//! requirement of the program — the quantity Eq. (12) models.

use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use super::ir::{Computation, Instruction, Module};

/// Borrow a computation with the *module's* lifetime (not the simulator
/// borrow), so recursive analysis needs no clones (§Perf L3).
fn lookup<'m>(module: &'m Module, name: &str) -> Option<&'m Computation> {
    module.comp_index.get(name).map(|&i| &module.computations[i])
}

/// Ops that allocate no new buffer (see module docs).
fn is_alias_op(op: &str) -> bool {
    matches!(
        op,
        "tuple"
            | "get-tuple-element"
            | "reshape"
            | "bitcast"
            | "copy-done"
            | "copy-start"
            | "dynamic-update-slice"
            | "while"
            | "optimization-barrier"
    )
}

fn is_call_op(op: &str) -> bool {
    matches!(op, "call" | "while" | "conditional")
}

/// Per-computation analysis (memoised).
#[derive(Debug, Clone, Default)]
struct CompReport {
    /// Peak dynamic bytes while this computation runs (callees included).
    dyn_peak: u64,
    /// Constants allocated inside (counted as static at entry level only).
    const_bytes: u64,
    /// Entry-level while-carry buffers (checkpoint stacks) — static class.
    state_bytes: u64,
    /// (source line, dynamic bytes) samples across the flattened schedule.
    timeline: Vec<(usize, u64)>,
}

/// Result of simulating a module.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    /// Entry parameter bytes (inputs, θ, υ, η — static).
    pub param_bytes: u64,
    /// Constant payload bytes across reachable computations (static).
    pub const_bytes: u64,
    /// Entry root output bytes.
    pub output_bytes: u64,
    /// Loop-state bytes: scan-carry checkpoint stacks (static, §4).
    pub state_bytes: u64,
    /// Peak dynamic (activation) bytes — the paper's target quantity.
    pub peak_dynamic: u64,
    /// Static + peak dynamic.
    pub peak_total: u64,
    /// (source line, dynamic bytes) across the flattened schedule —
    /// regenerates the paper's Figure 2.
    pub timeline: Vec<(usize, u64)>,
    /// Total instructions analysed (flattened, calls included once).
    pub instructions: usize,
}

impl MemoryReport {
    pub fn static_bytes(&self) -> u64 {
        self.param_bytes + self.const_bytes + self.output_bytes
            + self.state_bytes
    }
}

/// The simulator (holds the memoisation cache).
pub struct MemorySimulator<'m> {
    module: &'m Module,
    cache: HashMap<String, Rc<CompReport>>,
    /// Cap on timeline samples (big modules produce 100k+ points).
    pub max_timeline_points: usize,
}

impl<'m> MemorySimulator<'m> {
    pub fn new(module: &'m Module) -> Self {
        MemorySimulator {
            module,
            cache: HashMap::new(),
            max_timeline_points: 200_000,
        }
    }

    /// Skip timeline collection (sweep analyses don't need it — §Perf L3).
    pub fn without_timeline(module: &'m Module) -> Self {
        let mut s = Self::new(module);
        s.max_timeline_points = 0;
        s
    }

    /// Simulate the entry computation.
    pub fn run(&mut self) -> MemoryReport {
        let entry = self.module.entry();
        let report = self.analyze(entry, true);
        // Entry reports are not cached, so this unwrap never clones.
        let report = Rc::try_unwrap(report).unwrap_or_else(|rc| (*rc).clone());

        let param_bytes: u64 =
            entry.parameters().iter().map(|p| p.shape.bytes()).sum();
        let output_bytes = entry
            .root()
            .map(|r| r.shape.bytes())
            .unwrap_or(0);
        // Constants across all reachable computations.
        let mut const_bytes = report.const_bytes;
        let mut seen = HashSet::new();
        self.collect_consts(entry, &mut seen, &mut const_bytes);
        // `analyze` already counted entry-level constants; avoid double
        // counting by taking the recursive sweep as the single source.
        const_bytes -= report.const_bytes;

        let static_bytes =
            param_bytes + const_bytes + output_bytes + report.state_bytes;
        MemoryReport {
            param_bytes,
            const_bytes,
            output_bytes,
            state_bytes: report.state_bytes,
            peak_dynamic: report.dyn_peak,
            peak_total: static_bytes + report.dyn_peak,
            timeline: report.timeline,
            instructions: self.module.instruction_count(),
        }
    }

    fn collect_consts(
        &self,
        comp: &Computation,
        seen: &mut HashSet<String>,
        total: &mut u64,
    ) {
        if !seen.insert(comp.name.clone()) {
            return;
        }
        for ins in &comp.instructions {
            if ins.opcode == "constant" {
                *total += ins.shape.bytes();
            }
            for callee in ins.called_computations() {
                if let Some(c) = self.module.computation(callee) {
                    self.collect_consts(c, seen, total);
                }
            }
        }
    }

    /// Analyse one computation; memoised for non-entry computations.
    fn analyze(&mut self, comp: &Computation, is_entry: bool) -> Rc<CompReport> {
        if let Some(cached) = self.cache.get(&comp.name) {
            return Rc::clone(cached);
        }

        // Resolve alias chains: buffer "sources" of each instruction.
        // sources[name] = set of allocating instruction names this value
        // may point into.
        let mut sources: HashMap<&str, Vec<&str>> = HashMap::new();
        for ins in &comp.instructions {
            if is_alias_op(&ins.opcode)
                || (ins.opcode == "parameter" && !is_entry)
            {
                let mut src = Vec::new();
                for op in &ins.operands {
                    match sources.get(op.as_str()) {
                        Some(s) => src.extend(s.iter().copied()),
                        None => src.push(op.as_str()),
                    }
                }
                src.sort_unstable();
                src.dedup();
                sources.insert(&ins.name, src);
            }
        }
        let resolve = |name: &str| -> Vec<&str> {
            match sources.get(name) {
                Some(s) => s.clone(),
                None => vec![],
            }
        };

        // Entry-level while-carry roots: scan checkpoint stacks and loop
        // counters — the paper's static "checkpoints/states" class.
        let mut state_roots: HashSet<&str> = HashSet::new();
        if is_entry {
            for ins in &comp.instructions {
                if ins.opcode == "while" {
                    for op in &ins.operands {
                        match sources.get(op.as_str()) {
                            Some(rs) => state_roots.extend(rs.iter().copied()),
                            None => {
                                state_roots.insert(op.as_str());
                            }
                        }
                    }
                }
            }
        }

        // Last use (by flat index) of each allocating buffer.
        let mut last_use: HashMap<&str, usize> = HashMap::new();
        for (idx, ins) in comp.instructions.iter().enumerate() {
            for op in &ins.operands {
                let roots = sources.get(op.as_str());
                match roots {
                    Some(rs) => {
                        for r in rs {
                            last_use.insert(r, idx);
                        }
                    }
                    None => {
                        last_use.insert(op.as_str(), idx);
                    }
                }
            }
        }
        // The root's buffers survive the computation.
        let end = comp.instructions.len();
        if let Some(root) = comp.root() {
            let root_roots = if sources.contains_key(root.name.as_str()) {
                resolve(&root.name)
            } else {
                vec![root.name.as_str()]
            };
            for r in root_roots {
                last_use.insert(r, end);
            }
        }

        // Walk in program order.
        let mut live: u64 = 0;
        let mut peak: u64 = 0;
        let mut const_bytes: u64 = 0;
        let mut frees: HashMap<usize, Vec<u64>> = HashMap::new();
        let mut timeline: Vec<(usize, u64)> = Vec::new();

        let mut state_bytes: u64 = 0;
        for (idx, ins) in comp.instructions.iter().enumerate() {
            let allocates = self.allocates(ins, is_entry);
            if ins.opcode == "constant" {
                const_bytes += ins.shape.bytes();
            }
            if allocates > 0 && state_roots.contains(ins.name.as_str()) {
                // Checkpoint stacks: resident for the whole program,
                // accounted on the static side (paper §4).
                state_bytes += allocates;
            } else if allocates > 0 {
                live += allocates;
                let lu = last_use.get(ins.name.as_str()).copied().unwrap_or(idx);
                frees.entry(lu).or_default().push(allocates);
            }

            // Callee dynamic peak rides on top while the call runs.
            let mut callee_peak = 0u64;
            for callee in ins.called_computations() {
                if let Some(c) = lookup(self.module, callee) {
                    let r = self.analyze(c, false);
                    callee_peak = callee_peak.max(r.dyn_peak);
                    const_bytes += r.const_bytes;
                    if is_call_op(&ins.opcode)
                        && timeline.len() < self.max_timeline_points
                    {
                        for (l, b) in &r.timeline {
                            timeline.push((*l, live + b));
                        }
                    }
                }
            }
            peak = peak.max(live + callee_peak);
            if timeline.len() < self.max_timeline_points {
                timeline.push((ins.line, live));
            }

            // Free buffers whose last use was this instruction.
            if let Some(fs) = frees.remove(&idx) {
                for b in fs {
                    live = live.saturating_sub(b);
                }
            }
        }

        let report = Rc::new(CompReport {
            dyn_peak: peak,
            const_bytes,
            state_bytes,
            timeline,
        });
        if !is_entry {
            self.cache.insert(comp.name.clone(), Rc::clone(&report));
        }
        report
    }

    /// Bytes a (non-alias) instruction allocates.
    fn allocates(&self, ins: &Instruction, _is_entry: bool) -> u64 {
        if is_alias_op(&ins.opcode) || ins.opcode == "constant" {
            return 0; // constants are counted as static, not dynamic
        }
        if ins.opcode == "parameter" {
            // Entry params are static; callee params alias caller buffers.
            return 0;
        }
        ins.shape.bytes()
    }
}

/// Convenience: parse + simulate.
pub fn analyze_text(text: &str) -> Result<MemoryReport, super::parser::ParseError> {
    let module = super::parser::parse_module(text)?;
    let mut sim = MemorySimulator::new(&module);
    Ok(sim.run())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parser::parse_module;

    fn report(src: &str) -> MemoryReport {
        let m = parse_module(src).unwrap();
        MemorySimulator::new(&m).run()
    }

    #[test]
    fn simple_chain_frees_dead_buffers() {
        // a(16B) -> b(16B) -> c(16B); a dies after b, b after c.
        let r = report(
            "HloModule m\n\nENTRY e {\n  p = f32[4]{0} parameter(0)\n  a = f32[4]{0} negate(p)\n  b = f32[4]{0} negate(a)\n  ROOT c = f32[4]{0} negate(b)\n}\n",
        );
        // At any point at most two intermediates are live (producer+consumer).
        assert_eq!(r.peak_dynamic, 32);
        assert_eq!(r.param_bytes, 16);
        assert_eq!(r.output_bytes, 16);
    }

    #[test]
    fn fanout_keeps_buffer_alive() {
        // a used by both b and the root sum: a must stay live through both.
        let r = report(
            "HloModule m\n\nENTRY e {\n  p = f32[4]{0} parameter(0)\n  a = f32[4]{0} negate(p)\n  b = f32[4]{0} negate(a)\n  c = f32[4]{0} negate(b)\n  ROOT d = f32[4]{0} add(a, c)\n}\n",
        );
        // live at c: a + b + c = 48
        assert_eq!(r.peak_dynamic, 48);
    }

    #[test]
    fn tuple_and_gte_are_aliases() {
        let r = report(
            "HloModule m\n\nENTRY e {\n  p = f32[4]{0} parameter(0)\n  a = f32[4]{0} negate(p)\n  t = (f32[4]{0}, f32[4]{0}) tuple(a, a)\n  g = f32[4]{0} get-tuple-element(t), index=0\n  ROOT b = f32[4]{0} negate(g)\n}\n",
        );
        // tuple/gte add nothing: a (16) + b (16).
        assert_eq!(r.peak_dynamic, 32);
    }

    #[test]
    fn constants_are_static() {
        let r = report(
            "HloModule m\n\nENTRY e {\n  c = f32[8]{0} constant({0,0,0,0,0,0,0,0})\n  ROOT n = f32[8]{0} negate(c)\n}\n",
        );
        assert_eq!(r.const_bytes, 32);
        assert_eq!(r.peak_dynamic, 32); // just the negate output
    }

    #[test]
    fn callee_peak_rides_on_live_set() {
        let src = "HloModule m\n\nbig.1 {\n  bp = f32[4]{0} parameter(0)\n  t1 = f32[100]{0} broadcast(bp), dimensions={}\n  r1 = f32[] reduce-sum-placeholder(t1)\n  ROOT bo = f32[4]{0} broadcast(r1), dimensions={}\n}\n\nENTRY e {\n  p = f32[4]{0} parameter(0)\n  a = f32[4]{0} negate(p)\n  k = f32[4]{0} call(a), to_apply=big.1\n  ROOT z = f32[4]{0} add(a, k)\n}\n";
        let r = report(src);
        // callee peak = 400 (t1) + 4 (r1) + 16 (bo)... t1 dies after r1:
        // walk: t1 live 400 → r1 +4 then free t1 → bo +16 ⇒ peak 404.
        // entry: a(16) live + callee 404 + k(16 alloc before? k allocs 16
        // at its own step) → peak = 16 + 16 + 404 = 436.
        assert_eq!(r.peak_dynamic, 436);
    }

    #[test]
    fn while_output_aliases_carry() {
        let src = "HloModule m\n\ncond.1 {\n  cp = (s32[], f32[64]{0}) parameter(0)\n  i = s32[] get-tuple-element(cp), index=0\n  lim = s32[] constant(3)\n  ROOT lt = pred[] compare(i, lim), direction=LT\n}\n\nbody.1 {\n  bp = (s32[], f32[64]{0}) parameter(0)\n  i = s32[] get-tuple-element(bp), index=0\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  x = f32[64]{0} get-tuple-element(bp), index=1\n  x2 = f32[64]{0} negate(x)\n  ROOT t = (s32[], f32[64]{0}) tuple(i2, x2)\n}\n\nENTRY e {\n  z = s32[] constant(0)\n  p = f32[64]{0} parameter(0)\n  init = (s32[], f32[64]{0}) tuple(z, p)\n  w = (s32[], f32[64]{0}) while(init), condition=cond.1, body=body.1\n  ROOT out = f32[64]{0} get-tuple-element(w), index=1\n}\n";
        let r = report(src);
        // body dyn peak: i2(4) + x2(256) = 260; while aliases its carry.
        assert_eq!(r.peak_dynamic, 260);
        assert_eq!(r.param_bytes, 256);
    }

    #[test]
    fn timeline_covers_schedule() {
        let r = report(
            "HloModule m\n\nENTRY e {\n  p = f32[4]{0} parameter(0)\n  a = f32[4]{0} negate(p)\n  ROOT b = f32[4]{0} negate(a)\n}\n",
        );
        assert_eq!(r.timeline.len(), 3);
        let max = r.timeline.iter().map(|(_, b)| *b).max().unwrap();
        assert!(max <= r.peak_dynamic);
    }

    #[test]
    fn static_bytes_sums_parts() {
        let r = report(
            "HloModule m\n\nENTRY e {\n  p = f32[4]{0} parameter(0)\n  ROOT a = f32[4]{0} negate(p)\n}\n",
        );
        assert_eq!(r.static_bytes(), r.param_bytes + r.const_bytes + r.output_bytes);
    }
}

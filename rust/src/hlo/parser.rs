//! HLO text parser: `HloModule::ToString()` output → [`ir::Module`].
//!
//! The format is line-oriented and topologically sorted:
//!
//! ```text
//! HloModule jit_fn, entry_computation_layout={...}
//!
//! comp_name {
//!   a = f32[4]{0} parameter(0)
//!   ROOT b = f32[4]{0} add(a, a), metadata={...}
//! }
//!
//! ENTRY main.42 {
//!   ...
//! }
//! ```
//!
//! We parse names, shapes, opcodes, operand lists and attributes; constant
//! literal payloads are kept as raw text (their *shape* carries the bytes
//! the memory model needs).

use std::collections::HashMap;

use super::ir::{Computation, Instruction, Module};
use super::shape::Shape;

/// Parse error with line information.
#[derive(Debug, thiserror::Error)]
#[error("hlo parse error at line {line}: {msg}")]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

/// Parse a full HLO module from text.
pub fn parse_module(text: &str) -> Result<Module, ParseError> {
    let mut module_name = String::from("unknown");
    let mut computations = Vec::new();
    let mut current: Option<(String, bool, Vec<Instruction>)> = None;

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule ") {
            module_name = rest
                .split([',', ' '])
                .next()
                .unwrap_or("unknown")
                .to_string();
            continue;
        }
        if current.is_none() {
            // Expect a computation header: `[ENTRY ]name [(...)] ... {`
            if let Some(header) = line.strip_suffix('{') {
                let header = header.trim();
                let (is_entry, name_part) = match header.strip_prefix("ENTRY ")
                {
                    Some(rest) => (true, rest.trim()),
                    None => (false, header),
                };
                // Name ends at whitespace or '(' (param list prints for
                // some versions).
                let name = name_part
                    .split(|c: char| c.is_whitespace() || c == '(')
                    .next()
                    .unwrap_or(name_part)
                    .trim_start_matches('%')
                    .to_string();
                if name.is_empty() {
                    return Err(err(lineno + 1, "empty computation name"));
                }
                current = Some((name, is_entry, Vec::new()));
                continue;
            }
            return Err(err(
                lineno + 1,
                format!("expected computation header, got: {line}"),
            ));
        }
        if line == "}" {
            let (name, is_entry, instructions) = current.take().unwrap();
            let index = instructions
                .iter()
                .enumerate()
                .map(|(i, ins)| (ins.name.clone(), i))
                .collect();
            computations.push(Computation {
                name,
                is_entry,
                instructions,
                index,
            });
            continue;
        }
        let (_, _, instructions) = current.as_mut().unwrap();
        instructions.push(parse_instruction(line, lineno + 1)?);
    }
    if current.is_some() {
        return Err(err(usize::MAX, "unterminated computation"));
    }
    if computations.is_empty() {
        return Err(err(0, "no computations found"));
    }
    let comp_index = computations
        .iter()
        .enumerate()
        .map(|(i, c)| (c.name.clone(), i))
        .collect();
    Ok(Module { name: module_name, computations, comp_index })
}

/// Parse one instruction line.
fn parse_instruction(line: &str, lineno: usize) -> Result<Instruction, ParseError> {
    let (is_root, rest) = match line.strip_prefix("ROOT ") {
        Some(r) => (true, r),
        None => (false, line),
    };
    let eq = rest
        .find(" = ")
        .ok_or_else(|| err(lineno, format!("no ' = ' in: {line}")))?;
    let name = rest[..eq].trim().trim_start_matches('%').to_string();
    let after = &rest[eq + 3..];

    let (shape, after_shape) = Shape::parse_prefix(after)
        .ok_or_else(|| err(lineno, format!("bad shape in: {after}")))?;
    let after_shape = after_shape.trim_start();

    // Opcode up to '('.
    let paren = after_shape
        .find('(')
        .ok_or_else(|| err(lineno, format!("no '(' in: {after_shape}")))?;
    let opcode = after_shape[..paren].trim().to_string();
    if opcode.is_empty() || !opcode.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_') {
        return Err(err(lineno, format!("bad opcode: {opcode:?}")));
    }

    // Operand list: balanced-parenthesis scan from `paren`.
    let body_start = paren + 1;
    let mut depth = 1usize;
    let mut in_string = false;
    let bytes = after_shape.as_bytes();
    let mut i = body_start;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if in_string {
            if c == '"' {
                in_string = false;
            }
        } else {
            match c {
                '"' => in_string = true,
                '(' | '{' | '[' => depth += 1,
                ')' | '}' | ']' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
        }
        i += 1;
    }
    if depth != 0 {
        return Err(err(lineno, "unbalanced parens in operand list"));
    }
    let operand_text = &after_shape[body_start..i];
    let attr_text = after_shape[i + 1..].trim_start_matches(',').trim();

    // Constants keep their payload raw (stashed under "__payload" so the
    // cost model can read scalar loop bounds); everything else splits
    // operands at top-level commas.
    let mut payload: Option<String> = None;
    let operands = if opcode == "constant" {
        payload = Some(operand_text.trim().to_string());
        Vec::new()
    } else {
        split_top_level(operand_text)
            .into_iter()
            .map(|s| {
                super::shape::skip_index_comment(s.trim())
                    .trim_start_matches('%')
                    .to_string()
            })
            .filter(|s| !s.is_empty())
            .collect()
    };

    let mut attrs = parse_attrs(attr_text);
    if let Some(p) = payload {
        attrs.insert("__payload".to_string(), p);
    }
    Ok(Instruction {
        name,
        shape,
        opcode,
        operands,
        attrs,
        is_root,
        line: lineno,
    })
}

/// Split on commas not nested inside (), {}, [] or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_string = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        if in_string {
            if c == '"' {
                in_string = false;
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out.into_iter().filter(|p| !p.trim().is_empty()).collect()
}

/// Parse `key=value, key={...}, key="..."` attribute lists.
fn parse_attrs(s: &str) -> HashMap<String, String> {
    let mut attrs = HashMap::new();
    for part in split_top_level(s) {
        let part = part.trim();
        if let Some((k, v)) = part.split_once('=') {
            attrs.insert(
                k.trim().to_string(),
                v.trim().trim_start_matches('%').to_string(),
            );
        }
    }
    attrs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::shape::DType;

    const SAMPLE: &str = r#"HloModule jit_f, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

helper.1 {
  p = f32[4]{0} parameter(0)
  c = f32[] constant(2)
  b = f32[4]{0} broadcast(c), dimensions={}
  ROOT m = f32[4]{0} multiply(p, b)
}

ENTRY main.5 {
  x = f32[4]{0} parameter(0)
  call.1 = f32[4]{0} call(x), to_apply=helper.1
  t = (f32[4]{0}, f32[4]{0}) tuple(call.1, x)
  g = f32[4]{0} get-tuple-element(t), index=0
  ROOT out = f32[4]{0} add(g, x)
}
"#;

    #[test]
    fn parses_sample_module() {
        let m = parse_module(SAMPLE).unwrap();
        assert_eq!(m.name, "jit_f");
        assert_eq!(m.computations.len(), 2);
        assert_eq!(m.entry().name, "main.5");
        assert_eq!(m.instruction_count(), 9);
    }

    #[test]
    fn instruction_details() {
        let m = parse_module(SAMPLE).unwrap();
        let e = m.entry();
        let call = e.get("call.1").unwrap();
        assert_eq!(call.opcode, "call");
        assert_eq!(call.operands, ["x"]);
        assert_eq!(call.called_computations(), ["helper.1"]);
        let g = e.get("g").unwrap();
        assert_eq!(g.tuple_index(), Some(0));
        assert!(e.root().unwrap().name == "out");
    }

    #[test]
    fn parameter_numbers_and_shapes() {
        let m = parse_module(SAMPLE).unwrap();
        let h = m.computation("helper.1").unwrap();
        let p = h.parameters();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].parameter_number(), Some(0));
        assert_eq!(p[0].shape.dtype(), Some(DType::F32));
    }

    #[test]
    fn constant_payload_not_operands() {
        let line = "c.1 = f32[2,2]{1,0} constant({ { 1, 2 }, { 3, 4 } })";
        let i = parse_instruction(line, 1).unwrap();
        assert_eq!(i.opcode, "constant");
        assert!(i.operands.is_empty());
        assert_eq!(i.shape.bytes(), 16);
    }

    #[test]
    fn attrs_with_braces() {
        let line = "d = f32[2,3]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name=\"jit(f)/dot\"}";
        let i = parse_instruction(line, 1).unwrap();
        assert_eq!(i.operands, ["a", "b"]);
        assert_eq!(i.int_list_attr("lhs_contracting_dims"), Some(vec![1]));
        assert!(i.attrs.contains_key("metadata"));
    }

    #[test]
    fn while_attrs() {
        let line = "w = (s32[], f32[4]{0}) while(init), condition=cond.1, body=body.2";
        let i = parse_instruction(line, 1).unwrap();
        let called = i.called_computations();
        assert!(called.contains(&"body.2"));
        assert!(called.contains(&"cond.1"));
    }

    #[test]
    fn tuple_shape_with_index_comments() {
        let line = "t = (f32[2]{0}, /*index=1*/s32[]) tuple(a, b)";
        let i = parse_instruction(line, 1).unwrap();
        assert_eq!(i.shape.bytes(), 12);
        assert_eq!(i.operands, ["a", "b"]);
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse_module("ENTRY broken {\n  nonsense\n}\n").is_err());
        assert!(parse_module("").is_err());
        assert!(parse_instruction("x = q9[3] foo(a)", 1).is_err());
    }

    #[test]
    fn opcode_census() {
        let m = parse_module(SAMPLE).unwrap();
        let census = m.opcode_census();
        assert_eq!(census["parameter"], 2);
        assert_eq!(census["call"], 1);
    }
}

//! HLO-text analysis substrate (DESIGN.md S9–S13).
//!
//! The paper measured peak HBM on H100/TPUv5p fleets; our stand-in is a
//! structural analysis of the very HLO modules the runtime executes:
//!
//! * [`parser`] — HLO text → [`ir::Module`] (computations, instructions,
//!   operands, attributes, called-computation links).
//! * [`shape`] — dtype/shape grammar + byte sizes.
//! * [`memory`] — buffer-liveness simulator over the program order:
//!   peak memory, static/dynamic split, and the Fig.-2-style timeline.
//! * [`flops`] — FLOP/byte cost model per instruction (step-time model).
//!
//! HLO text straight out of `jax.lower` is *unoptimised*: its liveness is
//! exactly the "what must a memory-naive runtime hold" quantity, which is
//! the structural asymmetry MixFlow-MG attacks (stored inner-backward
//! activations vs streamed JVPs).  Ratios between default/mixflow modules
//! are therefore comparable to the paper's measured HBM ratios even though
//! the absolute bytes differ from a post-XLA allocation.

pub mod flops;
pub mod ir;
pub mod memory;
pub mod parser;
pub mod shape;

pub use ir::{Computation, Instruction, Module};
pub use memory::{MemoryReport, MemorySimulator};
pub use shape::Shape;

//! Vendored minimal `anyhow` substitute.
//!
//! The offline build image has no crates.io access, so this crate provides
//! the small slice of the real `anyhow` API the coordinator uses: the
//! [`Error`] type with a context chain, the [`anyhow!`]/[`bail!`] macros,
//! the [`Context`] extension trait and the [`Result`] alias.  Formatting
//! mirrors anyhow: `{}` prints the outermost message, `{:#}` the whole
//! chain joined by `: `, and `{:?}` a report with a `Caused by:` section.

use std::fmt;

/// `Result<T, anyhow::Error>` alias, like the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of context messages.
///
/// `chain[0]` is the outermost (most recently attached) message; the last
/// entries are the root cause and its sources.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// All messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.to_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("got {n} and {}", 4);
        assert_eq!(e.to_string(), "got 3 and 4");
    }

    #[test]
    fn from_std_error_and_chain() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        let e2 = Err::<(), Error>(e)
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        assert_eq!(format!("{e2:#}"), "step 2: reading manifest: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn bail_returns() {
        fn inner(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 1);
            }
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        assert_eq!(inner(true).unwrap_err().to_string(), "flagged 1");
    }
}

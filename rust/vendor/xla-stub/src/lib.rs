//! Compile-only stub of the `xla-rs` PJRT bindings (see README.md).
//!
//! Host-side [`Literal`] construction works for real; every device-side
//! entry point returns [`Error`] explaining that the stub is linked.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion
/// into `anyhow::Error` (it implements `std::error::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable: mixflow was built against the bundled \
         compile-only XLA stub (feature `pjrt` without a real XLA \
         toolchain); see rust/vendor/xla-stub/README.md"
    )))
}

/// Element types the manifest loader maps numpy dtypes onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

/// Typed storage behind a [`Literal`].
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::F64(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::I64(v) => v.len(),
        }
    }
}

/// Rust scalar types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn store(v: Vec<Self>) -> Storage;
    #[doc(hidden)]
    fn load(s: &Storage) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($ty:ty, $variant:ident) => {
        impl NativeType for $ty {
            fn store(v: Vec<Self>) -> Storage {
                Storage::$variant(v)
            }
            fn load(s: &Storage) -> Option<Vec<Self>> {
                match s {
                    Storage::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(f64, F64);
native!(i32, I32);
native!(i64, I64);

/// Host literal: flat typed buffer + dims.
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            storage: T::store(data.to_vec()),
        }
    }

    pub fn element_count(&self) -> usize {
        self.storage.len()
    }

    /// Same buffer under new dims (element counts must agree).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy out as `Vec<T>`; errors on a dtype mismatch like xla-rs.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.storage)
            .ok_or_else(|| Error("to_vec: literal dtype mismatch".into()))
    }

    /// Decompose a tuple literal — only real executions produce tuples,
    /// so the stub can never satisfy this.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// npz loading trait (mirrors xla-rs `FromRawBytes`).
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npz(path: &str, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();
    fn read_npz(_path: &str, _ctx: &()) -> Result<Vec<(String, Literal)>> {
        unavailable("Literal::read_npz")
    }
}

/// Parsed HLO module handle.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn device_paths_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::read_npz("x", &()).is_err());
    }
}

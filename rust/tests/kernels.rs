//! Acceptance tests for the kernel subsystem (`mixflow::kernels`).
//!
//! The subsystem's contract is *bit-for-bit determinism*: the blocked
//! GEMM must equal the scalar reference loop nest exactly, every pooled
//! kernel must produce identical bits at every thread count, and whole
//! hypergradients (naive / mixflow / fd, all tasks × optimisers) must
//! not change by a single ULP when `--threads` changes.  Also pins the
//! zero-skip removal: a 0.0 operand must propagate NaN/∞ from the other
//! side per IEEE-754, not mask it.

use mixflow::autodiff::engine::HypergradEngine;
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::autodiff::problems::{
    AttentionProblem, HyperLrProblem, LossWeightingProblem,
    MultiHeadAttentionProblem,
};
use mixflow::autodiff::tape::Tape;
use mixflow::autodiff::tensor::Tensor;
use mixflow::autodiff::BilevelProblem;
use mixflow::kernels::{elementwise, gemm, rows, DetPool};
use mixflow::meta::HypergradMode;
use mixflow::util::prng::Prng;
use mixflow::util::proptest;

fn randv(rng: &mut Prng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
}

/// Bitwise slice equality — distinguishes `-0.0` from `0.0` and treats
/// identical NaN payloads as equal, which plain `==` would not.
fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b.iter())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn max_abs_diff(a: &[Tensor], b: &[Tensor]) -> f64 {
    assert_eq!(a.len(), b.len(), "gradient pytree arity");
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| x.max_abs_diff(y))
        .fold(0.0, f64::max)
}

// ---- blocked GEMM ≡ scalar reference -------------------------------------

#[test]
fn blocked_gemm_is_bitwise_equal_to_the_scalar_reference() {
    // Shapes straddle the MC=32 / KC=128 / NC=128 block edges (exact
    // multiples, one-off each side, multi-block) across every transpose
    // combination.  Blocking with ascending k-blocks preserves the
    // reference per-output accumulation order, so equality is exact.
    let mut rng = Prng::new(0x6e11);
    let shapes = [
        (1, 1, 1),
        (3, 7, 5),
        (32, 128, 128),
        (33, 129, 130),
        (65, 257, 66),
        (40, 300, 17),
    ];
    for &(m, k, n) in &shapes {
        for &(ta, tb) in
            &[(false, false), (true, false), (false, true), (true, true)]
        {
            let (ar, ac) = if ta { (k, m) } else { (m, k) };
            let (br, bc) = if tb { (n, k) } else { (k, n) };
            let a = randv(&mut rng, ar * ac);
            let b = randv(&mut rng, br * bc);
            let mut want = vec![0.0; m * n];
            let mut got = vec![0.0; m * n];
            gemm::gemm_ref_into(&a, ar, ac, ta, &b, br, bc, tb, &mut want);
            gemm::gemm_into(&a, ar, ac, ta, &b, br, bc, tb, &mut got);
            assert!(
                bits_eq(&want, &got),
                "blocked gemm {m}x{k}x{n} ta={ta} tb={tb} diverged \
                 from the scalar reference"
            );
        }
    }
}

#[test]
fn blocked_gemm_accumulates_onto_existing_output() {
    // Both kernels are += kernels: a pre-seeded `out` must accumulate
    // identically (the tape uses this for gradient fan-in).
    let mut rng = Prng::new(0xacc);
    let (m, k, n) = (33, 129, 34);
    let a = randv(&mut rng, m * k);
    let b = randv(&mut rng, k * n);
    let seed = randv(&mut rng, m * n);
    let mut want = seed.clone();
    let mut got = seed;
    gemm::gemm_ref_into(&a, m, k, false, &b, k, n, false, &mut want);
    gemm::gemm_into(&a, m, k, false, &b, k, n, false, &mut got);
    assert!(bits_eq(&want, &got), "accumulating gemm diverged");
}

// ---- NaN/∞ propagation (zero-skip removal regression) --------------------

#[test]
fn matmul_propagates_nan_and_inf_through_zero_operands() {
    // Regression for the removed `if ail == 0.0 { continue }` zero-skip:
    // IEEE-754 defines 0·NaN = NaN and 0·∞ = NaN, so a structural zero
    // in one operand must not mask a NaN/∞ in the other.  The finite
    // lane must stay finite — propagation is targeted, not blanket.
    let a = [0.0, 1.0]; // 1×2
    let b = [f64::NAN, 3.0, 2.0, 4.0]; // 2×2: NaN reachable only via the 0
    let mut out = [0.0, 0.0];
    gemm::gemm_ref_into(&a, 1, 2, false, &b, 2, 2, false, &mut out);
    assert!(out[0].is_nan(), "reference kernel skipped 0·NaN");
    assert_eq!(out[1], 4.0, "finite lane contaminated");
    let mut out = [0.0, 0.0];
    gemm::gemm_into(&a, 1, 2, false, &b, 2, 2, false, &mut out);
    assert!(out[0].is_nan(), "blocked kernel skipped 0·NaN");
    assert_eq!(out[1], 4.0, "finite lane contaminated");

    let b_inf = [f64::INFINITY, 3.0, 2.0, 4.0];
    let mut out = [0.0, 0.0];
    gemm::gemm_into(&a, 1, 2, false, &b_inf, 2, 2, false, &mut out);
    assert!(out[0].is_nan(), "blocked kernel skipped 0·∞ (must be NaN)");

    // Tensor level — the tape's matmul/bmm paths.
    let ta = Tensor::new(vec![1, 2], vec![0.0, 1.0]);
    let tb = Tensor::new(vec![2, 2], vec![f64::NAN, 3.0, 2.0, 4.0]);
    let prod = ta.matmul(&tb, false, false);
    assert!(prod.data[0].is_nan(), "Tensor::matmul skipped 0·NaN");
    assert_eq!(prod.data[1], 4.0);

    let pool = DetPool::new(2);
    let g = 2usize;
    let ba: Vec<f64> = [0.0, 1.0].repeat(g);
    let bb: Vec<f64> = [f64::NAN, 3.0, 2.0, 4.0].repeat(g);
    let mut out = vec![0.0; g * 2];
    gemm::bmm_into(&pool, g, &ba, 1, 2, false, &bb, 2, 2, false, &mut out);
    for gi in 0..g {
        assert!(out[gi * 2].is_nan(), "bmm group {gi} skipped 0·NaN");
        assert_eq!(out[gi * 2 + 1], 4.0);
    }
}

// ---- per-kernel thread-count bit-identity --------------------------------

#[test]
fn every_pooled_kernel_is_bit_identical_across_thread_counts() {
    // Inputs sized to cross the parallelism thresholds (MIN_PAR_FLOPS
    // for bmm, CHUNK for elementwise, the per-row element budget for
    // row kernels) so the multi-threaded pools genuinely dispatch.
    let mut rng = Prng::new(0x7bead);
    let pools: Vec<DetPool> =
        [1usize, 2, 4].iter().map(|&t| DetPool::new(t)).collect();

    // bmm: 8 groups of 24×24 · 24×24 → 8·24³ = 110 592 flops.
    let (g, m, k, n) = (8usize, 24usize, 24usize, 24usize);
    let a = randv(&mut rng, g * m * k);
    let b = randv(&mut rng, g * k * n);
    let mut want = vec![0.0; g * m * n];
    gemm::bmm_into(&pools[0], g, &a, m, k, false, &b, k, n, false, &mut want);
    for pool in &pools[1..] {
        let mut got = vec![0.0; g * m * n];
        gemm::bmm_into(pool, g, &a, m, k, false, &b, k, n, false, &mut got);
        assert!(
            bits_eq(&want, &got),
            "bmm diverged at {} threads",
            pool.threads()
        );
    }
    assert!(
        pools[2].stats().jobs > 0,
        "bmm above MIN_PAR_FLOPS never dispatched to the 4-thread pool"
    );

    // Elementwise map / zip / fill_indexed: 3 chunks + a ragged tail.
    let nelem = 3 * 8192 + 17;
    let x = randv(&mut rng, nelem);
    let y = randv(&mut rng, nelem);
    let mut want_map = vec![0.0; nelem];
    let mut want_zip = vec![0.0; nelem];
    let mut want_fill = vec![0.0; nelem];
    elementwise::map_into(&pools[0], &x, |v| v.tanh(), &mut want_map);
    elementwise::zip_into(&pools[0], &x, &y, |p, q| p * q + q, &mut want_zip);
    elementwise::fill_indexed(
        &pools[0],
        nelem,
        |i| (i as f64).sqrt(),
        &mut want_fill,
    );
    for pool in &pools[1..] {
        let mut got = vec![0.0; nelem];
        elementwise::map_into(pool, &x, |v| v.tanh(), &mut got);
        assert!(bits_eq(&want_map, &got), "map diverged");
        elementwise::zip_into(pool, &x, &y, |p, q| p * q + q, &mut got);
        assert!(bits_eq(&want_zip, &got), "zip diverged");
        elementwise::fill_indexed(pool, nelem, |i| (i as f64).sqrt(), &mut got);
        assert!(bits_eq(&want_fill, &got), "fill_indexed diverged");
    }

    // Row kernels: 600 rows of width 12 → multiple row chunks.
    let (rm, rn) = (600usize, 12usize);
    let z = randv(&mut rng, rm * rn);
    let mut want_sm = vec![0.0; rm * rn];
    let mut want_lse = vec![0.0; rm];
    let mut want_ln = vec![0.0; rm * rn];
    rows::softmax_rows_into(&pools[0], &z, rm, rn, &mut want_sm);
    rows::logsumexp_rows_into(&pools[0], &z, rm, rn, &mut want_lse);
    rows::layernorm_rows_into(&pools[0], &z, rm, rn, 1e-5, &mut want_ln);
    for pool in &pools[1..] {
        let mut sm = vec![0.0; rm * rn];
        let mut lse = vec![0.0; rm];
        let mut ln = vec![0.0; rm * rn];
        rows::softmax_rows_into(pool, &z, rm, rn, &mut sm);
        rows::logsumexp_rows_into(pool, &z, rm, rn, &mut lse);
        rows::layernorm_rows_into(pool, &z, rm, rn, 1e-5, &mut ln);
        assert!(bits_eq(&want_sm, &sm), "softmax diverged");
        assert!(bits_eq(&want_lse, &lse), "logsumexp diverged");
        assert!(bits_eq(&want_ln, &ln), "layernorm diverged");
    }
}

// ---- fused row kernels ≡ tape composites ---------------------------------

#[test]
fn fused_layernorm_matches_the_tape_composite_bit_for_bit() {
    // `Tape::layernorm_rows` is a composite of primitive ops (row_sum,
    // scale, broadcast, sub, mul, offset, sqrt, div); the fused kernel
    // replicates its per-row float-op order exactly, so the two must
    // agree to the bit — that equality is what lets the JVP overlay use
    // the composite while dense forward paths use the fused kernel.
    let mut rng = Prng::new(0x1a7e);
    let (m, n) = (37usize, 11usize);
    let z = Tensor::randn(&[m, n], 1.0, &mut rng);
    let mut tape = Tape::new();
    let zid = tape.leaf(z.clone());
    let ln = tape.layernorm_rows(zid, 1e-5);
    let want = tape.value(ln).clone();
    let pool = DetPool::new(1);
    let mut got = vec![0.0; m * n];
    rows::layernorm_rows_into(&pool, &z.data, m, n, 1e-5, &mut got);
    assert!(
        bits_eq(&want.data, &got),
        "fused layernorm diverged from the tape composite"
    );
}

#[test]
fn tape_softmax_and_logsumexp_values_match_the_row_kernels() {
    // The tape's SoftmaxRows / LogSumExpRows forward values are computed
    // by these kernels; this pins the wiring (shape, stride, row order).
    let mut rng = Prng::new(0x50f7);
    let (m, n) = (19usize, 7usize);
    let z = Tensor::randn(&[m, n], 1.0, &mut rng);
    let mut tape = Tape::new();
    let zid = tape.leaf(z.clone());
    let sm = tape.softmax_rows(zid);
    let lse = tape.logsumexp_rows(zid);
    let pool = DetPool::new(1);
    let mut got_sm = vec![0.0; m * n];
    let mut got_lse = vec![0.0; m];
    rows::softmax_rows_into(&pool, &z.data, m, n, &mut got_sm);
    rows::logsumexp_rows_into(&pool, &z.data, m, n, &mut got_lse);
    assert!(bits_eq(&tape.value(sm).data, &got_sm), "softmax wiring");
    assert!(bits_eq(&tape.value(lse).data, &got_lse), "logsumexp wiring");
}

// ---- whole-hypergradient thread-count bit-identity (property) ------------

/// Random small bilevel instance spanning all four tasks and all three
/// inner optimisers (same family as `rust/tests/plan.rs`).
fn random_problem(g: &mut proptest::Gen) -> Box<dyn BilevelProblem> {
    let seed = g.rng.next_u64();
    let d = g.usize(2, 4);
    let hidden = g.usize(2, 5);
    let classes = g.usize(2, 4);
    let batch = g.usize(2, 5);
    let unroll = g.usize(1, 4);
    let alpha = g.f64(0.02, 0.12);
    let opt = *g.choose(&[
        InnerOptimiser::Sgd,
        InnerOptimiser::momentum(),
        InnerOptimiser::adam(),
    ]);
    match g.usize(0, 3) {
        0 => Box::new(
            HyperLrProblem::with_config(
                seed, d, hidden, classes, batch, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        1 => Box::new(
            LossWeightingProblem::with_config(
                seed,
                d,
                hidden,
                classes,
                batch,
                unroll,
                alpha,
                g.f64(0.0, 0.6),
            )
            .with_optimiser(opt),
        ),
        2 => Box::new(
            AttentionProblem::with_config(
                seed, d, batch, classes, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        _ => {
            let heads = g.usize(1, 3);
            let d_model = heads * g.usize(1, 2);
            let seqs = g.usize(1, 3);
            Box::new(
                MultiHeadAttentionProblem::with_config(
                    seed,
                    d_model,
                    heads,
                    seqs,
                    g.usize(2, 4),
                    classes,
                    unroll,
                    alpha,
                )
                .with_optimiser(opt),
            )
        }
    }
}

#[test]
fn property_hypergradients_are_bit_identical_across_thread_counts() {
    // The determinism contract end-to-end: naive / mixflow / fd
    // hypergradients over the random task × optimiser family must not
    // change by a single ULP across engines built with 1, 2, and 4
    // kernel threads.  Diffs are compared to literal 0.0, not a
    // tolerance.
    proptest::check("hypergrad-thread-bit-identity", 8, |g| {
        let problem = random_problem(g);
        let mode = *g.choose(&[
            HypergradMode::Naive,
            HypergradMode::Mixflow,
            HypergradMode::Fd,
        ]);
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        let mut reference = None;
        for &t in &[1usize, 2, 4] {
            let mut engine =
                HypergradEngine::builder().mode(mode).threads(t).build();
            let r = engine.run(problem.as_ref(), &theta0, &eta);
            match &reference {
                None => reference = Some(r),
                Some(base) => {
                    let diff = max_abs_diff(&base.d_eta, &r.d_eta);
                    if diff != 0.0 {
                        return Err(format!(
                            "{mode:?}: d_eta differs by {diff:.3e} \
                             between 1 and {t} threads"
                        ));
                    }
                    if base.outer_loss.to_bits() != r.outer_loss.to_bits() {
                        return Err(format!(
                            "{mode:?}: outer_loss bits differ between \
                             1 and {t} threads"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn multi_threaded_engine_dispatches_pool_jobs_on_the_ladder_cell() {
    // The widened attention cell used by the fig_native_walltime thread
    // ladder (d_model 32, seq 32, 2 heads × 2 batch) is big enough to
    // cross MIN_PAR_FLOPS: a 4-thread engine must actually dispatch
    // pool jobs and still match the single-threaded result exactly,
    // while the 1-thread engine's serial fast path counts none.
    let problem = MultiHeadAttentionProblem::with_config(
        1, 32, 2, 2, 32, 4, 2, 0.01,
    )
    .with_optimiser(InnerOptimiser::adam());
    let theta0 = problem.theta0();
    let eta = problem.eta0();
    let mut e1 = HypergradEngine::builder()
        .mode(HypergradMode::Mixflow)
        .threads(1)
        .build();
    let mut e4 = HypergradEngine::builder()
        .mode(HypergradMode::Mixflow)
        .threads(4)
        .build();
    let r1 = e1.run(&problem, &theta0, &eta);
    let r4 = e4.run(&problem, &theta0, &eta);
    assert_eq!(
        max_abs_diff(&r1.d_eta, &r4.d_eta),
        0.0,
        "ladder cell hypergradient changed with thread count"
    );
    assert!(
        e4.pool_stats().jobs > 0,
        "4-thread engine never dispatched a pool job on the ladder cell"
    );
    assert_eq!(
        e1.pool_stats().jobs,
        0,
        "serial fast path must not count pool jobs"
    );
    assert_eq!(e1.threads(), 1);
    assert_eq!(e4.threads(), 4);
}

//! Acceptance suite for the fault-tolerant serving layer.
//!
//! The supervisor's contract, pinned against deterministic chaos:
//!
//! * **No job loss** — exactly one terminal record per submitted job,
//!   whatever mix of panics, NaNs, slowdowns, allocation spikes,
//!   deadlines and backpressure sheds the run injects.
//! * **No engine reuse after quarantine** — a generation that appears
//!   in the quarantine ledger never serves a later attempt, anywhere.
//! * **Bounded retries** — attempts ≤ max_retries + 1, and the
//!   `serve.jobs.retried` counter equals Σ(attempts − 1).
//! * **Counter reconciliation** — `serve.jobs.{ok,failed,shed}`
//!   partition the job set; quarantine and deadline counters match the
//!   per-record ledgers.
//! * **Guard economics** — the non-finite guard off is bit-identical
//!   to PR 7's engine output; on, it catches injected NaNs with a
//!   typed phase-tagged error.
//! * **JSONL round-trip** — specs and records survive the wire format.

use std::collections::BTreeSet;

use mixflow::autodiff::{
    CheckpointPolicy, HypergradEngine, HypergradMode,
};
use mixflow::meta::NativeTask;
use mixflow::obs::Counter;
use mixflow::serve::{
    serve_jobs, BackpressurePolicy, ChaosConfig, HypergradError, JobSpec,
    JobStatus, ServeConfig, ServeOutcome,
};
use mixflow::util::json::Json;

fn spec(id: &str, seed: u64) -> JobSpec {
    JobSpec { id: id.to_string(), unroll: 3, seed, ..JobSpec::default() }
}

fn base_cfg() -> ServeConfig {
    ServeConfig {
        workers: 3,
        max_retries: 2,
        backoff_base_ms: 1,
        backoff_cap_ms: 2,
        ..ServeConfig::default()
    }
}

/// The cross-ledger invariants every serve run must satisfy.
fn assert_reconciled(out: &ServeOutcome, jobs: usize, max_retries: u64) {
    assert_eq!(out.records.len(), jobs, "exactly one record per job");
    let ok = out.counter(Counter::ServeJobsOk);
    let failed = out.counter(Counter::ServeJobsFailed);
    let shed = out.counter(Counter::ServeJobsShed);
    assert_eq!(
        ok + failed + shed,
        jobs as u64,
        "ok/failed/shed must partition the job set"
    );
    for r in &out.records {
        assert!(
            r.attempts <= max_retries + 1,
            "job {} spent {} attempts with max_retries {max_retries}",
            r.id,
            r.attempts
        );
        match r.status {
            JobStatus::Ok => {
                assert!(r.error.is_none() && r.outer_loss.is_some())
            }
            JobStatus::Failed => {
                assert!(r.error.is_some() && r.outer_loss.is_none())
            }
            JobStatus::Shed => {
                assert_eq!(r.attempts, 0, "shed jobs never ran");
                assert!(matches!(
                    r.error,
                    Some(HypergradError::QueueFull { .. })
                ));
            }
        }
    }
    let retried: u64 =
        out.records.iter().map(|r| r.attempts.saturating_sub(1)).sum();
    assert_eq!(
        out.counter(Counter::ServeJobsRetried),
        retried,
        "retried counter must equal Σ(attempts − 1)"
    );
    let record_quarantines: Vec<u64> = out
        .records
        .iter()
        .flat_map(|r| r.quarantined.iter().copied())
        .collect();
    assert_eq!(
        out.quarantined_generations.len(),
        record_quarantines.len(),
        "pool ledger and record ledgers must agree on quarantine count"
    );
    assert_eq!(
        out.counter(Counter::ServeEngineQuarantines),
        out.quarantined_generations.len() as u64
    );
    let pool: BTreeSet<u64> =
        out.quarantined_generations.iter().copied().collect();
    let recs: BTreeSet<u64> = record_quarantines.into_iter().collect();
    assert_eq!(pool, recs, "same generations in both ledgers");
}

/// A quarantined generation must never serve again.  An engine may
/// legitimately serve several attempts (and several jobs) *before* the
/// failure that retires it, so raw occurrence counts prove nothing.
/// Two consequences are checkable black-box on any run:
///
/// * quarantine is terminal and happens once — each retired generation
///   appears in exactly one record's quarantine ledger, and that record
///   actually ran it;
/// * with a single worker the record order IS the global attempt
///   chronology, so once a record retires a generation, no later record
///   may run it.
fn assert_no_reuse_after_quarantine(
    out: &ServeOutcome,
    single_worker: bool,
) {
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    for r in &out.records {
        for g in &r.quarantined {
            assert!(
                seen.insert(*g),
                "generation {g} quarantined twice — it must have served \
                 again after being retired"
            );
            assert!(
                r.generations.contains(g),
                "job {} quarantined generation {g} it never ran",
                r.id
            );
        }
    }
    let pool: BTreeSet<u64> =
        out.quarantined_generations.iter().copied().collect();
    assert_eq!(pool, seen, "pool and record quarantine ledgers agree");
    if single_worker {
        let mut retired: BTreeSet<u64> = BTreeSet::new();
        for r in &out.records {
            for g in &r.generations {
                assert!(
                    !retired.contains(g),
                    "job {} ran generation {g} after an earlier job \
                     quarantined it",
                    r.id
                );
            }
            retired.extend(r.quarantined.iter().copied());
        }
    }
}

#[test]
fn chaos_storm_loses_no_jobs_and_reconciles() {
    let chaos = ChaosConfig {
        seed: 20_240_817,
        panic_rate: 0.3,
        nan_rate: 0.3,
        slow_rate: 0.2,
        alloc_rate: 0.2,
        slow_ms: 3,
        alloc_bytes: 1 << 20,
    };
    // Breaker wide open: a shared circuit breaker tripping at
    // scheduling-dependent moments would make per-job outcomes depend
    // on worker interleaving; with it out of the way the fault plans
    // (pure functions of seed/job/attempt) fully determine every
    // status, so the storm's spot assertions are stable.
    let cfg = ServeConfig {
        quarantine_limit: usize::MAX / 2,
        chaos: Some(chaos),
        ..base_cfg()
    };
    let specs: Vec<JobSpec> = (0..24)
        .map(|i| {
            let mut s = spec(&format!("storm-{i}"), i % 5);
            if i % 3 == 1 {
                s.mode = HypergradMode::Naive;
            }
            if i % 4 == 2 {
                s.task = NativeTask::LossWeighting;
            }
            s
        })
        .collect();
    let out = serve_jobs(specs, &cfg);
    assert_reconciled(&out, 24, cfg.max_retries);
    assert_no_reuse_after_quarantine(&out, false);
    // The storm must actually exercise the machinery it claims to pin.
    assert!(out.counter(Counter::ServeJobsRetried) > 0, "storm retried");
    assert!(
        !out.quarantined_generations.is_empty(),
        "a 30% NaN rate must quarantine engines"
    );
    assert!(
        out.records.iter().any(|r| r.status == JobStatus::Ok),
        "some jobs must still serve through the storm"
    );
}

#[test]
fn chaos_outcomes_replay_bit_for_bit() {
    let chaos = ChaosConfig {
        seed: 77,
        panic_rate: 0.4,
        nan_rate: 0.3,
        ..ChaosConfig::default()
    };
    // Same reasoning as the storm: replay determinism needs outcomes
    // that are a pure function of the chaos plans, so the breaker (the
    // one scheduling-coupled piece of shared state) stays wide open.
    let cfg = ServeConfig {
        quarantine_limit: usize::MAX / 2,
        chaos: Some(chaos),
        ..base_cfg()
    };
    let specs = |n: u64| -> Vec<JobSpec> {
        (0..n).map(|i| spec(&format!("r{i}"), i)).collect()
    };
    let a = serve_jobs(specs(12), &cfg);
    let b = serve_jobs(specs(12), &cfg);
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.status, rb.status, "job {} status replays", ra.id);
        assert_eq!(ra.attempts, rb.attempts, "job {} attempts replay", ra.id);
        assert_eq!(ra.degradation, rb.degradation);
        assert_eq!(ra.error, rb.error);
        assert_eq!(ra.outer_loss, rb.outer_loss, "served values replay");
        assert_eq!(ra.hypergrad_norm, rb.hypergrad_norm);
    }
}

#[test]
fn property_every_chaos_mix_terminates_each_job_exactly_once() {
    mixflow::util::proptest::check("serve-terminal", 12, |g| {
        let n = g.usize(1, 10);
        let chaos = ChaosConfig {
            seed: g.int(0, i64::MAX / 2) as u64,
            panic_rate: g.f64(0.0, 0.6),
            nan_rate: g.f64(0.0, 0.6),
            slow_rate: g.f64(0.0, 0.4),
            alloc_rate: g.f64(0.0, 0.4),
            slow_ms: g.usize(1, 3) as u64,
            alloc_bytes: 1 << 16,
        };
        let cfg = ServeConfig {
            workers: g.usize(1, 3),
            max_retries: g.usize(0, 3) as u64,
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            guard: g.bool(),
            chaos: Some(chaos),
            ..ServeConfig::default()
        };
        let specs: Vec<JobSpec> = (0..n)
            .map(|i| spec(&format!("p{i}"), i as u64))
            .collect();
        let out = serve_jobs(specs, &cfg);
        if out.records.len() != n {
            return Err(format!(
                "{} records for {n} jobs",
                out.records.len()
            ));
        }
        let ok = out.counter(Counter::ServeJobsOk);
        let failed = out.counter(Counter::ServeJobsFailed);
        let shed = out.counter(Counter::ServeJobsShed);
        if ok + failed + shed != n as u64 {
            return Err(format!(
                "counters {ok}+{failed}+{shed} != {n}"
            ));
        }
        for r in &out.records {
            if r.attempts > cfg.max_retries + 1 {
                return Err(format!(
                    "job {} overspent attempts: {} > {}",
                    r.id,
                    r.attempts,
                    cfg.max_retries + 1
                ));
            }
        }
        // Quarantine is terminal: every retired generation appears in
        // exactly one record's ledger and was actually run by it; under
        // a single worker (chronological record order) it must never
        // appear in a later record.
        let mut seen: BTreeSet<u64> = BTreeSet::new();
        let mut retired: BTreeSet<u64> = BTreeSet::new();
        for r in &out.records {
            if cfg.workers == 1 {
                if let Some(gen) =
                    r.generations.iter().find(|g| retired.contains(*g))
                {
                    return Err(format!(
                        "job {} ran retired generation {gen}",
                        r.id
                    ));
                }
            }
            for gen in &r.quarantined {
                if !seen.insert(*gen) {
                    return Err(format!(
                        "generation {gen} quarantined twice"
                    ));
                }
                if !r.generations.contains(gen) {
                    return Err(format!(
                        "job {} quarantined generation {gen} it never ran",
                        r.id
                    ));
                }
            }
            retired.extend(r.quarantined.iter().copied());
        }
        let pool: BTreeSet<u64> =
            out.quarantined_generations.iter().copied().collect();
        if pool != seen {
            return Err("pool and record quarantine ledgers disagree"
                .to_string());
        }
        Ok(())
    });
}

#[test]
fn quarantined_generations_never_serve_again() {
    // One worker makes the record order the global attempt chronology,
    // so cross-job reuse of a retired generation is directly
    // observable — and the breaker stays at its default here, so its
    // refusals are exercised deterministically too.
    let chaos = ChaosConfig {
        seed: 41,
        panic_rate: 0.2,
        nan_rate: 0.6,
        ..ChaosConfig::default()
    };
    let cfg =
        ServeConfig { workers: 1, chaos: Some(chaos), ..base_cfg() };
    let out = serve_jobs(
        (0..12).map(|i| spec(&format!("q{i}"), i)).collect(),
        &cfg,
    );
    assert_reconciled(&out, 12, cfg.max_retries);
    assert_no_reuse_after_quarantine(&out, true);
    assert!(
        !out.quarantined_generations.is_empty(),
        "a 60% NaN rate must retire engines"
    );
}

#[test]
fn guard_off_is_bit_identical_to_the_bare_engine() {
    // The serving layer with guards off must serve the exact bits the
    // engine produces standalone — robustness must stay compiled out of
    // the fast path.
    let job = spec("bit", 3);
    let cfg = ServeConfig {
        workers: 1,
        guard: false,
        telemetry: false,
        ..base_cfg()
    };
    let out = serve_jobs(vec![job.clone()], &cfg);
    let rec = &out.records[0];
    assert_eq!(rec.status, JobStatus::Ok);

    let mut engine = HypergradEngine::builder()
        .mode(job.mode)
        .checkpoint(job.remat)
        .inner_opt(job.inner_opt)
        .build();
    let mut problem = mixflow::meta::NativeMetaTrainer::build_problem(
        job.task, job.seed, job.unroll, job.heads, job.batch,
    );
    engine.configure_problem(problem.as_mut());
    let h = engine.run(problem.as_ref(), &problem.theta0(), &problem.eta0());
    let norm = h
        .d_eta
        .iter()
        .flat_map(|g| g.data.iter())
        .map(|v| v * v)
        .sum::<f64>()
        .sqrt();
    assert_eq!(rec.outer_loss, Some(h.outer_loss), "loss bit-identical");
    assert_eq!(rec.hypergrad_norm, Some(norm), "norm bit-identical");
}

#[test]
fn guard_on_catches_nan_with_a_phase_tagged_error() {
    let chaos =
        ChaosConfig { seed: 3, nan_rate: 1.0, ..ChaosConfig::default() };
    let cfg = ServeConfig {
        workers: 1,
        max_retries: 0,
        guard: true,
        chaos: Some(chaos),
        ..base_cfg()
    };
    let out = serve_jobs(vec![spec("nan", 0)], &cfg);
    match out.records[0].error.as_ref().expect("job failed") {
        HypergradError::NonFinite { phase, .. } => {
            assert_ne!(
                phase, "result",
                "guard on: the tape catches the NaN in-flight, not at \
                 the result check"
            );
        }
        other => panic!("expected NonFinite, got {other:?}"),
    }
    assert!(
        !out.quarantined_generations.is_empty(),
        "a mid-phase unwind quarantines the engine"
    );
}

#[test]
fn guard_off_still_refuses_to_serve_non_finite_results() {
    let chaos =
        ChaosConfig { seed: 3, nan_rate: 1.0, ..ChaosConfig::default() };
    let cfg = ServeConfig {
        workers: 1,
        max_retries: 0,
        guard: false,
        chaos: Some(chaos),
        ..base_cfg()
    };
    let out = serve_jobs(vec![spec("nan-off", 0)], &cfg);
    match out.records[0].error.as_ref().expect("job failed") {
        HypergradError::NonFinite { phase, .. } => {
            assert_eq!(
                phase, "result",
                "guard off: only the terminal result check fires"
            );
        }
        other => panic!("expected NonFinite, got {other:?}"),
    }
    assert!(
        out.quarantined_generations.is_empty(),
        "no unwind, no quarantine: the engine completed normally"
    );
}

#[test]
fn deadline_failures_count_and_classify() {
    let chaos = ChaosConfig {
        seed: 5,
        slow_rate: 1.0,
        slow_ms: 50,
        ..ChaosConfig::default()
    };
    let cfg = ServeConfig {
        workers: 2,
        deadline_ms: Some(5),
        max_retries: 1,
        chaos: Some(chaos),
        ..base_cfg()
    };
    let out = serve_jobs(
        (0..3).map(|i| spec(&format!("d{i}"), i)).collect(),
        &cfg,
    );
    assert_reconciled(&out, 3, cfg.max_retries);
    for r in &out.records {
        assert_eq!(r.status, JobStatus::Failed);
        assert_eq!(
            r.error,
            Some(HypergradError::DeadlineExceeded { deadline_ms: 5 })
        );
        assert_eq!(r.attempts, 2, "deadline failures are retried");
    }
    assert_eq!(
        out.counter(Counter::ServeDeadlineExceeded),
        6,
        "every attempt of every job exceeded"
    );
}

#[test]
fn reject_backpressure_sheds_with_records_and_counters() {
    let chaos = ChaosConfig {
        seed: 8,
        slow_rate: 1.0,
        slow_ms: 50,
        ..ChaosConfig::default()
    };
    let cfg = ServeConfig {
        workers: 1,
        queue_capacity: 1,
        backpressure: BackpressurePolicy::Reject,
        max_retries: 0,
        chaos: Some(chaos),
        ..ServeConfig::default()
    };
    let out = serve_jobs(
        (0..6).map(|i| spec(&format!("s{i}"), i)).collect(),
        &cfg,
    );
    assert_reconciled(&out, 6, cfg.max_retries);
    let shed = out.counter(Counter::ServeJobsShed);
    assert!(shed >= 1, "50 ms/job on one worker must shed some of 6");
}

#[test]
fn degradation_chain_is_recorded_in_order() {
    // NaN on the first attempt only: the mixflow attempt trips the
    // guard, the retry degrades to fd, the second attempt's chaos draw
    // is clean for this seed, so fd serves the job.
    let chaos = ChaosConfig {
        seed: pick_seed_with_nan_then_clean(),
        nan_rate: 0.5,
        ..ChaosConfig::default()
    };
    let cfg = ServeConfig {
        workers: 1,
        max_retries: 2,
        chaos: Some(chaos),
        ..base_cfg()
    };
    let out = serve_jobs(vec![spec("deg", 1)], &cfg);
    let rec = &out.records[0];
    assert_eq!(rec.status, JobStatus::Ok);
    assert_eq!(rec.degradation, ["nonfinite:mixflow->fd"]);
    assert_eq!(rec.mode_requested, HypergradMode::Mixflow);
    assert_eq!(rec.mode_used, HypergradMode::Fd);
    assert!(rec.attempts >= 2);
    assert!(rec.backoff_ms >= 1, "retries back off");
}

/// Find a chaos seed whose job-0 draw injects NaN on attempt 1 but not
/// on the attempt that next runs an η-NaN-able path.  Pure search over
/// the deterministic plan function — no run needed.
fn pick_seed_with_nan_then_clean() -> u64 {
    for seed in 0..10_000u64 {
        let c = ChaosConfig { seed, nan_rate: 0.5, ..ChaosConfig::default() };
        if c.plan(0, 1).nan && !c.plan(0, 2).nan {
            return seed;
        }
    }
    panic!("no such seed in range — nan_rate draw is broken");
}

#[test]
fn spec_and_record_jsonl_round_trip() {
    let spec0 = JobSpec {
        id: "wire".to_string(),
        task: NativeTask::Attention,
        mode: HypergradMode::Mixflow,
        remat: CheckpointPolicy::Auto,
        heads: 2,
        batch: 2,
        unroll: 4,
        seed: 5,
        ..JobSpec::default()
    };
    let line = spec0.to_json().compact();
    let parsed = Json::parse(&line).expect("spec line parses");
    let spec1 = JobSpec::from_json(&parsed, "x").expect("spec round-trips");
    assert_eq!(spec0, spec1);

    let out = serve_jobs(vec![spec1], &ServeConfig::default());
    let rec = &out.records[0];
    assert_eq!(rec.status, JobStatus::Ok);
    let rec_line = rec.to_json().compact();
    let doc = Json::parse(&rec_line).expect("record line parses");
    assert_eq!(doc.get("id").and_then(Json::as_str), Some("wire"));
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(doc.get("attempts").and_then(Json::as_u64), Some(1));
    assert!(
        doc.get("outer_loss").and_then(Json::as_f64).is_some(),
        "served loss on the wire"
    );
    assert!(
        doc.get("phases").is_some(),
        "default telemetry surfaces phase timings"
    );
}

#[test]
fn new_modes_serve_end_to_end_with_deterministic_replay() {
    // Truncated and evograd jobs must flow through the full serving
    // path (admission → pooled engine → record) and replay bit-for-bit
    // across whole runs.  The evograd pair shares one engine key, so
    // with several workers the warm engine each job lands on is
    // scheduling-dependent — the per-attempt reseed must make the
    // results identical anyway.
    let jobs = || {
        vec![
            JobSpec {
                id: "t2".to_string(),
                mode: HypergradMode::Truncated { horizon: 2 },
                unroll: 4,
                seed: 3,
                ..JobSpec::default()
            },
            JobSpec {
                id: "t4".to_string(),
                mode: HypergradMode::Truncated { horizon: 4 },
                unroll: 4,
                seed: 3,
                ..JobSpec::default()
            },
            JobSpec {
                id: "full".to_string(),
                mode: HypergradMode::Mixflow,
                unroll: 4,
                seed: 3,
                ..JobSpec::default()
            },
            JobSpec {
                id: "evo-a".to_string(),
                mode: HypergradMode::Evograd,
                unroll: 4,
                seed: 9,
                ..JobSpec::default()
            },
            JobSpec {
                id: "evo-b".to_string(),
                mode: HypergradMode::Evograd,
                unroll: 4,
                seed: 9,
                ..JobSpec::default()
            },
        ]
    };
    let cfg = base_cfg();
    let a = serve_jobs(jobs(), &cfg);
    let b = serve_jobs(jobs(), &cfg);
    assert_reconciled(&a, 5, cfg.max_retries);
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.status, JobStatus::Ok, "job {} must serve", ra.id);
        assert_eq!(
            ra.outer_loss.map(f64::to_bits),
            rb.outer_loss.map(f64::to_bits),
            "job {} outer loss must replay bit-for-bit",
            ra.id
        );
        assert_eq!(
            ra.hypergrad_norm.map(f64::to_bits),
            rb.hypergrad_norm.map(f64::to_bits),
            "job {} hypergradient must replay bit-for-bit",
            ra.id
        );
        assert_eq!(ra.mode_used, ra.mode_requested, "no degradation");
    }
    let rec = |id: &str| {
        a.records.iter().find(|r| r.id == id).expect("record present")
    };
    // Same spec, same seed, any pooling order: identical estimate.
    assert_eq!(
        rec("evo-a").outer_loss.map(f64::to_bits),
        rec("evo-b").outer_loss.map(f64::to_bits)
    );
    assert_eq!(
        rec("evo-a").hypergrad_norm.map(f64::to_bits),
        rec("evo-b").hypergrad_norm.map(f64::to_bits)
    );
    // The horizon is a real axis: a horizon-2 window on a T = 4 problem
    // is biased away from the full-window (≡ mixflow) hypergradient...
    assert_ne!(
        rec("t2").hypergrad_norm.map(f64::to_bits),
        rec("t4").hypergrad_norm.map(f64::to_bits),
        "truncation must bias the served hypergradient"
    );
    // ...while horizon = T is bit-for-bit the mixflow path.
    assert_eq!(
        rec("t4").hypergrad_norm.map(f64::to_bits),
        rec("full").hypergrad_norm.map(f64::to_bits),
        "horizon = T must serve exactly the mixflow hypergradient"
    );
    assert_eq!(
        rec("t4").outer_loss.map(f64::to_bits),
        rec("full").outer_loss.map(f64::to_bits)
    );
}

//! Sweep-grid reporting coverage: `run_sweep` over a
//! task × inner-opt × mode × heads × seed grid, dumped through
//! [`mixflow::meta::sweep_report_json`] to a `BENCH_native`-style JSON
//! file, then parsed back and checked for grid-order completeness — the
//! golden-file pin on the sweep report schema.

use mixflow::autodiff::mixflow::CheckpointPolicy;
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::meta::{
    run_sweep, sweep_report_json, HypergradMode, NativeTask, SweepSpec,
};
use mixflow::util::json::Json;

fn small_grid_spec() -> SweepSpec {
    SweepSpec {
        tasks: vec![NativeTask::HyperLr, NativeTask::Attention],
        inner_opts: vec![InnerOptimiser::Sgd],
        modes: vec![
            HypergradMode::Mixflow,
            HypergradMode::Naive,
            HypergradMode::Truncated { horizon: 1 },
            HypergradMode::Evograd,
        ],
        heads: vec![1, 2],
        batch: 2,
        remat: CheckpointPolicy::Full,
        fd_epsilon: 1e-5,
        unroll: 2,
        steps: 2,
        base_seed: 21,
        n_seeds: 2,
        telemetry: false,
        threads: 1,
    }
}

#[test]
fn sweep_json_round_trips_with_grid_order_completeness() {
    let spec = small_grid_spec();
    let runs = run_sweep(&spec);
    let expected = spec.cells();
    assert_eq!(runs.len(), expected.len());
    // 2 tasks × 1 opt × 4 modes × 2 heads × 2 seeds.
    assert_eq!(expected.len(), 32);

    // Golden-file round trip: dump, re-read, parse.
    let doc = sweep_report_json(&spec, &runs);
    let path = std::env::temp_dir().join(format!(
        "mixflow_sweep_golden_{}.json",
        std::process::id()
    ));
    std::fs::write(&path, doc.pretty() + "\n").expect("write golden file");
    let text = std::fs::read_to_string(&path).expect("read golden file");
    std::fs::remove_file(&path).ok();
    let parsed = Json::parse(&text).expect("sweep JSON must parse");

    assert_eq!(
        parsed.get("bench").and_then(Json::as_str),
        Some("sweep_native")
    );
    assert_eq!(parsed.get("unroll").and_then(Json::as_u64), Some(2));
    assert_eq!(parsed.get("batch").and_then(Json::as_u64), Some(2));
    assert_eq!(parsed.get("remat").and_then(Json::as_str), Some("full"));

    // Every (task, opt, mode, heads, seed) tuple appears exactly once,
    // in exact grid order (task → opt → mode → heads → seed).
    let cells = parsed
        .get("cells")
        .and_then(Json::as_arr)
        .expect("cells array");
    assert_eq!(cells.len(), expected.len());
    for (row, want) in cells.iter().zip(expected.iter()) {
        assert_eq!(
            row.get("task").and_then(Json::as_str),
            Some(want.task.name()),
        );
        assert_eq!(
            row.get("inner_opt").and_then(Json::as_str),
            Some(want.inner_opt.name()),
        );
        assert_eq!(
            row.get("mode").and_then(Json::as_str),
            Some(want.mode.name().as_str()),
        );
        assert_eq!(
            row.get("heads").and_then(Json::as_u64),
            Some(want.heads as u64),
        );
        assert_eq!(
            row.get("seed").and_then(Json::as_u64),
            Some(want.seed),
        );
        assert_eq!(
            row.get("label").and_then(Json::as_str),
            Some(want.label().as_str()),
        );
        // Per-cell loss aggregation fields must be present and finite.
        for key in ["final_loss", "loss_mean", "loss_std"] {
            let v = row
                .get(key)
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("cell missing `{key}`"));
            assert!(v.is_finite(), "{key} must be finite, got {v}");
        }
        assert!(
            row.get("peak_bytes").and_then(Json::as_f64).unwrap_or(0.0)
                > 0.0,
            "cells must carry the memory report"
        );
    }

    // Aggregates fold exactly the seed axis, preserving config order.
    let aggs = parsed
        .get("aggregates")
        .and_then(Json::as_arr)
        .expect("aggregates array");
    assert_eq!(aggs.len(), expected.len() / spec.n_seeds);
    for (i, agg) in aggs.iter().enumerate() {
        let want = &expected[i * spec.n_seeds];
        assert_eq!(
            agg.get("config").and_then(Json::as_str),
            Some(want.config_label().as_str()),
        );
        assert_eq!(
            agg.get("n_seeds").and_then(Json::as_u64),
            Some(spec.n_seeds as u64),
        );
        let mean = agg.get("final_mean").and_then(Json::as_f64).unwrap();
        let std = agg.get("final_std").and_then(Json::as_f64).unwrap();
        assert!(mean.is_finite());
        assert!(std.is_finite() && std >= 0.0);
    }
}

#[test]
fn sweep_heads_axis_changes_the_attention_cells_only() {
    // heads is a real axis for the attention task (different model
    // width/shape ⇒ different losses) and a no-op duplicate for the MLP
    // tasks — both facts the grid report relies on.
    let spec = SweepSpec {
        tasks: vec![NativeTask::HyperLr, NativeTask::Attention],
        inner_opts: vec![InnerOptimiser::Sgd],
        modes: vec![HypergradMode::Mixflow],
        heads: vec![1, 2],
        batch: 1,
        remat: CheckpointPolicy::Full,
        fd_epsilon: 1e-5,
        unroll: 2,
        steps: 2,
        base_seed: 5,
        n_seeds: 1,
        telemetry: false,
        threads: 1,
    };
    let runs = run_sweep(&spec);
    assert_eq!(runs.len(), 4);
    // Grid order: hyperlr/h1, hyperlr/h2, attention/h1, attention/h2.
    assert_eq!(runs[0].cell.label(), "hyperlr/sgd/mixflow/h1/seed5");
    assert_eq!(runs[1].cell.label(), "hyperlr/sgd/mixflow/h2/seed5");
    assert_eq!(runs[2].cell.label(), "attention/sgd/mixflow/h1/seed5");
    assert_eq!(runs[3].cell.label(), "attention/sgd/mixflow/h2/seed5");
    assert_eq!(
        runs[0].report.losses, runs[1].report.losses,
        "heads must not affect the hyperlr task"
    );
    assert_ne!(
        runs[2].report.losses, runs[3].report.losses,
        "heads must change the attention task"
    );
    // The attention cells carry KV counters; the MLP cells don't.
    let mem2 = runs[2].memory.expect("memory recorded");
    assert!(mem2.kv_peak_bytes > 0);
    assert_eq!(runs[0].memory.expect("memory").kv_peak_bytes, 0);
}

//! Acceptance suite for the approximate hypergradient strategies:
//! truncated back-propagation and EvoGrad.
//!
//! The load-bearing contracts:
//!
//! * **Exactness at full width** — `truncated:{horizon}` with
//!   `horizon ≥ T` takes literally the same code path as mixflow
//!   (`start = 0` reduces every windowing condition away), so the
//!   hypergradient must be bit-for-bit identical across random tasks,
//!   optimisers and checkpoint policies — not merely within 1e-12.
//! * **Memory for bias** — a proper truncation (`horizon < T`) must
//!   shrink both checkpoint bytes and the overall peak, monotonically
//!   in the horizon.
//! * **EvoGrad is O(1) in T** — no checkpoints ever, and the reported
//!   outer loss is the unperturbed one (it matches mixflow's to the
//!   values-vs-taped tolerance the fd oracle is held to).
//! * **Determinism** — both strategies are bit-identical across kernel
//!   thread counts, and EvoGrad's perturbation stream is a pure
//!   function of (seed, outer step): rewinding via `reseed` replays
//!   the exact estimate.
//! * **Descent sanity** — averaged EvoGrad estimates point the same
//!   way as the exact hypergradient on the hyper-LR task.

use mixflow::autodiff::engine::{HypergradEngine, HypergradMode};
use mixflow::autodiff::mixflow::{
    mixflow_hypergrad, mixflow_hypergrad_with, CheckpointPolicy,
};
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::autodiff::problems::{
    AttentionProblem, HyperLrProblem, LossWeightingProblem,
    MultiHeadAttentionProblem,
};
use mixflow::autodiff::tensor::Tensor;
use mixflow::autodiff::BilevelProblem;
use mixflow::obs::Counter;
use mixflow::util::proptest;

/// Random small bilevel instance spanning all four tasks and all three
/// inner optimisers — the same family the engine equivalence properties
/// use.
fn random_problem(g: &mut proptest::Gen) -> Box<dyn BilevelProblem> {
    let seed = g.rng.next_u64();
    let d = g.usize(2, 4);
    let hidden = g.usize(2, 5);
    let classes = g.usize(2, 4);
    let batch = g.usize(2, 5);
    let unroll = g.usize(1, 4);
    let alpha = g.f64(0.02, 0.12);
    let opt = *g.choose(&[
        InnerOptimiser::Sgd,
        InnerOptimiser::momentum(),
        InnerOptimiser::adam(),
    ]);
    match g.usize(0, 3) {
        0 => Box::new(
            HyperLrProblem::with_config(
                seed, d, hidden, classes, batch, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        1 => Box::new(
            LossWeightingProblem::with_config(
                seed,
                d,
                hidden,
                classes,
                batch,
                unroll,
                alpha,
                g.f64(0.0, 0.6),
            )
            .with_optimiser(opt),
        ),
        2 => Box::new(
            AttentionProblem::with_config(
                seed, d, batch, classes, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        _ => {
            let heads = g.usize(1, 3);
            let d_model = heads * g.usize(1, 2);
            let seqs = g.usize(1, 3);
            Box::new(
                MultiHeadAttentionProblem::with_config(
                    seed,
                    d_model,
                    heads,
                    seqs,
                    g.usize(2, 4),
                    classes,
                    unroll,
                    alpha,
                )
                .with_optimiser(opt),
            )
        }
    }
}

fn max_abs_diff(a: &[Tensor], b: &[Tensor]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| x.max_abs_diff(y))
        .fold(0.0f64, f64::max)
}

#[test]
fn property_truncated_full_horizon_is_bitwise_mixflow() {
    // horizon = T and horizon > T (clamped) must both reproduce the
    // mixflow hypergradient bit-for-bit across tasks × optimisers ×
    // checkpoint policies — same code path, same op sequence, so the
    // bound is literal 0.0, stronger than the 1e-12 acceptance line.
    proptest::check("truncated(T)≡mixflow", 16, |g| {
        let problem = random_problem(g);
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        let t = problem.unroll().max(1);
        let policy = *g.choose(&[
            CheckpointPolicy::Full,
            CheckpointPolicy::Remat { segment: 2 },
            CheckpointPolicy::Auto,
        ]);
        let full =
            mixflow_hypergrad_with(problem.as_ref(), &theta0, &eta, policy);
        for horizon in [t, t + 3] {
            let mut engine = HypergradEngine::builder()
                .mode(HypergradMode::Truncated { horizon })
                .checkpoint(policy)
                .build();
            let trunc = engine.run(problem.as_ref(), &theta0, &eta);
            let diff = max_abs_diff(&full.d_eta, &trunc.d_eta);
            if diff != 0.0 {
                return Err(format!(
                    "truncated horizon {horizon} (T = {t}, {} policy, {} \
                     opt) differs from mixflow by {diff:.3e}",
                    policy.name(),
                    problem.optimiser().name()
                ));
            }
            if full.outer_loss.to_bits() != trunc.outer_loss.to_bits() {
                return Err(format!(
                    "truncated horizon {horizon} changed the outer loss: \
                     {} vs {}",
                    trunc.outer_loss, full.outer_loss
                ));
            }
            if full.memory.checkpoint_bytes
                != trunc.memory.checkpoint_bytes
            {
                return Err(format!(
                    "full-width window must checkpoint exactly like \
                     mixflow: {} vs {}",
                    trunc.memory.checkpoint_bytes,
                    full.memory.checkpoint_bytes
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn truncated_window_cuts_checkpoint_and_peak_memory_monotonically() {
    // The acceptance criterion's shape: attention + Adam at T = 8, where
    // the optimiser state doubles the per-step checkpoint payload.  A
    // horizon < T must sit strictly below full mixflow on both ledgers,
    // and shrinking the horizon further must never grow them.
    let p = AttentionProblem::with_unroll(1, 8)
        .with_optimiser(InnerOptimiser::adam());
    let theta0 = p.theta0();
    let eta = p.eta0();
    let full = mixflow_hypergrad(&p, &theta0, &eta);
    let run_horizon = |h: usize| {
        let mut engine = HypergradEngine::builder()
            .mode(HypergradMode::Truncated { horizon: h })
            .build();
        engine.run(&p, &theta0, &eta)
    };
    let h4 = run_horizon(4);
    let h2 = run_horizon(2);
    for (label, trunc) in [("horizon 4", &h4), ("horizon 2", &h2)] {
        assert!(
            trunc.memory.checkpoint_bytes < full.memory.checkpoint_bytes,
            "{label}: checkpoints {} not below full mixflow {}",
            trunc.memory.checkpoint_bytes,
            full.memory.checkpoint_bytes
        );
        assert!(
            trunc.memory.peak_bytes < full.memory.peak_bytes,
            "{label}: peak {} not below full mixflow {}",
            trunc.memory.peak_bytes,
            full.memory.peak_bytes
        );
    }
    assert!(
        h2.memory.checkpoint_bytes <= h4.memory.checkpoint_bytes,
        "checkpoint bytes must be monotone in the horizon"
    );
}

#[test]
fn truncated_counts_the_steps_it_skips() {
    // Telemetry: a horizon-2 window over T = 6 unrolls all six steps
    // but differentiates only the last two — the registry must record
    // the other four as skipped.
    let p = HyperLrProblem::with_unroll(9, 6);
    let theta0 = p.theta0();
    let eta = p.eta0();
    let mut engine = HypergradEngine::builder()
        .mode(HypergradMode::Truncated { horizon: 2 })
        .telemetry(true)
        .build();
    let _ = engine.run(&p, &theta0, &eta);
    assert_eq!(
        engine.metrics().counter(Counter::TruncatedSkippedSteps),
        4,
        "T = 6 with horizon 2 skips exactly 4 adjoint steps"
    );
    // A full-width window skips nothing.
    let mut full_width = HypergradEngine::builder()
        .mode(HypergradMode::Truncated { horizon: 6 })
        .telemetry(true)
        .build();
    let _ = full_width.run(&p, &theta0, &eta);
    assert_eq!(
        full_width.metrics().counter(Counter::TruncatedSkippedSteps),
        0
    );
}

#[test]
fn evograd_is_o1_memory_and_counts_its_population() {
    let p = HyperLrProblem::with_unroll(7, 6);
    let theta0 = p.theta0();
    let eta = p.eta0();
    let mut engine = HypergradEngine::builder()
        .mode(HypergradMode::Evograd)
        .evo_population(6)
        .telemetry(true)
        .build();
    let h = engine.run(&p, &theta0, &eta);
    assert_eq!(
        h.memory.checkpoint_bytes, 0,
        "evograd stores no inner-loop checkpoints"
    );
    assert!(h.outer_loss.is_finite());
    assert!(h
        .d_eta
        .iter()
        .all(|g| g.data.iter().all(|v| v.is_finite())));
    assert_eq!(
        engine.metrics().counter(Counter::EvogradPerturbations),
        6,
        "one counted perturbation per population member"
    );
    // The reported outer loss is the *unperturbed* one: same θ_T as the
    // exact paths, so it matches mixflow to the values-vs-taped bound
    // the fd oracle is held to.
    let exact = mixflow_hypergrad(&p, &theta0, &eta);
    assert!(
        (h.outer_loss - exact.outer_loss).abs() < 1e-9,
        "evograd outer loss {} vs mixflow {}",
        h.outer_loss,
        exact.outer_loss
    );
}

#[test]
fn evograd_replays_bit_for_bit_under_reseed() {
    // The serving contract: the perturbation stream is a pure function
    // of (seed, outer step).  Two runs after identical reseeds must be
    // bit-for-bit equal, a different seed must actually change the
    // estimate, and rewinding restores the original stream even after
    // the engine has served intervening runs.
    let p = HyperLrProblem::with_unroll(5, 4);
    let theta0 = p.theta0();
    let eta = p.eta0();
    let mut engine = HypergradEngine::builder()
        .mode(HypergradMode::Evograd)
        .evo_seed(11)
        .build();
    let first = engine.run(&p, &theta0, &eta);
    let drift = engine.run(&p, &theta0, &eta);
    assert!(
        max_abs_diff(&first.d_eta, &drift.d_eta) != 0.0,
        "consecutive outer steps must draw fresh populations"
    );
    engine.reseed(11);
    let replay = engine.run(&p, &theta0, &eta);
    assert_eq!(
        max_abs_diff(&first.d_eta, &replay.d_eta),
        0.0,
        "reseed(11) must rewind the stream to the first run exactly"
    );
    engine.reseed(12);
    let other = engine.run(&p, &theta0, &eta);
    assert!(
        max_abs_diff(&first.d_eta, &other.d_eta) != 0.0,
        "a different seed must perturb differently"
    );
}

#[test]
fn evograd_estimates_a_descent_direction_on_hyperlr() {
    // Descent sanity, pinned seeds: the softmax-weighted population
    // estimate is biased (one-step η sensitivity) and stochastic, but
    // averaged over a few fresh populations it must point the same way
    // as the exact hypergradient on the hyper-LR task.  Everything here
    // is deterministic — fixed problem seed, fixed evo seed — so this
    // is a regression pin, not a flaky statistical test.
    let p = HyperLrProblem::with_unroll(11, 3);
    let theta0 = p.theta0();
    let eta = p.eta0();
    let exact = mixflow_hypergrad(&p, &theta0, &eta);
    let mut engine = HypergradEngine::builder()
        .mode(HypergradMode::Evograd)
        .evo_population(32)
        .evo_seed(7)
        .build();
    let mut mean: Vec<Tensor> =
        eta.iter().map(|e| Tensor::zeros(&e.shape)).collect();
    let runs = 8;
    for _ in 0..runs {
        let h = engine.run(&p, &theta0, &eta);
        for (m, g) in mean.iter_mut().zip(h.d_eta.iter()) {
            for (mv, gv) in m.data.iter_mut().zip(g.data.iter()) {
                *mv += gv / runs as f64;
            }
        }
    }
    let mut dot = 0.0;
    let mut n_mean = 0.0;
    let mut n_exact = 0.0;
    for (m, g) in mean.iter().zip(exact.d_eta.iter()) {
        for (mv, gv) in m.data.iter().zip(g.data.iter()) {
            dot += mv * gv;
            n_mean += mv * mv;
            n_exact += gv * gv;
        }
    }
    let cosine = dot / (n_mean.sqrt() * n_exact.sqrt()).max(1e-300);
    assert!(
        cosine > 0.0,
        "averaged evograd estimate must positively align with the exact \
         hypergradient, got cosine {cosine:.4}"
    );
}

#[test]
fn property_new_modes_are_bit_identical_across_thread_counts() {
    // The kernel pool's determinism contract extends to both new
    // strategies: thread count must not change a single ULP.  (For
    // evograd the engines share seed 0 / call 0, so the populations are
    // identical by construction and any diff is a kernel-pool bug.)
    proptest::check("strategies-thread-bit-identity", 8, |g| {
        let problem = random_problem(g);
        let horizon = g.usize(1, 5);
        let mode = *g.choose(&[
            HypergradMode::Truncated { horizon },
            HypergradMode::Evograd,
        ]);
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        let mut reference = None;
        for &t in &[1usize, 4] {
            let mut engine =
                HypergradEngine::builder().mode(mode).threads(t).build();
            let r = engine.run(problem.as_ref(), &theta0, &eta);
            match &reference {
                None => reference = Some(r),
                Some(base) => {
                    let diff = max_abs_diff(&base.d_eta, &r.d_eta);
                    if diff != 0.0 {
                        return Err(format!(
                            "{mode:?}: d_eta differs by {diff:.3e} between \
                             1 and {t} threads"
                        ));
                    }
                    if base.outer_loss.to_bits() != r.outer_loss.to_bits() {
                        return Err(format!(
                            "{mode:?}: outer_loss bits differ between 1 \
                             and {t} threads"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn truncated_trains_the_hyper_lr_toward_the_full_window_target() {
    // End-to-end sanity that the truncated path is usable as a trainer
    // gradient, not just correct in isolation: a few outer steps of
    // horizon-2 truncated descent on hyper-LR must move η in the same
    // direction as full mixflow from the same start, and reduce the
    // outer loss.
    let p = HyperLrProblem::with_unroll(13, 6);
    let theta0 = p.theta0();
    let mut eta_trunc = p.eta0();
    let mut eta_full = p.eta0();
    let mut trunc_engine = HypergradEngine::builder()
        .mode(HypergradMode::Truncated { horizon: 2 })
        .build();
    let mut full_engine = HypergradEngine::builder().build();
    let first_loss =
        full_engine.run(&p, &theta0, &eta_full).outer_loss;
    let lr = 0.05;
    let mut last_trunc = f64::INFINITY;
    let mut last_full = f64::INFINITY;
    for _ in 0..6 {
        let ht = trunc_engine.run(&p, &theta0, &eta_trunc);
        let hf = full_engine.run(&p, &theta0, &eta_full);
        last_trunc = ht.outer_loss;
        last_full = hf.outer_loss;
        for (e, g) in eta_trunc.iter_mut().zip(ht.d_eta.iter()) {
            for (ev, gv) in e.data.iter_mut().zip(g.data.iter()) {
                *ev -= lr * gv;
            }
        }
        for (e, g) in eta_full.iter_mut().zip(hf.d_eta.iter()) {
            for (ev, gv) in e.data.iter_mut().zip(g.data.iter()) {
                *ev -= lr * gv;
            }
        }
    }
    assert!(
        last_trunc < first_loss,
        "truncated descent must reduce the outer loss: {last_trunc} vs \
         first {first_loss}"
    );
    assert!(
        last_full < first_loss,
        "full mixflow descent must reduce the outer loss"
    );
}

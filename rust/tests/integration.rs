//! Integration tests over real artifacts (DESIGN.md §6 item 2).
//!
//! These require `make artifacts` to have run; each test skips gracefully
//! (with a loud message) when the manifest is missing so `cargo test`
//! stays usable on a fresh clone.  Tests that *execute* artifacts
//! additionally need the `pjrt` feature (and a real XLA toolchain behind
//! it); analysis-only tests run everywhere.

use mixflow::coordinator::runner::{analyze_artifact, pair_ratios};
use mixflow::hlo::{flops::CostModel, parser, MemorySimulator};
use mixflow::runtime::Manifest;
#[cfg(feature = "pjrt")]
use mixflow::runtime::Runtime;

fn manifest() -> Option<Manifest> {
    match Manifest::discover() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn manifest_is_complete() {
    let Some(m) = manifest() else { return };
    assert!(m.artifacts.len() >= 50, "expected a full artifact set");
    for group in [
        "fig1_toy",
        "table2_ablation",
        "table3_ablation",
        "fig4_sweep",
        "fig5_data",
        "fig6_components",
        "fig7_ladder",
        "kernelized",
        "e2e",
    ] {
        assert!(!m.group(group).is_empty(), "group {group} missing");
    }
    // Every artifact's HLO file exists and has input/output specs.
    for meta in m.artifacts.values() {
        assert!(
            m.hlo_path(meta).exists(),
            "missing HLO file for {}",
            meta.key
        );
        assert!(!meta.inputs.is_empty(), "{} has no inputs", meta.key);
        assert!(!meta.outputs.is_empty(), "{} has no outputs", meta.key);
    }
}

#[test]
fn all_artifacts_parse_and_simulate() {
    let Some(m) = manifest() else { return };
    // Parse *every* artifact — the parser must handle the full corpus.
    // (This is also the strongest fuzz the HLO grammar gets: 100+ real
    // modules, ~300 MB of text.)
    let mut checked = 0;
    for meta in m.artifacts.values() {
        // Large ladder artifacts are covered by fig7; bound test time by
        // skipping files > 12 MB here.
        let path = m.hlo_path(meta);
        if std::fs::metadata(&path).map(|s| s.len()).unwrap_or(0) > 12 << 20 {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let module = parser::parse_module(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", meta.key));
        let mem = MemorySimulator::new(&module).run();
        assert!(mem.peak_dynamic > 0, "{}: zero dynamic peak", meta.key);
        assert!(mem.param_bytes > 0, "{}: zero params", meta.key);
        let cost = CostModel::new(&module).run();
        assert!(cost.flops > 0.0, "{}: zero flops", meta.key);
        checked += 1;
    }
    assert!(checked >= 50, "only {checked} artifacts checked");
}

#[test]
fn mixflow_reduces_dynamic_memory_on_every_pair() {
    let Some(m) = manifest() else { return };
    // The paper's Figure 4 claim: every configuration wins on memory.
    for group in ["fig4_sweep", "fig6_components", "fig7_ladder"] {
        let metas = m.group(group);
        let measurements: Vec<_> = metas
            .iter()
            .filter_map(|meta| analyze_artifact(&m, meta, group).ok())
            .collect();
        let pairs = pair_ratios(&measurements);
        assert!(!pairs.is_empty(), "{group}: no pairs");
        for p in &pairs {
            assert!(
                p.dynamic_ratio > 1.0,
                "{group}/{}: mixflow did not reduce simulated dynamic \
                 memory (ratio {:.3})",
                p.workload,
                p.dynamic_ratio
            );
        }
    }
}

#[test]
fn layer_scaling_matches_eq12() {
    let Some(m) = manifest() else { return };
    // Eq. (12) predicts the gain grows ~linearly in n_layers on
    // accelerator backends.  Our idealised-liveness simulator compresses
    // the ratio (see EXPERIMENTS.md "Reading guide"), so the invariant we
    // pin is that the mixflow gain does not *collapse* as L grows.
    let metas = m.group("fig6_components");
    let measurements: Vec<_> = metas
        .iter()
        .filter_map(|meta| analyze_artifact(&m, meta, "fig6").ok())
        .collect();
    let pairs = pair_ratios(&measurements);
    let ratio = |name: &str| {
        pairs
            .iter()
            .find(|p| p.size_name == name)
            .map(|p| p.dynamic_ratio)
    };
    let (Some(lo), Some(hi)) =
        (ratio("comp_n_layers2"), ratio("comp_n_layers16"))
    else {
        eprintln!("SKIP: layer-sweep artifacts missing");
        return;
    };
    assert!(
        hi / lo > 0.7,
        "mixflow layer-gain collapsed: L16/L2 = {:.2}",
        hi / lo
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn exec_pair_produces_identical_gradients() {
    let Some(m) = manifest() else { return };
    let runtime = Runtime::with_manifest(m).unwrap();
    // Smallest fig4 pair (cheapest compile).
    let metas = runtime.manifest.group("fig4_sweep");
    let mut pairs = runtime.manifest.pairs(&metas);
    pairs.sort_by_key(|(d, _)| (d.param_count, d.seq_len));
    let Some((d, x)) = pairs.first() else {
        panic!("no fig4 pairs");
    };
    let ld = runtime.load(&d.key).unwrap();
    let lx = runtime.load(&x.key).unwrap();
    let inputs = ld.default_inputs(0).unwrap();
    let od = ld.execute(&inputs).unwrap();
    let ox = lx.execute(&inputs).unwrap();
    assert_eq!(od.len(), ox.len());
    let mut max_diff = 0f32;
    for (a, b) in od.iter().zip(ox.iter()) {
        let va = a.to_vec::<f32>().unwrap();
        let vb = b.to_vec::<f32>().unwrap();
        assert_eq!(va.len(), vb.len());
        for (p, q) in va.iter().zip(vb.iter()) {
            max_diff = max_diff.max((p - q).abs());
        }
    }
    assert!(
        max_diff < 1e-3,
        "meta-gradients diverge: max |Δ| = {max_diff}"
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn exec_artifact_output_shapes_match_manifest() {
    let Some(m) = manifest() else { return };
    let runtime = Runtime::with_manifest(m).unwrap();
    let metas = runtime.manifest.group("kernelized");
    let Some(meta) = metas.first() else { panic!("kernelized missing") };
    let loaded = runtime.load(&meta.key).unwrap();
    let inputs = loaded.default_inputs(1).unwrap();
    let outputs = loaded.execute(&inputs).unwrap();
    assert_eq!(outputs.len(), meta.outputs.len());
    for (lit, spec) in outputs.iter().zip(meta.outputs.iter()) {
        assert_eq!(lit.element_count(), spec.elements());
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn train_step_runs_and_improves() {
    let Some(m) = manifest() else { return };
    let runtime = Runtime::with_manifest(m).unwrap();
    let Some(key) = runtime
        .manifest
        .group("e2e")
        .iter()
        .find(|meta| meta.task == "maml")
        .map(|meta| meta.key.clone())
    else {
        panic!("e2e maml artifact missing");
    };
    let mut trainer = mixflow::meta::MetaTrainer::new(&runtime, &key, 3);
    let report = trainer.train(30).unwrap();
    assert_eq!(report.losses.len(), 30);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let (head, tail) = report.improvement(5);
    assert!(
        tail < head,
        "30 outer steps must improve val loss ({head:.4} → {tail:.4})"
    );
}

#[test]
fn save_inner_grads_shows_in_static_memory() {
    let Some(m) = manifest() else { return };
    // Within the table3 cube at fixed (fwdrev, remat): saving inner grads
    // moves ∇L storage into the checkpoint (static) side.
    let metas = m.group("table3_ablation");
    let find = |sg: bool| {
        metas
            .iter()
            .find(|x| x.mode == "fwdrev" && x.block_remat && x.save_inner_grads == sg)
            .and_then(|x| analyze_artifact(&m, x, "t3").ok())
    };
    let (Some(no_sg), Some(sg)) = (find(false), find(true)) else {
        panic!("table3 artifacts missing");
    };
    // With grads saved the *dynamic* peak must not grow.
    assert!(
        sg.sim_dynamic_bytes <= no_sg.sim_dynamic_bytes * 11 / 10,
        "save_inner_grads blew up dynamic memory: {} vs {}",
        sg.sim_dynamic_bytes,
        no_sg.sim_dynamic_bytes
    );
}

//! Telemetry acceptance: trace-schema golden files, registry-vs-report
//! conformance on a warm persistent engine, telemetry-off bit-identity
//! with a pinned walltime overhead bound, and the `--trace-format` CLI
//! enum contract (mirroring the `rust/tests/sweep.rs` golden-file
//! pattern for the sweep report schema).

use mixflow::autodiff::engine::{HypergradEngine, HypergradMode};
use mixflow::autodiff::mixflow::{BilevelProblem, CheckpointPolicy};
use mixflow::autodiff::problems::HyperLrProblem;
use mixflow::obs::{
    chrome_trace, trace_jsonl, Counter, Phase, StepTrace, TraceFormat,
};
use mixflow::util::args::CliEnum;
use mixflow::util::json::Json;

/// Run `steps` hypergradients on a fresh telemetry-enabled engine in the
/// given mode and drain the traces.
fn traced_steps(
    mode: HypergradMode,
    policy: CheckpointPolicy,
    unroll: usize,
    steps: usize,
) -> Vec<StepTrace> {
    let problem = HyperLrProblem::with_unroll(3, unroll);
    let theta0 = problem.theta0();
    let eta = problem.eta0();
    let mut engine = HypergradEngine::builder()
        .mode(mode)
        .checkpoint(policy)
        .telemetry(true)
        .build();
    for _ in 0..steps {
        let h = engine.run(&problem, &theta0, &eta);
        assert!(h.outer_loss.is_finite());
    }
    engine.take_step_traces()
}

/// Each strategy must emit its full phase vocabulary: `naive` the
/// forward + backward pair, `mixflow` under remat all six phases, `fd`
/// its forward evaluations — and never a `jvp` span outside mixflow.
#[test]
fn strategies_emit_their_complete_phase_sets() {
    let naive = traced_steps(
        HypergradMode::Naive,
        CheckpointPolicy::Full,
        4,
        2,
    );
    assert_eq!(naive.len(), 2);
    for t in &naive {
        assert_eq!(t.strategy, "naive");
        assert!(t.phase(Phase::Forward).is_some());
        assert!(t.phase(Phase::BackwardVjp).is_some());
        assert!(t.phase(Phase::Jvp).is_none(), "naive path has no JVP");
        assert!(t.dur_us > 0);
    }

    // Remat segment 2 over unroll 4 exercises every mixflow phase,
    // including the checkpoint-thinning rebuild.
    let mixflow = traced_steps(
        HypergradMode::Mixflow,
        CheckpointPolicy::Remat { segment: 2 },
        4,
        2,
    );
    for t in &mixflow {
        assert_eq!(t.strategy, "mixflow");
        for phase in Phase::ALL {
            assert!(
                t.phase(phase).is_some(),
                "mixflow+remat step {} must span `{}`",
                t.step,
                phase.name()
            );
        }
        // T=4 / K=2 stores ceil includes t=0 boundary checkpoints and
        // rebuilds the intra-segment states on the way back.
        assert!(t.counter("checkpoint.stores").unwrap_or(0) > 0);
        assert!(t.counter("remat.rebuilds").unwrap_or(0) > 0);
    }

    let fd = traced_steps(HypergradMode::Fd, CheckpointPolicy::Full, 2, 1);
    for t in &fd {
        assert_eq!(t.strategy, "fd");
        let fwd = t.phase(Phase::Forward).expect("fd spans its unrolls");
        // Base point + one ± pair per η element means several spans.
        assert!(fwd.count >= 3, "fd forward spans, got {}", fwd.count);
        assert!(t.phase(Phase::BackwardVjp).is_none());
    }
}

/// Golden-file pin on the JSONL schema: dump, re-read, reparse every
/// line, and require step/phase/counter completeness.
#[test]
fn jsonl_trace_round_trips_with_counter_completeness() {
    let cells = vec![
        (
            "hyperlr/naive".to_string(),
            traced_steps(HypergradMode::Naive, CheckpointPolicy::Full, 4, 2),
        ),
        (
            "hyperlr/mixflow-remat2".to_string(),
            traced_steps(
                HypergradMode::Mixflow,
                CheckpointPolicy::Remat { segment: 2 },
                4,
                2,
            ),
        ),
    ];
    let path = std::env::temp_dir().join(format!(
        "mixflow_trace_golden_{}.jsonl",
        std::process::id()
    ));
    std::fs::write(&path, trace_jsonl(&cells)).expect("write trace file");
    let text = std::fs::read_to_string(&path).expect("read trace file");
    std::fs::remove_file(&path).ok();

    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4, "one record per (cell, outer step)");
    for (i, line) in lines.iter().enumerate() {
        let rec = Json::parse(line).expect("every trace line parses");
        let cell = rec.get("cell").and_then(Json::as_str).expect("cell");
        let want_cell = &cells[i / 2].0;
        assert_eq!(cell, want_cell);
        assert_eq!(
            rec.get("step").and_then(Json::as_u64),
            Some((i % 2) as u64)
        );
        let strategy =
            rec.get("strategy").and_then(Json::as_str).expect("strategy");
        assert!(want_cell.contains(strategy));
        assert!(rec.get("dur_us").and_then(Json::as_u64).unwrap_or(0) > 0);

        // Phase objects carry count + seconds for every recorded phase.
        let phases = rec.get("phases").expect("phases object");
        let fwd = phases.get("forward").expect("forward phase");
        assert!(fwd.get("count").and_then(Json::as_u64).unwrap_or(0) > 0);
        assert!(fwd.get("seconds").and_then(Json::as_f64).is_some());

        // Counter completeness: the delta block lists every registry
        // counter by its dotted name, zeros included.
        let counters = rec.get("counters").expect("counters object");
        for c in Counter::ALL {
            assert!(
                counters.get(c.name()).and_then(Json::as_u64).is_some(),
                "record {i} missing counter `{}`",
                c.name()
            );
        }
        assert!(
            counters
                .get("tape.nodes")
                .and_then(Json::as_u64)
                .unwrap_or(0)
                > 0
        );

        // The MemoryReport conformance block rides along.
        let report = rec.get("report").expect("report object");
        for key in ["arena_allocs", "arena_reuses", "nodes", "peak_bytes"] {
            assert!(
                report.get(key).and_then(Json::as_u64).is_some(),
                "record {i} missing report field `{key}`"
            );
        }
    }
}

/// The Chrome export must be a well-formed trace-event document: one
/// process-name metadata record per cell and only nonzero-duration "X"
/// events after it — that is what Perfetto / `chrome://tracing` loads.
#[test]
fn chrome_trace_round_trips_as_trace_event_json() {
    let steps =
        traced_steps(HypergradMode::Mixflow, CheckpointPolicy::Full, 4, 2);
    let n_events: usize = steps.iter().map(|s| s.events.len() + 1).sum();
    let cells = vec![("hyperlr/mixflow".to_string(), steps)];

    let path = std::env::temp_dir().join(format!(
        "mixflow_trace_golden_{}.chrome.json",
        std::process::id()
    ));
    mixflow::obs::write_trace(
        path.to_str().expect("temp path is utf-8"),
        TraceFormat::Chrome,
        &cells,
    )
    .expect("write chrome trace");
    let text = std::fs::read_to_string(&path).expect("read chrome trace");
    std::fs::remove_file(&path).ok();

    let doc = Json::parse(&text).expect("chrome trace parses as JSON");
    assert_eq!(
        doc.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms")
    );
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert_eq!(events.len(), 1 + n_events, "metadata + step/span events");
    assert_eq!(events[0].get("ph").and_then(Json::as_str), Some("M"));
    assert_eq!(
        events[0].path(&["args", "name"]).and_then(Json::as_str),
        Some("hyperlr/mixflow")
    );
    for ev in &events[1..] {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert!(ev.get("pid").and_then(Json::as_u64).is_some());
        assert!(ev.get("ts").and_then(Json::as_u64).is_some());
        assert!(
            ev.get("dur").and_then(Json::as_u64).unwrap_or(0) >= 1,
            "complete events need a nonzero duration"
        );
    }
    // Re-serialising what chrome_trace built gives the same document.
    assert_eq!(chrome_trace(&cells).pretty() + "\n", text);
}

/// Registry-vs-`MemoryReport` conformance on one persistent engine:
/// the engine mirrors arena deltas into the registry independently of
/// the strategy's own bookkeeping, and the warm second step must both
/// agree with its report and reuse strictly more than the cold first.
#[test]
fn warm_engine_registry_matches_memory_report() {
    let problem = HyperLrProblem::with_unroll(3, 4);
    let theta0 = problem.theta0();
    let eta = problem.eta0();
    let mut engine = HypergradEngine::builder().telemetry(true).build();

    let h1 = engine.run(&problem, &theta0, &eta);
    let h2 = engine.run(&problem, &theta0, &eta);
    let traces = engine.step_traces();
    assert_eq!(traces.len(), 2);

    for (t, h) in traces.iter().zip([&h1, &h2]) {
        assert_eq!(
            t.counter("arena.allocs"),
            Some(h.memory.arena_allocs as u64),
            "registry alloc delta must match the MemoryReport"
        );
        assert_eq!(
            t.counter("arena.reuses"),
            Some(h.memory.arena_reuses as u64),
            "registry reuse delta must match the MemoryReport"
        );
        // The trace's own conformance block carries the same numbers.
        assert_eq!(
            t.report_counter("arena_allocs"),
            Some(h.memory.arena_allocs as u64)
        );
        assert_eq!(
            t.report_counter("arena_reuses"),
            Some(h.memory.arena_reuses as u64)
        );
        assert_eq!(t.report_counter("nodes"), Some(h.memory.nodes as u64));
    }

    // Warm-arena acceptance: the second outer step draws from the
    // first step's recycled buffers.
    let (cold, warm) = (&traces[0], &traces[1]);
    assert!(
        warm.counter("arena.reuses") > cold.counter("arena.reuses"),
        "warm step must reuse strictly more than the cold step"
    );
    assert!(
        warm.counter("arena.allocs") < cold.counter("arena.allocs"),
        "warm step must allocate strictly less than the cold step"
    );
    // Registry totals accumulate across steps (they survive the drain).
    let registry = engine.metrics();
    assert_eq!(
        registry.counter(Counter::ArenaAllocs),
        (h1.memory.arena_allocs + h2.memory.arena_allocs) as u64
    );
    assert_eq!(
        registry.counter(Counter::ArenaReuses),
        (h1.memory.arena_reuses + h2.memory.arena_reuses) as u64
    );
}

/// Telemetry off must be free: bit-identical hypergradients, no traces,
/// and at most a few percent of walltime next to an instrumented twin.
#[test]
fn telemetry_off_is_bit_identical_with_bounded_overhead() {
    let problem = HyperLrProblem::with_unroll(3, 16);
    let theta0 = problem.theta0();
    let eta = problem.eta0();
    let mut off = HypergradEngine::builder().build();
    let mut on = HypergradEngine::builder().telemetry(true).build();

    // Bit-identity: the disabled path takes no timestamps and writes no
    // counters, so the numerics cannot differ in any bit.
    let h_off = off.run(&problem, &theta0, &eta);
    let h_on = on.run(&problem, &theta0, &eta);
    assert_eq!(h_off.d_eta.len(), h_on.d_eta.len());
    for (a, b) in h_off.d_eta.iter().zip(h_on.d_eta.iter()) {
        assert_eq!(a.shape, b.shape);
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "telemetry must not perturb the hypergradient"
            );
        }
    }
    assert_eq!(h_off.outer_loss.to_bits(), h_on.outer_loss.to_bits());
    assert!(off.step_traces().is_empty(), "off engine records nothing");
    assert_eq!(on.step_traces().len(), 1);

    // Overhead: interleaved warm samples, best-of-N on each side so a
    // single scheduler hiccup cannot fail the pin.  ≤5% is the
    // acceptance bound; the disabled comparison below it is the real
    // claim (`off` here IS the uninstrumented production path).
    for _ in 0..3 {
        off.run(&problem, &theta0, &eta);
        on.run(&problem, &theta0, &eta);
    }
    let mut off_min = f64::INFINITY;
    let mut on_min = f64::INFINITY;
    for _ in 0..12 {
        let t = std::time::Instant::now();
        off.run(&problem, &theta0, &eta);
        off_min = off_min.min(t.elapsed().as_secs_f64());
        let t = std::time::Instant::now();
        on.run(&problem, &theta0, &eta);
        on_min = on_min.min(t.elapsed().as_secs_f64());
    }
    assert!(
        on_min <= off_min * 1.05,
        "telemetry-on best step {on_min:.3e}s exceeds 105% of \
         telemetry-off best {off_min:.3e}s"
    );
}

/// `--trace-format` round-trips through the `CliEnum` contract exactly
/// like the PR-4 enums: every variant parses, names survive a
/// parse→name→parse cycle, and the error list is derived, not written.
#[test]
fn trace_format_cli_enum_round_trips() {
    for v in TraceFormat::variants() {
        let parsed = TraceFormat::parse(v)
            .unwrap_or_else(|| panic!("variant {v:?} must parse"));
        assert_eq!(parsed.name(), *v);
        assert_eq!(TraceFormat::parse(&parsed.name()), Some(parsed));
    }
    assert_eq!(TraceFormat::valid_values(), "jsonl|chrome");
    // Case/whitespace tolerance and the Perfetto alias.
    assert_eq!(TraceFormat::parse(" JSONL\t"), Some(TraceFormat::Jsonl));
    assert_eq!(TraceFormat::parse("Chrome"), Some(TraceFormat::Chrome));
    assert_eq!(TraceFormat::parse("perfetto"), Some(TraceFormat::Chrome));
    assert_eq!(TraceFormat::parse("csv"), None);
    assert_eq!(TraceFormat::parse(""), None);
}

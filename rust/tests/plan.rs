//! Acceptance tests for compiled step plans (`autodiff::plan`): replay
//! must be bit-for-bit equal to dynamic taping across every strategy and
//! checkpoint policy, warm replays must stop touching the allocator, a
//! topology change must fall back (correctly) and recompile, and the
//! plan's liveness schedule must agree exactly with the `hlo::memory`
//! analyzer on the plan's own HLO export.

use mixflow::autodiff::engine::HypergradEngine;
use mixflow::autodiff::mixflow::CheckpointPolicy;
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::autodiff::problems::{
    AttentionProblem, HyperLrProblem, LossWeightingProblem,
    MultiHeadAttentionProblem,
};
use mixflow::autodiff::tensor::Tensor;
use mixflow::autodiff::{BilevelProblem, PlanKey};
use mixflow::hlo::memory::analyze_text;
use mixflow::meta::HypergradMode;
use mixflow::util::proptest;

/// Plan replay re-records the same builder ops against the same values —
/// only the buffer *sourcing* changes — so plan-on and plan-off runs are
/// expected to agree exactly (0.0); the assertion bound is 1e-12.
const PLAN_TOL: f64 = 1e-12;

fn max_abs_diff(a: &[Tensor], b: &[Tensor]) -> f64 {
    assert_eq!(a.len(), b.len(), "gradient pytree arity");
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| x.max_abs_diff(y))
        .fold(0.0, f64::max)
}

/// Random small bilevel instance spanning all four tasks and all three
/// inner optimisers (same family as the equivalence properties in
/// `rust/tests/autodiff.rs`).
fn random_problem(g: &mut proptest::Gen) -> Box<dyn BilevelProblem> {
    let seed = g.rng.next_u64();
    let d = g.usize(2, 4);
    let hidden = g.usize(2, 5);
    let classes = g.usize(2, 4);
    let batch = g.usize(2, 5);
    let unroll = g.usize(1, 4);
    let alpha = g.f64(0.02, 0.12);
    let opt = *g.choose(&[
        InnerOptimiser::Sgd,
        InnerOptimiser::momentum(),
        InnerOptimiser::adam(),
    ]);
    match g.usize(0, 3) {
        0 => Box::new(
            HyperLrProblem::with_config(
                seed, d, hidden, classes, batch, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        1 => Box::new(
            LossWeightingProblem::with_config(
                seed,
                d,
                hidden,
                classes,
                batch,
                unroll,
                alpha,
                g.f64(0.0, 0.6),
            )
            .with_optimiser(opt),
        ),
        2 => Box::new(
            AttentionProblem::with_config(
                seed, d, batch, classes, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        _ => {
            let heads = g.usize(1, 3);
            let d_model = heads * g.usize(1, 2);
            let seqs = g.usize(1, 3);
            Box::new(
                MultiHeadAttentionProblem::with_config(
                    seed,
                    d_model,
                    heads,
                    seqs,
                    g.usize(2, 4),
                    classes,
                    unroll,
                    alpha,
                )
                .with_optimiser(opt),
            )
        }
    }
}

#[test]
fn property_plan_replay_is_bitwise_equal_to_dynamic_taping() {
    // Two persistent engines, identical except for the plan knob, run the
    // same outer steps; cold (compile) and warm (replay) hypergradients
    // must both match the always-dynamic engine.  Covers naive / mixflow
    // / fd strategies and all three checkpoint policies over the random
    // task × optimiser family.
    proptest::check("plan≡dynamic", 10, |g| {
        let problem = random_problem(g);
        let mode = *g.choose(&[
            HypergradMode::Naive,
            HypergradMode::Mixflow,
            HypergradMode::Fd,
        ]);
        let policy = *g.choose(&[
            CheckpointPolicy::Full,
            CheckpointPolicy::Remat { segment: 2 },
            CheckpointPolicy::Auto,
        ]);
        let mut planned = HypergradEngine::builder()
            .mode(mode)
            .checkpoint(policy)
            .build();
        let mut dynamic = HypergradEngine::builder()
            .mode(mode)
            .checkpoint(policy)
            .plan(false)
            .build();
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        for step in 0..2 {
            let a = planned.run(problem.as_ref(), &theta0, &eta);
            let b = dynamic.run(problem.as_ref(), &theta0, &eta);
            let diff = max_abs_diff(&a.d_eta, &b.d_eta);
            if diff > PLAN_TOL {
                return Err(format!(
                    "{mode:?}/{policy:?} step {step}: plan vs dynamic \
                     d_eta diff {diff:.3e} (expected exactly 0)"
                ));
            }
            let ldiff = (a.outer_loss - b.outer_loss).abs();
            if ldiff > PLAN_TOL {
                return Err(format!(
                    "{mode:?}/{policy:?} step {step}: plan vs dynamic \
                     outer_loss diff {ldiff:.3e}"
                ));
            }
        }
        let stats = planned.plan_stats();
        if stats.fallbacks != 0 {
            return Err(format!(
                "{mode:?}/{policy:?}: steady-state topology must never \
                 fall back (got {} fallbacks)",
                stats.fallbacks
            ));
        }
        if stats.replays == 0 {
            return Err(format!(
                "{mode:?}/{policy:?}: two outer steps compiled {} plans \
                 but never replayed one",
                stats.compiles
            ));
        }
        let off = dynamic.plan_stats();
        if off.compiles != 0 || off.replays != 0 {
            return Err(format!(
                "plan(false) engine still ran the plan machinery \
                 (compiles {}, replays {})",
                off.compiles, off.replays
            ));
        }
        Ok(())
    });
}

#[test]
fn warm_replay_allocator_traffic_plateaus() {
    // Persistent mixflow engine, full checkpointing, T = 4: the cycle
    // stream per run is 4 Inner + 1 Outer + 4 Backward.  Run 1 compiles
    // one plan per key (and already replays the later Inner/Backward
    // cycles); from run 2 every cycle replays warm against its slot
    // table.  Cycle-internal take-backed buffers then never touch the
    // allocator (the tape-level zero-alloc pin lives in the `tape.rs`
    // unit tests); what remains per warm run is the constant set of
    // buffers that *escape* the tape by design — checkpoints and
    // returned JVP tangents are cloned out and freed to the system, so
    // they re-alloc identically every run.  The pin is therefore a
    // plateau: warm allocs strictly below cold, and exactly equal
    // between consecutive warm runs.
    let problem = HyperLrProblem::with_config(7, 3, 4, 3, 4, 4, 0.05)
        .with_optimiser(InnerOptimiser::adam());
    let mut engine = HypergradEngine::builder().build();
    let theta0 = problem.theta0();
    let eta = problem.eta0();

    let h1 = engine.run(&problem, &theta0, &eta);
    let h2 = engine.run(&problem, &theta0, &eta);
    let h3 = engine.run(&problem, &theta0, &eta);

    assert!(h1.memory.arena_allocs > 0, "cold run must allocate");
    assert!(
        h2.memory.arena_allocs < h1.memory.arena_allocs,
        "warm run allocs ({}) must drop strictly below cold ({})",
        h2.memory.arena_allocs,
        h1.memory.arena_allocs
    );
    assert_eq!(
        h3.memory.arena_allocs, h2.memory.arena_allocs,
        "warm replays must plateau: no new allocator traffic beyond \
         the per-run escaped-buffer set"
    );
    assert!(
        h2.memory.arena_reuses > 0 && h3.memory.arena_reuses > 0,
        "warm runs must recirculate buffers"
    );

    // Replays are bit-for-bit: the plan only changes where buffers come
    // from, never what is written into them.
    assert_eq!(
        max_abs_diff(&h1.d_eta, &h3.d_eta),
        0.0,
        "cold vs warm hypergradients must be bitwise identical"
    );
    assert_eq!(h1.outer_loss.to_bits(), h3.outer_loss.to_bits());

    // Exactly one compile per key — Inner, Outer, Backward — and every
    // later cycle a replay: run 1 replays 3 Inner + 3 Backward cycles,
    // runs 2 and 3 replay all 9 each.
    let stats = engine.plan_stats();
    assert_eq!(stats.compiles, 3, "one compile per plan key");
    assert_eq!(stats.fallbacks, 0);
    assert_eq!(stats.replays, 6 + 9 + 9);
}

#[test]
fn engine_plans_export_hlo_that_matches_the_memory_analyzer() {
    // The compiled plan IS a liveness schedule; exporting it as HLO text
    // and running the repo's hlo::memory simulator over it must
    // reproduce the plan's own peak-bytes number exactly (zero
    // tolerance: same last-use liveness, same 8-byte f64 elements), with
    // one HLO instruction per tape node.
    let problem = AttentionProblem::with_config(11, 3, 4, 3, 3, 0.05)
        .with_optimiser(InnerOptimiser::adam());
    let mut engine = HypergradEngine::builder().build();
    let theta0 = problem.theta0();
    let eta = problem.eta0();
    engine.run(&problem, &theta0, &eta);

    for key in [PlanKey::Inner, PlanKey::Outer, PlanKey::Backward] {
        let plan = engine
            .plan(key)
            .unwrap_or_else(|| panic!("no compiled {} plan", key.name()));
        let text = plan.to_hlo_text();
        let report = analyze_text(&text).unwrap_or_else(|e| {
            panic!("{} plan exported unparseable HLO: {e:?}", key.name())
        });
        assert_eq!(
            report.peak_dynamic as usize,
            plan.peak_bytes(),
            "{} plan: hlo::memory peak vs plan liveness peak",
            key.name()
        );
        assert_eq!(
            report.instructions,
            plan.nodes(),
            "{} plan: one HLO instruction per tape node",
            key.name()
        );
    }
}

#[test]
fn topology_change_falls_back_recompiles_and_stays_correct() {
    // Re-using one engine across two differently-shaped problems: each
    // key's first cycle under the new shape diverges from its armed
    // plan, completes on the dynamic path (values correct), counts one
    // fallback and recompiles; after that the new plans replay cleanly.
    let small = HyperLrProblem::with_config(3, 2, 3, 2, 3, 2, 0.05);
    let big = HyperLrProblem::with_config(3, 4, 5, 3, 4, 2, 0.05);
    let mut engine = HypergradEngine::builder().build();

    engine.run(&small, &small.theta0(), &small.eta0());
    assert_eq!(engine.plan_stats().compiles, 3);
    assert_eq!(engine.plan_stats().fallbacks, 0);

    let big_theta0 = big.theta0();
    let big_eta = big.eta0();
    let h_big = engine.run(&big, &big_theta0, &big_eta);
    let stats = engine.plan_stats();
    assert_eq!(
        stats.fallbacks, 3,
        "each key's first cycle under the new shape must fall back once"
    );
    assert_eq!(stats.compiles, 6, "each fallback recompiles its key");

    // The fallback cycles recorded dynamically, so the result is still
    // exactly the no-plan hypergradient.
    let mut reference = HypergradEngine::builder().plan(false).build();
    let h_ref = reference.run(&big, &big_theta0, &big_eta);
    let diff = max_abs_diff(&h_big.d_eta, &h_ref.d_eta);
    assert!(
        diff <= PLAN_TOL,
        "fallback run drifted from dynamic taping by {diff:.3e}"
    );

    // The recompiled plans are healthy: another outer step replays with
    // no further fallbacks.
    let before = engine.plan_stats();
    engine.run(&big, &big_theta0, &big_eta);
    let after = engine.plan_stats();
    assert_eq!(after.fallbacks, before.fallbacks);
    assert_eq!(after.compiles, before.compiles);
    assert!(after.replays > before.replays);
}

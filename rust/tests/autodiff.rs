//! Integration tests for the native autodiff engine: finite-difference
//! checks for every op, property-tested naive ≈ mixflow hypergradient
//! agreement, persistent-engine ≡ fresh-call equivalence, CLI enum
//! round-trips, the tape-memory regression, and native E2E training.

use mixflow::autodiff::engine::HypergradEngine;
use mixflow::autodiff::mixflow::{
    inner_step_values, mixflow_hypergrad, mixflow_hypergrad_with,
    naive_hypergrad, rel_err, CheckpointPolicy, MemoryReport,
};
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::autodiff::problems::{
    AttentionProblem, HyperLrProblem, LossWeightingProblem,
    MultiHeadAttentionProblem,
};
use mixflow::autodiff::tape::{NodeId, Tape};
use mixflow::autodiff::tensor::Tensor;
use mixflow::autodiff::BilevelProblem;
use mixflow::meta::{HypergradMode, NativeMetaTrainer, NativeTask};
use mixflow::util::args::CliEnum;
use mixflow::util::prng::Prng;
use mixflow::util::proptest;

/// Check ∇(build) against central finite differences, and the JVP against
/// the FD directional derivative.  `build` must produce a scalar node.
fn fd_check(
    name: &str,
    x0: &Tensor,
    build: impl Fn(&mut Tape, NodeId) -> NodeId,
) {
    let h = 1e-6;
    let tol = 1e-5;
    let mut tape = Tape::new();
    let x = tape.leaf(x0.clone());
    let y = build(&mut tape, x);
    assert_eq!(tape.value(y).elements(), 1, "{name}: loss not scalar");
    let g = tape.grad(y, &[x]);
    let grad = tape.value(g[0]).clone();

    let eval = |data: &Tensor| -> f64 {
        let mut t = Tape::new();
        let l = t.leaf(data.clone());
        let out = build(&mut t, l);
        t.value(out).item()
    };
    let mut fd = Tensor::zeros(&x0.shape);
    for j in 0..x0.elements() {
        let mut plus = x0.clone();
        plus.data[j] += h;
        let mut minus = x0.clone();
        minus.data[j] -= h;
        fd.data[j] = (eval(&plus) - eval(&minus)) / (2.0 * h);
    }
    let err = grad.max_abs_diff(&fd);
    assert!(err < tol, "{name}: VJP err {err:.3e}");

    // JVP vs FD directional derivative.
    let mut rng = Prng::new(0xD1CE);
    let v = Tensor::randn(&x0.shape, 1.0, &mut rng);
    let (tangents, _) = tape.jvp(&[(x, v.clone())], &[y]);
    let fd_dir: f64 = fd
        .data
        .iter()
        .zip(v.data.iter())
        .map(|(a, b)| a * b)
        .sum();
    let jvp_err = (tangents[0].item() - fd_dir).abs();
    assert!(
        jvp_err < tol * (1.0 + fd_dir.abs()),
        "{name}: JVP err {jvp_err:.3e}"
    );
}

#[test]
fn fd_checks_elementwise_ops() {
    let mut rng = Prng::new(1);
    let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
    fd_check("add", &a, |t, x| {
        let c = t.constant(Tensor::full(&[3, 5], 0.3));
        let s = t.add(x, c);
        t.sum(s)
    });
    fd_check("sub", &a, |t, x| {
        let c = t.constant(Tensor::full(&[3, 5], 0.3));
        let s = t.sub(c, x);
        t.sum(s)
    });
    fd_check("mul_cube", &a, |t, x| {
        let sq = t.mul(x, x);
        let cube = t.mul(sq, x);
        t.sum(cube)
    });
    fd_check("scale_offset", &a, |t, x| {
        let s = t.scale(x, 2.5);
        let o = t.offset(s, 1.0);
        t.sum(o)
    });
    fd_check("relu", &a, |t, x| {
        let r = t.relu(x);
        t.sum(r)
    });
    fd_check("tanh", &a, |t, x| {
        let y = t.tanh(x);
        t.sum(y)
    });
    fd_check("exp", &a, |t, x| {
        let s = t.scale(x, 0.3);
        let e = t.exp(s);
        t.sum(e)
    });
}

#[test]
fn fd_checks_div_sqrt_layernorm() {
    let mut rng = Prng::new(21);
    let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
    let denom = Tensor::randn(&[3, 5], 1.0, &mut rng).map(|v| 1.0 + v.abs());
    let weight = Tensor::randn(&[3, 5], 0.5, &mut rng);
    fd_check("sqrt", &a, |t, x| {
        // √(x² + 0.5): keeps the argument positive for any probe point.
        let sq = t.mul(x, x);
        let o = t.offset(sq, 0.5);
        let r = t.sqrt(o);
        t.sum(r)
    });
    fd_check("div_numerator", &a, |t, x| {
        let c = t.constant(denom.clone());
        let d = t.div(x, c);
        t.sum(d)
    });
    fd_check("div_denominator", &a, |t, x| {
        // 1/(x² + 1): denominator bounded away from zero.
        let num = t.constant(weight.clone());
        let sq = t.mul(x, x);
        let o = t.offset(sq, 1.0);
        let d = t.div(num, o);
        t.sum(d)
    });
    fd_check("div_both_sides", &a, |t, x| {
        let sq = t.mul(x, x);
        let o = t.offset(sq, 1.0);
        let d = t.div(x, o);
        t.sum(d)
    });
    fd_check("layernorm_rows", &a, |t, x| {
        let ln = t.layernorm_rows(x, 1e-3);
        let y = t.tanh(ln);
        t.sum(y)
    });
    fd_check("adam_like_quotient", &a, |t, x| {
        // m̂/(√v̂ + ε) with m̂, v̂ both functions of x — the exact shape
        // the in-graph Adam update puts on the step tape.
        let sq = t.mul(x, x);
        let o = t.offset(sq, 1e-3);
        let root = t.sqrt(o);
        let den = t.offset(root, 1e-8);
        let d = t.div(x, den);
        t.sum(d)
    });
}

#[test]
fn fd_checks_matmul_all_transposes() {
    let mut rng = Prng::new(2);
    let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
    let bnn = Tensor::randn(&[5, 4], 1.0, &mut rng);
    let btn = Tensor::randn(&[3, 4], 1.0, &mut rng);
    let bnt = Tensor::randn(&[4, 5], 1.0, &mut rng);
    let btt = Tensor::randn(&[4, 3], 1.0, &mut rng);
    fd_check("matmul_nn", &a, |t, x| {
        let b = t.constant(bnn.clone());
        let c = t.matmul(x, b, false, false);
        let y = t.tanh(c);
        t.sum(y)
    });
    fd_check("matmul_tn", &a, |t, x| {
        let b = t.constant(btn.clone());
        let c = t.matmul(x, b, true, false);
        let y = t.tanh(c);
        t.sum(y)
    });
    fd_check("matmul_nt", &a, |t, x| {
        let b = t.constant(bnt.clone());
        let c = t.matmul(x, b, false, true);
        let y = t.tanh(c);
        t.sum(y)
    });
    fd_check("matmul_tt", &a, |t, x| {
        let b = t.constant(btt.clone());
        let c = t.matmul(x, b, true, true);
        let y = t.tanh(c);
        t.sum(y)
    });
    // And with the differentiated operand on the right.  (This used
    // bnt [4,5] as the left operand — inner dims 5 vs 3, a guaranteed
    // panic that survived four toolchain-less sessions; btt [4,3] is
    // the shape-compatible left constant.)
    fd_check("matmul_rhs", &a, |t, x| {
        let b = t.constant(btt.clone());
        let c = t.matmul(b, x, false, false);
        let y = t.tanh(c);
        t.sum(y)
    });
}

#[test]
fn fd_checks_batched_and_head_stacking_ops() {
    // The multi-head attention ops: batched 3-D matmul in all four
    // transpose combinations (both operand positions), column split and
    // concat, and the full split → per-head bmm → concat round trip.
    let mut rng = Prng::new(31);
    let a3 = Tensor::randn(&[2, 3, 4], 1.0, &mut rng);
    let bnn = Tensor::randn(&[2, 4, 2], 1.0, &mut rng);
    let btn = Tensor::randn(&[2, 3, 2], 1.0, &mut rng);
    let bnt = Tensor::randn(&[2, 2, 4], 1.0, &mut rng);
    let btt = Tensor::randn(&[2, 2, 3], 1.0, &mut rng);
    fd_check("batch_matmul_nn", &a3, |t, x| {
        let b = t.constant(bnn.clone());
        let c = t.batch_matmul(x, b, false, false);
        let y = t.tanh(c);
        t.sum(y)
    });
    fd_check("batch_matmul_tn", &a3, |t, x| {
        let b = t.constant(btn.clone());
        let c = t.batch_matmul(x, b, true, false);
        let y = t.tanh(c);
        t.sum(y)
    });
    fd_check("batch_matmul_nt", &a3, |t, x| {
        let b = t.constant(bnt.clone());
        let c = t.batch_matmul(x, b, false, true);
        let y = t.tanh(c);
        t.sum(y)
    });
    fd_check("batch_matmul_tt", &a3, |t, x| {
        let b = t.constant(btt.clone());
        let c = t.batch_matmul(x, b, true, true);
        let y = t.tanh(c);
        t.sum(y)
    });
    // Differentiated operand in the right slot: btt [2,2,3] · x [2,3,4].
    fd_check("batch_matmul_rhs", &a3, |t, x| {
        let b = t.constant(btt.clone());
        let c = t.batch_matmul(b, x, false, false);
        let y = t.tanh(c);
        t.sum(y)
    });
    let m = Tensor::randn(&[3, 6], 1.0, &mut rng);
    fd_check("split_cols", &m, |t, x| {
        let mid = t.split_cols(x, 2, 3);
        let y = t.tanh(mid);
        t.sum(y)
    });
    fd_check("concat_cols", &m, |t, x| {
        let left = t.split_cols(x, 0, 2);
        let right = t.split_cols(x, 2, 4);
        let l2 = t.scale(left, 2.0);
        let r3 = t.scale(right, 3.0);
        let cat = t.concat_cols(&[l2, r3]);
        let y = t.tanh(cat);
        t.sum(y)
    });
    fd_check("split_bmm_concat_head_stack", &m, |t, x| {
        // The exact multi-head wiring: 2 heads of width 3 over a
        // 1-sequence batch, scores → context → concat.
        let mut heads = Vec::new();
        for h in 0..2 {
            let xh = t.split_cols(x, h * 3, 3);
            let x3 = t.reshape(xh, vec![1, 3, 3]);
            let scores = t.batch_matmul(x3, x3, false, true);
            let ctx = t.batch_matmul(scores, x3, false, false);
            heads.push(t.reshape(ctx, vec![3, 3]));
        }
        let cat = t.concat_cols(&heads);
        let y = t.tanh(cat);
        t.sum(y)
    });
}

#[test]
fn fd_checks_reductions_and_broadcasts() {
    let mut rng = Prng::new(3);
    let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
    fd_check("row_sum", &a, |t, x| {
        let r = t.row_sum(x);
        let y = t.tanh(r);
        t.sum(y)
    });
    fd_check("col_sum", &a, |t, x| {
        let c = t.col_sum(x);
        let y = t.tanh(c);
        t.sum(y)
    });
    fd_check("row_broadcast", &a, |t, x| {
        let r = t.row_sum(x);
        let b = t.row_broadcast(r, 7);
        let y = t.tanh(b);
        t.sum(y)
    });
    fd_check("col_broadcast", &a, |t, x| {
        let c = t.col_sum(x);
        let b = t.col_broadcast(c, 7);
        let y = t.tanh(b);
        t.sum(y)
    });
    fd_check("broadcast_scalar", &a, |t, x| {
        let s = t.sum(x);
        let sc = t.scale(s, 0.1);
        let b = t.broadcast(sc, &[2, 3]);
        let y = t.tanh(b);
        t.sum(y)
    });
    fd_check("reshape", &a, |t, x| {
        let r = t.reshape(x, vec![5, 3]);
        let y = t.tanh(r);
        t.sum(y)
    });
    fd_check("mean", &a, |t, x| {
        let sq = t.mul(x, x);
        t.mean(sq)
    });
}

#[test]
fn fd_checks_softmax_family() {
    let mut rng = Prng::new(4);
    let z = Tensor::randn(&[3, 4], 1.0, &mut rng);
    let w = Tensor::randn(&[3, 4], 0.5, &mut rng);
    let idx = vec![1usize, 0, 3];
    fd_check("softmax_rows", &z, |t, x| {
        let s = t.softmax_rows(x);
        let c = t.constant(w.clone());
        let p = t.mul(s, c);
        t.sum(p)
    });
    fd_check("logsumexp_rows", &z, |t, x| {
        let l = t.logsumexp_rows(x);
        t.sum(l)
    });
    fd_check("gather_cols", &z, |t, x| {
        let g = t.gather_cols(x, idx.clone());
        let y = t.tanh(g);
        t.sum(y)
    });
    fd_check("scatter_cols", &z, |t, x| {
        let g = t.gather_cols(x, idx.clone());
        let s = t.scatter_cols(g, idx.clone(), 4);
        let y = t.tanh(s);
        t.sum(y)
    });
    fd_check("cross_entropy", &z, |t, x| {
        let lse = t.logsumexp_rows(x);
        let picked = t.gather_cols(x, idx.clone());
        let ce = t.sub(lse, picked);
        let s = t.sum(ce);
        t.scale(s, 1.0 / 3.0)
    });
}

#[test]
fn grad_of_grad_matches_fd() {
    // s(x) = ½‖∇f(x)‖² for f = Σ tanh(xW)²; ∇s needs reverse-over-reverse.
    let mut rng = Prng::new(5);
    let w = Tensor::randn(&[4, 3], 0.5, &mut rng);
    let x0 = Tensor::randn(&[2, 4], 1.0, &mut rng);

    let half_grad_norm = |tape: &mut Tape, x: NodeId, w: &Tensor| -> NodeId {
        let wc = tape.constant(w.clone());
        let xw = tape.matmul(x, wc, false, false);
        let th = tape.tanh(xw);
        let sq = tape.mul(th, th);
        let f = tape.sum(sq);
        let g = tape.grad(f, &[x]);
        let gg = tape.mul(g[0], g[0]);
        let s = tape.sum(gg);
        tape.scale(s, 0.5)
    };

    let mut tape = Tape::new();
    let x = tape.leaf(x0.clone());
    let s = half_grad_norm(&mut tape, x, &w);
    let gg = tape.grad(s, &[x]);
    let got = tape.value(gg[0]).clone();

    let eval = |data: &Tensor| -> f64 {
        let mut t = Tape::new();
        let l = t.leaf(data.clone());
        let out = half_grad_norm(&mut t, l, &w);
        t.value(out).item()
    };
    let h = 1e-6;
    let mut fd = Tensor::zeros(&x0.shape);
    for j in 0..x0.elements() {
        let mut plus = x0.clone();
        plus.data[j] += h;
        let mut minus = x0.clone();
        minus.data[j] -= h;
        fd.data[j] = (eval(&plus) - eval(&minus)) / (2.0 * h);
    }
    let err = got.max_abs_diff(&fd) / (1.0 + fd.max_abs());
    assert!(err < 1e-5, "grad-of-grad rel err {err:.3e}");
}

#[test]
fn forward_over_reverse_hvp_matches_fd() {
    let mut rng = Prng::new(6);
    let w = Tensor::randn(&[4, 3], 0.5, &mut rng);
    let x0 = Tensor::randn(&[2, 4], 1.0, &mut rng);
    let v = Tensor::randn(&[2, 4], 1.0, &mut rng);

    let grad_at = |data: &Tensor| -> Tensor {
        let mut t = Tape::new();
        let x = t.leaf(data.clone());
        let wc = t.constant(w.clone());
        let xw = t.matmul(x, wc, false, false);
        let th = t.tanh(xw);
        let sq = t.mul(th, th);
        let f = t.sum(sq);
        let g = t.grad(f, &[x]);
        t.value(g[0]).clone()
    };

    // HVP via the dual overlay: tangent of the gradient nodes.
    let mut tape = Tape::new();
    let x = tape.leaf(x0.clone());
    let wc = tape.constant(w.clone());
    let xw = tape.matmul(x, wc, false, false);
    let th = tape.tanh(xw);
    let sq = tape.mul(th, th);
    let f = tape.sum(sq);
    let g = tape.grad(f, &[x]);
    let (tangents, tangent_bytes) = tape.jvp(&[(x, v.clone())], &[g[0]]);
    assert!(tangent_bytes > 0);

    let h = 1e-6;
    let mut plus = x0.clone();
    let mut minus = x0.clone();
    for j in 0..x0.elements() {
        plus.data[j] += h * v.data[j];
        minus.data[j] -= h * v.data[j];
    }
    let gp = grad_at(&plus);
    let gm = grad_at(&minus);
    let fd_hvp = gp.zip(&gm, |a, b| (a - b) / (2.0 * h));
    let err = tangents[0].max_abs_diff(&fd_hvp) / (1.0 + fd_hvp.max_abs());
    assert!(err < 1e-5, "HVP rel err {err:.3e}");
}

/// Hold every hypergradient path to the central-difference oracle on one
/// problem, all three running on **persistent engines** (the ROADMAP
/// follow-up from PR 4: the throwaway-engine `fd_hypergrad` shims are
/// gone from the oracle tests).  Each engine computes the hypergradient
/// twice: the warm second run must (a) reproduce the cold run
/// bit-for-bit and (b) draw strictly more buffers out of the persistent
/// arena than the cold run did — the second-step arena-reuse contract.
fn assert_engines_match_fd_oracle(
    label: &str,
    problem: &dyn mixflow::autodiff::BilevelProblem,
) {
    let theta0 = problem.theta0();
    let eta = problem.eta0();
    let mut naive_engine =
        HypergradEngine::builder().mode(HypergradMode::Naive).build();
    let mut mixflow_engine = HypergradEngine::builder().build();
    let mut fd_engine =
        HypergradEngine::builder().mode(HypergradMode::Fd).build();
    let naive = naive_engine.run(problem, &theta0, &eta);
    let mixed = mixflow_engine.run(problem, &theta0, &eta);
    let fd = fd_engine.run(problem, &theta0, &eta);
    assert!(
        rel_err(&naive.d_eta, &fd.d_eta) < 1e-4,
        "{label}: naive vs fd"
    );
    assert!(
        rel_err(&mixed.d_eta, &fd.d_eta) < 1e-4,
        "{label}: mixflow vs fd"
    );
    assert!(
        rel_err(&naive.d_eta, &mixed.d_eta) < 1e-6,
        "{label}: naive vs mixflow"
    );
    for (name, engine, cold) in [
        ("naive", &mut naive_engine, &naive),
        ("mixflow", &mut mixflow_engine, &mixed),
        ("fd", &mut fd_engine, &fd),
    ] {
        let warm = engine.run(problem, &theta0, &eta);
        for (a, b) in cold.d_eta.iter().zip(warm.d_eta.iter()) {
            assert_eq!(
                a.max_abs_diff(b),
                0.0,
                "{label}/{name}: warm rerun must be bit-for-bit"
            );
        }
        assert!(
            warm.memory.arena_reuses > cold.memory.arena_reuses,
            "{label}/{name}: second engine step must reuse more arena \
             buffers than the cold step ({} vs {})",
            warm.memory.arena_reuses,
            cold.memory.arena_reuses
        );
        assert_eq!(engine.outer_steps(), 2, "{label}/{name}");
    }
}

#[test]
fn hypergrads_match_fd_oracle() {
    // Small instances; both MLP tasks against central differences, on
    // persistent engines.
    assert_engines_match_fd_oracle(
        "hyperlr",
        &HyperLrProblem::with_config(11, 3, 4, 3, 4, 3, 0.08),
    );
    assert_engines_match_fd_oracle(
        "weighting",
        &LossWeightingProblem::with_config(13, 3, 4, 3, 4, 3, 0.15, 0.5),
    );
}

#[test]
fn hypergrads_match_fd_oracle_stateful_optimisers() {
    // The optimiser-state adjoint path (m/v moments, bias correction)
    // must be held to the same FD oracle as plain SGD.
    assert_engines_match_fd_oracle(
        "momentum",
        &HyperLrProblem::with_config(11, 3, 4, 3, 4, 3, 0.08)
            .with_optimiser(InnerOptimiser::momentum()),
    );
    assert_engines_match_fd_oracle(
        "adam",
        &HyperLrProblem::with_config(11, 3, 4, 3, 4, 3, 0.08)
            .with_optimiser(InnerOptimiser::adam()),
    );
    // Adam under a dense mixed ∂²L/∂η∂θ term (η inside the inner loss).
    assert_engines_match_fd_oracle(
        "weighting+adam",
        &LossWeightingProblem::with_config(13, 3, 4, 3, 4, 3, 0.15, 0.5)
            .with_optimiser(InnerOptimiser::adam()),
    );
}

#[test]
fn hypergrads_match_fd_oracle_attention_adam() {
    // The paper's benchmark shape: attention + layernorm inner model,
    // Adam inner optimiser — single-head and multi-head batched.
    assert_engines_match_fd_oracle(
        "attention",
        &AttentionProblem::with_config(19, 3, 4, 3, 3, 0.05)
            .with_optimiser(InnerOptimiser::adam()),
    );
    assert_engines_match_fd_oracle(
        "attention_mh",
        &MultiHeadAttentionProblem::with_config(19, 4, 2, 2, 3, 3, 3, 0.05)
            .with_optimiser(InnerOptimiser::adam()),
    );
}

/// Random small bilevel instance spanning all four tasks (multi-head
/// batched attention included) and all three inner optimisers — shared
/// by the equivalence property tests.
fn random_problem(g: &mut proptest::Gen) -> Box<dyn BilevelProblem> {
    let seed = g.rng.next_u64();
    let d = g.usize(2, 4);
    let hidden = g.usize(2, 5);
    let classes = g.usize(2, 4);
    let batch = g.usize(2, 5);
    let unroll = g.usize(1, 4);
    let alpha = g.f64(0.02, 0.12);
    let opt = *g.choose(&[
        InnerOptimiser::Sgd,
        InnerOptimiser::momentum(),
        InnerOptimiser::adam(),
    ]);
    match g.usize(0, 3) {
        0 => Box::new(
            HyperLrProblem::with_config(
                seed, d, hidden, classes, batch, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        1 => Box::new(
            LossWeightingProblem::with_config(
                seed,
                d,
                hidden,
                classes,
                batch,
                unroll,
                alpha,
                g.f64(0.0, 0.6),
            )
            .with_optimiser(opt),
        ),
        2 => Box::new(
            AttentionProblem::with_config(
                seed, d, batch, classes, unroll, alpha,
            )
            .with_optimiser(opt),
        ),
        _ => {
            // Multi-head batched attention: d_model must divide by the
            // head count, so draw (heads, head dim) and multiply.
            let heads = g.usize(1, 3);
            let d_model = heads * g.usize(1, 2);
            let seqs = g.usize(1, 3);
            Box::new(
                MultiHeadAttentionProblem::with_config(
                    seed,
                    d_model,
                    heads,
                    seqs,
                    g.usize(2, 4),
                    classes,
                    unroll,
                    alpha,
                )
                .with_optimiser(opt),
            )
        }
    }
}

#[test]
fn property_multihead_heads1_is_bitwise_single_head_attention() {
    // The tentpole's conformance pin: MultiHeadAttentionProblem with
    // heads = 1, batch = 1 must reproduce the legacy single-head
    // AttentionProblem hypergradient to ≤ 1e-12 (bit-for-bit in
    // practice — the splits/concats are exact copies and one-group
    // batched matmuls run the identical kernel loops) for the naive,
    // mixflow and remat paths, across random shapes and optimisers.
    proptest::check("mha-h1≡attention", 12, |g| {
        let seed = g.rng.next_u64();
        let d = g.usize(2, 4);
        let seq = g.usize(2, 5);
        let classes = g.usize(2, 4);
        let unroll = g.usize(1, 4);
        let alpha = g.f64(0.02, 0.12);
        let opt = *g.choose(&[
            InnerOptimiser::Sgd,
            InnerOptimiser::momentum(),
            InnerOptimiser::adam(),
        ]);
        let old = AttentionProblem::with_config(
            seed, d, seq, classes, unroll, alpha,
        )
        .with_optimiser(opt);
        let new = MultiHeadAttentionProblem::with_config(
            seed, d, 1, 1, seq, classes, unroll, alpha,
        )
        .with_optimiser(opt);
        let theta0 = old.theta0();
        let eta = old.eta0();
        for (a, b) in theta0.iter().zip(new.theta0().iter()) {
            if a.max_abs_diff(b) != 0.0 {
                return Err("theta init diverged".to_string());
            }
        }
        for mode in ["naive", "mixflow", "remat2"] {
            let run = |p: &dyn BilevelProblem| match mode {
                "naive" => naive_hypergrad(p, &theta0, &eta),
                "mixflow" => mixflow_hypergrad(p, &theta0, &eta),
                _ => mixflow_hypergrad_with(
                    p,
                    &theta0,
                    &eta,
                    CheckpointPolicy::Remat { segment: 2 },
                ),
            };
            let a = run(&old);
            let b = run(&new);
            let err = rel_err(&a.d_eta, &b.d_eta);
            if err > 1e-12 {
                return Err(format!(
                    "{mode}: heads=1 multi-head diverged from single-head \
                     (rel err {err:.3e}, {} opt, unroll {unroll})",
                    opt.name()
                ));
            }
            if (a.outer_loss - b.outer_loss).abs() > 1e-12 {
                return Err(format!(
                    "{mode}: outer loss {} vs {}",
                    b.outer_loss, a.outer_loss
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn property_naive_equals_mixflow_on_random_instances() {
    proptest::check("naive≈mixflow", 18, |g| {
        let problem = random_problem(g);
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        let naive = naive_hypergrad(problem.as_ref(), &theta0, &eta);
        let mixed = mixflow_hypergrad(problem.as_ref(), &theta0, &eta);
        let err = rel_err(&naive.d_eta, &mixed.d_eta);
        if err < 1e-6 {
            Ok(())
        } else {
            Err(format!(
                "naive vs mixflow diverged ({} inner opt): rel err {err:.3e}",
                problem.optimiser().name()
            ))
        }
    });
}

#[test]
fn property_persistent_engine_is_bitwise_equal_to_fresh_calls() {
    // The engine rebuild's core contract: a persistent HypergradEngine
    // reused over N outer steps — buffers recirculating through one
    // arena the whole time — must be bit-for-bit equal to a fresh
    // per-call mixflow_hypergrad_with at every step, across random
    // tasks, optimisers and checkpoint policies.
    proptest::check("engine≡fresh", 12, |g| {
        let mut problem = random_problem(g);
        let theta0 = problem.theta0();
        let mut eta = problem.eta0();
        let policy = *g.choose(&[
            CheckpointPolicy::Full,
            CheckpointPolicy::Remat { segment: 2 },
            CheckpointPolicy::Auto,
        ]);
        let mut engine = HypergradEngine::builder().checkpoint(policy).build();
        let mut cold_reuses = None;
        for step in 0..3 {
            problem.resample();
            let fresh = mixflow_hypergrad_with(
                problem.as_ref(),
                &theta0,
                &eta,
                policy,
            );
            let live = engine.run(problem.as_ref(), &theta0, &eta);
            for (a, b) in fresh.d_eta.iter().zip(live.d_eta.iter()) {
                if a.max_abs_diff(b) != 0.0 {
                    return Err(format!(
                        "step {step}: persistent engine diverged from fresh \
                         call ({} policy, {} opt)",
                        policy.name(),
                        problem.optimiser().name()
                    ));
                }
            }
            if fresh.outer_loss != live.outer_loss {
                return Err(format!(
                    "step {step}: outer loss {} vs {}",
                    live.outer_loss, fresh.outer_loss
                ));
            }
            // The acceptance knob: every warm outer step must reuse
            // strictly more buffers per run than the cold first step.
            // (Warm steps compare equal to each other — the arena hits
            // steady state after one run — so the baseline is step 0.)
            match cold_reuses {
                None => cold_reuses = Some(live.memory.arena_reuses),
                Some(cold) => {
                    if live.memory.arena_reuses <= cold {
                        return Err(format!(
                            "step {step}: warm-run arena reuse {} not above \
                             the cold run's {}",
                            live.memory.arena_reuses, cold
                        ));
                    }
                }
            }
            // Walk η a little so consecutive steps differ.
            for (e, gvec) in eta.iter_mut().zip(fresh.d_eta.iter()) {
                for j in 0..e.data.len() {
                    e.data[j] -= 0.01 * gvec.data[j];
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_cli_enum_names_round_trip() {
    // parse(name()) == Some(self) for every canonically-constructed
    // value of all four CLI enums, plus: every advertised variant
    // string parses.
    for mode in [
        HypergradMode::Naive,
        HypergradMode::Mixflow,
        HypergradMode::Fd,
        HypergradMode::Truncated { horizon: 4 },
        HypergradMode::Evograd,
    ] {
        assert_eq!(HypergradMode::parse(&mode.name()), Some(mode));
    }
    for task in [
        NativeTask::HyperLr,
        NativeTask::LossWeighting,
        NativeTask::Attention,
    ] {
        assert_eq!(NativeTask::parse(task.name()), Some(task));
    }
    for opt in [
        InnerOptimiser::Sgd,
        InnerOptimiser::momentum(),
        InnerOptimiser::adam(),
    ] {
        assert_eq!(InnerOptimiser::parse(opt.name()), Some(opt));
    }
    for v in <HypergradMode as CliEnum>::variants() {
        assert!(HypergradMode::parse(v).is_some(), "variant {v}");
    }
    for v in <NativeTask as CliEnum>::variants() {
        assert!(NativeTask::parse(v).is_some(), "variant {v}");
    }
    for v in <InnerOptimiser as CliEnum>::variants() {
        assert!(InnerOptimiser::parse(v).is_some(), "variant {v}");
    }
    for v in <CheckpointPolicy as CliEnum>::variants() {
        assert!(CheckpointPolicy::parse(v).is_some(), "variant {v}");
    }
    // The open-ended policy round-trips over random canonical segments.
    proptest::check("policy-roundtrip", 40, |g| {
        let policy = match g.usize(0, 2) {
            0 => CheckpointPolicy::Full,
            1 => CheckpointPolicy::Auto,
            _ => CheckpointPolicy::Remat { segment: g.usize(2, 64) },
        };
        if CheckpointPolicy::parse(&policy.name()) == Some(policy) {
            Ok(())
        } else {
            Err(format!("{policy:?} did not round-trip via {:?}", policy.name()))
        }
    });
    // Valid-value lists the CLI derives are non-empty and mention every
    // mode (the drift the shared trait exists to prevent).
    let modes = <HypergradMode as CliEnum>::valid_values();
    assert_eq!(modes, "naive|mixflow|fd");
}

#[test]
fn auto_policy_matches_full_checkpointing_numerically() {
    // Auto resolves K=round(√T) at run time; the remat recompute replays
    // the identical op sequence, so it must reproduce the K=1 result.
    let p = AttentionProblem::with_unroll(1, 9)
        .with_optimiser(InnerOptimiser::adam());
    let theta0 = p.theta0();
    let eta = p.eta0();
    let full = mixflow_hypergrad(&p, &theta0, &eta);
    let auto = mixflow_hypergrad_with(
        &p,
        &theta0,
        &eta,
        CheckpointPolicy::Auto,
    );
    assert!(
        rel_err(&full.d_eta, &auto.d_eta) <= 1e-12,
        "auto remat drifted from full checkpointing"
    );
    // K=3 at T=9 stores fewer checkpoints than K=1.
    assert!(
        auto.memory.checkpoint_bytes < full.memory.checkpoint_bytes,
        "auto ({}) must checkpoint less than full ({})",
        auto.memory.checkpoint_bytes,
        full.memory.checkpoint_bytes
    );
    // At T ≤ 2 auto degrades to full checkpointing exactly (K = 1).
    let tiny = HyperLrProblem::with_unroll(3, 2);
    let theta0 = tiny.theta0();
    let eta = tiny.eta0();
    let a = mixflow_hypergrad(&tiny, &theta0, &eta);
    let b = mixflow_hypergrad_with(
        &tiny,
        &theta0,
        &eta,
        CheckpointPolicy::Auto,
    );
    for (x, y) in a.d_eta.iter().zip(b.d_eta.iter()) {
        assert_eq!(x.max_abs_diff(y), 0.0, "T≤2 auto must be bit-for-bit");
    }
    assert_eq!(a.memory.checkpoint_bytes, b.memory.checkpoint_bytes);
}

#[test]
fn property_remat_equals_full_checkpointing() {
    // Remat recomputes the identical op sequence from the same
    // checkpoints, so every segment length must reproduce the
    // full-checkpoint hypergradient to 1e-12 (bit-for-bit in practice)
    // across tasks, optimisers and K ∈ {1, 2, 4, T}.
    proptest::check("remat≡full", 16, |g| {
        let problem = random_problem(g);
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        let full = mixflow_hypergrad(problem.as_ref(), &theta0, &eta);
        let t = problem.unroll().max(1);
        for k in [1usize, 2, 4, t] {
            let remat = mixflow_hypergrad_with(
                problem.as_ref(),
                &theta0,
                &eta,
                CheckpointPolicy::Remat { segment: k },
            );
            let err = rel_err(&full.d_eta, &remat.d_eta);
            if err > 1e-12 {
                return Err(format!(
                    "remat K={k} diverged from full checkpointing: rel err \
                     {err:.3e} ({} inner opt, unroll {t})",
                    problem.optimiser().name()
                ));
            }
            if (remat.outer_loss - full.outer_loss).abs() > 1e-12 {
                return Err(format!(
                    "remat K={k} changed the outer loss: {} vs {}",
                    remat.outer_loss, full.outer_loss
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn remat_segment_one_is_bitwise_identical_to_full() {
    let p = HyperLrProblem::with_unroll(3, 5)
        .with_optimiser(InnerOptimiser::momentum());
    let theta0 = p.theta0();
    let eta = p.eta0();
    let a = mixflow_hypergrad(&p, &theta0, &eta);
    let b = mixflow_hypergrad_with(
        &p,
        &theta0,
        &eta,
        CheckpointPolicy::Remat { segment: 1 },
    );
    for (x, y) in a.d_eta.iter().zip(b.d_eta.iter()) {
        assert_eq!(x.max_abs_diff(y), 0.0, "K=1 must be bit-for-bit");
    }
    assert_eq!(a.outer_loss, b.outer_loss);
    assert_eq!(a.memory.checkpoint_bytes, b.memory.checkpoint_bytes);
    assert_eq!(a.memory.tape_bytes, b.memory.tape_bytes);
}

#[test]
fn remat_peak_bytes_shrink_monotonically_with_segment() {
    // The acceptance knob: on the paper's headline configuration
    // (attention + Adam, T = 16), growing K up to ~√T must strictly
    // shrink both the peak checkpoint bytes and the overall peak, while
    // reproducing the K=1 hypergradient.
    let p = AttentionProblem::with_unroll(1, 16)
        .with_optimiser(InnerOptimiser::adam());
    let theta0 = p.theta0();
    let eta = p.eta0();
    let full = mixflow_hypergrad(&p, &theta0, &eta);
    let mut prev: Option<MemoryReport> = None;
    for k in [1usize, 2, 4] {
        let h = mixflow_hypergrad_with(
            &p,
            &theta0,
            &eta,
            CheckpointPolicy::Remat { segment: k },
        );
        assert!(
            rel_err(&full.d_eta, &h.d_eta) <= 1e-12,
            "remat K={k} drifted from the full-checkpoint hypergradient"
        );
        if let Some(prev) = &prev {
            assert!(
                h.memory.checkpoint_bytes < prev.checkpoint_bytes,
                "K={k}: checkpoint bytes {} not below previous {}",
                h.memory.checkpoint_bytes,
                prev.checkpoint_bytes
            );
            assert!(
                h.memory.peak_bytes < prev.peak_bytes,
                "K={k}: peak bytes {} not below previous {}",
                h.memory.peak_bytes,
                prev.peak_bytes
            );
            assert!(
                h.memory.total_bytes() < prev.total_bytes(),
                "K={k}: total bytes {} not below previous {}",
                h.memory.total_bytes(),
                prev.total_bytes()
            );
        }
        prev = Some(h.memory);
    }
}

#[test]
fn mixflow_reuses_arena_buffers_naive_does_not() {
    let p = HyperLrProblem::with_unroll(2, 6);
    let theta0 = p.theta0();
    let eta = p.eta0();
    let mixed = mixflow_hypergrad(&p, &theta0, &eta);
    assert!(
        mixed.memory.arena_reuses > 0,
        "step tapes must recycle buffers through the shared arena"
    );
    assert!(mixed.memory.arena_allocs > 0);
    assert!(mixed.memory.forward_seconds >= 0.0);
    assert!(mixed.memory.backward_seconds >= 0.0);
    // The naive path records one monolithic tape and never resets it, so
    // nothing ever returns to its arena.
    let naive = naive_hypergrad(&p, &theta0, &eta);
    assert_eq!(naive.memory.arena_reuses, 0);
    assert_eq!(naive.memory.peak_bytes, naive.memory.tape_bytes);
}

#[test]
fn mixflow_tape_memory_beats_naive_for_long_unrolls() {
    let mut prev_ratio = 0.0;
    for unroll in [4usize, 8, 16] {
        let p = HyperLrProblem::with_unroll(1, unroll);
        let theta0 = p.theta0();
        let eta = p.eta0();
        let naive = naive_hypergrad(&p, &theta0, &eta);
        let mixed = mixflow_hypergrad(&p, &theta0, &eta);
        let nb = naive.memory.total_bytes();
        let mb = mixed.memory.total_bytes();
        assert!(
            mb < nb,
            "unroll {unroll}: mixflow {mb} bytes not below naive {nb}"
        );
        let ratio = nb as f64 / mb as f64;
        assert!(
            ratio > prev_ratio,
            "memory ratio must widen with unroll ({prev_ratio:.2} → {ratio:.2})"
        );
        prev_ratio = ratio;
    }
}

#[test]
fn adam_attention_tape_memory_beats_naive_for_long_unrolls() {
    // The paper's headline configuration: the gap must reproduce with
    // moment-state checkpoints included, and widen with unroll.
    let mut prev_ratio = 0.0;
    for unroll in [4usize, 8, 16] {
        let p = AttentionProblem::with_unroll(1, unroll)
            .with_optimiser(InnerOptimiser::adam());
        let theta0 = p.theta0();
        let eta = p.eta0();
        let naive = naive_hypergrad(&p, &theta0, &eta);
        let mixed = mixflow_hypergrad(&p, &theta0, &eta);
        let nb = naive.memory.total_bytes();
        let mb = mixed.memory.total_bytes();
        assert!(
            mb < nb,
            "unroll {unroll}: adam+attention mixflow {mb} bytes not below \
             naive {nb}"
        );
        let ratio = nb as f64 / mb as f64;
        assert!(
            ratio > prev_ratio,
            "memory ratio must widen with unroll ({prev_ratio:.2} → {ratio:.2})"
        );
        prev_ratio = ratio;
    }
}

#[test]
fn multihead_attention_memory_gap_and_kv_counters() {
    // The tentpole acceptance shape: on the multi-head batched workload
    // the mixflow peak must stay below naive at T ∈ {4, 8, 16}, with the
    // KV-reuse counters attributing part of the saving to the K/V
    // projections specifically.
    for unroll in [4usize, 8, 16] {
        let p = MultiHeadAttentionProblem::with_unroll(1, unroll)
            .with_optimiser(InnerOptimiser::adam());
        let theta0 = p.theta0();
        let eta = p.eta0();
        let naive = naive_hypergrad(&p, &theta0, &eta);
        let mixed = mixflow_hypergrad(&p, &theta0, &eta);
        assert!(
            rel_err(&naive.d_eta, &mixed.d_eta) < 1e-6,
            "T={unroll}: multihead naive vs mixflow"
        );
        assert!(
            mixed.memory.peak_bytes < naive.memory.peak_bytes,
            "T={unroll}: mixflow peak {} not below naive {}",
            mixed.memory.peak_bytes,
            naive.memory.peak_bytes
        );
        // Naive keeps every step's K/V projections live on the
        // monolithic tape; mixflow holds at most one step's worth.
        assert!(naive.memory.kv_peak_bytes > 0, "naive KV untagged");
        assert!(mixed.memory.kv_peak_bytes > 0, "mixflow KV untagged");
        assert!(
            mixed.memory.kv_peak_bytes < naive.memory.kv_peak_bytes,
            "T={unroll}: mixflow KV peak {} not below naive {}",
            mixed.memory.kv_peak_bytes,
            naive.memory.kv_peak_bytes
        );
        // Full checkpointing: every backward step rebuilds K/V from a
        // stored-checkpoint alias; nothing is rematerialised.
        assert!(mixed.memory.kv_ckpt_alias_bytes > 0);
        assert_eq!(mixed.memory.kv_remat_bytes, 0);
        assert_eq!(naive.memory.kv_ckpt_alias_bytes, 0);
        assert_eq!(naive.memory.kv_remat_bytes, 0);
    }
}

#[test]
fn kv_counters_split_by_checkpoint_policy() {
    // Under Remat{K}: segment-boundary backward steps alias stored
    // checkpoints, intra-segment steps (and the recompute pass) book as
    // rematerialised — so K = 1 puts everything in the alias bucket and
    // K ≥ 2 moves a strictly positive share into the remat bucket while
    // the total K/V rebuild volume only grows (the recompute pass
    // rebuilds K/V the full-checkpoint path never re-touches).
    let p = MultiHeadAttentionProblem::with_unroll(3, 8)
        .with_optimiser(InnerOptimiser::adam());
    let theta0 = p.theta0();
    let eta = p.eta0();
    let full = mixflow_hypergrad(&p, &theta0, &eta);
    assert!(full.memory.kv_ckpt_alias_bytes > 0);
    assert_eq!(full.memory.kv_remat_bytes, 0);
    let remat = mixflow_hypergrad_with(
        &p,
        &theta0,
        &eta,
        CheckpointPolicy::Remat { segment: 4 },
    );
    assert!(remat.memory.kv_remat_bytes > 0, "K=4 must remat some K/V");
    assert!(
        remat.memory.kv_ckpt_alias_bytes < full.memory.kv_ckpt_alias_bytes,
        "K=4 must alias fewer checkpoints than K=1 ({} vs {})",
        remat.memory.kv_ckpt_alias_bytes,
        full.memory.kv_ckpt_alias_bytes
    );
    let full_total =
        full.memory.kv_ckpt_alias_bytes + full.memory.kv_remat_bytes;
    let remat_total =
        remat.memory.kv_ckpt_alias_bytes + remat.memory.kv_remat_bytes;
    assert!(
        remat_total > full_total,
        "remat must rebuild strictly more K/V overall ({remat_total} vs \
         {full_total})"
    );
    // The per-tape KV peak is a one-step quantity — thinning checkpoints
    // must not change it.
    assert_eq!(full.memory.kv_peak_bytes, remat.memory.kv_peak_bytes);
}

#[test]
fn forward_sweep_stats_fold_into_memory_report() {
    // Regression: the forward sweep used to return only bytes, so
    // MemoryReport.nodes silently ignored forward-pass step tapes.
    let p = HyperLrProblem::with_config(5, 3, 4, 3, 4, 2, 0.08)
        .with_optimiser(InnerOptimiser::adam());
    let theta0 = p.theta0();
    let eta = p.eta0();
    let state = p.optimiser().init_state(&theta0);
    let (next_theta, next_state, stats) =
        inner_step_values(&p, &theta0, &state, &eta, 0);
    assert_eq!(next_theta.len(), theta0.len());
    assert_eq!(next_state.len(), state.len());
    assert!(stats.nodes > 0, "forward step tape must report node count");
    assert!(stats.bytes > 0, "forward step tape must report bytes");
    let mixed = mixflow_hypergrad(&p, &theta0, &eta);
    assert!(
        mixed.memory.nodes >= stats.nodes,
        "MemoryReport.nodes ({}) must fold in the forward-sweep step tape \
         ({})",
        mixed.memory.nodes,
        stats.nodes
    );
}

#[test]
fn native_training_improves_validation_loss() {
    let mut trainer = NativeMetaTrainer::new(NativeTask::HyperLr, 7);
    let report = trainer.train(50);
    assert_eq!(report.losses.len(), 50);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let (head, tail) = report.improvement(10);
    assert!(
        tail < head,
        "50 native outer steps must improve val loss ({head:.4} → {tail:.4})"
    );
}

#[test]
fn naive_mode_trains_too() {
    let mut trainer = NativeMetaTrainer::with_unroll(NativeTask::HyperLr, 7, 4)
        .with_mode(HypergradMode::Naive);
    let report = trainer.train(20);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let (head, tail) = report.improvement(5);
    assert!(tail < head, "naive path must also train ({head:.4} → {tail:.4})");
}

#[test]
fn attention_adam_native_training_improves_validation_loss() {
    // `mixflow native --task attention --inner-opt adam` end-to-end.
    // α₀ starts deliberately small, so the meta level must grow the LRs.
    let mut trainer =
        NativeMetaTrainer::with_unroll(NativeTask::Attention, 7, 6)
            .with_inner_opt(InnerOptimiser::adam());
    let report = trainer.train(50);
    assert_eq!(report.losses.len(), 50);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let (head, tail) = report.improvement(10);
    assert!(
        tail < head,
        "attention+adam outer steps must improve val loss \
         ({head:.4} → {tail:.4})"
    );
    let mem = trainer.last_memory.expect("memory report recorded");
    assert!(mem.tape_bytes > 0 && mem.checkpoint_bytes > 0 && mem.nodes > 0);
}

//! Figure 1 — motivating example (§3.2): peak memory and step time vs the
//! number of per-step transformations M, default vs mixed-mode.
//! Also prints the Figure-9 graph census for the largest M.

use mixflow::coordinator::runner::{pair_ratios, ExperimentRunner, RunOptions};
use mixflow::runtime::Runtime;
use mixflow::util::bench::Bench;
use mixflow::util::stats::human_bytes;
use mixflow::util::table::Table;

fn main() {
    let runtime = Runtime::new().expect("artifacts missing — run make artifacts");
    let mut bench = Bench::new("fig1_toy").with_iters(1, 5).with_budget(120.0);

    let metas = runtime.manifest.group("fig1_toy");
    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 5, execute: true, seed: 0 },
    );

    // Group by M (model.num_maps encoded in the key "toy_M<m>_...").
    let mut rows: Vec<(usize, String, u64, Option<u64>, Option<f64>)> = Vec::new();
    for meta in &metas {
        let m: usize = meta
            .key
            .split('M')
            .nth(1)
            .and_then(|s| s.split('_').next())
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let meas = match runner.run_one(meta, "fig1_toy") {
            Ok(x) => x,
            Err(e) => {
                eprintln!("skip {}: {e}", meta.key);
                continue;
            }
        };
        if let Some(s) = meas.step_seconds {
            bench.record(
                &format!("M={m} {}", meta.variant),
                mixflow::util::stats::Summary::of(&[s]),
            );
        }
        rows.push((
            m,
            meta.variant.clone(),
            meas.sim_dynamic_bytes,
            meas.xla_temp_bytes,
            meas.step_seconds,
        ));
    }
    rows.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));

    println!("\nFigure 1 — toy example: peak memory & step time across M");
    let mut t = Table::new(&[
        "M", "variant", "sim dyn HBM", "XLA temp", "step time (ms)",
    ])
    .numeric_cols(&[0, 2, 3, 4]);
    for (m, variant, dynb, xla, secs) in &rows {
        t.row(vec![
            m.to_string(),
            variant.clone(),
            human_bytes(*dynb),
            xla.map(human_bytes).unwrap_or_else(|| "-".into()),
            secs.map(|s| format!("{:.2}", s * 1e3))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());

    // Ratio summary per M (the two Fig. 1 panels).
    let measurements: Vec<_> = metas
        .iter()
        .filter_map(|m| runner.run_one(m, "fig1_toy").ok())
        .collect();
    // Pair by seq_len field (toy stores D there) + M via size_name.
    let mut t2 = Table::new(&["M", "dyn HBM ratio", "XLA temp ratio", "time ratio"])
        .numeric_cols(&[0, 1, 2, 3]);
    let ms: Vec<usize> = {
        let mut v: Vec<usize> = rows.iter().map(|r| r.0).collect();
        v.sort();
        v.dedup();
        v
    };
    for m in ms {
        let find = |variant: &str| {
            rows.iter().find(|r| r.0 == m && r.1 == variant)
        };
        if let (Some(d), Some(x)) = (find("default"), find("mixflow")) {
            let dyn_ratio = d.2 as f64 / x.2.max(1) as f64;
            let xla_ratio = match (d.3, x.3) {
                (Some(a), Some(b)) if b > 0 => format!("{:.2}", a as f64 / b as f64),
                _ => "-".into(),
            };
            let time_ratio = match (d.4, x.4) {
                (Some(a), Some(b)) if b > 0.0 => format!("{:.2}", a / b),
                _ => "-".into(),
            };
            t2.row(vec![
                m.to_string(),
                format!("{dyn_ratio:.2}"),
                xla_ratio,
                time_ratio,
            ]);
        }
    }
    println!("{}", t2.render());
    let pairs = pair_ratios(&measurements);
    if !pairs.is_empty() {
        println!(
            "paper shape: ratios grow with M (memory up to ~6.7x / 85% at large M)"
        );
    }
    bench.report();
}

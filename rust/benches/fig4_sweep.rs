//! Figure 4 — the joint sweep over tasks × model sizes × T × S (Table 1
//! scaled): sorted peak-dynamic-HBM and step-time ratios between default
//! and MixFlow-MG, plus the §5.2 aggregate claims.
//!
//! Exec tier: every pair is compiled once and timed on the PJRT client.
//! Set MIXFLOW_FIG4_NO_EXEC=1 for a fast analysis-only pass.

use mixflow::coordinator::report::fig4_sorted_ratios;
use mixflow::coordinator::runner::{pair_ratios, ExperimentRunner, RunOptions};
use mixflow::coordinator::ResultsStore;
use mixflow::runtime::Runtime;
use mixflow::util::bench::Bench;

fn main() {
    let execute = std::env::var("MIXFLOW_FIG4_NO_EXEC").is_err();
    let runtime = Runtime::new().expect("run make artifacts");
    let mut bench = Bench::new("fig4_sweep").with_iters(0, 1);
    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 3, execute, seed: 0 },
    );

    let mut measurements = Vec::new();
    bench.run("joint sweep (compile+time all pairs)", || {
        measurements = runner.run_group("fig4_sweep");
    });

    let store = ResultsStore::discover().expect("results dir");
    for m in &measurements {
        store.append("fig4_sweep", m).ok();
    }

    let pairs = pair_ratios(&measurements);
    println!("{}", fig4_sorted_ratios(&pairs));
    println!("paper shape: ALL pairs win on memory; time wins nearly uniform;");
    println!("memory gains vary with architecture (disentangled in Figs. 5-7).");
    bench.report();
}

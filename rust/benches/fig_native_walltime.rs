//! Native wall-clock + remat figure: naive reverse-over-reverse vs
//! MixFlow-MG vs MixFlow-MG with block rematerialisation, plus the
//! approximate-strategy frontier (truncated back-propagation and
//! EvoGrad) — every row in `BENCH_native.json` carries
//! `bias_vs_mixflow` next to `peak_bytes` and `median_s`, so the
//! artifact charts the bias-vs-memory-vs-walltime trade-off in one
//! place.
//!
//! The paper claims not just a >10x memory reduction but up to 25%
//! wall-clock improvement; this binary pins the repo's perf trajectory
//! by timing all three paths on the hyper-LR (SGD inner loop), the
//! single-head attention+layernorm (Adam inner loop) and the multi-head
//! batched attention (`attention_mh2b2`, Adam) workloads across the
//! unroll ladder, via [`mixflow::util::bench`].  Each variant runs on ONE
//! persistent [`HypergradEngine`], so the timed iterations measure the
//! steady-state (arena-warm) path every driver now runs.  It writes
//! every timing and memory counter to `BENCH_native.json` (CI uploads it
//! as an artifact and gates regressions against the committed baseline
//! via the `perf_gate` bin).  A second, telemetry-enabled twin of every
//! engine runs two untimed steps per rung so each JSON row also carries
//! `phase_s` (per-phase seconds of the warm step — what `perf_gate`
//! gates at phase level) and the full traces land in
//! `TRACE_native.jsonl` + `TRACE_native_chrome.json` next to the bench
//! JSON; the timed engines stay uninstrumented so telemetry cost can
//! never leak into the gated medians.  The attention rungs additionally
//! time a `mixflow_noplan` twin (`.plan(false)`: compiled step plans
//! off, the pre-plan free-list arena path) so the JSON carries the
//! plan-on/plan-off A/B next to each gated mixflow row — reported, not
//! hard-gated, since the delta is machine-dependent.  It exits nonzero
//! if
//!
//! * naive and mixflow disagree beyond 1e-6 (float-op reordering bound),
//! * remat (K = 4) leaves the full-checkpoint hypergradient by more
//!   than 1e-12 (it recomputes the identical op sequence, so it is
//!   bit-for-bit in practice),
//! * truncated (horizon = 4) is not bit-for-bit mixflow on the rungs
//!   where the horizon covers the whole unroll (T ≤ 4), or evograd
//!   checkpoints anything / goes non-finite anywhere,
//! * remat fails to shrink peak checkpoint bytes for T > K,
//! * plan-on and plan-off mixflow disagree beyond 1e-12 (plans only
//!   change where buffers come from, so they are bit-for-bit),
//! * a timed mixflow engine finishes the ladder without a single plan
//!   replay (the compiled-plan path never engaged), or
//! * the kernel-pool thread ladder (threads ∈ {1, 2, 4} on a widened
//!   `attention_mh2b2` cell) breaks bit-identity at any thread count,
//!   never dispatches a parallel region, or — full mode only — fails
//!   to put the best multi-threaded median below single-threaded.
//!
//! ```bash
//! cargo run --release --bin fig_native_walltime            # full ladder
//! cargo run --release --bin fig_native_walltime -- --smoke # CI mode
//! ```

use mixflow::autodiff::engine::{HypergradEngine, HypergradMode};
use mixflow::autodiff::mixflow::{
    rel_err, BilevelProblem, CheckpointPolicy, Hypergrad,
};
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::autodiff::problems::{
    AttentionProblem, HyperLrProblem, MultiHeadAttentionProblem,
};
use mixflow::obs::{write_trace, StepTrace, TraceFormat};
use mixflow::util::bench::Bench;
use mixflow::util::json::Json;
use mixflow::util::stats::{human_bytes, Summary};
use mixflow::util::table::Table;

/// Remat segment length for the third variant (√T-ish for the ladder's
/// midpoint, and the acceptance point for the memory regression).
const REMAT_K: usize = 4;

type ProblemBuilder = fn(usize) -> Box<dyn BilevelProblem>;

fn build_hyperlr_sgd(unroll: usize) -> Box<dyn BilevelProblem> {
    Box::new(HyperLrProblem::with_unroll(1, unroll))
}

fn build_attention_adam(unroll: usize) -> Box<dyn BilevelProblem> {
    Box::new(
        AttentionProblem::with_unroll(1, unroll)
            .with_optimiser(InnerOptimiser::adam()),
    )
}

fn build_multihead_attention_adam(unroll: usize) -> Box<dyn BilevelProblem> {
    // The canonical multi-head default (2 heads × 2-sequence batches),
    // Adam inner loop — the paper's benchmark shape.  `perf_gate` gates
    // this cell's mixflow rows once the committed baseline carries them.
    Box::new(
        MultiHeadAttentionProblem::with_unroll(1, unroll)
            .with_optimiser(InnerOptimiser::adam()),
    )
}

/// Per-phase seconds of the warm (last) traced step, as a JSON object —
/// the `phase_s` row field `perf_gate` gates phase-level walltime on.
fn phase_seconds(traces: &[StepTrace]) -> Json {
    let mut o = Json::obj();
    if let Some(t) = traces.last() {
        for p in &t.phases {
            o.insert(p.phase.name(), Json::Num(p.seconds));
        }
    }
    o
}

fn result_row(
    task: &str,
    opt: &str,
    unroll: usize,
    variant: &str,
    timing: &Summary,
    h: &Hypergrad,
) -> Json {
    let mut row = Json::obj();
    row.insert("task", Json::Str(task.to_string()));
    row.insert("inner_opt", Json::Str(opt.to_string()));
    row.insert("unroll", Json::Num(unroll as f64));
    row.insert("variant", Json::Str(variant.to_string()));
    row.insert("median_s", Json::Num(timing.median));
    row.insert("mean_s", Json::Num(timing.mean));
    row.insert("p95_s", Json::Num(timing.p95));
    row.insert("samples", Json::Num(timing.n as f64));
    row.insert("tape_bytes", Json::Num(h.memory.tape_bytes as f64));
    row.insert(
        "checkpoint_bytes",
        Json::Num(h.memory.checkpoint_bytes as f64),
    );
    row.insert("peak_bytes", Json::Num(h.memory.peak_bytes as f64));
    row.insert("nodes", Json::Num(h.memory.nodes as f64));
    row.insert("arena_allocs", Json::Num(h.memory.arena_allocs as f64));
    row.insert("arena_reuses", Json::Num(h.memory.arena_reuses as f64));
    row
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let unrolls: &[usize] = if smoke { &[4, 8] } else { &[4, 8, 16, 32] };
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 9) };
    println!(
        "Figure (native) — wall-clock: naive vs MixFlow-MG vs MixFlow+remat \
         (K={REMAT_K}){}",
        if smoke { "  [smoke]" } else { "" }
    );

    let configs: [(&str, &str, ProblemBuilder); 3] = [
        ("hyperlr", "sgd", build_hyperlr_sgd),
        ("attention", "adam", build_attention_adam),
        ("attention_mh2b2", "adam", build_multihead_attention_adam),
    ];
    let remat = CheckpointPolicy::Remat { segment: REMAT_K };
    let mut bench = Bench::new("fig_native_walltime")
        .with_iters(warmup, iters)
        .with_budget(if smoke { 10.0 } else { 60.0 });
    let mut rows: Vec<Json> = Vec::new();
    let mut trace_cells: Vec<(String, Vec<StepTrace>)> = Vec::new();
    let mut table = Table::new(&[
        "task",
        "T",
        "naive",
        "mixflow",
        "remat4",
        "trunc4",
        "evograd",
        "mix/naive",
        "ckpt full",
        "ckpt remat",
    ])
    .numeric_cols(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
    let mut ok = true;

    for (task, opt, build) in configs {
        // Persistent engines: warmup iterations fill the arena, timed
        // iterations measure the allocator-free steady state.
        let mut naive_engine =
            HypergradEngine::builder().mode(HypergradMode::Naive).build();
        let mut full_engine = HypergradEngine::builder().build();
        let mut remat_engine =
            HypergradEngine::builder().checkpoint(remat).build();
        // Plan-off twin of the full-checkpoint mixflow engine: same
        // strategy, same persistent arena discipline, but every cycle
        // records dynamically — the A/B for the compiled-plan speedup.
        let mut noplan_engine =
            HypergradEngine::builder().plan(false).build();
        // The approximate-strategy frontier: a truncated window the
        // width of the remat segment, and the evograd population
        // estimate (stochastic, O(1) memory in T).
        let mut trunc_engine = HypergradEngine::builder()
            .mode(HypergradMode::Truncated { horizon: REMAT_K })
            .build();
        let mut evo_engine =
            HypergradEngine::builder().mode(HypergradMode::Evograd).build();
        // Telemetry twins: identically configured instrumented engines
        // that run two untimed steps per rung (cold + arena-warm) to
        // source `phase_s` and the exported traces — keeping the timed
        // engines above uninstrumented.
        let mut naive_tw = HypergradEngine::builder()
            .mode(HypergradMode::Naive)
            .telemetry(true)
            .build();
        let mut full_tw = HypergradEngine::builder().telemetry(true).build();
        let mut remat_tw = HypergradEngine::builder()
            .checkpoint(remat)
            .telemetry(true)
            .build();
        for &unroll in unrolls {
            let problem = build(unroll);
            let theta0 = problem.theta0();
            let eta = problem.eta0();

            // The timed closures keep their last result, so the
            // numerics/memory cross-checks below reuse the measured
            // runs instead of re-executing each variant.
            let mut naive_h = None;
            let s_naive =
                bench.run(&format!("{task}+{opt}/T{unroll}/naive"), || {
                    naive_h = Some(naive_engine.run(
                        problem.as_ref(),
                        &theta0,
                        &eta,
                    ));
                });
            let mut full_h = None;
            let s_full =
                bench.run(&format!("{task}+{opt}/T{unroll}/mixflow"), || {
                    full_h = Some(full_engine.run(
                        problem.as_ref(),
                        &theta0,
                        &eta,
                    ));
                });
            let mut rem_h = None;
            let s_remat = bench.run(
                &format!("{task}+{opt}/T{unroll}/mixflow-remat{REMAT_K}"),
                || {
                    rem_h = Some(remat_engine.run(
                        problem.as_ref(),
                        &theta0,
                        &eta,
                    ));
                },
            );
            let mut trunc_h = None;
            let s_trunc = bench.run(
                &format!("{task}+{opt}/T{unroll}/truncated{REMAT_K}"),
                || {
                    trunc_h = Some(trunc_engine.run(
                        problem.as_ref(),
                        &theta0,
                        &eta,
                    ));
                },
            );
            let mut evo_h = None;
            let s_evo =
                bench.run(&format!("{task}+{opt}/T{unroll}/evograd"), || {
                    evo_h = Some(evo_engine.run(
                        problem.as_ref(),
                        &theta0,
                        &eta,
                    ));
                });
            let naive = naive_h.expect("bench ran at least one iteration");
            let full = full_h.expect("bench ran at least one iteration");
            let rem = rem_h.expect("bench ran at least one iteration");
            let trunc = trunc_h.expect("bench ran at least one iteration");
            let evo = evo_h.expect("bench ran at least one iteration");

            // Plan-on/plan-off A/B on the attention rungs (where the
            // step tapes are large enough for arena probing to show up).
            let mut noplan = None;
            if task.starts_with("attention") {
                let mut noplan_h = None;
                let s_noplan = bench.run(
                    &format!("{task}+{opt}/T{unroll}/mixflow_noplan"),
                    || {
                        noplan_h = Some(noplan_engine.run(
                            problem.as_ref(),
                            &theta0,
                            &eta,
                        ));
                    },
                );
                let np = noplan_h.expect("bench ran at least one iteration");
                let err_pn = rel_err(&full.d_eta, &np.d_eta);
                if err_pn > 1e-12 {
                    eprintln!(
                        "FAIL {task} T={unroll}: plan vs noplan rel err \
                         {err_pn:.3e}"
                    );
                    ok = false;
                }
                println!(
                    "  plan A/B {task}+{opt}/T{unroll}: plan {:.2}ms vs \
                     noplan {:.2}ms (ratio {:.2})",
                    s_full.median * 1e3,
                    s_noplan.median * 1e3,
                    s_full.median / s_noplan.median.max(1e-12)
                );
                noplan = Some((s_noplan, np));
            }

            let err_nf = rel_err(&naive.d_eta, &full.d_eta);
            if err_nf > 1e-6 {
                eprintln!(
                    "FAIL {task} T={unroll}: naive vs mixflow rel err \
                     {err_nf:.3e}"
                );
                ok = false;
            }
            let err_fr = rel_err(&full.d_eta, &rem.d_eta);
            if err_fr > 1e-12 {
                eprintln!(
                    "FAIL {task} T={unroll}: remat K={REMAT_K} vs full rel \
                     err {err_fr:.3e}"
                );
                ok = false;
            }
            if unroll > REMAT_K
                && rem.memory.checkpoint_bytes >= full.memory.checkpoint_bytes
            {
                eprintln!(
                    "FAIL {task} T={unroll}: remat checkpoints {} not below \
                     full {}",
                    rem.memory.checkpoint_bytes, full.memory.checkpoint_bytes
                );
                ok = false;
            }
            // Frontier contracts: a full-width truncation window is
            // exact (same code path as mixflow), and evograd never
            // checkpoints and never goes non-finite.  Their truncation
            // bias / estimator variance elsewhere is *reported* via
            // `bias_vs_mixflow`, not gated — that's the trade-off the
            // figure exists to chart.
            let bias_trunc = rel_err(&full.d_eta, &trunc.d_eta);
            let bias_evo = rel_err(&full.d_eta, &evo.d_eta);
            if unroll <= REMAT_K {
                let diff = full
                    .d_eta
                    .iter()
                    .zip(trunc.d_eta.iter())
                    .map(|(a, b)| a.max_abs_diff(b))
                    .fold(0.0f64, f64::max);
                if diff != 0.0 {
                    eprintln!(
                        "FAIL {task} T={unroll}: truncated horizon \
                         {REMAT_K} >= T must be bit-for-bit mixflow, \
                         diff {diff:.3e}"
                    );
                    ok = false;
                }
            }
            if evo.memory.checkpoint_bytes != 0 {
                eprintln!(
                    "FAIL {task} T={unroll}: evograd checkpointed {} bytes",
                    evo.memory.checkpoint_bytes
                );
                ok = false;
            }
            if !evo.outer_loss.is_finite()
                || evo
                    .d_eta
                    .iter()
                    .any(|g| g.data.iter().any(|v| !v.is_finite()))
            {
                eprintln!("FAIL {task} T={unroll}: evograd went non-finite");
                ok = false;
            }

            // Two untimed instrumented steps per rung: the second runs
            // arena-warm, so its trace reflects the same steady state
            // the timed medians measure.
            for _ in 0..2 {
                let _ = naive_tw.run(problem.as_ref(), &theta0, &eta);
                let _ = full_tw.run(problem.as_ref(), &theta0, &eta);
                let _ = remat_tw.run(problem.as_ref(), &theta0, &eta);
            }
            let tr_naive = naive_tw.take_step_traces();
            let tr_full = full_tw.take_step_traces();
            let tr_remat = remat_tw.take_step_traces();

            let mut row =
                result_row(task, opt, unroll, "naive", &s_naive, &naive);
            row.insert("phase_s", phase_seconds(&tr_naive));
            row.insert("bias_vs_mixflow", Json::Num(err_nf));
            rows.push(row);
            let mut row =
                result_row(task, opt, unroll, "mixflow", &s_full, &full);
            row.insert("phase_s", phase_seconds(&tr_full));
            row.insert("bias_vs_mixflow", Json::Num(0.0));
            rows.push(row);
            let mut row = result_row(
                task,
                opt,
                unroll,
                &format!("mixflow_remat{REMAT_K}"),
                &s_remat,
                &rem,
            );
            row.insert("phase_s", phase_seconds(&tr_remat));
            row.insert("bias_vs_mixflow", Json::Num(err_fr));
            rows.push(row);
            let mut row = result_row(
                task,
                opt,
                unroll,
                &format!("truncated{REMAT_K}"),
                &s_trunc,
                &trunc,
            );
            row.insert("bias_vs_mixflow", Json::Num(bias_trunc));
            rows.push(row);
            let mut row =
                result_row(task, opt, unroll, "evograd", &s_evo, &evo);
            row.insert("bias_vs_mixflow", Json::Num(bias_evo));
            rows.push(row);
            if let Some((s_noplan, np)) = &noplan {
                rows.push(result_row(
                    task,
                    opt,
                    unroll,
                    "mixflow_noplan",
                    s_noplan,
                    np,
                ));
            }

            trace_cells
                .push((format!("{task}+{opt}/T{unroll}/naive"), tr_naive));
            trace_cells
                .push((format!("{task}+{opt}/T{unroll}/mixflow"), tr_full));
            trace_cells.push((
                format!("{task}+{opt}/T{unroll}/mixflow-remat{REMAT_K}"),
                tr_remat,
            ));
            table.row(vec![
                format!("{task}+{opt}"),
                unroll.to_string(),
                format!("{:.2}ms", s_naive.median * 1e3),
                format!("{:.2}ms", s_full.median * 1e3),
                format!("{:.2}ms", s_remat.median * 1e3),
                format!("{:.2}ms", s_trunc.median * 1e3),
                format!("{:.2}ms", s_evo.median * 1e3),
                format!("{:.2}", s_full.median / s_naive.median.max(1e-12)),
                human_bytes(full.memory.checkpoint_bytes as u64),
                human_bytes(rem.memory.checkpoint_bytes as u64),
            ]);
        }

        // The timed mixflow engines must have actually exercised the
        // compiled-plan path: every rung after the first cycle of a
        // topology replays, so zero replays means plans never armed.
        for (name, engine) in [
            ("mixflow", &full_engine),
            ("remat", &remat_engine),
            ("truncated", &trunc_engine),
        ] {
            let stats = engine.plan_stats();
            if stats.replays == 0 {
                eprintln!(
                    "FAIL {task}: {name} engine never replayed a compiled \
                     plan (compiles {}, fallbacks {})",
                    stats.compiles, stats.fallbacks
                );
                ok = false;
            }
        }
    }

    println!("{}", table.render());

    // ---- kernel-pool thread ladder ---------------------------------
    // The same attention_mh2b2 task shape-scaled up (d_model 32, seq 32
    // — the default bench cell is too tiny for a pool wake to amortise)
    // timed at threads ∈ {1, 2, 4} on otherwise identical engines.  Two
    // checks: hypergradients must be bit-for-bit identical at every
    // thread count (the pool's determinism contract), and in full mode
    // the best multi-threaded median must beat single-threaded (the
    // speedup `perf_gate` tracks once the baseline carries these rows).
    // The smoke run keeps the rows (schema + CI artifact) but skips the
    // strict-win check — shared runners don't guarantee idle cores.
    let ladder_threads: &[usize] = &[1, 2, 4];
    let ladder_unroll = if smoke { 2 } else { 8 };
    let ladder_problem: Box<dyn BilevelProblem> = Box::new(
        MultiHeadAttentionProblem::with_config(
            1,
            32,
            2,
            2,
            32,
            4,
            ladder_unroll,
            0.01,
        )
        .with_optimiser(InnerOptimiser::adam()),
    );
    let theta0 = ladder_problem.theta0();
    let eta = ladder_problem.eta0();
    let mut ladder: Vec<(usize, Summary, Hypergrad)> = Vec::new();
    for &threads in ladder_threads {
        let mut engine =
            HypergradEngine::builder().threads(threads).build();
        let mut h = None;
        let s = bench.run(
            &format!(
                "attention_mh2b2+adam/T{ladder_unroll}/mixflow_t{threads}"
            ),
            || {
                h = Some(engine.run(
                    ladder_problem.as_ref(),
                    &theta0,
                    &eta,
                ));
            },
        );
        let h = h.expect("bench ran at least one iteration");
        if threads > 1 && engine.pool_stats().jobs == 0 {
            eprintln!(
                "FAIL thread ladder: threads={threads} engine never \
                 dispatched a parallel region"
            );
            ok = false;
        }
        ladder.push((threads, s, h));
    }
    for (threads, _, h) in &ladder[1..] {
        let base = &ladder[0].2;
        let diff = base
            .d_eta
            .iter()
            .zip(h.d_eta.iter())
            .map(|(a, b)| a.max_abs_diff(b))
            .fold(0.0f64, f64::max);
        if diff != 0.0 {
            eprintln!(
                "FAIL thread ladder: threads={threads} hypergradient \
                 differs from threads=1 by {diff:.3e} (must be \
                 bit-for-bit)"
            );
            ok = false;
        }
    }
    let t1_median = ladder[0].1.median;
    let best_multi = ladder[1..]
        .iter()
        .map(|(_, s, _)| s.median)
        .fold(f64::INFINITY, f64::min);
    println!(
        "thread ladder attention_mh2b2 (d32/s32, T={ladder_unroll}): \
         t1 {:.2}ms, best multi {:.2}ms (ratio {:.2})",
        t1_median * 1e3,
        best_multi * 1e3,
        best_multi / t1_median.max(1e-12)
    );
    if !smoke && best_multi >= t1_median {
        eprintln!(
            "FAIL thread ladder: best multi-threaded median \
             {best_multi:.4e}s not below single-threaded \
             {t1_median:.4e}s"
        );
        ok = false;
    }
    for (threads, s, h) in &ladder {
        let mut row = result_row(
            "attention_mh2b2",
            "adam",
            ladder_unroll,
            &format!("mixflow_t{threads}"),
            s,
            h,
        );
        row.insert("threads", Json::Num(*threads as f64));
        rows.push(row);
    }

    bench.report();

    let mut doc = Json::obj();
    doc.insert("bench", Json::Str("fig_native_walltime".to_string()));
    doc.insert("smoke", Json::Bool(smoke));
    doc.insert("remat_segment", Json::Num(REMAT_K as f64));
    doc.insert("results", Json::Arr(rows));
    let path = "BENCH_native.json";
    if let Err(e) = std::fs::write(path, doc.pretty() + "\n") {
        eprintln!("FAIL: could not write {path}: {e}");
        ok = false;
    }
    for (tpath, format) in [
        ("TRACE_native.jsonl", TraceFormat::Jsonl),
        ("TRACE_native_chrome.json", TraceFormat::Chrome),
    ] {
        if let Err(e) = write_trace(tpath, format, &trace_cells) {
            eprintln!("FAIL: could not write {tpath}: {e}");
            ok = false;
        }
    }

    if !ok {
        eprintln!("FAIL: fig_native_walltime checks did not hold");
        std::process::exit(1);
    }
    println!(
        "fig_native_walltime OK ({path}, TRACE_native.jsonl, \
         TRACE_native_chrome.json written)"
    );
}

//! Figure 5 (+ Fig. 11, Table 4) — data-regime sweeps: peak-dynamic-HBM
//! ratio along each axis (model size, sequence length, inner updates T,
//! batch size) with the other axes fixed at the base point.
//!
//! Paper shape (Eq. 12): ratio ~constant in B and T, sub-linear growth in
//! S, grows with model size.

use mixflow::coordinator::report::axis_series;
use mixflow::coordinator::runner::{pair_ratios, ExperimentRunner, PairRatios, RunOptions};
use mixflow::coordinator::ResultsStore;
use mixflow::runtime::Runtime;
use mixflow::util::bench::Bench;

fn main() {
    let runtime = Runtime::new().expect("run make artifacts");
    let mut bench = Bench::new("fig5_data_regimes").with_iters(0, 1);
    // Paper Fig. 5 reports the peak-dynamic-HBM ratio only, so this bench
    // is analysis-tier (no PJRT executions).
    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 0, execute: false, seed: 0 },
    );

    let mut measurements = Vec::new();
    bench.run("data-regime sweep", || {
        measurements = runner.run_group("fig5_data");
    });
    let store = ResultsStore::discover().expect("results dir");
    for m in &measurements {
        store.append("fig5_data", m).ok();
    }
    let pairs = pair_ratios(&measurements);

    // Base point (everything else pinned): small model, S=64, B=2, T=2.
    let base = |p: &&PairRatios| {
        p.size_name == "small" && p.seq_len == 64 && p.batch == 2 && p.inner_steps == 2
    };

    // Model-size axis.
    let mut size_pts: Vec<(String, &PairRatios)> = pairs
        .iter()
        .filter(|p| p.seq_len == 64 && p.batch == 2 && p.inner_steps == 2)
        .map(|p| (p.size_name.clone(), p))
        .collect();
    size_pts.sort_by_key(|(_, p)| p.param_count);
    println!("{}", axis_series("Figure 5a — model-size axis", "size", &size_pts));

    // Sequence-length axis.
    let mut s_pts: Vec<(String, &PairRatios)> = pairs
        .iter()
        .filter(|p| p.size_name == "small" && p.batch == 2 && p.inner_steps == 2)
        .map(|p| (p.seq_len.to_string(), p))
        .collect();
    s_pts.sort_by_key(|(_, p)| p.seq_len);
    println!("{}", axis_series("Figure 5b — sequence-length axis", "S", &s_pts));

    // Inner-updates axis.
    let mut t_pts: Vec<(String, &PairRatios)> = pairs
        .iter()
        .filter(|p| p.size_name == "small" && p.seq_len == 64 && p.batch == 2)
        .map(|p| (p.inner_steps.to_string(), p))
        .collect();
    t_pts.sort_by_key(|(_, p)| p.inner_steps);
    println!("{}", axis_series("Figure 5c — inner-updates (T) axis", "T", &t_pts));

    // Batch axis.
    let mut b_pts: Vec<(String, &PairRatios)> = pairs
        .iter()
        .filter(|p| p.size_name == "small" && p.seq_len == 64 && p.inner_steps == 2)
        .map(|p| (p.batch.to_string(), p))
        .collect();
    b_pts.sort_by_key(|(_, p)| p.batch);
    println!("{}", axis_series("Figure 5d — batch-size axis", "B", &b_pts));

    if let Some(b) = pairs.iter().find(base) {
        println!("base point dyn ratio: {:.2}x", b.dynamic_ratio);
    }
    bench.report();
}

//! L3 substrate micro-bench: HLO parse + liveness simulation + cost model
//! throughput on real artifacts (the §Perf L3 profile target).

use mixflow::hlo::{flops::CostModel, parser, MemorySimulator};
use mixflow::runtime::Manifest;
use mixflow::util::bench::Bench;

fn main() {
    let manifest = Manifest::discover().expect("run make artifacts");
    let mut bench = Bench::new("hlo_analyzer").with_iters(1, 5);

    // One small and one large artifact.
    let small = manifest
        .group("fig4_sweep")
        .first()
        .map(|m| manifest.hlo_path(m))
        .expect("fig4 artifacts");
    let large = manifest
        .group("fig7_ladder")
        .iter()
        .max_by_key(|m| m.param_count)
        .map(|m| manifest.hlo_path(m))
        .expect("ladder artifacts");

    for (label, path) in [("small", small), ("large", large)] {
        let text = std::fs::read_to_string(&path).unwrap();
        let mb = text.len() as f64 / 1e6;
        let mut module = None;
        let s = bench.run(&format!("parse {label} ({mb:.1} MB)"), || {
            module = Some(parser::parse_module(&text).expect("parse"));
        });
        println!(
            "  parse throughput: {:.1} MB/s",
            mb / s.median.max(1e-9)
        );
        let module = module.unwrap();
        bench.run(&format!("liveness {label}"), || {
            let _ = MemorySimulator::new(&module).run();
        });
        bench.run(&format!("liveness {label} (no timeline)"), || {
            let _ = MemorySimulator::without_timeline(&module).run();
        });
        bench.run(&format!("cost model {label}"), || {
            let _ = CostModel::new(&module).run();
        });
    }
    bench.report();
}

//! Serving-throughput figure: jobs/sec of the fault-tolerant
//! hypergradient serving pool across worker counts, clean and under
//! deterministic chaos.
//!
//! Two sweeps over the worker axis:
//!
//! * **clean** — no injected faults: every job must serve `ok` in one
//!   attempt, pinning the pool's happy-path overhead (queue, engine
//!   checkout, record assembly) and reporting the throughput scaling
//!   headroom.
//! * **chaos** — the deterministic fault harness at a fixed rate/seed:
//!   the same job list survives injected panics, NaNs, slowdowns and
//!   allocation spikes.  The bench exits nonzero if any job loses its
//!   record, any terminal counter stops reconciling with the records,
//!   or the chaos outcome differs across worker counts (fault plans are
//!   a pure function of `(seed, job, attempt)`, so per-job terminal
//!   status must be scheduling-independent whenever retries don't race
//!   a shared circuit breaker — the bench keeps the breaker wide open).
//!
//! Writes every row to `BENCH_serve.json`.  Scaling ratios are
//! reported, not gated — CI boxes have unpredictable core counts.
//!
//! ```bash
//! cargo run --release --bin fig_native_serve            # full ladder
//! cargo run --release --bin fig_native_serve -- --smoke # CI mode
//! ```

use mixflow::autodiff::HypergradMode;
use mixflow::meta::NativeTask;
use mixflow::obs::Counter;
use mixflow::serve::{
    serve_jobs, ChaosConfig, JobSpec, JobStatus, ServeConfig, ServeOutcome,
};
use mixflow::util::json::Json;
use mixflow::util::table::Table;

/// A small mixed workload: two tasks × two modes, several seeds, so the
/// pool exercises engine-key coalescing and not just one hot engine.
fn job_list(n: usize) -> Vec<JobSpec> {
    (0..n)
        .map(|i| JobSpec {
            id: format!("job-{i}"),
            task: if i % 4 == 3 {
                NativeTask::LossWeighting
            } else {
                NativeTask::HyperLr
            },
            mode: if i % 2 == 0 {
                HypergradMode::Mixflow
            } else {
                HypergradMode::Naive
            },
            unroll: 4,
            seed: (i / 4) as u64,
            ..JobSpec::default()
        })
        .collect()
}

fn serve_config(workers: usize, chaos: Option<ChaosConfig>) -> ServeConfig {
    ServeConfig {
        workers,
        max_retries: 2,
        backoff_base_ms: 1,
        backoff_cap_ms: 4,
        // Effectively no circuit breaker: the bench pins scheduling-
        // independent per-job outcomes, and a shared breaker tripping
        // at different moments under different worker counts would
        // break that on purpose-built grounds.
        quarantine_limit: usize::MAX / 2,
        chaos,
        ..ServeConfig::default()
    }
}

fn outcome_row(workers: usize, label: &str, out: &ServeOutcome, seconds: f64) -> Json {
    let mut row = Json::obj();
    row.insert("variant", Json::Str(label.to_string()));
    row.insert("workers", Json::Num(workers as f64));
    row.insert("jobs", Json::Num(out.records.len() as f64));
    row.insert("seconds", Json::Num(seconds));
    row.insert(
        "jobs_per_s",
        Json::Num(out.records.len() as f64 / seconds.max(1e-9)),
    );
    for (key, counter) in [
        ("ok", Counter::ServeJobsOk),
        ("failed", Counter::ServeJobsFailed),
        ("shed", Counter::ServeJobsShed),
        ("retried", Counter::ServeJobsRetried),
        ("quarantines", Counter::ServeEngineQuarantines),
        ("deadline_exceeded", Counter::ServeDeadlineExceeded),
    ] {
        row.insert(key, Json::Num(out.counter(counter) as f64));
    }
    row.insert("engines_built", Json::Num(out.engines_built as f64));
    row
}

/// Counter/record reconciliation — the invariant every serve run must
/// hold whatever the fault mix.  Returns an error string on violation.
fn reconcile(out: &ServeOutcome, jobs: usize) -> Result<(), String> {
    if out.records.len() != jobs {
        return Err(format!(
            "{} records for {jobs} jobs — jobs were lost",
            out.records.len()
        ));
    }
    let ok = out.counter(Counter::ServeJobsOk);
    let failed = out.counter(Counter::ServeJobsFailed);
    let shed = out.counter(Counter::ServeJobsShed);
    if ok + failed + shed != jobs as u64 {
        return Err(format!(
            "terminal counters don't cover the jobs: ok {ok} + failed \
             {failed} + shed {shed} != {jobs}"
        ));
    }
    let retried: u64 =
        out.records.iter().map(|r| r.attempts.saturating_sub(1)).sum();
    if out.counter(Counter::ServeJobsRetried) != retried {
        return Err(format!(
            "retried counter {} != Σ(attempts-1) {retried}",
            out.counter(Counter::ServeJobsRetried)
        ));
    }
    let quarantined: usize =
        out.records.iter().map(|r| r.quarantined.len()).sum();
    if out.quarantined_generations.len() != quarantined
        || out.counter(Counter::ServeEngineQuarantines)
            != quarantined as u64
    {
        return Err(format!(
            "quarantine ledgers disagree: pool {}, records {quarantined}, \
             counter {}",
            out.quarantined_generations.len(),
            out.counter(Counter::ServeEngineQuarantines)
        ));
    }
    Ok(())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n_jobs = if smoke { 8 } else { 32 };
    let worker_ladder: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let chaos = ChaosConfig {
        seed: 1234,
        panic_rate: 0.15,
        nan_rate: 0.15,
        slow_rate: 0.1,
        alloc_rate: 0.1,
        slow_ms: 2,
        alloc_bytes: 1 << 20,
    };
    println!(
        "Figure (native) — serving throughput: clean vs chaos{}",
        if smoke { "  [smoke]" } else { "" }
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut table = Table::new(&[
        "variant", "workers", "jobs/s", "ok", "failed", "retried",
        "quarantines",
    ])
    .numeric_cols(&[1, 2, 3, 4, 5, 6]);
    let mut ok = true;
    // Per-job terminal statuses of the chaos runs, by worker count —
    // chaos is deterministic, so these must all agree.
    let mut chaos_statuses: Vec<Vec<JobStatus>> = Vec::new();

    for &workers in worker_ladder {
        for (label, chaos_cfg) in
            [("clean", None), ("chaos", Some(chaos))]
        {
            let cfg = serve_config(workers, chaos_cfg);
            let t0 = std::time::Instant::now();
            let out = serve_jobs(job_list(n_jobs), &cfg);
            let seconds = t0.elapsed().as_secs_f64();
            if let Err(e) = reconcile(&out, n_jobs) {
                eprintln!("FAIL {label}/w{workers}: {e}");
                ok = false;
            }
            if label == "clean"
                && out.counter(Counter::ServeJobsOk) != n_jobs as u64
            {
                eprintln!(
                    "FAIL clean/w{workers}: {} of {n_jobs} ok — clean \
                     serving must not fail jobs",
                    out.counter(Counter::ServeJobsOk)
                );
                ok = false;
            }
            if label == "chaos" {
                chaos_statuses.push(
                    out.records.iter().map(|r| r.status).collect(),
                );
            }
            table.row(vec![
                label.to_string(),
                workers.to_string(),
                format!(
                    "{:.1}",
                    n_jobs as f64 / seconds.max(1e-9)
                ),
                out.counter(Counter::ServeJobsOk).to_string(),
                out.counter(Counter::ServeJobsFailed).to_string(),
                out.counter(Counter::ServeJobsRetried).to_string(),
                out.counter(Counter::ServeEngineQuarantines).to_string(),
            ]);
            rows.push(outcome_row(workers, label, &out, seconds));
        }
    }

    for (i, statuses) in chaos_statuses.iter().enumerate().skip(1) {
        if statuses != &chaos_statuses[0] {
            eprintln!(
                "FAIL: chaos outcome at workers={} differs from workers={} \
                 — fault injection must be scheduling-independent",
                worker_ladder[i], worker_ladder[0]
            );
            ok = false;
        }
    }

    println!("{}", table.render());

    let mut doc = Json::obj();
    doc.insert("bench", Json::Str("fig_native_serve".to_string()));
    doc.insert("smoke", Json::Bool(smoke));
    doc.insert("jobs", Json::Num(n_jobs as f64));
    doc.insert("chaos_seed", Json::Num(chaos.seed as f64));
    doc.insert("results", Json::Arr(rows));
    let path = "BENCH_serve.json";
    if let Err(e) = std::fs::write(path, doc.pretty() + "\n") {
        eprintln!("FAIL: could not write {path}: {e}");
        ok = false;
    }

    if !ok {
        eprintln!("FAIL: fig_native_serve checks did not hold");
        std::process::exit(1);
    }
    println!("fig_native_serve OK ({path} written)");
}

//! Figure 2 — device-memory footprint across the compiled schedule for one
//! outer update: static band (params/inputs/checkpoints) + dynamic
//! activations, default vs MixFlow-MG.  Pure analysis (no execution).

use mixflow::coordinator::report::timeline_plot;
use mixflow::hlo::{parser, MemorySimulator};
use mixflow::runtime::Manifest;
use mixflow::util::bench::Bench;
use mixflow::util::stats::human_bytes;

fn main() {
    let manifest = Manifest::discover().expect("run make artifacts");
    let mut bench = Bench::new("fig2_timeline").with_iters(0, 3);

    // The Table-3 ablation pair at full optimisation settings.
    let metas = manifest.group("table3_ablation");
    let default = metas
        .iter()
        .find(|m| m.mode == "default" && m.block_remat && !m.save_inner_grads)
        .expect("default artifact");
    let mixflow = metas
        .iter()
        .find(|m| m.mode == "fwdrev" && m.block_remat && m.save_inner_grads)
        .expect("mixflow artifact");

    for meta in [default, mixflow] {
        let text = std::fs::read_to_string(manifest.hlo_path(meta)).unwrap();
        let mut parsed = None;
        bench.run(&format!("parse {}", meta.variant), || {
            parsed = Some(parser::parse_module(&text).expect("parse"));
        });
        let module = parsed.unwrap();
        let mut report = None;
        bench.run(&format!("simulate {}", meta.variant), || {
            report = Some(MemorySimulator::new(&module).run());
        });
        let mem = report.unwrap();
        println!(
            "{}",
            timeline_plot(
                &format!(
                    "Figure 2 — {} (44M-scaled MAML): dynamic memory over instruction number",
                    meta.variant
                ),
                &mem.timeline,
                110,
                14,
            )
        );
        println!(
            "  static {} | peak dynamic {} | peak total {}\n",
            human_bytes(mem.static_bytes()),
            human_bytes(mem.peak_dynamic),
            human_bytes(mem.peak_total),
        );
    }
    println!("paper shape: the default variant's dynamic band dwarfs its static band;");
    println!("mixed-mode removes the per-block backward buffers (Fig. 3 block #3).");
    bench.report();
}

//! CI perf-regression gate: compare the smoke-mode `BENCH_native.json`
//! written by `fig_native_walltime` against the committed baseline
//! (`rust/benches/BENCH_native_baseline.json`) and fail the job when a
//! mixflow variant regresses by more than 20% on either axis:
//!
//! * **peak_bytes** — compared directly: the byte counters are
//!   deterministic, so any growth is a real memory regression.
//! * **walltime** — compared as the `mixflow/naive` median ratio within
//!   each file rather than as absolute seconds, so a slower or faster CI
//!   machine cancels out of both sides and only a genuine slowdown of
//!   the mixflow path relative to the naive baseline trips the gate.
//! * **phase walltime** — rows carrying the telemetry-derived `phase_s`
//!   map (per-phase seconds of the warm instrumented step) are also
//!   gated phase by phase, normalised the same machine-independent way
//!   (phase seconds / same-file naive median).  Only phases worth at
//!   least 10% of their baseline row's total phase time are gated — the
//!   sub-10% ones are timer noise — and at a wider 35% tolerance, since
//!   single phases are shorter and noisier than whole steps.  This is
//!   what turns "mixflow got 20% slower" into "the jvp phase did".
//! * **thread-ladder walltime** — the kernel-pool ladder rows
//!   (`mixflow_t1`/`mixflow_t2`/`mixflow_t4` on the widened
//!   `attention_mh2b2` cell) have no naive twin, so each multi-threaded
//!   row is gated as its `mixflow_tN / mixflow_t1` median ratio — the
//!   parallel speedup itself — under the same 20% tolerance.
//!
//! Every `mixflow*` row the smoke bench emits is gated — including the
//! multi-head batched attention cell (`attention_mh2b2+adam`) — as soon
//! as the committed baseline carries a matching row.  Rows present in
//! only one file are reported but never fail the gate (new
//! configurations need a baseline refresh, not a red build; the
//! multi-head cell warns-and-passes this way while the baseline is
//! still the bootstrap placeholder).  To refresh after an intentional
//! perf change:
//!
//! ```bash
//! cargo run --release --bin fig_native_walltime -- --smoke
//! cp BENCH_native.json rust/benches/BENCH_native_baseline.json
//! ```
//!
//! ```bash
//! cargo run --release --bin perf_gate [current.json [baseline.json]]
//! ```
//!
//! `--write-baseline <path>` additionally copies the current results
//! file to `<path>` (after validating it parses and carries a `results`
//! array) before the gate runs.  CI uses this to publish every run's
//! measurements as a candidate-baseline artifact, so refreshing the
//! committed baseline after an intentional perf change is a download
//! instead of a local re-run.

use std::collections::BTreeMap;

use mixflow::util::json::Json;
use mixflow::util::table::Table;

/// Regression threshold: fail at >20% worse than baseline.
const TOLERANCE: f64 = 0.20;

/// Phase-level threshold — wider than the end-to-end gate because a
/// single phase is a fraction of a step and proportionally noisier.
const PHASE_TOLERANCE: f64 = 0.35;

/// Gate a phase only when it carries at least this share of its
/// baseline row's total phase time; thinner slices are timer noise.
const MIN_PHASE_SHARE: f64 = 0.10;

/// Row key inside one results file.
type Key = (String, String, u64, String); // (task, inner_opt, unroll, variant)

struct Row {
    median_s: f64,
    peak_bytes: f64,
    /// Telemetry-derived per-phase seconds (`phase_s` in the bench
    /// JSON); empty for rows written before the telemetry subsystem.
    phase_s: Vec<(String, f64)>,
}

fn load_rows(path: &str) -> Result<BTreeMap<Key, Row>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let results = doc
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path}: no `results` array"))?;
    let mut out = BTreeMap::new();
    for (i, row) in results.iter().enumerate() {
        let s = |k: &str| -> Result<String, String> {
            row.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("{path}: results[{i}] missing `{k}`"))
        };
        let n = |k: &str| -> Result<f64, String> {
            row.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("{path}: results[{i}] missing `{k}`"))
        };
        let key =
            (s("task")?, s("inner_opt")?, n("unroll")? as u64, s("variant")?);
        let mut phase_s = Vec::new();
        if let Some(phases) = row.get("phase_s") {
            for name in phases.keys() {
                if let Some(v) = phases.get(name).and_then(Json::as_f64) {
                    phase_s.push((name.clone(), v));
                }
            }
        }
        out.insert(
            key,
            Row {
                median_s: n("median_s")?,
                peak_bytes: n("peak_bytes")?,
                phase_s,
            },
        );
    }
    Ok(out)
}

/// `mixflow-variant walltime / naive walltime` for one (task, opt, T)
/// within a single results file — the machine-independent timing signal.
fn walltime_ratio(
    rows: &BTreeMap<Key, Row>,
    task: &str,
    opt: &str,
    unroll: u64,
    variant: &str,
) -> Option<f64> {
    let naive = rows.get(&(
        task.to_string(),
        opt.to_string(),
        unroll,
        "naive".to_string(),
    ))?;
    let var = rows.get(&(
        task.to_string(),
        opt.to_string(),
        unroll,
        variant.to_string(),
    ))?;
    if naive.median_s <= 0.0 {
        return None;
    }
    Some(var.median_s / naive.median_s)
}

/// `mixflow_tN walltime / mixflow_t1 walltime` for one (task, opt, T)
/// within a single results file — the thread-ladder speedup signal,
/// machine-independent for the same reason the mixflow/naive ratio is.
fn ladder_ratio(
    rows: &BTreeMap<Key, Row>,
    task: &str,
    opt: &str,
    unroll: u64,
    variant: &str,
) -> Option<f64> {
    let t1 = rows.get(&(
        task.to_string(),
        opt.to_string(),
        unroll,
        "mixflow_t1".to_string(),
    ))?;
    let var = rows.get(&(
        task.to_string(),
        opt.to_string(),
        unroll,
        variant.to_string(),
    ))?;
    if t1.median_s <= 0.0 {
        return None;
    }
    Some(var.median_s / t1.median_s)
}

/// The naive row's median for one (task, opt, T) within a file — the
/// machine-speed normaliser the phase-level gate divides by.
fn naive_median(
    rows: &BTreeMap<Key, Row>,
    task: &str,
    opt: &str,
    unroll: u64,
) -> Option<f64> {
    let naive = rows.get(&(
        task.to_string(),
        opt.to_string(),
        unroll,
        "naive".to_string(),
    ))?;
    (naive.median_s > 0.0).then_some(naive.median_s)
}

/// Copy the current results file to `path` as a candidate baseline,
/// refusing (exit 1) when the source is missing or not a results
/// document — a truncated bench run must not overwrite a good artifact.
fn write_baseline(current_path: &str, path: &str) {
    let text = match std::fs::read_to_string(current_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!(
                "FAIL: --write-baseline: cannot read {current_path}: {e}"
            );
            std::process::exit(1);
        }
    };
    let ok = Json::parse(&text)
        .ok()
        .and_then(|doc| doc.get("results").and_then(Json::as_arr).map(|_| ()))
        .is_some();
    if !ok {
        eprintln!(
            "FAIL: --write-baseline: {current_path} is not a bench results \
             document (no `results` array)"
        );
        std::process::exit(1);
    }
    if let Err(e) = std::fs::write(path, &text) {
        eprintln!("FAIL: --write-baseline: cannot write {path}: {e}");
        std::process::exit(1);
    }
    println!("wrote candidate baseline {path} (copy of {current_path})");
}

fn main() {
    // `--write-baseline <path>` is a flag with a value; strip it before
    // the positional [current [baseline]] parse so it composes with
    // explicit paths in any order.
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let mut args: Vec<String> = Vec::new();
    let mut baseline_out: Option<String> = None;
    let mut it = raw.into_iter();
    while let Some(a) = it.next() {
        if a == "--write-baseline" {
            match it.next() {
                Some(p) => baseline_out = Some(p),
                None => {
                    eprintln!("FAIL: --write-baseline needs a path");
                    std::process::exit(1);
                }
            }
        } else {
            args.push(a);
        }
    }
    let current_path =
        args.first().map(String::as_str).unwrap_or("BENCH_native.json");
    let baseline_path = args
        .get(1)
        .map(String::as_str)
        .unwrap_or("rust/benches/BENCH_native_baseline.json");
    println!(
        "perf gate: {current_path} vs baseline {baseline_path} \
         (tolerance {:.0}%)",
        TOLERANCE * 100.0
    );

    // Publish the candidate baseline first: it must exist even when the
    // gate below is not armed (bootstrap placeholder) or fails.
    if let Some(out) = &baseline_out {
        write_baseline(current_path, out);
    }

    // A baseline marked `"bootstrap": true` has no measured rows yet
    // (it was committed from an environment without a Rust toolchain):
    // pass with a loud warning so the first CI machine with real
    // numbers can refresh it, after which the gate arms itself.
    if let Ok(text) = std::fs::read_to_string(baseline_path) {
        if let Ok(doc) = Json::parse(&text) {
            if doc.get("bootstrap").and_then(Json::as_bool) == Some(true) {
                println!(
                    "WARN: baseline {baseline_path} is a bootstrap \
                     placeholder — gate not armed.\nRefresh it with:\n  \
                     cargo run --release --bin fig_native_walltime -- \
                     --smoke\n  cp {current_path} {baseline_path}"
                );
                return;
            }
        }
    }

    let (current, baseline) =
        match (load_rows(current_path), load_rows(baseline_path)) {
            (Ok(c), Ok(b)) => (c, b),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("FAIL: {e}");
                std::process::exit(1);
            }
        };

    let mut t = Table::new(&[
        "config",
        "variant",
        "peak now",
        "peak base",
        "Δpeak",
        "wall ratio now",
        "wall ratio base",
        "Δwall",
        "phases",
        "verdict",
    ])
    .numeric_cols(&[2, 3, 4, 5, 6, 7]);
    let mut failures: Vec<String> = Vec::new();
    let mut compared = 0usize;
    let mut phases_compared = 0usize;

    for ((task, opt, unroll, variant), cur) in &current {
        if !variant.starts_with("mixflow") {
            continue;
        }
        let key =
            (task.clone(), opt.clone(), *unroll, variant.clone());
        let Some(base) = baseline.get(&key) else {
            println!(
                "note: {task}+{opt}/T{unroll}/{variant} has no baseline row \
                 (new config?) — skipped"
            );
            continue;
        };
        compared += 1;
        let peak_rel = if base.peak_bytes > 0.0 {
            cur.peak_bytes / base.peak_bytes - 1.0
        } else {
            0.0
        };
        // Thread-ladder rows normalise against their own mixflow_t1 row
        // (there is no naive twin on the ladder cell); everything else
        // normalises against the naive row as before.
        let is_ladder =
            variant.starts_with("mixflow_t") && variant != "mixflow_t1";
        let (wall_now, wall_base) = if is_ladder {
            (
                ladder_ratio(&current, task, opt, *unroll, variant),
                ladder_ratio(&baseline, task, opt, *unroll, variant),
            )
        } else {
            (
                walltime_ratio(&current, task, opt, *unroll, variant),
                walltime_ratio(&baseline, task, opt, *unroll, variant),
            )
        };
        let wall_rel = match (wall_now, wall_base) {
            (Some(now), Some(base)) if base > 0.0 => Some(now / base - 1.0),
            _ => None,
        };

        let mut verdict = "ok";
        if peak_rel > TOLERANCE {
            verdict = "FAIL";
            failures.push(format!(
                "{task}+{opt}/T{unroll}/{variant}: peak_bytes {} vs \
                 baseline {} (+{:.1}%)",
                cur.peak_bytes as u64,
                base.peak_bytes as u64,
                peak_rel * 100.0
            ));
        }
        if let Some(rel) = wall_rel {
            if rel > TOLERANCE {
                verdict = "FAIL";
                let norm = if is_ladder { "mixflow_t1" } else { "naive" };
                failures.push(format!(
                    "{task}+{opt}/T{unroll}/{variant}: {variant}/{norm} \
                     walltime ratio {:.3} vs baseline {:.3} (+{:.1}%)",
                    wall_now.unwrap_or(f64::NAN),
                    wall_base.unwrap_or(f64::NAN),
                    rel * 100.0
                ));
            }
        }

        // Phase-level gate: each telemetry phase normalised by the same
        // file's naive median, so machine speed cancels here too.
        let mut phases_gated = 0usize;
        let mut phases_failed = 0usize;
        let cur_norm = naive_median(&current, task, opt, *unroll);
        let base_norm = naive_median(&baseline, task, opt, *unroll);
        if let (Some(cn), Some(bn)) = (cur_norm, base_norm) {
            let base_total: f64 =
                base.phase_s.iter().map(|(_, v)| v).sum();
            for (phase, base_v) in &base.phase_s {
                if base_total <= 0.0
                    || *base_v <= 0.0
                    || base_v / base_total < MIN_PHASE_SHARE
                {
                    continue;
                }
                let Some((_, cur_v)) =
                    cur.phase_s.iter().find(|(p, _)| p == phase)
                else {
                    continue;
                };
                phases_gated += 1;
                let rel = (cur_v / cn) / (base_v / bn) - 1.0;
                if rel > PHASE_TOLERANCE {
                    verdict = "FAIL";
                    phases_failed += 1;
                    failures.push(format!(
                        "{task}+{opt}/T{unroll}/{variant}: phase `{phase}` \
                         normalised walltime +{:.1}% vs baseline \
                         (tolerance {:.0}%)",
                        rel * 100.0,
                        PHASE_TOLERANCE * 100.0
                    ));
                }
            }
        }
        phases_compared += phases_gated;

        t.row(vec![
            format!("{task}+{opt}/T{unroll}"),
            variant.clone(),
            format!("{}", cur.peak_bytes as u64),
            format!("{}", base.peak_bytes as u64),
            format!("{:+.1}%", peak_rel * 100.0),
            wall_now.map_or("-".to_string(), |r| format!("{r:.3}")),
            wall_base.map_or("-".to_string(), |r| format!("{r:.3}")),
            wall_rel.map_or("-".to_string(), |r| format!("{:+.1}%", r * 100.0)),
            if phases_gated == 0 {
                "-".to_string()
            } else if phases_failed == 0 {
                format!("{phases_gated} ok")
            } else {
                format!("{phases_failed}/{phases_gated} FAIL")
            },
            verdict.to_string(),
        ]);
    }

    for key in baseline.keys() {
        if !current.contains_key(key) && key.3.starts_with("mixflow") {
            println!(
                "note: baseline row {}+{}/T{}/{} missing from current run",
                key.0, key.1, key.2, key.3
            );
        }
    }

    println!("{}", t.render());
    if compared == 0 {
        eprintln!(
            "FAIL: no overlapping mixflow rows between {current_path} and \
             {baseline_path}"
        );
        std::process::exit(1);
    }
    if !failures.is_empty() {
        eprintln!("FAIL: perf regressions beyond {:.0}%:", TOLERANCE * 100.0);
        for f in &failures {
            eprintln!("  {f}");
        }
        eprintln!(
            "(intentional? refresh the baseline: cp BENCH_native.json \
             rust/benches/BENCH_native_baseline.json)"
        );
        std::process::exit(1);
    }
    println!(
        "perf_gate OK ({compared} mixflow rows, {phases_compared} gated \
         phases within tolerance)"
    );
}

//! Table 3 (+ Fig. 3 / Fig. 10 at this scale) — the §4 ablation cube on
//! the 44M-scaled model: {mixed mode} × {block remat} × {save grads},
//! simulated dynamic HBM + XLA temp bytes + measured step time.

use mixflow::coordinator::report::ablation_table;
use mixflow::coordinator::runner::{ExperimentRunner, RunOptions};
use mixflow::coordinator::ResultsStore;
use mixflow::runtime::Runtime;
use mixflow::util::bench::Bench;

fn main() {
    let runtime = Runtime::new().expect("run make artifacts");
    let mut bench = Bench::new("table3_ablation").with_iters(0, 1);
    // 8 artifacts, each compiled once and timed: budget generously.
    // MIXFLOW_NO_EXEC=1 skips the eight PJRT compiles (40-90 s each on a
    // throttled core); memory columns are unaffected.
    let execute = std::env::var("MIXFLOW_NO_EXEC").is_err();
    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 2, execute, seed: 0 },
    );

    let mut measurements = Vec::new();
    bench.run("run 8-combo cube (compile+time)", || {
        measurements = runner.run_group("table3_ablation");
    });

    let store = ResultsStore::discover().expect("results dir");
    for m in &measurements {
        store.append("table3_ablation", m).ok();
    }

    let mut rows: Vec<(String, &mixflow::coordinator::Measurement)> =
        measurements.iter().map(|m| (m.variant.clone(), m)).collect();
    rows.sort_by(|a, b| a.0.cmp(&b.0));
    println!(
        "{}",
        ablation_table(
            "Table 3 — 44M-scaled transformer ablation (paper Table 3)",
            &rows
        )
    );
    println!("paper shape: mixed+remat+save-grads is the memory minimum;");
    println!("remat matters most, save-grads amplifies the mixed-mode win.");
    bench.report();
}
